//! Multi-replica cluster serving demo (sim executor, fully offline).
//!
//! Serves one overloaded 8-model ReAct workload through clusters of
//! R ∈ {1, 2, 4, 8} engine replicas — each replica on its own OS
//! thread with its own KV pool — and then compares the three
//! workflow-routing policies at R = 4.  The merged stats show the
//! cluster story: tail latency falls as the per-replica arrival rate
//! drops, while the fleet's KV footprint grows additively.
//!
//!   cargo run --release --example cluster_serve
//!
//! Equivalent CLI (for the R=4 least_loaded row): icarus serve
//! --replicas 4 --cluster-routing least_loaded --models 8 --qps 4.0
//! --requests 256 --seed 11 --kv-pool-mb 32

use icarus::bench_util::KV_BPT_SMALL;
use icarus::cluster::Cluster;
use icarus::config::{ClusterRouting, ServingConfig, WorkloadConfig};
use icarus::engine::executor::CostModel;
use icarus::workload::generate;

fn main() {
    let wcfg = WorkloadConfig {
        n_models: 8,
        qps: 4.0,
        n_requests: 256,
        seed: 11,
        ..Default::default()
    };
    let workload = generate(&wcfg);

    println!("== cluster_serve: 256 workflows, 8 agents, qps 4.0, 32 MB KV/replica ==\n");
    println!(
        "{:>9} {:>10} {:>10} {:>14} {:>10} {:>12}",
        "replicas", "p95(s)", "p50(s)", "tput(tok/s)", "hit-rate", "peakKV(MB)"
    );
    for r in [1usize, 2, 4, 8] {
        let scfg = ServingConfig { replicas: r, kv_pool_bytes: 32 << 20, ..Default::default() };
        let out = Cluster::new(scfg, KV_BPT_SMALL, wcfg.n_models)
            .run_sim(CostModel::default(), workload.clone());
        let tl = out.merged.turn_latency.as_ref().unwrap();
        println!(
            "{:>9} {:>10.3} {:>10.3} {:>14.1} {:>10.3} {:>12.1}",
            r,
            tl.p95(),
            tl.p50(),
            out.merged.throughput_tok_s(),
            out.merged.cache_hit_rate(),
            out.merged.peak_kv_bytes as f64 / (1 << 20) as f64,
        );
    }

    println!("\n-- routing policies at 4 replicas --\n");
    println!(
        "{:>14} {:>10} {:>14} {:>10} {:>20}",
        "routing", "p95(s)", "tput(tok/s)", "hit-rate", "completed/replica"
    );
    for routing in [
        ClusterRouting::RoundRobin,
        ClusterRouting::LeastLoaded,
        ClusterRouting::HashPrefix,
    ] {
        let scfg = ServingConfig {
            replicas: 4,
            cluster_routing: routing,
            kv_pool_bytes: 32 << 20,
            ..Default::default()
        };
        let out = Cluster::new(scfg, KV_BPT_SMALL, wcfg.n_models)
            .run_sim(CostModel::default(), workload.clone());
        let tl = out.merged.turn_latency.as_ref().unwrap();
        let counts: Vec<u64> = out.per_replica.iter().map(|s| s.completed_requests).collect();
        println!(
            "{:>14} {:>10.3} {:>14.1} {:>10.3} {:>20}",
            routing.as_str(),
            tl.p95(),
            out.merged.throughput_tok_s(),
            out.merged.cache_hit_rate(),
            format!("{counts:?}")
        );
    }
}
