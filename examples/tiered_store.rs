//! The tiered KV snapshot store as a runnable example: contexts
//! evicted from a starved GPU pool survive in bounded host/disk tiers
//! and restore over modeled PCIe/NVMe instead of re-prefilling — and,
//! shared behind four replicas, turn plain round-robin routing into a
//! warm-cache cluster.
//!
//!   cargo run --release --example tiered_store
//!
//! (Full sweep vs the fig8 swap baseline: `cargo bench --bench
//! store_tiers`.)

use icarus::bench_util::{header, print_row, Point, Row, KV_BPT_SMALL};
use icarus::config::ServingMode;

fn main() {
    println!("== tiered snapshot store, ReAct N=4, qps 1.5, pool 12 MB/replica ==\n");
    header();
    // (label, replicas, host bytes, disk bytes, prefetch)
    let scenarios: &[(&str, usize, u64, u64, bool)] = &[
        ("no store (drop on evict)", 1, 0, 0, false),
        ("host 64M", 1, 64 << 20, 0, false),
        ("host 8M + disk 256M", 1, 8 << 20, 256 << 20, false),
        ("host 8M + disk + prefetch", 1, 8 << 20, 256 << 20, true),
        ("4 replicas, no store", 4, 0, 0, false),
        ("4 replicas, shared host 64M", 4, 64 << 20, 0, false),
    ];
    for &(label, replicas, host, disk, prefetch) in scenarios {
        let p = Point {
            mode: ServingMode::Icarus,
            n_models: 4,
            qps: 1.5,
            kv_pool_bytes: 12 << 20,
            kv_bytes_per_token: KV_BPT_SMALL,
            replicas,
            store_host_bytes: host,
            store_disk_bytes: disk,
            store_prefetch: prefetch,
            ..Default::default()
        };
        let s = p.run();
        let mut r = Row::from_stats(&p, &s);
        r.label = label.to_string();
        print_row(&r);
        if host + disk > 0 {
            println!(
                "    restored {} tokens ({:.1} MB) over {} host / {} disk hits, \
                 {} from other replicas, {} prefetch stagings",
                s.store_restored_tokens,
                s.store_restored_bytes as f64 / (1 << 20) as f64,
                s.store_host_hits,
                s.store_disk_hits,
                s.store_remote_hits,
                s.store_prefetches,
            );
        }
    }
    println!(
        "\nEvicted contexts come back at transfer cost instead of recompute cost, and a \
         context prefilled on one replica is a warm hit on every other."
    );
}
