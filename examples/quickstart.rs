//! Quickstart: two ICaRus task-agents sharing one KV cache on the real
//! PJRT runtime.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the `serve-small` artifacts (`make artifacts` first), prefills
//! one prompt with the logical encoder, then lets two different LoRA
//! agents decode continuations *from the same cache snapshot* — the
//! thing conventional multi-model serving cannot do.

use anyhow::Result;
use icarus::config::ServingMode;
use icarus::engine::executor::{DecodeSlot, Executor};
use icarus::runtime::{Manifest, PjrtExecutor};
use icarus::tokenizer::Tokenizer;

fn main() -> Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let mut ex = PjrtExecutor::load(&manifest, "serve-small", ServingMode::Icarus, 2)?;
    let tok = Tokenizer::new(ex.spec().vocab as u32);

    let prompt_text = "question which museum is closer to the river crossing";
    let prompt = tok.encode(prompt_text);
    println!("prompt: {prompt_text:?} -> {} tokens", prompt.len());

    // Logical encoder builds the shared cache (one prefill, ever).
    let t0 = std::time::Instant::now();
    let prefill = ex.prefill(0, &prompt, 0, None)?;
    println!("prefill: {:.1} ms (first token {})", t0.elapsed().as_secs_f64() * 1e3, prefill.first_token);
    let shared = ex.snapshot(prefill.cache);

    // Both agents decode from the SAME snapshot.
    for agent in 0..2usize {
        let cache = ex.snapshot(shared); // refcount bump, zero copy
        let mut slot = DecodeSlot {
            seq_id: agent as u64,
            model_id: agent,
            cache,
            context_len: prompt.len(),
            last_token: prefill.first_token,
            next_token: 0,
        };
        let mut generated = vec![prefill.first_token];
        let t0 = std::time::Instant::now();
        for _ in 0..12 {
            ex.decode(std::slice::from_mut(&mut slot))?;
            generated.push(slot.next_token);
            slot.last_token = slot.next_token;
            slot.context_len += 1;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "agent {agent}: {} ({:.1} ms/token)",
            tok.decode(&generated),
            dt / 12.0 * 1e3
        );
        ex.drop_snapshot(slot.cache);
    }
    println!(
        "\nlive cache snapshots: {} (shared prefix stored once — the ICaRus win)",
        ex.live_snapshots()
    );
    Ok(())
}
