//! Appendix E scenario as a runnable example: multi-model serving with
//! swap-based KV eviction instead of recompute (4 GB host swap tier).
//!
//!   cargo run --release --example swap_eviction
//!
//! Shows the paper's point that swap and ICaRus are orthogonal: swap
//! changes what happens *after* the pool fills; ICaRus keeps the pool
//! from filling.  (Full sweep: `cargo bench --bench fig8_swap`.)

use icarus::bench_util::{header, print_row, Point, Row, KV_BPT_SMALL};
use icarus::config::{EvictionPolicy, ServingMode};

fn main() {
    println!("== swap-based eviction, ReAct N=4, qps 2.0, pool 12 MB ==\n");
    header();
    for mode in [ServingMode::Baseline, ServingMode::Icarus] {
        for eviction in [EvictionPolicy::Recompute, EvictionPolicy::Swap] {
            let p = Point {
                mode,
                n_models: 4,
                qps: 2.0,
                eviction,
                kv_pool_bytes: 12 << 20,
                kv_bytes_per_token: KV_BPT_SMALL,
                ..Default::default()
            };
            let s = p.run();
            let mut r = Row::from_stats(&p, &s);
            r.label = format!("{}/{}", mode.as_str(), eviction.as_str());
            print_row(&r);
            println!(
                "    swap-outs {} swap-ins {} recomputed-tokens {}",
                s.swap_outs, s.swap_ins, s.recomputed_tokens
            );
        }
    }
    println!("\nICaRus rarely touches the swap tier at all — its KV footprint stays below the pool budget.");
}
