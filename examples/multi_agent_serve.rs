//! End-to-end driver (the EXPERIMENTS.md §E2E run): serve a multi-agent
//! ReAct workload through the full stack — workload generator → router →
//! continuous-batching scheduler → paged KV manager with cross-model
//! prefix caching → real PJRT decode of the AOT artifacts — and report
//! P95 latency + throughput for baseline vs ICaRus on identical traces.
//!
//!   cargo run --release --example multi_agent_serve [n_workflows]
//!
//! Real compute on CPU PJRT is slow, so the default workload is small
//! (12 workflows, 2 models); the sim-executor benches sweep the full
//! paper grid with costs calibrated against exactly this path.

use anyhow::Result;
use icarus::config::{ServingConfig, ServingMode, WorkloadConfig};
use icarus::engine::Engine;
use icarus::runtime::{Manifest, PjrtExecutor};
use icarus::workload::generate;

fn main() -> Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let manifest = Manifest::load("artifacts")?;
    let spec = manifest.spec("serve-small")?;
    let kv_bpt = spec.kv_bytes_per_token;

    let wcfg = WorkloadConfig {
        n_models: 2,
        qps: 2.0,
        n_requests: n,
        prompt_mean: 48.0,
        prompt_std: 12.0,
        turns_min: 2,
        turns_max: 3,
        output_mean: 12.0,
        output_std: 4.0,
        obs_mean: 8.0,
        obs_std: 2.0,
        seed: 42,
        ..Default::default()
    };

    println!("== multi_agent_serve: {} workflows, 2 agents, ReAct, serve-small ==", n);
    for mode in [ServingMode::Baseline, ServingMode::Icarus] {
        let scfg = ServingConfig { mode, kv_pool_bytes: 256 << 20, ..Default::default() };
        let exec = PjrtExecutor::load(&manifest, "serve-small", mode, wcfg.n_models)?;
        let t0 = std::time::Instant::now();
        let stats = Engine::new(scfg, kv_bpt, wcfg.n_models, exec).run(generate(&wcfg));
        let tl = stats.turn_latency.as_ref().unwrap();
        println!(
            "\n[{}] wall {:.1}s | turns {} | P95 {:.3}s P50 {:.3}s | {:.1} tok/s | \
             prefix hit-rate {:.3} | prefill {} cached {} tokens",
            mode.as_str(),
            t0.elapsed().as_secs_f64(),
            stats.completed_turns,
            tl.p95(),
            tl.p50(),
            stats.throughput_tok_s(),
            stats.cache_hit_rate(),
            stats.prefill_tokens,
            stats.cached_prefill_tokens,
        );
        std::fs::create_dir_all("bench_results").ok();
        std::fs::write(
            format!("bench_results/e2e_pjrt_{}.json", mode.as_str()),
            stats.to_json().to_string_pretty(),
        )?;
    }
    println!("\nwrote bench_results/e2e_pjrt_{{baseline,icarus}}.json");
    Ok(())
}
