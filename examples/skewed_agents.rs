//! Appendix F scenario as a runnable example: random + skewed agent
//! invocation — one hot agent gets 50% of turns, the rest share the
//! remainder in random order.
//!
//!   cargo run --release --example skewed_agents
//!
//! (Full sweep: `cargo bench --bench fig9_skewed`.)

use icarus::bench_util::{header, print_row, Point, Row, KV_BPT_SMALL};
use icarus::config::{Routing, ServingMode};

fn main() {
    println!("== skewed invocation (hot agent 50%), ReAct, qps 0.4 ==\n");
    header();
    for &n in &[2usize, 8] {
        for mode in [ServingMode::Baseline, ServingMode::Icarus] {
            let p = Point {
                mode,
                n_models: n,
                qps: 0.4,
                routing: Routing::Skewed { hot_p_percent: 50 },
                kv_pool_bytes: 24 << 20,
                kv_bytes_per_token: KV_BPT_SMALL,
                ..Default::default()
            };
            let s = p.run();
            let mut r = Row::from_stats(&p, &s);
            r.label = format!("{}/N={n}/skewed", mode.as_str());
            print_row(&r);
        }
    }
    println!("\nEven under skew, baseline pays per-model cache duplication on every handoff;");
    println!("ICaRus turns are prefix hits regardless of which agent served the previous turn.");
}
