//! The serving front end as a runnable example: open-loop heavy-tailed
//! traffic drives a 4-replica cluster into overload, with and without
//! the admission gate — then the same gate does live backpressure over
//! loopback HTTP.
//!
//!   cargo run --release --example open_loop_serve
//!
//! (Full sweep with the goodput/SLO curves: `cargo bench --bench
//! serving`.)

use std::sync::Arc;

use icarus::bench_util::{Point, Row, KV_BPT_SMALL};
use icarus::config::ServingMode;
use icarus::serve::http::http_request;
use icarus::serve::{AdmissionLimits, Frontend, Server};

fn main() -> anyhow::Result<()> {
    println!("== open-loop Pareto traffic, ICaRus N=4, R=4, qps 6.0 ==\n");
    println!(
        "{:<28} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "scenario", "goodput", "ttft_att", "p95(s)", "rejected", "completed"
    );
    // Same offered load three ways: closed-form Poisson-ish workload,
    // open-loop Pareto (overload stays visible), open-loop + admission.
    let scenarios: &[(&str, bool, usize)] = &[
        ("scripted arrivals", false, 0),
        ("open-loop pareto", true, 0),
        ("open-loop + admit_queue=32", true, 32),
    ];
    for &(label, open_loop, admit_queue) in scenarios {
        let p = Point {
            mode: ServingMode::Icarus,
            n_models: 4,
            qps: 6.0,
            n_requests: 192,
            kv_bytes_per_token: KV_BPT_SMALL,
            replicas: 4,
            open_loop,
            admit_queue,
            seed: 7,
            ..Default::default()
        };
        let s = p.run();
        let r = Row::from_stats(&p, &s);
        println!(
            "{label:<28} {:>9.3} {:>9.3} {:>9.3} {:>9} {:>9}",
            r.goodput_rps, r.ttft_attainment, r.p95_s, r.rejected, s.completed_requests
        );
    }

    // The same admission semantics, live: a front end with one slot
    // sheds the second concurrent request with 503 + Retry-After.
    println!("\n== live front end over loopback ==");
    let fe = Frontend::new(AdmissionLimits { max_queue: 1, max_tokens: 0 }, 4);
    let gate = fe.gate();
    let server = Server::start("127.0.0.1:0", Arc::new(fe))?;
    let addr = server.addr();

    let body = r#"{"text": "draft a reply to the customer", "max_tokens": 8}"#;
    let (status, _, reply) = http_request(addr, "POST", "/v2/models/1/infer", Some(body))?;
    println!("infer -> {status}: {}", String::from_utf8_lossy(&reply).replace('\n', " "));

    let _held = gate.try_admit_owned(64).expect("slot free");
    let (status, headers, _) = http_request(addr, "POST", "/v2/models/1/infer", Some(body))?;
    let retry = headers.iter().find(|(k, _)| k == "retry-after").map(|(_, v)| v.as_str());
    println!("infer while saturated -> {status} (retry-after: {})", retry.unwrap_or("-"));
    drop(_held);

    let (_, _, stats) = http_request(addr, "GET", "/v2/stats", None)?;
    println!("stats -> {}", String::from_utf8_lossy(&stats).replace('\n', " "));
    println!(
        "\nOpen-loop arrivals keep coming during overload, so goodput and SLO attainment \
         collapse unless the gate sheds; the HTTP front end applies the same bounds in \
         wall-clock time."
    );
    Ok(())
}
