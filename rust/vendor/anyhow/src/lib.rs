//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This build environment has no crates.io access, so the repository
//! vendors just the slice of the `anyhow` API it uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait.  The surface is call-compatible with the
//! real crate — point the `anyhow` path dependency in `rust/Cargo.toml`
//! at crates.io and nothing in the tree changes.
//!
//! Simplifications vs. the real crate: the error is a flat message
//! (sources are folded into the string eagerly, no backtraces, no
//! downcasting).  That is all the serving engine needs — errors here are
//! terminal diagnostics, never control flow.

use std::fmt;

/// Flat error value: a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    // Debug is what `unwrap`/`expect` and `fn main() -> Result<()>`
    // print; show the message, not a struct dump.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real crate, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent alongside the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` defaulted to [`Error`], as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

/// Attach context to a fallible result (subset of `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} at {}", "token", 7);
        assert_eq!(e.to_string(), "bad token at 7");
        fn bails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 1");
        fn ensures(x: u32) -> Result<u32> {
            ensure!(x > 2, "too small: {x}");
            Ok(x)
        }
        assert!(ensures(3).is_ok());
        assert_eq!(ensures(1).unwrap_err().to_string(), "too small: 1");
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
        let r2: std::result::Result<(), std::io::Error> = Err(io_err());
        let e2 = r2.with_context(|| format!("pass {}", 2)).unwrap_err();
        assert!(e2.to_string().starts_with("pass 2: "));
    }
}
