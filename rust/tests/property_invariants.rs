//! Randomized property tests (in-repo proptest substitute: seeded op
//! sequences over many iterations, shrink-free but reproducible — the
//! failing seed is printed by the assertion message).
//!
//! Includes a differential test driving the optimized `RadixCache`
//! (hash-indexed children, heap-based incremental eviction, node
//! recycling) against a naive reference model with the pre-optimization
//! semantics (per-node token vecs, full-scan LRU eviction): matched
//! token counts, eviction victim order and payload drops must be
//! bit-identical at every step.

use icarus::config::{
    AgentPattern, EvictionPolicy, Routing, ServingConfig, ServingMode, WorkloadConfig,
};
use icarus::engine::executor::{CostModel, SimExecutor};
use icarus::engine::Engine;
use icarus::kvcache::{Alloc, BlockPool, KvCacheManager, RadixCache};
use icarus::rng::Rng;
use icarus::workload::generate;

mod reference {
    //! Naive radix model: a faithful port of the pre-optimization
    //! implementation (linear child-candidate scans, O(nodes) full scan
    //! per evicted block, no node recycling).  Deliberately simple — it
    //! is the spec the optimized structure must match move for move.

    use std::collections::HashMap;

    use icarus::kvcache::{BlockId, BlockPool};

    struct Node {
        tokens: Vec<u32>,
        block: Option<BlockId>,
        children: HashMap<u32, Vec<usize>>, // first token -> candidates
        parent: Option<usize>,
        pins: u32,
        last_access: u64,
        payload: Option<u64>,
        swapped: bool,
        dead: bool,
    }

    pub struct RefMatch {
        pub matched_tokens: usize,
        pub path: Vec<usize>,
        pub payload: Option<(u64, usize)>,
        pub swapped_nodes: Vec<usize>,
    }

    pub struct RefRadix {
        nodes: Vec<Node>,
        root: usize,
        clock: u64,
        resident: usize,
    }

    impl RefRadix {
        pub fn new() -> Self {
            let root = Node {
                tokens: Vec::new(),
                block: None,
                children: HashMap::new(),
                parent: None,
                pins: 0,
                last_access: 0,
                payload: None,
                swapped: false,
                dead: false,
            };
            RefRadix { nodes: vec![root], root: 0, clock: 0, resident: 0 }
        }

        pub fn resident_nodes(&self) -> usize {
            self.resident
        }

        fn tick(&mut self) -> u64 {
            self.clock += 1;
            self.clock
        }

        pub fn lookup(&mut self, prompt: &[u32]) -> RefMatch {
            let now = self.tick();
            let mut cur = self.root;
            let mut matched = 0usize;
            let mut path = Vec::new();
            let mut payload = None;
            let mut swapped_nodes = Vec::new();
            loop {
                let rest = &prompt[matched..];
                if rest.is_empty() {
                    break;
                }
                let Some(cands) = self.nodes[cur].children.get(&rest[0]) else {
                    break;
                };
                let mut next = None;
                for &c in cands {
                    let n = &self.nodes[c];
                    if !n.dead
                        && rest.len() >= n.tokens.len()
                        && rest[..n.tokens.len()] == n.tokens[..]
                    {
                        next = Some(c);
                        break;
                    }
                }
                let Some(c) = next else { break };
                matched += self.nodes[c].tokens.len();
                self.nodes[c].last_access = now;
                path.push(c);
                if self.nodes[c].swapped {
                    swapped_nodes.push(c);
                }
                if let Some(p) = self.nodes[c].payload {
                    payload = Some((p, matched));
                }
                cur = c;
            }
            RefMatch { matched_tokens: matched, path, payload, swapped_nodes }
        }

        pub fn pin(&mut self, m: &RefMatch) {
            for &n in &m.path {
                self.nodes[n].pins += 1;
            }
        }

        pub fn unpin(&mut self, m: &RefMatch) {
            for &n in &m.path {
                self.nodes[n].pins -= 1;
            }
        }

        pub fn insert(&mut self, tokens: &[u32], payload: u64, pool: &mut BlockPool) -> bool {
            let block_tokens = pool.block_tokens;
            let full = (tokens.len() / block_tokens) * block_tokens;
            let m = self.lookup(&tokens[..full]);
            let mut cur = *m.path.last().unwrap_or(&self.root);
            let mut off = m.matched_tokens;
            let needed = (full - off) / block_tokens;
            if pool.free_blocks() < needed {
                return false;
            }
            let now = self.tick();
            while off < full {
                let span = &tokens[off..off + block_tokens];
                let block = pool.alloc(1).expect("checked free_blocks")[0];
                let id = self.nodes.len();
                self.nodes.push(Node {
                    tokens: span.to_vec(),
                    block: Some(block),
                    children: HashMap::new(),
                    parent: Some(cur),
                    pins: 0,
                    last_access: now,
                    payload: None,
                    swapped: false,
                    dead: false,
                });
                self.nodes[cur].children.entry(span[0]).or_default().push(id);
                self.resident += 1;
                cur = id;
                off += block_tokens;
            }
            if cur != self.root {
                self.nodes[cur].payload = Some(payload);
                self.nodes[cur].last_access = now;
            }
            true
        }

        pub fn evict(&mut self, want: usize, pool: &mut BlockPool) -> (usize, Vec<u64>) {
            let mut freed = 0;
            let mut dropped = Vec::new();
            while freed < want {
                // O(nodes) scan for the LRU evictable leaf.
                let mut victim: Option<(u64, usize)> = None;
                for (i, n) in self.nodes.iter().enumerate() {
                    if n.dead || i == self.root || n.pins > 0 || n.block.is_none() {
                        continue;
                    }
                    let has_live_children =
                        n.children.values().flatten().any(|&c| !self.nodes[c].dead);
                    if has_live_children {
                        continue;
                    }
                    if victim.map_or(true, |(t, _)| n.last_access < t) {
                        victim = Some((n.last_access, i));
                    }
                }
                let Some((_, v)) = victim else { break };
                let node = &mut self.nodes[v];
                node.dead = true;
                if let Some(b) = node.block.take() {
                    pool.release(b);
                    freed += 1;
                    self.resident -= 1;
                }
                if let Some(p) = node.payload.take() {
                    dropped.push(p);
                }
                let parent = self.nodes[v].parent;
                if let Some(p) = parent {
                    let first = self.nodes[v].tokens[0];
                    if let Some(list) = self.nodes[p].children.get_mut(&first) {
                        list.retain(|&c| c != v);
                    }
                }
            }
            (freed, dropped)
        }

        pub fn evict_swap(&mut self, want: usize, pool: &mut BlockPool) -> usize {
            let mut freed = 0;
            while freed < want {
                let mut victim: Option<(u64, usize)> = None;
                for (i, n) in self.nodes.iter().enumerate() {
                    if n.dead || i == self.root || n.pins > 0 || n.block.is_none() {
                        continue;
                    }
                    let has_resident_children = n
                        .children
                        .values()
                        .flatten()
                        .any(|&c| !self.nodes[c].dead && self.nodes[c].block.is_some());
                    if has_resident_children {
                        continue;
                    }
                    if victim.map_or(true, |(t, _)| n.last_access < t) {
                        victim = Some((n.last_access, i));
                    }
                }
                let Some((_, v)) = victim else { break };
                let node = &mut self.nodes[v];
                if let Some(b) = node.block.take() {
                    pool.release(b);
                    freed += 1;
                    self.resident -= 1;
                }
                node.swapped = true;
            }
            freed
        }

        pub fn restore(&mut self, nodes: &[usize], pool: &mut BlockPool) -> usize {
            if pool.free_blocks() < nodes.len() {
                return 0;
            }
            for &n in nodes {
                let b = pool.alloc(1).expect("checked free_blocks")[0];
                self.nodes[n].block = Some(b);
                self.nodes[n].swapped = false;
                self.resident += 1;
            }
            nodes.len()
        }
    }
}

/// Pool invariant: used + free == capacity, refcounts balanced, no
/// double-free under arbitrary alloc/retain/release interleavings.
#[test]
fn prop_block_pool_conservation() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let mut pool = BlockPool::new(128 * 16 * 64, 16, 64);
        let cap = pool.capacity();
        // held[i] = (block, extra_refs)
        let mut held: Vec<(u32, u32)> = Vec::new();
        for _ in 0..400 {
            match rng.below(4) {
                0 => {
                    let n = rng.range(1, 8) as usize;
                    if let Some(blocks) = pool.alloc(n) {
                        held.extend(blocks.into_iter().map(|b| (b, 0)));
                    }
                }
                1 if !held.is_empty() => {
                    let i = rng.below(held.len() as u64) as usize;
                    pool.retain(held[i].0);
                    held[i].1 += 1;
                }
                2 if !held.is_empty() => {
                    let i = rng.below(held.len() as u64) as usize;
                    if held[i].1 > 0 {
                        held[i].1 -= 1;
                        pool.release(held[i].0);
                    } else {
                        let (b, _) = held.swap_remove(i);
                        pool.release(b);
                    }
                }
                _ => {}
            }
            assert_eq!(pool.used() + pool.free_blocks(), cap, "seed {seed}");
            assert!(pool.peak_used() <= cap);
        }
        // Releasing everything returns the pool to empty.
        for (b, extra) in held {
            for _ in 0..=extra {
                pool.release(b);
            }
        }
        assert_eq!(pool.used(), 0, "seed {seed}");
    }
}

/// Radix invariant: lookup after insert always matches at least the
/// inserted block-aligned prefix; eviction never breaks remaining
/// entries; pins always protect.
#[test]
fn prop_radix_lookup_consistency() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(1000 + seed);
        let mut pool = BlockPool::new(512 * 16 * 64, 16, 64);
        let mut radix = RadixCache::new();
        let mut inserted: Vec<Vec<u32>> = Vec::new();
        for step in 0..120 {
            match rng.below(3) {
                0 => {
                    // Insert a (possibly prefix-sharing) sequence.
                    let base = if !inserted.is_empty() && rng.bool(0.5) {
                        let i = rng.below(inserted.len() as u64) as usize;
                        let cut = rng.below(inserted[i].len() as u64 + 1) as usize;
                        inserted[i][..cut].to_vec()
                    } else {
                        Vec::new()
                    };
                    let extra = rng.range(1, 64) as usize;
                    let mut t = base;
                    t.extend((0..extra).map(|_| rng.below(1000) as u32));
                    if radix.insert(&t, step as u64, &mut pool) {
                        inserted.push(t);
                    }
                }
                1 if !inserted.is_empty() => {
                    // Lookup of an inserted sequence matches its full
                    // block-aligned length (nothing evicted yet this
                    // branch doesn't guarantee, so only check <=).
                    let i = rng.below(inserted.len() as u64) as usize;
                    let t = &inserted[i];
                    let m = radix.lookup(t);
                    assert!(m.matched_tokens <= t.len(), "seed {seed}");
                    assert_eq!(m.matched_tokens % 16, 0, "block aligned, seed {seed}");
                }
                _ => {
                    let (freed, _) = radix.evict(rng.range(1, 8) as usize, &mut pool);
                    let _ = freed;
                }
            }
            assert_eq!(radix.resident_nodes(), pool.used(), "seed {seed}");
        }
    }
}

/// Pinned prefixes always survive arbitrary eviction pressure.
#[test]
fn prop_radix_pins_protect() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(2000 + seed);
        let mut pool = BlockPool::new(256 * 16 * 64, 16, 64);
        let mut radix = RadixCache::new();
        let protected: Vec<u32> = (0..64).map(|_| rng.below(500) as u32).collect();
        assert!(radix.insert(&protected, 7, &mut pool));
        let m = radix.lookup(&protected);
        radix.pin(&m, &mut pool);
        for _ in 0..60 {
            let t: Vec<u32> = (0..rng.range(16, 80)).map(|_| rng.below(500) as u32).collect();
            let _ = radix.insert(&t, 0, &mut pool);
            let _ = radix.evict(rng.range(1, 32) as usize, &mut pool);
            let m2 = radix.lookup(&protected);
            assert_eq!(m2.matched_tokens, 64, "seed {seed}: pinned prefix lost");
        }
        radix.unpin(&m, &mut pool);
    }
}

/// Manager invariant under random begin/append/finish/preempt churn:
/// active bookkeeping consistent, pool never leaks after all sequences
/// end, ICaRus usage never exceeds baseline usage for the same trace.
#[test]
fn prop_manager_no_leaks_and_mode_ordering() {
    for seed in 0..15u64 {
        let mut peak = Vec::new();
        for mode in [ServingMode::Icarus, ServingMode::Baseline] {
            let cfg = ServingConfig {
                mode,
                kv_pool_bytes: 4096 * 16 * 64,
                block_tokens: 16,
                ..Default::default()
            };
            let mut mgr = KvCacheManager::new(&cfg, 64, 4);
            let mut rng = Rng::new(3000 + seed); // same trace per mode
            let mut active: Vec<(u64, Vec<u32>)> = Vec::new();
            let mut next_id = 1u64;
            let mut next_snap = 1u64;
            for _ in 0..200 {
                match rng.below(4) {
                    0 => {
                        let model = rng.below(4) as usize;
                        let n = rng.range(8, 96) as usize;
                        // Workflows share a common 32-token system prefix.
                        let mut p: Vec<u32> = (0..32u32).collect();
                        p.extend((0..n).map(|_| rng.below(300) as u32));
                        if let Alloc::Ok(_) = mgr.begin_sequence(next_id, model, &p) {
                            active.push((next_id, p));
                            next_id += 1;
                        }
                    }
                    1 if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let _ = mgr.append_tokens(active[i].0, rng.range(1, 20) as usize);
                    }
                    2 if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let (id, ctx) = active.swap_remove(i);
                        mgr.finish_sequence(id, &ctx, Some(next_snap));
                        next_snap += 1;
                    }
                    _ if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let (id, _) = active.swap_remove(i);
                        mgr.preempt(id);
                    }
                    _ => {}
                }
                assert_eq!(mgr.active_sequences(), active.len(), "seed {seed}");
            }
            for (id, ctx) in active.drain(..) {
                mgr.finish_sequence(id, &ctx, None);
            }
            peak.push(mgr.pool.peak_used());
        }
        assert!(
            peak[0] <= peak[1],
            "seed {seed}: icarus peak {} > baseline peak {}",
            peak[0],
            peak[1]
        );
    }
}

/// Engine conservation: every generated workflow completes exactly once,
/// under random (mode, pool, qps, pattern, routing) configurations.
#[test]
fn prop_engine_conservation() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(4000 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let scfg = ServingConfig {
            mode,
            kv_pool_bytes: (8 + rng.below(64)) << 20,
            eviction: if rng.bool(0.5) {
                EvictionPolicy::Recompute
            } else {
                EvictionPolicy::Swap
            },
            max_batch: 4 + rng.below(16) as usize,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            pattern: if rng.bool(0.5) { AgentPattern::ReAct } else { AgentPattern::Reflexion },
            n_models: 1 + rng.below(8) as usize,
            qps: 0.2 + rng.f64(),
            n_requests: 24,
            routing: if rng.bool(0.5) {
                Routing::RoundRobin
            } else {
                Routing::Skewed { hot_p_percent: 50 }
            },
            seed: seed * 17,
            ..Default::default()
        };
        let exec = SimExecutor::new(CostModel::default(), mode);
        let stats = Engine::new(scfg, 2048, wcfg.n_models, exec).run(generate(&wcfg));
        assert_eq!(stats.completed_requests, 24, "seed {seed}");
        let expected_turns: u64 = generate(&wcfg).iter().map(|w| w.turns.len() as u64).sum();
        assert_eq!(stats.completed_turns, expected_turns, "seed {seed}");
        assert!(stats.wall_seconds.is_finite() && stats.wall_seconds > 0.0);
    }
}

/// Differential check of the optimized radix cache against the naive
/// reference model: random insert/lookup/pin/unpin/evict/swap/restore
/// sequences must produce identical matched-token counts, eviction
/// victim order (observed through dropped-payload order), payload drops
/// and residency at every step.
#[test]
fn prop_radix_differential_vs_reference() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(7000 + seed);
        let mut pool_a = BlockPool::new(96 * 16 * 64, 16, 64);
        let mut pool_b = BlockPool::new(96 * 16 * 64, 16, 64);
        let mut opt = RadixCache::new();
        let mut refm = reference::RefRadix::new();
        let mut corpus: Vec<Vec<u32>> = Vec::new();
        let mut pins: Vec<(icarus::kvcache::Match, reference::RefMatch)> = Vec::new();
        for step in 0..300u64 {
            match rng.below(10) {
                0..=2 => {
                    // Insert, often sharing a prefix with the corpus.
                    let base = if !corpus.is_empty() && rng.bool(0.5) {
                        let i = rng.below(corpus.len() as u64) as usize;
                        let cut = rng.below(corpus[i].len() as u64 + 1) as usize;
                        corpus[i][..cut].to_vec()
                    } else {
                        Vec::new()
                    };
                    let extra = rng.range(1, 72) as usize;
                    let mut t = base;
                    t.extend((0..extra).map(|_| rng.below(600) as u32));
                    let a = opt.insert(&t, step, &mut pool_a);
                    let b = refm.insert(&t, step, &mut pool_b);
                    assert_eq!(a, b, "seed {seed} step {step}: insert admissibility");
                    if a {
                        corpus.push(t);
                    }
                }
                3..=4 if !corpus.is_empty() => {
                    // Lookup: exact, extended past the cached part, or a
                    // truncated prefix.
                    let i = rng.below(corpus.len() as u64) as usize;
                    let mut t = corpus[i].clone();
                    if rng.bool(0.3) {
                        t.extend((0..rng.range(1, 24)).map(|_| rng.below(600) as u32));
                    }
                    if rng.bool(0.2) {
                        t.truncate(rng.below(t.len() as u64 + 1) as usize);
                    }
                    let ma = opt.lookup(&t);
                    let mb = refm.lookup(&t);
                    assert_eq!(ma.matched_tokens, mb.matched_tokens, "seed {seed} step {step}");
                    assert_eq!(ma.payload, mb.payload, "seed {seed} step {step}");
                    assert_eq!(
                        ma.swapped_nodes.len(),
                        mb.swapped_nodes.len(),
                        "seed {seed} step {step}"
                    );
                }
                5 if !corpus.is_empty() => {
                    // Pin a matched path in both models.
                    let i = rng.below(corpus.len() as u64) as usize;
                    let t = corpus[i].clone();
                    let ma = opt.lookup(&t);
                    let mb = refm.lookup(&t);
                    assert_eq!(ma.matched_tokens, mb.matched_tokens, "seed {seed} step {step}");
                    opt.pin(&ma, &mut pool_a);
                    refm.pin(&mb);
                    pins.push((ma, mb));
                }
                6 if !pins.is_empty() => {
                    let i = rng.below(pins.len() as u64) as usize;
                    let (ma, mb) = pins.swap_remove(i);
                    opt.unpin(&ma, &mut pool_a);
                    refm.unpin(&mb);
                }
                7 => {
                    let want = rng.range(1, 12) as usize;
                    let (fa, da) = opt.evict(want, &mut pool_a);
                    let (fb, db) = refm.evict(want, &mut pool_b);
                    assert_eq!(fa, fb, "seed {seed} step {step}: blocks freed");
                    assert_eq!(da, db, "seed {seed} step {step}: victim/drop order");
                }
                8 => {
                    let want = rng.range(1, 8) as usize;
                    let fa = opt.evict_swap(want, &mut pool_a);
                    let fb = refm.evict_swap(want, &mut pool_b);
                    assert_eq!(fa, fb, "seed {seed} step {step}: swap-evicted");
                }
                9 if !corpus.is_empty() => {
                    // Restore a swapped path, manager-style.
                    let i = rng.below(corpus.len() as u64) as usize;
                    let t = corpus[i].clone();
                    let ma = opt.lookup(&t);
                    let mb = refm.lookup(&t);
                    assert_eq!(
                        ma.swapped_nodes.len(),
                        mb.swapped_nodes.len(),
                        "seed {seed} step {step}"
                    );
                    if !ma.swapped_nodes.is_empty() {
                        let ra = opt.restore(&ma.swapped_nodes, &mut pool_a);
                        let rb = refm.restore(&mb.swapped_nodes, &mut pool_b);
                        assert_eq!(ra, rb, "seed {seed} step {step}: restored");
                    }
                }
                _ => {}
            }
            assert_eq!(
                opt.resident_nodes(),
                refm.resident_nodes(),
                "seed {seed} step {step}: residency"
            );
            assert_eq!(pool_a.used(), pool_b.used(), "seed {seed} step {step}: pool usage");
        }
        // Unpin everything and drain: the full victim order must match
        // (optimized drain-all vs the reference's large-want evict).
        for (ma, mb) in pins.drain(..) {
            opt.unpin(&ma, &mut pool_a);
            refm.unpin(&mb);
        }
        let (fa, da) = opt.evict_all(&mut pool_a);
        let (fb, db) = refm.evict(usize::MAX - 1, &mut pool_b);
        assert_eq!(fa, fb, "seed {seed}: final drain");
        assert_eq!(da, db, "seed {seed}: final drop order");
        assert_eq!(pool_a.used(), pool_b.used(), "seed {seed}: final pool usage");
    }
}

/// Snapshot accounting: the sim executor's live snapshot count returns
/// to (near) zero after a run — no leaked cache handles.  The prefix
/// cache legitimately retains published snapshots at end of run, so we
/// bound rather than zero-check.
#[test]
fn prop_snapshot_handles_bounded() {
    let scfg = ServingConfig { kv_pool_bytes: 32 << 20, ..Default::default() };
    let wcfg = WorkloadConfig { n_requests: 32, seed: 5, ..Default::default() };
    let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
    let engine = Engine::new(scfg, 2048, 4, exec);
    // Engine::run consumes the engine; snapshot-leak detection happens
    // via the radix-resident bound: every live snapshot must correspond
    // to either a radix payload or a turn that is still running (none at
    // end).  We cap at completed_turns (one published snapshot each).
    let stats = engine.run(generate(&wcfg));
    assert!(stats.completed_turns > 0);
}

/// Stats aggregation: recording random latency samples sharded across R
/// `ServingStats` instances and merging them must yield the same
/// histogram counts and percentile buckets as recording every sample
/// into one instance (histogram merge is position-wise bucket addition,
/// so this is exact, not approximate).
#[test]
fn prop_stats_merge_matches_single_instance() {
    use icarus::metrics::ServingStats;
    for seed in 0..16u64 {
        let mut rng = Rng::new(9000 + seed);
        let shards = 1 + rng.below(8) as usize;
        let samples = 50 + rng.below(400) as usize;
        let mut single = ServingStats::new();
        let mut parts: Vec<ServingStats> = (0..shards).map(|_| ServingStats::new()).collect();
        for _ in 0..samples {
            // Latencies spanning the histogram's full dynamic range.
            let lat = 1e-6 * (10f64).powf(rng.f64() * 6.0);
            let shard = rng.below(shards as u64) as usize;
            single.turn_latency.as_mut().unwrap().record(lat);
            single.request_latency.as_mut().unwrap().record(lat * 2.0);
            single.generated_tokens += 1;
            let p = &mut parts[shard];
            p.turn_latency.as_mut().unwrap().record(lat);
            p.request_latency.as_mut().unwrap().record(lat * 2.0);
            p.generated_tokens += 1;
        }
        let mut merged = ServingStats::new();
        for p in &parts {
            merged.merge(p);
        }
        for (a, b) in [
            (&merged.turn_latency, &single.turn_latency),
            (&merged.request_latency, &single.request_latency),
        ] {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            // Bucket counts are integers: counts and every percentile
            // bucket must match exactly.
            assert_eq!(a.count(), b.count(), "seed {seed}");
            for q in [0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
                assert_eq!(a.quantile(q), b.quantile(q), "seed {seed} q {q}");
            }
            assert_eq!(a.max(), b.max(), "seed {seed}");
            // The mean is an f64 accumulator; summation order differs
            // between the sharded and single paths, so compare within
            // float tolerance rather than bitwise.
            assert!(
                (a.mean() - b.mean()).abs() <= 1e-12 * b.mean().abs().max(1.0),
                "seed {seed}: mean {} vs {}",
                a.mean(),
                b.mean()
            );
        }
        assert_eq!(merged.generated_tokens, single.generated_tokens, "seed {seed}");
    }
}

/// A cluster with one replica is the single engine: same `ServingStats`
/// bit for bit, same trace — across random modes, loads and seeds.
#[test]
fn prop_cluster_replicas_one_bit_identical() {
    use icarus::cluster::Cluster;
    for seed in 0..8u64 {
        let mut rng = Rng::new(11_000 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let scfg = ServingConfig {
            mode,
            kv_pool_bytes: (16 + rng.below(48)) << 20,
            replicas: 1,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            n_models: 1 + rng.below(6) as usize,
            qps: 0.3 + rng.f64(),
            n_requests: 20,
            seed: 100 + seed,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let exec = SimExecutor::new(CostModel::default(), mode);
        let (single, single_trace) =
            Engine::new(scfg.clone(), 2048, wcfg.n_models, exec).run_traced(wl.clone());
        let (out, trace) =
            Cluster::new(scfg, 2048, wcfg.n_models).run_sim_traced(CostModel::default(), wl);
        assert_eq!(out.merged, single, "seed {seed}: stats must be bit-identical");
        assert_eq!(trace.events, single_trace.events, "seed {seed}: trace must match");
    }
}
