//! Randomized property tests (in-repo proptest substitute: seeded op
//! sequences over many iterations, shrink-free but reproducible — the
//! failing seed is printed by the assertion message).
//!
//! Includes two differential suites:
//!
//!   * the optimized `RadixCache` (hash-indexed children, heap-based
//!     incremental eviction, node recycling) against a naive reference
//!     model with the pre-optimization semantics (per-node token vecs,
//!     full-scan LRU eviction): matched token counts, eviction victim
//!     order and payload drops must be bit-identical at every step;
//!   * the scheduler-refactored `Engine` under `--sched-policy fcfs`
//!     with chunking disabled against `legacy_engine`, a frozen
//!     verbatim port of the pre-scheduler event loop: serving stats
//!     and the full per-turn trace must be bit-identical on seeded
//!     ReAct/Reflexion × round-robin/skewed workloads, across modes,
//!     eviction policies and memory-pressure levels.

use icarus::config::{
    AgentPattern, EvictionPolicy, Routing, SchedPolicy, ServingConfig, ServingMode,
    WorkloadConfig,
};
use icarus::engine::executor::{CostModel, SimExecutor};
use icarus::engine::Engine;
use icarus::kvcache::{Alloc, BlockPool, KvCacheManager, RadixCache};
use icarus::rng::Rng;
use icarus::workload::generate;

mod reference {
    //! Naive radix model: a faithful port of the pre-optimization
    //! implementation (linear child-candidate scans, O(nodes) full scan
    //! per evicted block, no node recycling).  Deliberately simple — it
    //! is the spec the optimized structure must match move for move.

    use std::collections::HashMap;

    use icarus::kvcache::{BlockId, BlockPool};

    struct Node {
        tokens: Vec<u32>,
        block: Option<BlockId>,
        children: HashMap<u32, Vec<usize>>, // first token -> candidates
        parent: Option<usize>,
        pins: u32,
        last_access: u64,
        payload: Option<u64>,
        swapped: bool,
        dead: bool,
    }

    pub struct RefMatch {
        pub matched_tokens: usize,
        pub path: Vec<usize>,
        pub payload: Option<(u64, usize)>,
        pub swapped_nodes: Vec<usize>,
    }

    pub struct RefRadix {
        nodes: Vec<Node>,
        root: usize,
        clock: u64,
        resident: usize,
    }

    impl RefRadix {
        pub fn new() -> Self {
            let root = Node {
                tokens: Vec::new(),
                block: None,
                children: HashMap::new(),
                parent: None,
                pins: 0,
                last_access: 0,
                payload: None,
                swapped: false,
                dead: false,
            };
            RefRadix { nodes: vec![root], root: 0, clock: 0, resident: 0 }
        }

        pub fn resident_nodes(&self) -> usize {
            self.resident
        }

        fn tick(&mut self) -> u64 {
            self.clock += 1;
            self.clock
        }

        pub fn lookup(&mut self, prompt: &[u32]) -> RefMatch {
            let now = self.tick();
            let mut cur = self.root;
            let mut matched = 0usize;
            let mut path = Vec::new();
            let mut payload = None;
            let mut swapped_nodes = Vec::new();
            loop {
                let rest = &prompt[matched..];
                if rest.is_empty() {
                    break;
                }
                let Some(cands) = self.nodes[cur].children.get(&rest[0]) else {
                    break;
                };
                let mut next = None;
                for &c in cands {
                    let n = &self.nodes[c];
                    if !n.dead
                        && rest.len() >= n.tokens.len()
                        && rest[..n.tokens.len()] == n.tokens[..]
                    {
                        next = Some(c);
                        break;
                    }
                }
                let Some(c) = next else { break };
                matched += self.nodes[c].tokens.len();
                self.nodes[c].last_access = now;
                path.push(c);
                if self.nodes[c].swapped {
                    swapped_nodes.push(c);
                }
                if let Some(p) = self.nodes[c].payload {
                    payload = Some((p, matched));
                }
                cur = c;
            }
            RefMatch { matched_tokens: matched, path, payload, swapped_nodes }
        }

        pub fn pin(&mut self, m: &RefMatch) {
            for &n in &m.path {
                self.nodes[n].pins += 1;
            }
        }

        pub fn unpin(&mut self, m: &RefMatch) {
            for &n in &m.path {
                self.nodes[n].pins -= 1;
            }
        }

        pub fn insert(&mut self, tokens: &[u32], payload: u64, pool: &mut BlockPool) -> bool {
            let block_tokens = pool.block_tokens;
            let full = (tokens.len() / block_tokens) * block_tokens;
            let m = self.lookup(&tokens[..full]);
            let mut cur = *m.path.last().unwrap_or(&self.root);
            let mut off = m.matched_tokens;
            let needed = (full - off) / block_tokens;
            if pool.free_blocks() < needed {
                return false;
            }
            let now = self.tick();
            while off < full {
                let span = &tokens[off..off + block_tokens];
                let block = pool.alloc(1).expect("checked free_blocks")[0];
                let id = self.nodes.len();
                self.nodes.push(Node {
                    tokens: span.to_vec(),
                    block: Some(block),
                    children: HashMap::new(),
                    parent: Some(cur),
                    pins: 0,
                    last_access: now,
                    payload: None,
                    swapped: false,
                    dead: false,
                });
                self.nodes[cur].children.entry(span[0]).or_default().push(id);
                self.resident += 1;
                cur = id;
                off += block_tokens;
            }
            if cur != self.root {
                self.nodes[cur].payload = Some(payload);
                self.nodes[cur].last_access = now;
            }
            true
        }

        pub fn evict(&mut self, want: usize, pool: &mut BlockPool) -> (usize, Vec<u64>) {
            let mut freed = 0;
            let mut dropped = Vec::new();
            while freed < want {
                // O(nodes) scan for the LRU evictable leaf.
                let mut victim: Option<(u64, usize)> = None;
                for (i, n) in self.nodes.iter().enumerate() {
                    if n.dead || i == self.root || n.pins > 0 || n.block.is_none() {
                        continue;
                    }
                    let has_live_children =
                        n.children.values().flatten().any(|&c| !self.nodes[c].dead);
                    if has_live_children {
                        continue;
                    }
                    if victim.map_or(true, |(t, _)| n.last_access < t) {
                        victim = Some((n.last_access, i));
                    }
                }
                let Some((_, v)) = victim else { break };
                let node = &mut self.nodes[v];
                node.dead = true;
                if let Some(b) = node.block.take() {
                    pool.release(b);
                    freed += 1;
                    self.resident -= 1;
                }
                if let Some(p) = node.payload.take() {
                    dropped.push(p);
                }
                let parent = self.nodes[v].parent;
                if let Some(p) = parent {
                    let first = self.nodes[v].tokens[0];
                    if let Some(list) = self.nodes[p].children.get_mut(&first) {
                        list.retain(|&c| c != v);
                    }
                }
            }
            (freed, dropped)
        }

        pub fn evict_swap(&mut self, want: usize, pool: &mut BlockPool) -> usize {
            let mut freed = 0;
            while freed < want {
                let mut victim: Option<(u64, usize)> = None;
                for (i, n) in self.nodes.iter().enumerate() {
                    if n.dead || i == self.root || n.pins > 0 || n.block.is_none() {
                        continue;
                    }
                    let has_resident_children = n
                        .children
                        .values()
                        .flatten()
                        .any(|&c| !self.nodes[c].dead && self.nodes[c].block.is_some());
                    if has_resident_children {
                        continue;
                    }
                    if victim.map_or(true, |(t, _)| n.last_access < t) {
                        victim = Some((n.last_access, i));
                    }
                }
                let Some((_, v)) = victim else { break };
                let node = &mut self.nodes[v];
                if let Some(b) = node.block.take() {
                    pool.release(b);
                    freed += 1;
                    self.resident -= 1;
                }
                node.swapped = true;
            }
            freed
        }

        pub fn restore(&mut self, nodes: &[usize], pool: &mut BlockPool) -> usize {
            if pool.free_blocks() < nodes.len() {
                return 0;
            }
            for &n in nodes {
                let b = pool.alloc(1).expect("checked free_blocks")[0];
                self.nodes[n].block = Some(b);
                self.nodes[n].swapped = false;
                self.resident += 1;
            }
            nodes.len()
        }
    }
}

/// Pool invariant: used + free == capacity, refcounts balanced, no
/// double-free under arbitrary alloc/retain/release interleavings.
#[test]
fn prop_block_pool_conservation() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let mut pool = BlockPool::new(128 * 16 * 64, 16, 64);
        let cap = pool.capacity();
        // held[i] = (block, extra_refs)
        let mut held: Vec<(u32, u32)> = Vec::new();
        for _ in 0..400 {
            match rng.below(4) {
                0 => {
                    let n = rng.range(1, 8) as usize;
                    if let Some(blocks) = pool.alloc(n) {
                        held.extend(blocks.into_iter().map(|b| (b, 0)));
                    }
                }
                1 if !held.is_empty() => {
                    let i = rng.below(held.len() as u64) as usize;
                    pool.retain(held[i].0);
                    held[i].1 += 1;
                }
                2 if !held.is_empty() => {
                    let i = rng.below(held.len() as u64) as usize;
                    if held[i].1 > 0 {
                        held[i].1 -= 1;
                        pool.release(held[i].0);
                    } else {
                        let (b, _) = held.swap_remove(i);
                        pool.release(b);
                    }
                }
                _ => {}
            }
            assert_eq!(pool.used() + pool.free_blocks(), cap, "seed {seed}");
            assert!(pool.peak_used() <= cap);
        }
        // Releasing everything returns the pool to empty.
        for (b, extra) in held {
            for _ in 0..=extra {
                pool.release(b);
            }
        }
        assert_eq!(pool.used(), 0, "seed {seed}");
    }
}

/// Radix invariant: lookup after insert always matches at least the
/// inserted block-aligned prefix; eviction never breaks remaining
/// entries; pins always protect.
#[test]
fn prop_radix_lookup_consistency() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(1000 + seed);
        let mut pool = BlockPool::new(512 * 16 * 64, 16, 64);
        let mut radix = RadixCache::new();
        let mut inserted: Vec<Vec<u32>> = Vec::new();
        for step in 0..120 {
            match rng.below(3) {
                0 => {
                    // Insert a (possibly prefix-sharing) sequence.
                    let base = if !inserted.is_empty() && rng.bool(0.5) {
                        let i = rng.below(inserted.len() as u64) as usize;
                        let cut = rng.below(inserted[i].len() as u64 + 1) as usize;
                        inserted[i][..cut].to_vec()
                    } else {
                        Vec::new()
                    };
                    let extra = rng.range(1, 64) as usize;
                    let mut t = base;
                    t.extend((0..extra).map(|_| rng.below(1000) as u32));
                    if radix.insert(&t, step as u64, &mut pool) {
                        inserted.push(t);
                    }
                }
                1 if !inserted.is_empty() => {
                    // Lookup of an inserted sequence matches its full
                    // block-aligned length (nothing evicted yet this
                    // branch doesn't guarantee, so only check <=).
                    let i = rng.below(inserted.len() as u64) as usize;
                    let t = &inserted[i];
                    let m = radix.lookup(t);
                    assert!(m.matched_tokens <= t.len(), "seed {seed}");
                    assert_eq!(m.matched_tokens % 16, 0, "block aligned, seed {seed}");
                }
                _ => {
                    let (freed, _) = radix.evict(rng.range(1, 8) as usize, &mut pool);
                    let _ = freed;
                }
            }
            assert_eq!(radix.resident_nodes(), pool.used(), "seed {seed}");
        }
    }
}

/// Pinned prefixes always survive arbitrary eviction pressure.
#[test]
fn prop_radix_pins_protect() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(2000 + seed);
        let mut pool = BlockPool::new(256 * 16 * 64, 16, 64);
        let mut radix = RadixCache::new();
        let protected: Vec<u32> = (0..64).map(|_| rng.below(500) as u32).collect();
        assert!(radix.insert(&protected, 7, &mut pool));
        let m = radix.lookup(&protected);
        radix.pin(&m, &mut pool);
        for _ in 0..60 {
            let t: Vec<u32> = (0..rng.range(16, 80)).map(|_| rng.below(500) as u32).collect();
            let _ = radix.insert(&t, 0, &mut pool);
            let _ = radix.evict(rng.range(1, 32) as usize, &mut pool);
            let m2 = radix.lookup(&protected);
            assert_eq!(m2.matched_tokens, 64, "seed {seed}: pinned prefix lost");
        }
        radix.unpin(&m, &mut pool);
    }
}

/// Manager invariant under random begin/append/finish/preempt churn:
/// active bookkeeping consistent, pool never leaks after all sequences
/// end, ICaRus usage never exceeds baseline usage for the same trace.
#[test]
fn prop_manager_no_leaks_and_mode_ordering() {
    for seed in 0..15u64 {
        let mut peak = Vec::new();
        for mode in [ServingMode::Icarus, ServingMode::Baseline] {
            let cfg = ServingConfig {
                mode,
                kv_pool_bytes: 4096 * 16 * 64,
                block_tokens: 16,
                ..Default::default()
            };
            let mut mgr = KvCacheManager::new(&cfg, 64, 4);
            let mut rng = Rng::new(3000 + seed); // same trace per mode
            let mut active: Vec<(u64, Vec<u32>)> = Vec::new();
            let mut next_id = 1u64;
            let mut next_snap = 1u64;
            for _ in 0..200 {
                match rng.below(4) {
                    0 => {
                        let model = rng.below(4) as usize;
                        let n = rng.range(8, 96) as usize;
                        // Workflows share a common 32-token system prefix.
                        let mut p: Vec<u32> = (0..32u32).collect();
                        p.extend((0..n).map(|_| rng.below(300) as u32));
                        if let Alloc::Ok(_) = mgr.begin_sequence(next_id, model, &p) {
                            active.push((next_id, p));
                            next_id += 1;
                        }
                    }
                    1 if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let _ = mgr.append_tokens(active[i].0, rng.range(1, 20) as usize);
                    }
                    2 if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let (id, ctx) = active.swap_remove(i);
                        mgr.finish_sequence(id, &ctx, Some(next_snap));
                        next_snap += 1;
                    }
                    _ if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let (id, _) = active.swap_remove(i);
                        mgr.preempt(id);
                    }
                    _ => {}
                }
                assert_eq!(mgr.active_sequences(), active.len(), "seed {seed}");
            }
            for (id, ctx) in active.drain(..) {
                mgr.finish_sequence(id, &ctx, None);
            }
            peak.push(mgr.pool.peak_used());
        }
        assert!(
            peak[0] <= peak[1],
            "seed {seed}: icarus peak {} > baseline peak {}",
            peak[0],
            peak[1]
        );
    }
}

/// Engine conservation: every generated workflow completes exactly once,
/// under random (mode, pool, qps, pattern, routing) configurations.
#[test]
fn prop_engine_conservation() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(4000 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let scfg = ServingConfig {
            mode,
            kv_pool_bytes: (8 + rng.below(64)) << 20,
            eviction: if rng.bool(0.5) {
                EvictionPolicy::Recompute
            } else {
                EvictionPolicy::Swap
            },
            max_batch: 4 + rng.below(16) as usize,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            pattern: if rng.bool(0.5) { AgentPattern::ReAct } else { AgentPattern::Reflexion },
            n_models: 1 + rng.below(8) as usize,
            qps: 0.2 + rng.f64(),
            n_requests: 24,
            routing: if rng.bool(0.5) {
                Routing::RoundRobin
            } else {
                Routing::Skewed { hot_p_percent: 50 }
            },
            seed: seed * 17,
            ..Default::default()
        };
        let exec = SimExecutor::new(CostModel::default(), mode);
        let stats = Engine::new(scfg, 2048, wcfg.n_models, exec).run(generate(&wcfg));
        assert_eq!(stats.completed_requests, 24, "seed {seed}");
        let expected_turns: u64 = generate(&wcfg).iter().map(|w| w.turns.len() as u64).sum();
        assert_eq!(stats.completed_turns, expected_turns, "seed {seed}");
        assert!(stats.wall_seconds.is_finite() && stats.wall_seconds > 0.0);
    }
}

/// Differential check of the optimized radix cache against the naive
/// reference model: random insert/lookup/pin/unpin/evict/swap/restore
/// sequences must produce identical matched-token counts, eviction
/// victim order (observed through dropped-payload order), payload drops
/// and residency at every step.
#[test]
fn prop_radix_differential_vs_reference() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(7000 + seed);
        let mut pool_a = BlockPool::new(96 * 16 * 64, 16, 64);
        let mut pool_b = BlockPool::new(96 * 16 * 64, 16, 64);
        let mut opt = RadixCache::new();
        let mut refm = reference::RefRadix::new();
        let mut corpus: Vec<Vec<u32>> = Vec::new();
        let mut pins: Vec<(icarus::kvcache::Match, reference::RefMatch)> = Vec::new();
        for step in 0..300u64 {
            match rng.below(10) {
                0..=2 => {
                    // Insert, often sharing a prefix with the corpus.
                    let base = if !corpus.is_empty() && rng.bool(0.5) {
                        let i = rng.below(corpus.len() as u64) as usize;
                        let cut = rng.below(corpus[i].len() as u64 + 1) as usize;
                        corpus[i][..cut].to_vec()
                    } else {
                        Vec::new()
                    };
                    let extra = rng.range(1, 72) as usize;
                    let mut t = base;
                    t.extend((0..extra).map(|_| rng.below(600) as u32));
                    let a = opt.insert(&t, step, &mut pool_a);
                    let b = refm.insert(&t, step, &mut pool_b);
                    assert_eq!(a, b, "seed {seed} step {step}: insert admissibility");
                    if a {
                        corpus.push(t);
                    }
                }
                3..=4 if !corpus.is_empty() => {
                    // Lookup: exact, extended past the cached part, or a
                    // truncated prefix.
                    let i = rng.below(corpus.len() as u64) as usize;
                    let mut t = corpus[i].clone();
                    if rng.bool(0.3) {
                        t.extend((0..rng.range(1, 24)).map(|_| rng.below(600) as u32));
                    }
                    if rng.bool(0.2) {
                        t.truncate(rng.below(t.len() as u64 + 1) as usize);
                    }
                    let ma = opt.lookup(&t);
                    let mb = refm.lookup(&t);
                    assert_eq!(ma.matched_tokens, mb.matched_tokens, "seed {seed} step {step}");
                    assert_eq!(ma.payload, mb.payload, "seed {seed} step {step}");
                    assert_eq!(
                        ma.swapped_nodes.len(),
                        mb.swapped_nodes.len(),
                        "seed {seed} step {step}"
                    );
                }
                5 if !corpus.is_empty() => {
                    // Pin a matched path in both models.
                    let i = rng.below(corpus.len() as u64) as usize;
                    let t = corpus[i].clone();
                    let ma = opt.lookup(&t);
                    let mb = refm.lookup(&t);
                    assert_eq!(ma.matched_tokens, mb.matched_tokens, "seed {seed} step {step}");
                    opt.pin(&ma, &mut pool_a);
                    refm.pin(&mb);
                    pins.push((ma, mb));
                }
                6 if !pins.is_empty() => {
                    let i = rng.below(pins.len() as u64) as usize;
                    let (ma, mb) = pins.swap_remove(i);
                    opt.unpin(&ma, &mut pool_a);
                    refm.unpin(&mb);
                }
                7 => {
                    let want = rng.range(1, 12) as usize;
                    let (fa, da) = opt.evict(want, &mut pool_a);
                    let (fb, db) = refm.evict(want, &mut pool_b);
                    assert_eq!(fa, fb, "seed {seed} step {step}: blocks freed");
                    assert_eq!(da, db, "seed {seed} step {step}: victim/drop order");
                }
                8 => {
                    let want = rng.range(1, 8) as usize;
                    let fa = opt.evict_swap(want, &mut pool_a);
                    let fb = refm.evict_swap(want, &mut pool_b);
                    assert_eq!(fa, fb, "seed {seed} step {step}: swap-evicted");
                }
                9 if !corpus.is_empty() => {
                    // Restore a swapped path, manager-style.
                    let i = rng.below(corpus.len() as u64) as usize;
                    let t = corpus[i].clone();
                    let ma = opt.lookup(&t);
                    let mb = refm.lookup(&t);
                    assert_eq!(
                        ma.swapped_nodes.len(),
                        mb.swapped_nodes.len(),
                        "seed {seed} step {step}"
                    );
                    if !ma.swapped_nodes.is_empty() {
                        let ra = opt.restore(&ma.swapped_nodes, &mut pool_a);
                        let rb = refm.restore(&mb.swapped_nodes, &mut pool_b);
                        assert_eq!(ra, rb, "seed {seed} step {step}: restored");
                    }
                }
                _ => {}
            }
            assert_eq!(
                opt.resident_nodes(),
                refm.resident_nodes(),
                "seed {seed} step {step}: residency"
            );
            assert_eq!(pool_a.used(), pool_b.used(), "seed {seed} step {step}: pool usage");
        }
        // Unpin everything and drain: the full victim order must match
        // (optimized drain-all vs the reference's large-want evict).
        for (ma, mb) in pins.drain(..) {
            opt.unpin(&ma, &mut pool_a);
            refm.unpin(&mb);
        }
        let (fa, da) = opt.evict_all(&mut pool_a);
        let (fb, db) = refm.evict(usize::MAX - 1, &mut pool_b);
        assert_eq!(fa, fb, "seed {seed}: final drain");
        assert_eq!(da, db, "seed {seed}: final drop order");
        assert_eq!(pool_a.used(), pool_b.used(), "seed {seed}: final pool usage");
    }
}

/// Snapshot accounting: the sim executor's live snapshot count returns
/// to (near) zero after a run — no leaked cache handles.  The prefix
/// cache legitimately retains published snapshots at end of run, so we
/// bound rather than zero-check.
#[test]
fn prop_snapshot_handles_bounded() {
    let scfg = ServingConfig { kv_pool_bytes: 32 << 20, ..Default::default() };
    let wcfg = WorkloadConfig { n_requests: 32, seed: 5, ..Default::default() };
    let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
    let engine = Engine::new(scfg, 2048, 4, exec);
    // Engine::run consumes the engine; snapshot-leak detection happens
    // via the radix-resident bound: every live snapshot must correspond
    // to either a radix payload or a turn that is still running (none at
    // end).  We cap at completed_turns (one published snapshot each).
    let stats = engine.run(generate(&wcfg));
    assert!(stats.completed_turns > 0);
}

/// Stats aggregation: recording random latency samples sharded across R
/// `ServingStats` instances and merging them must yield the same
/// histogram counts and percentile buckets as recording every sample
/// into one instance (histogram merge is position-wise bucket addition,
/// so this is exact, not approximate).
#[test]
fn prop_stats_merge_matches_single_instance() {
    use icarus::metrics::ServingStats;
    for seed in 0..16u64 {
        let mut rng = Rng::new(9000 + seed);
        let shards = 1 + rng.below(8) as usize;
        let samples = 50 + rng.below(400) as usize;
        let mut single = ServingStats::new();
        let mut parts: Vec<ServingStats> = (0..shards).map(|_| ServingStats::new()).collect();
        for _ in 0..samples {
            // Latencies spanning the histogram's full dynamic range.
            let lat = 1e-6 * (10f64).powf(rng.f64() * 6.0);
            let shard = rng.below(shards as u64) as usize;
            single.turn_latency.as_mut().unwrap().record(lat);
            single.request_latency.as_mut().unwrap().record(lat * 2.0);
            single.generated_tokens += 1;
            let p = &mut parts[shard];
            p.turn_latency.as_mut().unwrap().record(lat);
            p.request_latency.as_mut().unwrap().record(lat * 2.0);
            p.generated_tokens += 1;
        }
        let mut merged = ServingStats::new();
        for p in &parts {
            merged.merge(p);
        }
        for (a, b) in [
            (&merged.turn_latency, &single.turn_latency),
            (&merged.request_latency, &single.request_latency),
        ] {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            // Bucket counts are integers: counts and every percentile
            // bucket must match exactly.
            assert_eq!(a.count(), b.count(), "seed {seed}");
            for q in [0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
                assert_eq!(a.quantile(q), b.quantile(q), "seed {seed} q {q}");
            }
            assert_eq!(a.max(), b.max(), "seed {seed}");
            // The mean is an f64 accumulator; summation order differs
            // between the sharded and single paths, so compare within
            // float tolerance rather than bitwise.
            assert!(
                (a.mean() - b.mean()).abs() <= 1e-12 * b.mean().abs().max(1.0),
                "seed {seed}: mean {} vs {}",
                a.mean(),
                b.mean()
            );
        }
        assert_eq!(merged.generated_tokens, single.generated_tokens, "seed {seed}");
    }
}

mod legacy_engine {
    //! Frozen verbatim port of the engine event loop as it existed
    //! before the scheduler extraction (PR 4): hardwired FCFS
    //! admission, conservative whole-prompt budget estimate, atomic
    //! prefill at admission.  Deliberately unmaintained — it is the
    //! spec the refactored engine must match move for move under
    //! `SchedPolicy::Fcfs` with chunking disabled.

    use std::collections::VecDeque;

    use icarus::config::{EvictionPolicy, ServingConfig};
    use icarus::engine::executor::{DecodeSlot, Executor, PrefillOut};
    use icarus::kvcache::{Alloc, KvCacheManager};
    use icarus::metrics::ServingStats;
    use icarus::trace::{Trace, TurnEvent};
    use icarus::workload::Workflow;
    use icarus::TokenBuf;

    struct PendingTurn {
        wf_idx: usize,
        turn_idx: usize,
        ready_at: f64,
        prompt: TokenBuf,
        remaining_gen: usize,
        was_preempted: bool,
        swapped: Option<(u64, u64)>,
    }

    struct RunningSeq {
        seq_id: u64,
        wf_idx: usize,
        turn_idx: usize,
        model_id: usize,
        prompt: TokenBuf,
        generated: Vec<u32>,
        remaining_gen: usize,
        cache: u64,
        cached_tokens: usize,
        ready_at: f64,
        admitted_at: f64,
    }

    impl RunningSeq {
        fn context_len(&self) -> usize {
            self.prompt.len() + self.generated.len()
        }

        fn into_context(self) -> TokenBuf {
            self.prompt.extended(&self.generated)
        }
    }

    struct WfState {
        spec: Workflow,
        context: TokenBuf,
        next_turn: usize,
    }

    pub struct LegacyEngine<E: Executor> {
        cfg: ServingConfig,
        exec: E,
        kv: KvCacheManager,
        now: f64,
        next_seq_id: u64,
        wfs: Vec<WfState>,
        future: VecDeque<usize>,
        waiting: VecDeque<PendingTurn>,
        delayed: Vec<PendingTurn>,
        running: Vec<RunningSeq>,
        stats: ServingStats,
        trace: Trace,
    }

    impl<E: Executor> LegacyEngine<E> {
        pub fn new(cfg: ServingConfig, kv_bytes_per_token: u64, n_models: usize, exec: E) -> Self {
            assert_eq!(cfg.mode, exec.mode(), "engine/executor mode mismatch");
            let kv = KvCacheManager::new(&cfg, kv_bytes_per_token, n_models);
            LegacyEngine {
                cfg,
                exec,
                kv,
                now: 0.0,
                next_seq_id: 1,
                wfs: Vec::new(),
                future: VecDeque::new(),
                waiting: VecDeque::new(),
                delayed: Vec::new(),
                running: Vec::new(),
                stats: ServingStats::new(),
                trace: Trace::new(),
            }
        }

        pub fn run_traced(mut self, workload: Vec<Workflow>) -> (ServingStats, Trace) {
            let mut idx: Vec<usize> = (0..workload.len()).collect();
            idx.sort_by(|&a, &b| workload[a].arrival.total_cmp(&workload[b].arrival));
            self.wfs = workload
                .into_iter()
                .map(|spec| {
                    let context = spec.prompt.clone();
                    WfState { spec, context, next_turn: 0 }
                })
                .collect();
            self.future = idx.into();

            loop {
                self.surface_arrivals();
                self.surface_delayed();
                if self.waiting.is_empty() && self.running.is_empty() {
                    let next_arrival = self.future.front().map(|&w| self.wfs[w].spec.arrival);
                    let next_ready =
                        self.delayed.iter().map(|t| t.ready_at).min_by(f64::total_cmp);
                    match [next_arrival, next_ready].into_iter().flatten().min_by(f64::total_cmp) {
                        Some(t) => {
                            self.now = self.now.max(t);
                            continue;
                        }
                        None => break,
                    }
                }
                self.admit();
                self.decode_step();
            }
            self.stats.wall_seconds = self.now;
            self.stats.peak_kv_bytes = self.kv.pool.peak_bytes();
            self.stats.swap_outs = self.kv.swap.swap_outs;
            self.stats.swap_ins = self.kv.swap.swap_ins;
            self.stats.evictions = self.kv.stats.evicted_blocks;
            (self.stats, self.trace)
        }

        fn surface_delayed(&mut self) {
            let now = self.now;
            let mut i = 0;
            while i < self.delayed.len() {
                if self.delayed[i].ready_at <= now {
                    let t = self.delayed.swap_remove(i);
                    self.waiting.push_back(t);
                } else {
                    i += 1;
                }
            }
        }

        fn surface_arrivals(&mut self) {
            while let Some(&w) = self.future.front() {
                if self.wfs[w].spec.arrival > self.now {
                    break;
                }
                self.future.pop_front();
                let wf = &mut self.wfs[w];
                let prompt = std::mem::take(&mut wf.context);
                self.waiting.push_back(PendingTurn {
                    wf_idx: w,
                    turn_idx: 0,
                    ready_at: wf.spec.arrival,
                    prompt,
                    remaining_gen: wf.spec.turns[0].gen_len,
                    was_preempted: false,
                    swapped: None,
                });
            }
        }

        fn admit(&mut self) {
            let mut prefill_budget = self.cfg.max_prefill_tokens;
            let mut attempts = self.waiting.len();
            while self.running.len() < self.cfg.max_batch && attempts > 0 {
                attempts -= 1;
                let Some(turn) = self.waiting.front() else { break };
                let uncached_upper = turn.prompt.len(); // worst case
                if uncached_upper > prefill_budget && prefill_budget < self.cfg.max_prefill_tokens {
                    break;
                }
                let mut turn = self.waiting.pop_front().unwrap();
                let model_id = self.wfs[turn.wf_idx].spec.turns[turn.turn_idx].model_id;
                let seq_id = self.next_seq_id;

                if let Some((handle, bytes)) = turn.swapped.take() {
                    match self.kv.begin_sequence(seq_id, model_id, &turn.prompt) {
                        Alloc::Ok(adm) => {
                            self.drop_snapshots(&adm.dropped_snapshots);
                            self.kv.swap.swap_in(bytes).expect("swap tier accounting");
                            self.now += self.exec.swap_in_cost(bytes);
                            self.next_seq_id += 1;
                            self.running.push(RunningSeq {
                                seq_id,
                                wf_idx: turn.wf_idx,
                                turn_idx: turn.turn_idx,
                                model_id,
                                prompt: turn.prompt,
                                generated: Vec::new(),
                                remaining_gen: turn.remaining_gen,
                                cache: handle,
                                cached_tokens: 0,
                                ready_at: turn.ready_at,
                                admitted_at: self.now,
                            });
                            continue;
                        }
                        Alloc::NoSpace => {
                            turn.swapped = Some((handle, bytes));
                            self.check_admissible_when_idle(&turn);
                            self.waiting.push_front(turn);
                            break;
                        }
                    }
                }

                match self.kv.begin_sequence(seq_id, model_id, &turn.prompt) {
                    Alloc::Ok(adm) => {
                        self.next_seq_id += 1;
                        self.drop_snapshots(&adm.dropped_snapshots);
                        if adm.swap_in_bytes > 0 {
                            self.now += self.exec.swap_in_cost(adm.swap_in_bytes);
                        }
                        let (base, cached) = match adm.snapshot {
                            Some((snap, covered)) => (Some(snap), covered),
                            None => (None, 0),
                        };
                        let cached = cached.min(adm.cached_tokens);
                        let uncached = turn.prompt.len() - cached;
                        prefill_budget = prefill_budget.saturating_sub(uncached);
                        let PrefillOut { duration, cache, first_token } = self
                            .exec
                            .prefill(model_id, &turn.prompt, cached, base)
                            .expect("prefill failed");
                        self.now += duration;
                        self.stats.prefill_tokens += uncached as u64;
                        self.stats.cached_prefill_tokens += cached as u64;
                        if turn.was_preempted {
                            self.stats.recomputed_tokens += uncached as u64;
                        }
                        self.stats
                            .time_to_first_token
                            .as_mut()
                            .unwrap()
                            .record((self.now - turn.ready_at).max(0.0));
                        turn.remaining_gen = turn.remaining_gen.saturating_sub(1);
                        let seq = RunningSeq {
                            seq_id,
                            wf_idx: turn.wf_idx,
                            turn_idx: turn.turn_idx,
                            model_id,
                            prompt: turn.prompt,
                            generated: vec![first_token],
                            remaining_gen: turn.remaining_gen,
                            cache,
                            cached_tokens: cached,
                            ready_at: turn.ready_at,
                            admitted_at: self.now,
                        };
                        if let Alloc::NoSpace = self.kv.append_tokens(seq_id, 1) {
                            self.kv.preempt(seq.seq_id);
                            self.stats.preemptions += 1;
                            self.requeue_preempted(seq);
                            continue;
                        }
                        self.running.push(seq);
                    }
                    Alloc::NoSpace => {
                        self.check_admissible_when_idle(&turn);
                        self.waiting.push_front(turn);
                        break;
                    }
                }
            }
        }

        fn check_admissible_when_idle(&self, turn: &PendingTurn) {
            if self.running.is_empty() {
                panic!(
                    "KV pool cannot hold a {}-token prompt even when idle",
                    turn.prompt.len()
                );
            }
        }

        fn requeue_preempted(&mut self, victim: RunningSeq) {
            let cache = victim.cache;
            let context_len = victim.context_len();
            let mut turn = PendingTurn {
                wf_idx: victim.wf_idx,
                turn_idx: victim.turn_idx,
                ready_at: victim.ready_at,
                remaining_gen: victim.remaining_gen,
                was_preempted: true,
                swapped: None,
                prompt: victim.into_context(),
            };
            match self.cfg.eviction {
                EvictionPolicy::Recompute => {
                    self.exec.drop_snapshot(cache);
                }
                EvictionPolicy::Swap => {
                    let bytes = context_len as u64 * self.kv.kv_bytes_per_token();
                    if self.kv.swap.swap_out(bytes) {
                        turn.swapped = Some((cache, bytes));
                        turn.was_preempted = false;
                    } else {
                        self.kv.stats.swap_tier_full += 1;
                        self.exec.drop_snapshot(cache);
                    }
                }
            }
            self.waiting.push_back(turn);
        }

        fn decode_step(&mut self) {
            if self.running.is_empty() {
                return;
            }
            let mut i = 0;
            while i < self.running.len() {
                let seq_id = self.running[i].seq_id;
                match self.kv.append_tokens(seq_id, 1) {
                    Alloc::Ok(adm) => {
                        self.drop_snapshots(&adm.dropped_snapshots);
                        i += 1;
                    }
                    Alloc::NoSpace => {
                        if !self.preempt_other(i) {
                            let victim = self.running.swap_remove(i);
                            self.kv.preempt(victim.seq_id);
                            self.stats.preemptions += 1;
                            self.requeue_preempted(victim);
                        }
                    }
                }
            }
            if self.running.is_empty() {
                return;
            }
            let mut slots: Vec<DecodeSlot> = self
                .running
                .iter()
                .map(|s| DecodeSlot {
                    seq_id: s.seq_id,
                    model_id: s.model_id,
                    cache: s.cache,
                    context_len: s.context_len(),
                    last_token: *s.generated.last().unwrap_or(&1),
                    next_token: 0,
                })
                .collect();
            let dur = self.exec.decode(&mut slots).expect("decode failed");
            self.now += dur;
            for (seq, slot) in self.running.iter_mut().zip(&slots) {
                seq.cache = slot.cache;
                seq.generated.push(slot.next_token);
                seq.remaining_gen = seq.remaining_gen.saturating_sub(1);
                self.stats.generated_tokens += 1;
            }
            let mut j = 0;
            while j < self.running.len() {
                if self.running[j].remaining_gen == 0 {
                    let seq = self.running.swap_remove(j);
                    self.finish_turn(seq);
                } else {
                    j += 1;
                }
            }
        }

        fn preempt_other(&mut self, keep: usize) -> bool {
            let Some(pos) = self
                .running
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != keep)
                .max_by(|a, b| a.1.admitted_at.total_cmp(&b.1.admitted_at))
                .map(|(i, _)| i)
            else {
                return false;
            };
            let victim = self.running.swap_remove(pos);
            self.kv.preempt(victim.seq_id);
            self.stats.preemptions += 1;
            self.requeue_preempted(victim);
            true
        }

        fn finish_turn(&mut self, seq: RunningSeq) {
            self.stats.completed_turns += 1;
            self.trace.record(TurnEvent {
                wf_id: self.wfs[seq.wf_idx].spec.id,
                turn_idx: seq.turn_idx,
                model_id: seq.model_id,
                ready_at: seq.ready_at,
                completed_at: self.now,
                prompt_tokens: seq.prompt.len(),
                cached_tokens: seq.cached_tokens,
                generated_tokens: seq.generated.len(),
                // The frozen reference predates the obs layer; the
                // breakdown fields stay at their obs-off value.
                queue_wait: 0.0,
                prefill_time: 0.0,
                stall_time: 0.0,
            });
            self.stats
                .turn_latency
                .as_mut()
                .unwrap()
                .record((self.now - seq.ready_at).max(0.0));
            let seq_id = seq.seq_id;
            let wf_idx = seq.wf_idx;
            let turn_idx = seq.turn_idx;
            let cache = seq.cache;
            let full = seq.into_context();
            let snap = self.exec.snapshot(cache);
            let dropped = self.kv.finish_sequence(seq_id, &full, Some(snap));
            self.drop_snapshots(&dropped);

            let wf = &mut self.wfs[wf_idx];
            let spec_turn = &wf.spec.turns[turn_idx];
            let ctx = full.extended(&spec_turn.obs);
            wf.next_turn = turn_idx + 1;
            if wf.next_turn < wf.spec.turns.len() {
                let next = &wf.spec.turns[wf.next_turn];
                let gen = next.gen_len;
                let ready_at = self.now + next.think_s;
                let turn = PendingTurn {
                    wf_idx,
                    turn_idx: wf.next_turn,
                    ready_at,
                    prompt: ctx,
                    remaining_gen: gen,
                    was_preempted: false,
                    swapped: None,
                };
                if ready_at > self.now {
                    self.delayed.push(turn);
                } else {
                    self.waiting.push_back(turn);
                }
            } else {
                wf.context = ctx;
                self.stats.completed_requests += 1;
                let arrival = wf.spec.arrival;
                self.stats
                    .request_latency
                    .as_mut()
                    .unwrap()
                    .record((self.now - arrival).max(0.0));
            }
        }

        fn drop_snapshots(&mut self, snaps: &[u64]) {
            for &s in snaps {
                self.exec.drop_snapshot(s);
            }
        }
    }
}

/// The scheduler refactor is provably a refactor: `--sched-policy
/// fcfs` with chunking disabled reproduces the pre-scheduler engine's
/// serving stats and full per-turn trace bit for bit, on seeded
/// ReAct/Reflexion x round-robin/skewed workloads across modes,
/// eviction policies and memory-pressure levels (tiny pools force the
/// preemption, swap and recompute paths through both loops).
#[test]
fn prop_fcfs_unchunked_bit_identical_to_legacy_engine() {
    use legacy_engine::LegacyEngine;
    let cases: &[(ServingMode, EvictionPolicy, AgentPattern, Routing, f64, u64, usize, u64)] = &[
        // (mode, eviction, pattern, routing, qps, pool_mb, n_models, seed)
        (
            ServingMode::Icarus,
            EvictionPolicy::Recompute,
            AgentPattern::ReAct,
            Routing::RoundRobin,
            0.5,
            64,
            4,
            7,
        ),
        (
            ServingMode::Baseline,
            EvictionPolicy::Recompute,
            AgentPattern::ReAct,
            Routing::RoundRobin,
            1.0,
            4,
            8,
            3,
        ),
        (
            ServingMode::Icarus,
            EvictionPolicy::Swap,
            AgentPattern::ReAct,
            Routing::Skewed { hot_p_percent: 50 },
            0.8,
            8,
            8,
            5,
        ),
        (
            ServingMode::Baseline,
            EvictionPolicy::Swap,
            AgentPattern::Reflexion,
            Routing::RoundRobin,
            1.0,
            4,
            8,
            9,
        ),
        (
            ServingMode::Icarus,
            EvictionPolicy::Recompute,
            AgentPattern::Reflexion,
            Routing::Skewed { hot_p_percent: 70 },
            1.5,
            16,
            4,
            21,
        ),
    ];
    for &(mode, eviction, pattern, routing, qps, pool_mb, n_models, seed) in cases {
        let scfg = ServingConfig {
            mode,
            eviction,
            kv_pool_bytes: pool_mb << 20,
            sched_policy: SchedPolicy::Fcfs,
            prefill_chunk: 0,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            pattern,
            n_models,
            qps,
            n_requests: 40,
            routing,
            seed,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let tag = format!("{mode:?}/{eviction:?}/{pattern:?}/qps={qps}/pool={pool_mb}MB");

        let legacy_exec = SimExecutor::new(CostModel::default(), mode);
        let (l, lt) =
            LegacyEngine::new(scfg.clone(), 2048, n_models, legacy_exec).run_traced(wl.clone());

        let exec = SimExecutor::new(CostModel::default(), mode);
        let (n, nt) = Engine::new(scfg, 2048, n_models, exec).run_traced(wl);

        // Every stat the pre-scheduler engine reported, bit for bit.
        assert_eq!(n.completed_requests, l.completed_requests, "{tag}: requests");
        assert_eq!(n.completed_turns, l.completed_turns, "{tag}: turns");
        assert_eq!(n.generated_tokens, l.generated_tokens, "{tag}: generated");
        assert_eq!(n.prefill_tokens, l.prefill_tokens, "{tag}: prefilled");
        assert_eq!(n.cached_prefill_tokens, l.cached_prefill_tokens, "{tag}: cached");
        assert_eq!(n.recomputed_tokens, l.recomputed_tokens, "{tag}: recomputed");
        assert_eq!(n.evictions, l.evictions, "{tag}: evictions");
        assert_eq!(n.swap_outs, l.swap_outs, "{tag}: swap outs");
        assert_eq!(n.swap_ins, l.swap_ins, "{tag}: swap ins");
        assert_eq!(n.preemptions, l.preemptions, "{tag}: preemptions");
        assert_eq!(n.peak_kv_bytes, l.peak_kv_bytes, "{tag}: peak kv");
        assert_eq!(n.prefill_chunks, 0, "{tag}: no chunks with chunking off");
        assert_eq!(
            n.wall_seconds.to_bits(),
            l.wall_seconds.to_bits(),
            "{tag}: wall clock must be bit-identical ({} vs {})",
            n.wall_seconds,
            l.wall_seconds
        );
        assert_eq!(n.request_latency, l.request_latency, "{tag}: request hist");
        assert_eq!(n.turn_latency, l.turn_latency, "{tag}: turn hist");
        assert_eq!(n.time_to_first_token, l.time_to_first_token, "{tag}: ttft hist");
        // And the full per-turn timeline.
        assert_eq!(nt.events, lt.events, "{tag}: trace must be bit-identical");
    }
}

/// No resource leaks under any scheduling policy x chunking x mode:
/// after a full run every sequence has drained from the KV manager,
/// the only resident blocks belong to the prefix cache, and the only
/// live executor snapshot handles are the prefix cache's published
/// payloads (the engine dropped everything it was handed back —
/// including displaced payloads from identical-context re-publishes
/// and partial chunked-prefill caches of preempted sequences).
#[test]
fn prop_no_leaks_under_every_policy() {
    for &policy in &[SchedPolicy::Fcfs, SchedPolicy::CacheAware, SchedPolicy::Sjf] {
        for &chunk in &[0usize, 96] {
            for &(mode, eviction, pool_mb) in &[
                (ServingMode::Icarus, EvictionPolicy::Recompute, 8u64),
                (ServingMode::Baseline, EvictionPolicy::Recompute, 4),
                (ServingMode::Icarus, EvictionPolicy::Swap, 8),
            ] {
                let tag = format!("{policy:?}/chunk={chunk}/{mode:?}/{eviction:?}");
                let scfg = ServingConfig {
                    mode,
                    eviction,
                    kv_pool_bytes: pool_mb << 20,
                    sched_policy: policy,
                    prefill_chunk: chunk,
                    ..Default::default()
                };
                let wcfg = WorkloadConfig {
                    n_models: 4,
                    qps: 1.0,
                    n_requests: 24,
                    seed: 13,
                    ..Default::default()
                };
                let exec = SimExecutor::new(CostModel::default(), mode);
                let mut engine = Engine::new(scfg, 2048, 4, exec);
                let stats = engine.run_in_place(generate(&wcfg));
                assert_eq!(stats.completed_requests, 24, "{tag}: completion");
                assert_eq!(engine.kv().active_sequences(), 0, "{tag}: leaked sequences");
                assert_eq!(
                    engine.kv().resident_blocks(),
                    engine.kv().resident_cache_blocks(),
                    "{tag}: blocks owned by dead sequences"
                );
                assert_eq!(
                    engine.executor().live_snapshots(),
                    engine.kv().live_payloads() as u64,
                    "{tag}: leaked snapshot handles"
                );
            }
        }
    }
}

/// Satellite: byte conservation across the full demotion pipeline
/// (GPU pool -> swap tier -> snapshot-store host -> disk -> dropped),
/// under random begin/append/finish/preempt churn with the store
/// enabled, for every eviction policy.  At every step:
///
///   * swap-tier occupancy equals the swapped radix nodes' bytes
///     (evict_swap reserves, restore releases — never out of step);
///   * the store ledger balances: every published byte is host-
///     resident, disk-resident or dropped (restores are copies and
///     must not perturb it);
///   * tier budgets are never exceeded;
///   * pool blocks held by the trees never exceed total pool usage.
#[test]
fn prop_demotion_pipeline_conserves_bytes() {
    use icarus::store::{SnapshotStore, TieredStore};
    for &eviction in &[EvictionPolicy::Recompute, EvictionPolicy::Swap] {
        for seed in 0..10u64 {
            let mut rng = Rng::new(16_000 + seed);
            let cfg = ServingConfig {
                mode: ServingMode::Icarus,
                kv_pool_bytes: 64 * 16 * 64, // 64 blocks of 16 tokens @ 64 B/token
                block_tokens: 16,
                eviction,
                swap_bytes: 24 * 16 * 64,
                store_host_bytes: 20 * 16 * 64,
                store_disk_bytes: 12 * 16 * 64,
                ..Default::default()
            };
            let mut m = KvCacheManager::new(&cfg, 64, 4);
            let store = TieredStore::new(cfg.store_host_bytes, cfg.store_disk_bytes, 16, 64);
            let mut now = 0.0f64;
            let mut active: Vec<(u64, Vec<u32>)> = Vec::new();
            let mut published: Vec<Vec<u32>> = Vec::new();
            let mut next_id = 1u64;
            let mut next_snap = 1u64;
            let tag = format!("{eviction:?} seed {seed}");
            for step in 0..300 {
                now += 0.01;
                match rng.below(5) {
                    0 | 1 => {
                        let n = rng.range(8, 96) as usize;
                        let mut p: Vec<u32> = (0..32u32).collect(); // shared prefix
                        p.extend((0..n).map(|_| rng.below(300) as u32));
                        if let Alloc::Ok(_) = m.begin_sequence(next_id, 0, &p) {
                            active.push((next_id, p));
                            next_id += 1;
                        }
                    }
                    2 if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let _ = m.append_tokens(active[i].0, rng.range(1, 20) as usize);
                    }
                    3 if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let (id, ctx) = active.swap_remove(i);
                        m.finish_sequence(id, &ctx, Some(next_snap));
                        next_snap += 1;
                        // Write-through, as the engine does on finish.
                        store.publish(&ctx, now, now, 0);
                        published.push(ctx);
                    }
                    _ if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let (id, _) = active.swap_remove(i);
                        m.preempt(id);
                    }
                    _ => {}
                }
                // Demotion pipeline: hard-evicted payload contexts flow
                // GPU -> host tier (the store cascades the rest).
                for ctx in m.take_demoted() {
                    store.publish(&ctx, now, now, 0);
                }
                // Restores are copies: they must not bend the ledger.
                if !published.is_empty() && rng.bool(0.25) {
                    let i = rng.below(published.len() as u64) as usize;
                    let _ = store.begin_restore(&published[i], 0, now + 10.0, 1);
                }
                if rng.bool(0.1) && !published.is_empty() {
                    let i = rng.below(published.len() as u64) as usize;
                    store.stage(&published[i], now, &|_| 0.5);
                }
                let st = store.stats();
                assert_eq!(
                    st.bytes_published,
                    st.host_used + st.disk_used + st.bytes_dropped,
                    "{tag} step {step}: store ledger"
                );
                assert!(st.host_used <= st.host_capacity, "{tag} step {step}: host budget");
                assert!(st.disk_used <= st.disk_capacity, "{tag} step {step}: disk budget");
                assert_eq!(
                    m.swap.used(),
                    m.swapped_cache_blocks() as u64 * m.pool.block_bytes,
                    "{tag} step {step}: swap occupancy"
                );
                assert!(
                    m.resident_cache_blocks() <= m.pool.used(),
                    "{tag} step {step}: tree blocks exceed pool usage"
                );
            }
            // Drain everything: per-sequence state goes to zero and the
            // tree owns exactly the remaining pool blocks.
            for (id, ctx) in active.drain(..) {
                m.finish_sequence(id, &ctx, None);
            }
            assert_eq!(m.active_sequences(), 0, "{tag}");
            assert_eq!(m.resident_cache_blocks(), m.pool.used(), "{tag}: end residency");
            let st = store.stats();
            assert_eq!(
                st.bytes_published,
                st.host_used + st.disk_used + st.bytes_dropped,
                "{tag}: final ledger"
            );
        }
    }
}

/// The store's disable gate: with both tier budgets zero (and even the
/// prefetch flag left on) the cluster — at any replica count — builds
/// no store and produces bit-identical stats *and* traces to the
/// default configuration, across modes, eviction policies and pool
/// pressures.  This pins that the knobs alone can never perturb a
/// store-less run; the claim that store-less PR-5 code equals
/// *pre-store* behavior is pinned separately by
/// `prop_fcfs_unchunked_bit_identical_to_legacy_engine` above, whose
/// frozen reference loop predates the store entirely and exercises the
/// restructured admit path, the demotion drain and the swap-stat split
/// through the default (store-less) engine.
#[test]
fn prop_store_zero_budget_bit_identical() {
    use icarus::cluster::Cluster;
    for seed in 0..8u64 {
        let mut rng = Rng::new(17_000 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let eviction =
            if rng.bool(0.5) { EvictionPolicy::Recompute } else { EvictionPolicy::Swap };
        let replicas = 1 + rng.below(4) as usize;
        let n_models = 1 + rng.below(6) as usize;
        let base = ServingConfig {
            mode,
            eviction,
            kv_pool_bytes: (8 + rng.below(48)) << 20,
            replicas,
            ..Default::default()
        };
        let zeroed = ServingConfig {
            store_host_bytes: 0,
            store_disk_bytes: 0,
            store_prefetch: true, // must be inert without tier budgets
            ..base.clone()
        };
        let wcfg = WorkloadConfig {
            n_models,
            qps: 0.3 + rng.f64(),
            n_requests: 24,
            seed: 500 + seed,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let (a, at) =
            Cluster::new(base, 2048, n_models).run_sim_traced(CostModel::default(), wl.clone());
        let (b, bt) =
            Cluster::new(zeroed, 2048, n_models).run_sim_traced(CostModel::default(), wl);
        assert_eq!(a.merged, b.merged, "seed {seed}: stats must be bit-identical");
        assert_eq!(at.events, bt.events, "seed {seed}: trace must be bit-identical");
        assert!(b.store.is_none(), "seed {seed}: zero budgets must not build a store");
        assert_eq!(b.merged.store_hits(), 0, "seed {seed}");
    }
}

/// Lock striping is a pure contention optimization: for ANY shard
/// count the store's observable behavior is bit-identical to the
/// serial layout (`--store-shards 1`, the pre-shard single-lock
/// store).  Two layers:
///
///   * op-level differential — one seeded op sequence applied to
///     stores at shard counts 1/2/4/8 must return identical results
///     from every probe/restore/stage/prefetch and identical stats
///     after every step, with budgets tight enough that demotion,
///     rejection and the publish all-shard upgrade path all fire;
///   * run-level differential — cluster runs with the store enabled
///     produce bit-identical merged stats, traces and store counters
///     for explicit shard counts and the auto default (`0`).
#[test]
fn prop_store_shards_bit_identical() {
    use icarus::cluster::Cluster;
    use icarus::store::{SnapshotStore, TieredStore};
    for seed in 0..10u64 {
        let shard_counts = [1usize, 2, 4, 8];
        let stores: Vec<TieredStore> = shard_counts
            .iter()
            .map(|&s| TieredStore::with_shards(20 * 16 * 64, 12 * 16 * 64, 16, 64, s))
            .collect();
        let mut rng = Rng::new(18_000 + seed);
        let mut published: Vec<Vec<u32>> = Vec::new();
        let mut now = 0.0f64;
        for step in 0..400 {
            now += 0.01;
            let tag = format!("seed {seed} step {step}");
            match rng.below(7) {
                0 | 1 => {
                    let n = rng.range(8, 120) as usize;
                    let mut ctx: Vec<u32> = (0..16u32).collect(); // shared prefix
                    ctx.extend((0..n).map(|_| rng.below(200) as u32));
                    let rep = rng.below(4) as usize;
                    for s in &stores {
                        s.publish(&ctx, now, now, rep);
                    }
                    published.push(ctx);
                }
                2 if !published.is_empty() => {
                    let i = rng.below(published.len() as u64) as usize;
                    let peeks: Vec<usize> =
                        stores.iter().map(|s| s.peek(&published[i], now)).collect();
                    assert!(peeks.windows(2).all(|w| w[0] == w[1]), "{tag}: peek {peeks:?}");
                }
                3 if !published.is_empty() => {
                    let i = rng.below(published.len() as u64) as usize;
                    let rep = rng.below(4) as usize;
                    let hits: Vec<_> = stores
                        .iter()
                        .map(|s| s.begin_restore(&published[i], 0, now, rep))
                        .collect();
                    assert!(hits.windows(2).all(|w| w[0] == w[1]), "{tag}: restore {hits:?}");
                }
                4 if !published.is_empty() => {
                    let i = rng.below(published.len() as u64) as usize;
                    let staged: Vec<bool> =
                        stores.iter().map(|s| s.stage(&published[i], now, &|_| 0.5)).collect();
                    assert!(staged.windows(2).all(|w| w[0] == w[1]), "{tag}: stage {staged:?}");
                }
                5 if !published.is_empty() => {
                    let i = rng.below(published.len() as u64) as usize;
                    let pf: Vec<_> = stores
                        .iter()
                        .map(|s| s.prefetch_candidate(&published[i], now))
                        .collect();
                    assert!(pf.windows(2).all(|w| w[0] == w[1]), "{tag}: prefetch {pf:?}");
                }
                _ if !published.is_empty() => {
                    let i = rng.below(published.len() as u64) as usize;
                    if rng.bool(0.5) {
                        for s in &stores {
                            s.pin(&published[i]);
                        }
                    } else {
                        // Saturating at zero pins, so blind unpins are
                        // fine — and identical across layouts.
                        for s in &stores {
                            s.unpin(&published[i]);
                        }
                    }
                }
                _ => {}
            }
            let stats: Vec<_> = stores.iter().map(|s| s.stats()).collect();
            assert!(stats.windows(2).all(|w| w[0] == w[1]), "{tag}: stats diverged {stats:?}");
        }
    }
    for seed in 0..5u64 {
        let mut rng = Rng::new(18_500 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let eviction =
            if rng.bool(0.5) { EvictionPolicy::Recompute } else { EvictionPolicy::Swap };
        let replicas = 1 + rng.below(4) as usize;
        let n_models = 1 + rng.below(6) as usize;
        let base = ServingConfig {
            mode,
            eviction,
            kv_pool_bytes: (8 + rng.below(48)) << 20,
            replicas,
            store_host_bytes: 6 << 20,
            store_disk_bytes: 4 << 20,
            store_prefetch: rng.bool(0.5),
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            n_models,
            qps: 0.3 + rng.f64(),
            n_requests: 24,
            seed: 700 + seed,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let serial = ServingConfig { store_shards: 1, ..base.clone() };
        let (a, at) = Cluster::new(serial, 2048, n_models)
            .run_sim_traced(CostModel::default(), wl.clone());
        for shards in [0usize, 2, 8] {
            let cfg = ServingConfig { store_shards: shards, ..base.clone() };
            let (b, bt) = Cluster::new(cfg, 2048, n_models)
                .run_sim_traced(CostModel::default(), wl.clone());
            assert_eq!(a.merged, b.merged, "seed {seed} shards {shards}: stats");
            assert_eq!(at.events, bt.events, "seed {seed} shards {shards}: trace");
            assert_eq!(a.store, b.store, "seed {seed} shards {shards}: store counters");
        }
        assert!(a.store.is_some(), "seed {seed}: store must be built");
    }
}

/// The sharded store's atomic tier budgets never over-admit and its
/// byte ledger balances — under true concurrency (threads hammering
/// one store through every public op) and at the end of engine runs
/// under both eviction policies.
#[test]
fn prop_sharded_budget_conservation() {
    use std::sync::Arc;

    use icarus::cluster::Cluster;
    use icarus::store::{SnapshotStore, TieredStore};
    for seed in 0..3u64 {
        for shards in [2usize, 8] {
            let store =
                Arc::new(TieredStore::with_shards(24 * 16 * 64, 10 * 16 * 64, 16, 64, shards));
            std::thread::scope(|scope| {
                for t in 0..8u64 {
                    let store = Arc::clone(&store);
                    scope.spawn(move || {
                        let mut rng = Rng::new(19_000 + seed * 100 + t);
                        let mut published: Vec<Vec<u32>> = Vec::new();
                        let mut now = 0.0f64;
                        for _ in 0..300 {
                            now += 0.01;
                            match rng.below(5) {
                                0 | 1 => {
                                    let n = rng.range(8, 96) as usize;
                                    let mut ctx: Vec<u32> = (0..16u32).collect();
                                    ctx.extend((0..n).map(|_| rng.below(150) as u32));
                                    store.publish(&ctx, now, now, t as usize);
                                    published.push(ctx);
                                }
                                2 if !published.is_empty() => {
                                    let i = rng.below(published.len() as u64) as usize;
                                    let _ = store.begin_restore(
                                        &published[i],
                                        0,
                                        now + 1.0,
                                        (t as usize + 1) % 8,
                                    );
                                }
                                3 if !published.is_empty() => {
                                    let i = rng.below(published.len() as u64) as usize;
                                    let _ = store.peek(&published[i], now);
                                    let _ = store.stage(&published[i], now, &|_| 0.5);
                                }
                                _ if !published.is_empty() => {
                                    let i = rng.below(published.len() as u64) as usize;
                                    if rng.bool(0.5) {
                                        store.pin(&published[i]);
                                    } else {
                                        store.unpin(&published[i]);
                                    }
                                }
                                _ => {}
                            }
                        }
                    });
                }
            });
            // Quiescent: every op completed, so the ledger must balance
            // exactly and neither tier may sit above capacity (atomic
            // reserve-then-commit admission).
            let st = store.stats();
            let tag = format!("seed {seed} shards {shards}");
            assert_eq!(
                st.bytes_published,
                st.host_used + st.disk_used + st.bytes_dropped,
                "{tag}: concurrent ledger"
            );
            assert!(st.host_used <= st.host_capacity, "{tag}: host budget over-admitted");
            assert!(st.disk_used <= st.disk_capacity, "{tag}: disk budget over-admitted");
            assert_eq!(st.lock_poisoned, 0, "{tag}: no poisoned locks");
        }
    }
    for &eviction in &[EvictionPolicy::Recompute, EvictionPolicy::Swap] {
        for seed in 0..4u64 {
            let mut rng = Rng::new(19_500 + seed);
            let replicas = 2 + rng.below(3) as usize;
            let n_models = 1 + rng.below(6) as usize;
            let cfg = ServingConfig {
                mode: ServingMode::Icarus,
                eviction,
                kv_pool_bytes: (8 + rng.below(24)) << 20,
                replicas,
                store_host_bytes: 4 << 20,
                store_disk_bytes: 2 << 20,
                store_prefetch: true,
                store_shards: [0, 2, 8][rng.below(3) as usize],
                ..Default::default()
            };
            let wcfg = WorkloadConfig {
                n_models,
                qps: 0.3 + rng.f64(),
                n_requests: 24,
                seed: 900 + seed,
                ..Default::default()
            };
            let out = Cluster::new(cfg, 2048, n_models)
                .run_sim(CostModel::default(), generate(&wcfg));
            let st = out.store.expect("store enabled");
            let tag = format!("{eviction:?} seed {seed}");
            assert_eq!(
                st.bytes_published,
                st.host_used + st.disk_used + st.bytes_dropped,
                "{tag}: end-of-run ledger"
            );
            assert!(st.host_used <= st.host_capacity, "{tag}: host budget");
            assert!(st.disk_used <= st.disk_capacity, "{tag}: disk budget");
            assert_eq!(st.lock_poisoned, 0, "{tag}: poisoned locks");
        }
    }
}

/// A cluster with one replica is the single engine: same `ServingStats`
/// bit for bit, same trace — across random modes, loads and seeds.
#[test]
fn prop_cluster_replicas_one_bit_identical() {
    use icarus::cluster::Cluster;
    for seed in 0..8u64 {
        let mut rng = Rng::new(11_000 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let scfg = ServingConfig {
            mode,
            kv_pool_bytes: (16 + rng.below(48)) << 20,
            replicas: 1,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            n_models: 1 + rng.below(6) as usize,
            qps: 0.3 + rng.f64(),
            n_requests: 20,
            seed: 100 + seed,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let exec = SimExecutor::new(CostModel::default(), mode);
        let (single, single_trace) =
            Engine::new(scfg.clone(), 2048, wcfg.n_models, exec).run_traced(wl.clone());
        let (out, trace) =
            Cluster::new(scfg, 2048, wcfg.n_models).run_sim_traced(CostModel::default(), wl);
        assert_eq!(out.merged, single, "seed {seed}: stats must be bit-identical");
        assert_eq!(trace.events, single_trace.events, "seed {seed}: trace must match");
    }
}

/// The overlap runtime's off switch is provably inert: with `overlap:
/// false` pinned explicitly (not just defaulted), the task-runtime-
/// aware engine reproduces the frozen pre-scheduler serial loop bit
/// for bit — stats and full per-turn trace — under fcfs, chunking
/// disabled, one replica and zero store budget, across modes, eviction
/// policies and pool pressures.  The overlap counters must read
/// exactly zero: the serial path may not touch them.
#[test]
fn prop_overlap_off_bit_identical_to_legacy_engine() {
    use legacy_engine::LegacyEngine;
    let cases: &[(ServingMode, EvictionPolicy, f64, u64, usize, u64)] = &[
        // (mode, eviction, qps, pool_mb, n_models, seed)
        (ServingMode::Icarus, EvictionPolicy::Recompute, 0.8, 16, 4, 31),
        (ServingMode::Baseline, EvictionPolicy::Recompute, 1.2, 4, 8, 33),
        (ServingMode::Icarus, EvictionPolicy::Swap, 1.0, 8, 8, 37),
    ];
    for &(mode, eviction, qps, pool_mb, n_models, seed) in cases {
        let scfg = ServingConfig {
            mode,
            eviction,
            kv_pool_bytes: pool_mb << 20,
            sched_policy: SchedPolicy::Fcfs,
            prefill_chunk: 0,
            store_host_bytes: 0,
            store_disk_bytes: 0,
            overlap: false,
            ..Default::default()
        };
        let wcfg = WorkloadConfig { n_models, qps, n_requests: 40, seed, ..Default::default() };
        let wl = generate(&wcfg);
        let tag = format!("{mode:?}/{eviction:?}/qps={qps}/pool={pool_mb}MB");

        let legacy_exec = SimExecutor::new(CostModel::default(), mode);
        let (l, lt) =
            LegacyEngine::new(scfg.clone(), 2048, n_models, legacy_exec).run_traced(wl.clone());

        let exec = SimExecutor::new(CostModel::default(), mode);
        let (n, nt) = Engine::new(scfg, 2048, n_models, exec).run_traced(wl);

        assert_eq!(n.completed_requests, l.completed_requests, "{tag}: requests");
        assert_eq!(n.completed_turns, l.completed_turns, "{tag}: turns");
        assert_eq!(n.generated_tokens, l.generated_tokens, "{tag}: generated");
        assert_eq!(n.prefill_tokens, l.prefill_tokens, "{tag}: prefilled");
        assert_eq!(n.cached_prefill_tokens, l.cached_prefill_tokens, "{tag}: cached");
        assert_eq!(n.recomputed_tokens, l.recomputed_tokens, "{tag}: recomputed");
        assert_eq!(n.evictions, l.evictions, "{tag}: evictions");
        assert_eq!(n.swap_outs, l.swap_outs, "{tag}: swap outs");
        assert_eq!(n.swap_ins, l.swap_ins, "{tag}: swap ins");
        assert_eq!(n.preemptions, l.preemptions, "{tag}: preemptions");
        assert_eq!(n.peak_kv_bytes, l.peak_kv_bytes, "{tag}: peak kv");
        assert_eq!(
            n.wall_seconds.to_bits(),
            l.wall_seconds.to_bits(),
            "{tag}: wall clock must be bit-identical ({} vs {})",
            n.wall_seconds,
            l.wall_seconds
        );
        assert_eq!(n.request_latency, l.request_latency, "{tag}: request hist");
        assert_eq!(n.turn_latency, l.turn_latency, "{tag}: turn hist");
        assert_eq!(n.time_to_first_token, l.time_to_first_token, "{tag}: ttft hist");
        assert_eq!(nt.events, lt.events, "{tag}: trace must be bit-identical");
        // The serial path never touches the overlap machinery.
        assert_eq!(n.tasks_spawned, 0, "{tag}: no tasks with overlap off");
        assert_eq!(n.stalled_transfer_time, 0.0, "{tag}: no stall accounting");
        assert_eq!(n.overlapped_transfer_time, 0.0, "{tag}: no overlap accounting");
    }
}

/// `--overlap on` is run-to-run deterministic: the same seed produces
/// bit-identical serving stats (whole struct, overlap counters
/// included) and per-turn traces across two fresh runs, under the
/// configs the overlap runtime targets — one replica over a tiered
/// store (with and without prefetch and chunked prefill), and two
/// replicas with swap eviction and no store (swap-ins ride the
/// executor there).  Multi-replica *shared-store* runs are excluded by
/// design: cross-replica eviction-tie ordering under the sub-window
/// LRU is already documented as schedule-dependent (see
/// `store::fence`), independent of overlap.
#[test]
fn prop_overlap_on_deterministic() {
    use icarus::cluster::Cluster;
    let cases: &[(usize, u64, u64, bool, usize, EvictionPolicy, u64)] = &[
        // (replicas, host, disk, prefetch, chunk, eviction, seed)
        (1, 64 << 20, 0, false, 0, EvictionPolicy::Recompute, 51),
        (1, 8 << 20, 256 << 20, true, 0, EvictionPolicy::Recompute, 53),
        (1, 8 << 20, 256 << 20, true, 96, EvictionPolicy::Recompute, 57),
        (2, 0, 0, false, 0, EvictionPolicy::Swap, 59),
    ];
    for &(replicas, host, disk, prefetch, chunk, eviction, seed) in cases {
        let scfg = ServingConfig {
            mode: ServingMode::Icarus,
            eviction,
            kv_pool_bytes: 12 << 20,
            prefill_chunk: chunk,
            replicas,
            store_host_bytes: host,
            store_disk_bytes: disk,
            store_prefetch: prefetch,
            overlap: true,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            n_models: 4,
            qps: 1.0,
            n_requests: 32,
            seed,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let tag = format!("R={replicas}/host={host}/disk={disk}/pf={prefetch}/chunk={chunk}");
        let run = || {
            Cluster::new(scfg.clone(), 2048, 4).run_sim_traced(CostModel::default(), wl.clone())
        };
        let (a, at) = run();
        let (b, bt) = run();
        assert_eq!(a.merged, b.merged, "{tag}: merged stats must be run-to-run identical");
        assert_eq!(a.per_replica, b.per_replica, "{tag}: per-replica stats must match");
        assert_eq!(at.events, bt.events, "{tag}: trace must be run-to-run identical");
        assert_eq!(a.merged.completed_requests, 32, "{tag}: completion");
        if host + disk > 0 {
            assert!(a.merged.tasks_spawned > 0, "{tag}: transfers should ride the executor");
        }
    }
}

/// The disaggregation gate is provably inert: with `disagg: false`
/// pinned explicitly, the `prefill_replicas` knob set to arbitrary
/// values and the `prefill_decode` routing policy selected, a cluster
/// produces bit-identical stats *and* traces to the default
/// configuration — across modes, eviction policies, replica counts and
/// store on/off.  (This also pins the documented claim that
/// `prefill_decode` routing degenerates to `round_robin` exactly
/// outside `--disagg`.)  Store-on cases keep the host tier comfortably
/// over-provisioned: cross-replica eviction-tie ordering under the
/// store's sub-window LRU is documented as schedule-dependent (see
/// `store::fence`), and this differential must not depend on it.
#[test]
fn prop_disagg_off_bit_identical() {
    use icarus::cluster::Cluster;
    use icarus::config::ClusterRouting;
    use icarus::ReplicaRole;
    for seed in 0..8u64 {
        let mut rng = Rng::new(19_000 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let eviction =
            if rng.bool(0.5) { EvictionPolicy::Recompute } else { EvictionPolicy::Swap };
        let replicas = 1 + rng.below(4) as usize;
        let n_models = 1 + rng.below(6) as usize;
        let host = if rng.bool(0.5) { 0 } else { 256 << 20 };
        let base = ServingConfig {
            mode,
            eviction,
            kv_pool_bytes: (8 + rng.below(48)) << 20,
            replicas,
            store_host_bytes: host,
            ..Default::default()
        };
        let knobs = ServingConfig {
            disagg: false,
            prefill_replicas: 1 + rng.below(7) as usize,
            cluster_routing: ClusterRouting::PrefillDecode,
            ..base.clone()
        };
        let wcfg = WorkloadConfig {
            n_models,
            qps: 0.3 + rng.f64(),
            n_requests: 24,
            seed: 600 + seed,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let (a, at) =
            Cluster::new(base, 2048, n_models).run_sim_traced(CostModel::default(), wl.clone());
        let (b, bt) =
            Cluster::new(knobs, 2048, n_models).run_sim_traced(CostModel::default(), wl);
        assert_eq!(a.merged, b.merged, "seed {seed}: stats must be bit-identical");
        assert_eq!(a.per_replica, b.per_replica, "seed {seed}: per-replica stats must match");
        assert_eq!(at.events, bt.events, "seed {seed}: trace must be bit-identical");
        assert!(
            b.roles.iter().all(|&r| r == ReplicaRole::Hybrid),
            "seed {seed}: no roles without --disagg"
        );
        assert!(!b.is_disaggregated(), "seed {seed}");
        assert_eq!(b.merged.prefill_handoffs, 0, "seed {seed}: handoff edge must stay cold");
        assert_eq!(b.merged.decode_handoffs, 0, "seed {seed}");
    }
}

/// Disaggregated runs conserve handoffs and respect publish causality,
/// across random tier splits, loads and seeds:
///
///   * every turn crosses the prefill→decode edge exactly once
///     (prefill handoffs == decode handoffs == completed turns —
///     preemption requeues re-admit locally rather than re-forwarding);
///   * consuming a handoff means restoring the published prefix over
///     the modeled transfer path, never re-prefilling it, and a
///     restore can only begin once the publish is visible through the
///     clock fence (`ClockFence` + the store's write-back horizon) —
///     observable as decode-tier store restores with the prefill tier
///     generating zero tokens and recording zero turn latencies;
///   * every pin taken at publish is released at consumption (the
///     pinned-block gauge drains to zero).
#[test]
fn prop_disagg_handoff_balance_and_causality() {
    use icarus::cluster::Cluster;
    use icarus::config::ClusterRouting;
    use icarus::ReplicaRole;
    for seed in 0..6u64 {
        let mut rng = Rng::new(20_000 + seed);
        let replicas = 2 + rng.below(3) as usize;
        let prefill_replicas = 1 + rng.below(replicas as u64 - 1) as usize;
        let scfg = ServingConfig {
            disagg: true,
            prefill_replicas,
            replicas,
            cluster_routing: ClusterRouting::PrefillDecode,
            kv_pool_bytes: 32 << 20,
            store_host_bytes: 512 << 20,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            n_models: 1 + rng.below(6) as usize,
            qps: 0.5 + rng.f64() * 2.0,
            n_requests: 32,
            seed: 700 + seed,
            ..Default::default()
        };
        let tag = format!("seed {seed} split {prefill_replicas}:{}", replicas - prefill_replicas);
        let out = Cluster::new(scfg, 2048, wcfg.n_models)
            .run_sim(CostModel::default(), generate(&wcfg));
        let expected_turns: u64 = generate(&wcfg).iter().map(|w| w.turns.len() as u64).sum();
        assert_eq!(out.merged.completed_requests, 32, "{tag}: completion");
        assert_eq!(out.merged.completed_turns, expected_turns, "{tag}: turns");
        assert_eq!(out.merged.prefill_handoffs, expected_turns, "{tag}: handoffs out");
        assert_eq!(out.merged.decode_handoffs, expected_turns, "{tag}: handoffs in");
        let prefill = out.merged_for_role(ReplicaRole::Prefill).expect("prefill tier");
        assert_eq!(prefill.generated_tokens, 0, "{tag}: prefill tier must not decode");
        assert_eq!(
            prefill.turn_latency.as_ref().unwrap().count(),
            0,
            "{tag}: prefill tier must not record decode latencies"
        );
        let decode = out.merged_for_role(ReplicaRole::Decode).expect("decode tier");
        assert_eq!(decode.completed_turns, expected_turns, "{tag}: decode tier owns turns");
        assert!(decode.store_restored_tokens > 0, "{tag}: handoffs must restore, not re-prefill");
        let st = out.store.as_ref().expect("disagg requires the store");
        assert_eq!(st.handoff_pins, expected_turns, "{tag}: one pin per handoff");
        assert_eq!(st.pinned_blocks, 0, "{tag}: every pin released at consumption");
    }
}

/// Executor invariants under seeded random task/timer workloads: every
/// spawned task completes (none leaks), every registered timer fires
/// exactly once (the wheel debug-asserts a double fire and panics on a
/// backwards clock), and the wheel drains to empty.  Tasks chain
/// sleeps through *unsorted* random deadlines — a hop into the past
/// must resolve on the next advance instead of hanging.
#[test]
fn prop_executor_invariants() {
    use icarus::runtime::exec::LocalExecutor;
    for seed in 0..12u64 {
        let mut rng = Rng::new(18_000 + seed);
        let mut rt = LocalExecutor::new();
        let n_tasks = 1 + rng.below(24) as usize;
        let horizon = 1.0 + rng.f64() * 9.0;
        for _ in 0..n_tasks {
            let timers = rt.timers();
            let hops: Vec<f64> = (0..1 + rng.below(5)).map(|_| rng.f64() * horizon).collect();
            rt.spawn(async move {
                for d in hops {
                    timers.sleep_until(d).await;
                }
            });
        }
        // Advance in random monotone increments past the horizon, then
        // drain hops registered during the final advances (re-advancing
        // at equal time fires past-deadline sleeps).
        let mut now = 0.0;
        while now < horizon {
            now += 1e-3 + rng.f64() * horizon / 4.0;
            rt.advance_to(now);
        }
        while let Some(t) = rt.next_deadline() {
            now = now.max(t);
            rt.advance_to(now);
        }
        let m = rt.metrics();
        assert_eq!(m.spawned, n_tasks as u64, "seed {seed}: spawn count");
        assert_eq!(m.completed, m.spawned, "seed {seed}: task leaked");
        assert_eq!(rt.live_tasks(), 0, "seed {seed}: live tasks after drain");
        assert_eq!(
            m.timers_fired,
            m.timers_registered,
            "seed {seed}: every timer fires exactly once"
        );
        assert!(rt.next_deadline().is_none(), "seed {seed}: wheel drained");
        assert!(m.polls >= m.spawned, "seed {seed}: every task polled at least once");
    }
}

/// The open-loop generator is a pure function of its config: same seed
/// gives a bit-identical workload (ids, arrival-time bits, prompts and
/// turn plans), a different seed shifts the arrival process, and a full
/// cluster run over the generated traffic is run-to-run deterministic
/// in both stats and trace — across user populations, tail indices,
/// diurnal amplitudes and replica counts.
#[test]
fn prop_openloop_deterministic() {
    use icarus::cluster::Cluster;
    use icarus::serve::{generate_open_loop, OpenLoopConfig};
    for seed in 0..6u64 {
        let mut rng = Rng::new(21_000 + seed);
        let cfg = OpenLoopConfig {
            base: WorkloadConfig {
                n_models: 1 + rng.below(6) as usize,
                qps: 0.5 + rng.f64() * 4.0,
                n_requests: 48,
                seed: 700 + seed,
                ..Default::default()
            },
            users: 1 + rng.below(1 << 16),
            pareto_alpha: 1.1 + rng.f64(),
            diurnal_amplitude: rng.f64() * 0.8,
            ..Default::default()
        };
        let a = generate_open_loop(&cfg);
        let b = generate_open_loop(&cfg);
        assert_eq!(a.len(), b.len(), "seed {seed}: workload length");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id, "seed {seed}: ids");
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "seed {seed}: arrival bits");
            assert_eq!(x.prompt.as_slice(), y.prompt.as_slice(), "seed {seed}: prompt");
            assert_eq!(x.turns.len(), y.turns.len(), "seed {seed}: turn count");
            for (t, u) in x.turns.iter().zip(&y.turns) {
                assert_eq!(t.model_id, u.model_id, "seed {seed}: routing");
                assert_eq!(t.gen_len, u.gen_len, "seed {seed}: gen plan");
                assert_eq!(t.obs, u.obs, "seed {seed}: observations");
                assert_eq!(t.think_s.to_bits(), u.think_s.to_bits(), "seed {seed}: think gaps");
            }
        }
        let reseeded = OpenLoopConfig {
            base: WorkloadConfig { seed: 7000 + seed, ..cfg.base.clone() },
            ..cfg.clone()
        };
        let c = generate_open_loop(&reseeded);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.arrival.to_bits() != y.arrival.to_bits()),
            "seed {seed}: a different seed must shift the arrival process"
        );
        let scfg = ServingConfig {
            replicas: 1 + rng.below(3) as usize,
            admit_queue: 32,
            ..Default::default()
        };
        let run = |wl| {
            Cluster::new(scfg.clone(), 2048, cfg.base.n_models)
                .run_sim_traced(CostModel::default(), wl)
        };
        let (s1, t1) = run(a);
        let (s2, t2) = run(b);
        assert_eq!(s1.merged, s2.merged, "seed {seed}: stats run-to-run deterministic");
        assert_eq!(s1.per_replica, s2.per_replica, "seed {seed}: per-replica stats");
        assert_eq!(t1.events, t2.events, "seed {seed}: trace run-to-run deterministic");
    }
}

/// The observability gate is provably inert: `--obs on` only
/// *observes* the schedule, so stats and trace at the same seed are
/// bit-identical to the off run modulo the data obs adds (per-model
/// phase histograms; per-turn breakdown fields), and the obs-off
/// results JSON keeps its exact pre-obs shape — no `phases`, no
/// `store_shards` keys, no recorders — across modes, store on/off,
/// overlap and replica counts.
#[test]
fn prop_obs_off_bit_identical() {
    use icarus::cluster::Cluster;
    for seed in 0..8u64 {
        let mut rng = Rng::new(23_000 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let base = ServingConfig {
            mode,
            kv_pool_bytes: (8 + rng.below(48)) << 20,
            replicas: 1 + rng.below(3) as usize,
            store_host_bytes: if rng.bool(0.5) { 0 } else { 256 << 20 },
            overlap: rng.bool(0.5),
            ..Default::default()
        };
        let obs_on = ServingConfig { obs: true, ..base.clone() };
        let wcfg = WorkloadConfig {
            n_models: 1 + rng.below(6) as usize,
            qps: 0.3 + rng.f64(),
            n_requests: 24,
            seed: 900 + seed,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let (off, off_t) = Cluster::new(base, 2048, wcfg.n_models)
            .run_sim_traced(CostModel::default(), wl.clone());
        let (on, on_t) =
            Cluster::new(obs_on, 2048, wcfg.n_models).run_sim_traced(CostModel::default(), wl);
        // Stats: identical except the phase histograms obs adds.
        assert!(off.merged.phases.is_empty(), "seed {seed}: no phase data off");
        assert!(!on.merged.phases.is_empty(), "seed {seed}: phase data on");
        let mut scrubbed = on.merged.clone();
        scrubbed.phases.clear();
        assert_eq!(off.merged, scrubbed, "seed {seed}: stats bit-identical modulo phases");
        for (o, n) in off.per_replica.iter().zip(&on.per_replica) {
            let mut n = n.clone();
            n.phases.clear();
            assert_eq!(*o, n, "seed {seed}: per-replica stats bit-identical modulo phases");
        }
        // Trace: identical except the per-turn breakdown fields.
        assert_eq!(off_t.events.len(), on_t.events.len(), "seed {seed}: trace length");
        for (o, n) in off_t.events.iter().zip(&on_t.events) {
            assert!(
                o.queue_wait == 0.0 && o.prefill_time == 0.0 && o.stall_time == 0.0,
                "seed {seed}: breakdown must stay zero with obs off"
            );
            let mut n = n.clone();
            n.queue_wait = 0.0;
            n.prefill_time = 0.0;
            n.stall_time = 0.0;
            assert_eq!(*o, n, "seed {seed}: trace bit-identical modulo breakdown");
        }
        // Off leaves no obs residue in the results JSON.
        assert!(off.obs.is_empty() && off.store_shards.is_empty(), "seed {seed}: no recorders");
        assert_eq!(on.obs.len(), on.per_replica.len(), "seed {seed}: one lane per replica");
        let off_json = off.to_json().to_string_pretty();
        assert!(
            !off_json.contains("phases") && !off_json.contains("store_shards"),
            "seed {seed}: obs-off JSON must keep its pre-obs shape"
        );
    }
}

/// The Perfetto export is a pure function of (config, workload): the
/// same seed yields a byte-identical trace file across runs *and*
/// across store shard counts — spans and counter tracks are keyed by
/// virtual time and engine-local values only, so lock striping (which
/// `prop_store_shards_bit_identical` already pins as stats-inert)
/// cannot leak into the timeline either.
#[test]
fn prop_obs_deterministic() {
    use icarus::cluster::Cluster;
    use icarus::obs::export_chrome_trace;
    for seed in 0..6u64 {
        let mut rng = Rng::new(24_000 + seed);
        let overlap = rng.bool(0.5);
        let qps = 0.5 + rng.f64();
        let n_models = 1 + rng.below(4) as usize;
        let mk = |shards: usize| {
            let scfg = ServingConfig {
                obs: true,
                replicas: 2,
                kv_pool_bytes: 16 << 20,
                store_host_bytes: 256 << 20,
                store_shards: shards,
                overlap,
                ..Default::default()
            };
            let wcfg = WorkloadConfig {
                n_models,
                qps,
                n_requests: 24,
                seed: 950 + seed,
                ..Default::default()
            };
            let out =
                Cluster::new(scfg, 2048, n_models).run_sim(CostModel::default(), generate(&wcfg));
            export_chrome_trace(&out.obs).to_string_pretty()
        };
        let a = mk(1);
        let b = mk(1);
        assert_eq!(a, b, "seed {seed}: export must be run-to-run byte-identical");
        let c = mk(4);
        assert_eq!(a, c, "seed {seed}: shard count must not leak into the timeline");
        assert!(a.contains("traceEvents"), "seed {seed}: export shape");
    }
}

/// Admission accounting conserves requests end to end: with the gate
/// enabled, every open-loop arrival reaches it (`submitted ==
/// n_requests`), every submitted request is either completed or
/// rejected — no accepted request is silently dropped — and the
/// per-replica counters sum to the merged ones, across random bounds,
/// loads, tails and replica counts.
#[test]
fn prop_serve_admission_conservation() {
    use icarus::cluster::Cluster;
    use icarus::serve::{generate_open_loop, OpenLoopConfig};
    for seed in 0..8u64 {
        let mut rng = Rng::new(22_000 + seed);
        let n_requests = 24 + rng.below(40) as usize;
        let n_models = 1 + rng.below(4) as usize;
        let mut scfg = ServingConfig {
            replicas: 1 + rng.below(4) as usize,
            admit_queue: if rng.bool(0.7) { 1 + rng.below(12) as usize } else { 0 },
            admit_tokens: if rng.bool(0.5) { 256 + rng.below(4096) as usize } else { 0 },
            ..Default::default()
        };
        if scfg.admit_queue + scfg.admit_tokens == 0 {
            scfg.admit_queue = 4; // keep the gate armed in every case
        }
        let tag = format!(
            "seed {seed} (R={} q={} tok={})",
            scfg.replicas, scfg.admit_queue, scfg.admit_tokens
        );
        let ocfg = OpenLoopConfig {
            base: WorkloadConfig {
                n_models,
                qps: 1.0 + rng.f64() * 7.0,
                n_requests,
                seed: 800 + seed,
                ..Default::default()
            },
            pareto_alpha: 1.1 + rng.f64(),
            ..Default::default()
        };
        let wl = generate_open_loop(&ocfg);
        let out = Cluster::new(scfg, 2048, n_models).run_sim(CostModel::default(), wl);
        let m = &out.merged;
        assert_eq!(m.submitted_requests, n_requests as u64, "{tag}: every arrival counted");
        assert_eq!(
            m.completed_requests + m.rejected_requests,
            m.submitted_requests,
            "{tag}: no accepted request may be silently dropped"
        );
        let sub: u64 = out.per_replica.iter().map(|r| r.submitted_requests).sum();
        let rej: u64 = out.per_replica.iter().map(|r| r.rejected_requests).sum();
        let comp: u64 = out.per_replica.iter().map(|r| r.completed_requests).sum();
        assert_eq!(
            (sub, rej, comp),
            (m.submitted_requests, m.rejected_requests, m.completed_requests),
            "{tag}: per-replica counters must sum to the merged ones"
        );
    }
}

/// The serving front end is provably inert when off: with both
/// admission bounds at the default 0 the gate counters stay 0 (so the
/// frozen-legacy differential above keeps pinning the default path to
/// the pre-front-end engine), and arming the gate with unreachably
/// large bounds changes nothing but the `submitted_requests` counter —
/// stats and trace otherwise bit-identical, across modes, eviction
/// policies and replica counts.
#[test]
fn prop_serve_off_bit_identical() {
    use icarus::cluster::Cluster;
    for seed in 0..8u64 {
        let mut rng = Rng::new(23_000 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let eviction =
            if rng.bool(0.5) { EvictionPolicy::Recompute } else { EvictionPolicy::Swap };
        let n_models = 1 + rng.below(5) as usize;
        let base = ServingConfig {
            mode,
            eviction,
            kv_pool_bytes: (8 + rng.below(48)) << 20,
            replicas: 1 + rng.below(4) as usize,
            ..Default::default()
        };
        let armed = ServingConfig {
            admit_queue: usize::MAX / 2,
            admit_tokens: usize::MAX / 2,
            ..base.clone()
        };
        let wcfg = WorkloadConfig {
            n_models,
            qps: 0.3 + rng.f64() * 2.0,
            n_requests: 24,
            seed: 900 + seed,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let (a, at) =
            Cluster::new(base, 2048, n_models).run_sim_traced(CostModel::default(), wl.clone());
        let (b, bt) =
            Cluster::new(armed, 2048, n_models).run_sim_traced(CostModel::default(), wl);
        assert_eq!(at.events, bt.events, "seed {seed}: trace bit-identical with the gate inert");
        assert_eq!(a.merged.submitted_requests, 0, "seed {seed}: gate off counts nothing");
        assert_eq!(a.merged.rejected_requests, 0, "seed {seed}: gate off rejects nothing");
        assert_eq!(b.merged.submitted_requests, 24, "seed {seed}: armed gate counts arrivals");
        assert_eq!(b.merged.rejected_requests, 0, "seed {seed}: unreachable bounds never shed");
        let mut bm = b.merged.clone();
        bm.submitted_requests = 0;
        assert_eq!(a.merged, bm, "seed {seed}: stats identical apart from the gate counter");
        let scrubbed: Vec<_> = b
            .per_replica
            .iter()
            .cloned()
            .map(|mut s| {
                s.submitted_requests = 0;
                s
            })
            .collect();
        assert_eq!(a.per_replica, scrubbed, "seed {seed}: per-replica stats identical");
    }
}
