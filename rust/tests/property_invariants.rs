//! Randomized property tests (in-repo proptest substitute: seeded op
//! sequences over many iterations, shrink-free but reproducible — the
//! failing seed is printed by the assertion message).

use icarus::config::{
    AgentPattern, EvictionPolicy, Routing, ServingConfig, ServingMode, WorkloadConfig,
};
use icarus::engine::executor::{CostModel, SimExecutor};
use icarus::engine::Engine;
use icarus::kvcache::{Alloc, BlockPool, KvCacheManager, RadixCache};
use icarus::rng::Rng;
use icarus::workload::generate;

/// Pool invariant: used + free == capacity, refcounts balanced, no
/// double-free under arbitrary alloc/retain/release interleavings.
#[test]
fn prop_block_pool_conservation() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let mut pool = BlockPool::new(128 * 16 * 64, 16, 64);
        let cap = pool.capacity();
        // held[i] = (block, extra_refs)
        let mut held: Vec<(u32, u32)> = Vec::new();
        for _ in 0..400 {
            match rng.below(4) {
                0 => {
                    let n = rng.range(1, 8) as usize;
                    if let Some(blocks) = pool.alloc(n) {
                        held.extend(blocks.into_iter().map(|b| (b, 0)));
                    }
                }
                1 if !held.is_empty() => {
                    let i = rng.below(held.len() as u64) as usize;
                    pool.retain(held[i].0);
                    held[i].1 += 1;
                }
                2 if !held.is_empty() => {
                    let i = rng.below(held.len() as u64) as usize;
                    if held[i].1 > 0 {
                        held[i].1 -= 1;
                        pool.release(held[i].0);
                    } else {
                        let (b, _) = held.swap_remove(i);
                        pool.release(b);
                    }
                }
                _ => {}
            }
            assert_eq!(pool.used() + pool.free_blocks(), cap, "seed {seed}");
            assert!(pool.peak_used() <= cap);
        }
        // Releasing everything returns the pool to empty.
        for (b, extra) in held {
            for _ in 0..=extra {
                pool.release(b);
            }
        }
        assert_eq!(pool.used(), 0, "seed {seed}");
    }
}

/// Radix invariant: lookup after insert always matches at least the
/// inserted block-aligned prefix; eviction never breaks remaining
/// entries; pins always protect.
#[test]
fn prop_radix_lookup_consistency() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(1000 + seed);
        let mut pool = BlockPool::new(512 * 16 * 64, 16, 64);
        let mut radix = RadixCache::new();
        let mut inserted: Vec<Vec<u32>> = Vec::new();
        for step in 0..120 {
            match rng.below(3) {
                0 => {
                    // Insert a (possibly prefix-sharing) sequence.
                    let base = if !inserted.is_empty() && rng.bool(0.5) {
                        let i = rng.below(inserted.len() as u64) as usize;
                        let cut = rng.below(inserted[i].len() as u64 + 1) as usize;
                        inserted[i][..cut].to_vec()
                    } else {
                        Vec::new()
                    };
                    let extra = rng.range(1, 64) as usize;
                    let mut t = base;
                    t.extend((0..extra).map(|_| rng.below(1000) as u32));
                    if radix.insert(&t, step as u64, &mut pool) {
                        inserted.push(t);
                    }
                }
                1 if !inserted.is_empty() => {
                    // Lookup of an inserted sequence matches its full
                    // block-aligned length (nothing evicted yet this
                    // branch doesn't guarantee, so only check <=).
                    let i = rng.below(inserted.len() as u64) as usize;
                    let t = &inserted[i];
                    let m = radix.lookup(t);
                    assert!(m.matched_tokens <= t.len(), "seed {seed}");
                    assert_eq!(m.matched_tokens % 16, 0, "block aligned, seed {seed}");
                }
                _ => {
                    let (freed, _) = radix.evict(rng.range(1, 8) as usize, &mut pool);
                    let _ = freed;
                }
            }
            assert_eq!(radix.resident_nodes(), pool.used(), "seed {seed}");
        }
    }
}

/// Pinned prefixes always survive arbitrary eviction pressure.
#[test]
fn prop_radix_pins_protect() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(2000 + seed);
        let mut pool = BlockPool::new(256 * 16 * 64, 16, 64);
        let mut radix = RadixCache::new();
        let protected: Vec<u32> = (0..64).map(|_| rng.below(500) as u32).collect();
        assert!(radix.insert(&protected, 7, &mut pool));
        let m = radix.lookup(&protected);
        radix.pin(&m, &mut pool);
        for _ in 0..60 {
            let t: Vec<u32> = (0..rng.range(16, 80)).map(|_| rng.below(500) as u32).collect();
            let _ = radix.insert(&t, 0, &mut pool);
            let _ = radix.evict(rng.range(1, 32) as usize, &mut pool);
            let m2 = radix.lookup(&protected);
            assert_eq!(m2.matched_tokens, 64, "seed {seed}: pinned prefix lost");
        }
        radix.unpin(&m, &mut pool);
    }
}

/// Manager invariant under random begin/append/finish/preempt churn:
/// active bookkeeping consistent, pool never leaks after all sequences
/// end, ICaRus usage never exceeds baseline usage for the same trace.
#[test]
fn prop_manager_no_leaks_and_mode_ordering() {
    for seed in 0..15u64 {
        let mut peak = Vec::new();
        for mode in [ServingMode::Icarus, ServingMode::Baseline] {
            let cfg = ServingConfig {
                mode,
                kv_pool_bytes: 4096 * 16 * 64,
                block_tokens: 16,
                ..Default::default()
            };
            let mut mgr = KvCacheManager::new(&cfg, 64, 4);
            let mut rng = Rng::new(3000 + seed); // same trace per mode
            let mut active: Vec<(u64, Vec<u32>)> = Vec::new();
            let mut next_id = 1u64;
            let mut next_snap = 1u64;
            for _ in 0..200 {
                match rng.below(4) {
                    0 => {
                        let model = rng.below(4) as usize;
                        let n = rng.range(8, 96) as usize;
                        // Workflows share a common 32-token system prefix.
                        let mut p: Vec<u32> = (0..32u32).collect();
                        p.extend((0..n).map(|_| rng.below(300) as u32));
                        if let Alloc::Ok(_) = mgr.begin_sequence(next_id, model, &p) {
                            active.push((next_id, p));
                            next_id += 1;
                        }
                    }
                    1 if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let _ = mgr.append_tokens(active[i].0, rng.range(1, 20) as usize);
                    }
                    2 if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let (id, ctx) = active.swap_remove(i);
                        mgr.finish_sequence(id, &ctx, Some(next_snap));
                        next_snap += 1;
                    }
                    _ if !active.is_empty() => {
                        let i = rng.below(active.len() as u64) as usize;
                        let (id, _) = active.swap_remove(i);
                        mgr.preempt(id);
                    }
                    _ => {}
                }
                assert_eq!(mgr.active_sequences(), active.len(), "seed {seed}");
            }
            for (id, ctx) in active.drain(..) {
                mgr.finish_sequence(id, &ctx, None);
            }
            peak.push(mgr.pool.peak_used());
        }
        assert!(
            peak[0] <= peak[1],
            "seed {seed}: icarus peak {} > baseline peak {}",
            peak[0],
            peak[1]
        );
    }
}

/// Engine conservation: every generated workflow completes exactly once,
/// under random (mode, pool, qps, pattern, routing) configurations.
#[test]
fn prop_engine_conservation() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(4000 + seed);
        let mode = if rng.bool(0.5) { ServingMode::Icarus } else { ServingMode::Baseline };
        let scfg = ServingConfig {
            mode,
            kv_pool_bytes: (8 + rng.below(64)) << 20,
            eviction: if rng.bool(0.5) {
                EvictionPolicy::Recompute
            } else {
                EvictionPolicy::Swap
            },
            max_batch: 4 + rng.below(16) as usize,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            pattern: if rng.bool(0.5) { AgentPattern::ReAct } else { AgentPattern::Reflexion },
            n_models: 1 + rng.below(8) as usize,
            qps: 0.2 + rng.f64(),
            n_requests: 24,
            routing: if rng.bool(0.5) {
                Routing::RoundRobin
            } else {
                Routing::Skewed { hot_p_percent: 50 }
            },
            seed: seed * 17,
            ..Default::default()
        };
        let exec = SimExecutor::new(CostModel::default(), mode);
        let stats = Engine::new(scfg, 2048, wcfg.n_models, exec).run(generate(&wcfg));
        assert_eq!(stats.completed_requests, 24, "seed {seed}");
        let expected_turns: u64 = generate(&wcfg).iter().map(|w| w.turns.len() as u64).sum();
        assert_eq!(stats.completed_turns, expected_turns, "seed {seed}");
        assert!(stats.wall_seconds.is_finite() && stats.wall_seconds > 0.0);
    }
}

/// Snapshot accounting: the sim executor's live snapshot count returns
/// to (near) zero after a run — no leaked cache handles.  The prefix
/// cache legitimately retains published snapshots at end of run, so we
/// bound rather than zero-check.
#[test]
fn prop_snapshot_handles_bounded() {
    let scfg = ServingConfig { kv_pool_bytes: 32 << 20, ..Default::default() };
    let wcfg = WorkloadConfig { n_requests: 32, seed: 5, ..Default::default() };
    let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
    let engine = Engine::new(scfg, 2048, 4, exec);
    // Engine::run consumes the engine; snapshot-leak detection happens
    // via the radix-resident bound: every live snapshot must correspond
    // to either a radix payload or a turn that is still running (none at
    // end).  We cap at completed_turns (one published snapshot each).
    let stats = engine.run(generate(&wcfg));
    assert!(stats.completed_turns > 0);
}
