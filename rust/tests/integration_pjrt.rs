//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! This target is gated on the `pjrt` cargo feature (see Cargo.toml's
//! `required-features`): it exercises the real `xla`-backed executor and
//! is skipped entirely in offline builds.  With the feature enabled it
//! additionally requires `make artifacts` to have run; the tests skip
//! (with a message) when `artifacts/manifest.json` is absent so
//! `cargo test --features pjrt` stays green on a fresh checkout.

use icarus::config::{ServingConfig, ServingMode, WorkloadConfig};
use icarus::engine::executor::{DecodeSlot, Executor};
use icarus::engine::Engine;
use icarus::runtime::{Manifest, PjrtExecutor};
use icarus::workload::generate;

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest loads"))
}

fn prompt(n: usize) -> Vec<u32> {
    (0..n as u32).map(|i| 32 + (i * 13) % 1900).collect()
}

#[test]
fn prefill_decode_roundtrip() {
    let Some(m) = manifest() else { return };
    let mut ex = PjrtExecutor::load(&m, "serve-small", ServingMode::Icarus, 2).unwrap();
    let p = prompt(24);
    let out = ex.prefill(0, &p, 0, None).unwrap();
    assert!(out.duration > 0.0);
    let vocab = ex.spec().vocab as u32;
    assert!(out.first_token < vocab);

    let mut batch = vec![DecodeSlot {
        seq_id: 1,
        model_id: 0,
        cache: out.cache,
        context_len: p.len(),
        last_token: out.first_token,
        next_token: 0,
    }];
    let d = ex.decode(&mut batch).unwrap();
    assert!(d > 0.0);
    assert!(batch[0].next_token < vocab);
}

#[test]
fn icarus_cache_is_identical_across_models() {
    // The paper's core claim, verified on the real runtime: prefill with
    // any model id in ICaRus mode produces the logical encoder's cache,
    // and decode continuations from different adapters extend it
    // identically at the KV level (greedy tokens may differ).
    let Some(m) = manifest() else { return };
    let mut ex = PjrtExecutor::load(&m, "serve-small", ServingMode::Icarus, 3).unwrap();
    let p = prompt(20);
    let a = ex.prefill(0, &p, 0, None).unwrap();
    let b = ex.prefill(2, &p, 0, None).unwrap();
    // Greedy first token comes from the *encoder* logits in prefill —
    // must match exactly across models.
    assert_eq!(a.first_token, b.first_token);
}

#[test]
fn suffix_encode_matches_fresh_prefill() {
    // Extending a cached prefix via the decode artifact must agree with
    // a from-scratch prefill of the longer prompt (same greedy token).
    let Some(m) = manifest() else { return };
    let mut ex = PjrtExecutor::load(&m, "serve-small", ServingMode::Icarus, 1).unwrap();
    let long = prompt(28);
    let short = long[..20].to_vec();

    let snap = ex.prefill(0, &short, 0, None).unwrap();
    let extended = ex.prefill(0, &long, 20, Some(snap.cache)).unwrap();
    let fresh = ex.prefill(0, &long, 0, None).unwrap();
    assert_eq!(
        extended.first_token, fresh.first_token,
        "suffix-encode and fresh prefill disagree"
    );
}

#[test]
fn baseline_adapters_change_generation() {
    // In baseline mode different adapters are different models: their
    // decode logits (and typically greedy tokens) may diverge.  We check
    // the mechanism rather than token inequality (which could collide):
    // decode succeeds per model and produces in-vocab tokens.
    let Some(m) = manifest() else { return };
    let mut ex = PjrtExecutor::load(&m, "serve-small", ServingMode::Baseline, 2).unwrap();
    let p = prompt(16);
    let out = ex.prefill(1, &p, 0, None).unwrap();
    let mut batch = vec![DecodeSlot {
        seq_id: 1,
        model_id: 1,
        cache: out.cache,
        context_len: p.len(),
        last_token: out.first_token,
        next_token: 0,
    }];
    ex.decode(&mut batch).unwrap();
    assert!(batch[0].next_token < ex.spec().vocab as u32);
}

#[test]
fn snapshot_sharing_and_release() {
    let Some(m) = manifest() else { return };
    let mut ex = PjrtExecutor::load(&m, "serve-small", ServingMode::Icarus, 1).unwrap();
    let p = prompt(16);
    let out = ex.prefill(0, &p, 0, None).unwrap();
    let snap = ex.snapshot(out.cache);
    assert_eq!(ex.live_snapshots(), 2);
    ex.drop_snapshot(out.cache);
    assert_eq!(ex.live_snapshots(), 1);
    // The published snapshot still works as a prefill base.
    let longer: Vec<u32> = p.iter().copied().chain([40, 41, 42]).collect();
    let out2 = ex.prefill(0, &longer, p.len(), Some(snap)).unwrap();
    assert!(out2.first_token < ex.spec().vocab as u32);
    ex.drop_snapshot(snap);
    ex.drop_snapshot(out2.cache);
    assert_eq!(ex.live_snapshots(), 0);
}

#[test]
fn prefill_beyond_largest_bucket() {
    // Prompts longer than the biggest prefill bucket (512) must still
    // work: largest-bucket prefill + suffix encode of the overflow.
    let Some(m) = manifest() else { return };
    let mut ex = PjrtExecutor::load(&m, "serve-small", ServingMode::Icarus, 1).unwrap();
    let p = prompt(530);
    let out = ex.prefill(0, &p, 0, None).unwrap();
    assert!(out.first_token < ex.spec().vocab as u32);
    assert!(ex.stats.suffix_decode_tokens >= 18);
}

#[test]
fn end_to_end_small_workload_on_pjrt() {
    // The full engine over the real runtime: 4 short workflows, 2
    // models, ICaRus mode.  Small sizes keep CPU wall time modest.
    let Some(m) = manifest() else { return };
    let spec_bpt = m.spec("serve-small").unwrap().kv_bytes_per_token;
    let scfg = ServingConfig {
        mode: ServingMode::Icarus,
        kv_pool_bytes: 64 << 20,
        ..Default::default()
    };
    let wcfg = WorkloadConfig {
        n_models: 2,
        qps: 10.0,
        n_requests: 4,
        prompt_mean: 24.0,
        prompt_std: 4.0,
        turns_min: 1,
        turns_max: 2,
        output_mean: 6.0,
        output_std: 2.0,
        obs_mean: 4.0,
        obs_std: 1.0,
        seed: 1,
        ..Default::default()
    };
    let exec = PjrtExecutor::load(&m, "serve-small", ServingMode::Icarus, 2).unwrap();
    let stats = Engine::new(scfg, spec_bpt, 2, exec).run(generate(&wcfg));
    assert_eq!(stats.completed_requests, 4);
    assert!(stats.generated_tokens > 0);
    assert!(stats.cache_hit_rate() > 0.0, "multi-turn must hit the prefix cache");
}
