//! Deterministic PRNG + the distributions the workload layer needs.
//!
//! `rand` is unavailable offline, so this is a small PCG64-family
//! generator (splitmix64-seeded xoshiro256**) with exponential / Poisson
//! / Zipf helpers.  Everything in the repo that uses randomness threads
//! one of these through explicitly — seeded runs are bit-reproducible.

/// xoshiro256** — fast, high-quality, tiny.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Generator seeded via splitmix64 (any seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Independent child stream (for per-request / per-agent rngs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's method without the rejection loop is fine here (n is
        // tiny vs 2^64; bias is immeasurable for simulation purposes).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate lambda (mean 1/lambda) — Poisson inter-
    /// arrival times for the QPS workload generator.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Pareto(alpha, x_m) via inverse transform — the heavy-tailed
    /// inter-arrival distribution of the open-loop traffic generator
    /// (`serve::openloop`).  The mean is `alpha * x_m / (alpha - 1)`
    /// for `alpha > 1` (infinite otherwise), so callers targeting a
    /// mean rate scale `x_m` accordingly; smaller `alpha` means
    /// burstier traffic.
    pub fn pareto(&mut self, alpha: f64, x_m: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        x_m / u.powf(1.0 / alpha)
    }

    /// Approximate bounded Zipf draw: a rank in [0, n) where rank k is
    /// ~proportional to 1/(k+1)^s, via inverse transform on the
    /// continuous CDF (exact in the large-n limit — fine for workload
    /// popularity skew, and O(1) per draw so a million-user population
    /// costs nothing).  Requires `s > 1` and `n >= 1`.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(s > 1.0 && n >= 1);
        let u = self.f64();
        // P(X <= x) = (1 - x^(1-s)) / (1 - n^(1-s)) over x in [1, n].
        let tail = 1.0 - (n as f64).powf(1.0 - s);
        let x = (1.0 - u * tail).powf(1.0 / (1.0 - s));
        (x.floor() as u64).clamp(1, n) - 1
    }

    /// Sample an index from explicit (unnormalized) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Gaussian via Box–Muller (used by length distributions).
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        mean + std * (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Integer sample from a clamped gaussian — token-length draws.
    pub fn len_sample(&mut self, mean: f64, std: f64, lo: u64, hi: u64) -> u64 {
        (self.gaussian(mean, std).round().max(lo as f64) as u64).min(hi)
    }

    /// Shuffle (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {}", mean);
    }

    #[test]
    fn pareto_mean_and_tail() {
        let mut r = Rng::new(23);
        let n = 200_000;
        // alpha=3, x_m=2 -> mean = 3*2/2 = 3.
        let xs: Vec<f64> = (0..n).map(|_| r.pareto(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean {mean}");
        // Every sample is at least x_m.
        assert!(xs.iter().all(|&x| x >= 2.0));
        // Heavy tail: the max is far above what an exponential with the
        // same mean would ever produce in n draws (~mean * ln n ≈ 37).
        let max = xs.iter().cloned().fold(0.0, f64::max);
        assert!(max > 60.0, "max {max} not heavy-tailed");
    }

    #[test]
    fn zipf_skewed_and_bounded() {
        let mut r = Rng::new(29);
        let n = 1000u64;
        let mut counts = vec![0usize; n as usize];
        for _ in 0..100_000 {
            let k = r.zipf(n, 1.5);
            assert!(k < n);
            counts[k as usize] += 1;
        }
        // Rank 0 dominates and the frequency decays with rank.
        assert!(counts[0] > counts[9] && counts[9] > counts[99]);
        assert!(counts[0] > 10 * counts[99], "{} vs {}", counts[0], counts[99]);
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {}", ratio);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(13);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.15);
    }
}
