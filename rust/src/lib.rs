//! # ICaRus — Identical Cache Reuse for Efficient Multi Model Inference
//!
//! Rust + JAX + Pallas reproduction of the ICaRus serving system
//! (Woo, Kil, et al., 2026).  Multiple task-specialized models share one
//! KV cache because only the frozen logical encoder (the base model)
//! ever writes cache entries; task adapters live purely in the logical
//! decoder.
//!
//! Three layers (see README.md §Architecture):
//!   * L1 — Pallas kernels (paired-query attention, fused ICaRusLinear),
//!     authored in `python/compile/kernels/`, verified against jnp
//!     oracles, AOT-lowered into the HLO artifacts.
//!   * L2 — the JAX transformer (`python/compile/model.py`), lowered once
//!     to HLO text per serving config.
//!   * L3 — this crate: the multi-model serving engine (paged KV cache,
//!     cross-model prefix caching, continuous batching with pluggable
//!     admission scheduling and chunked prefill — see `sched` — and
//!     agentic workload drivers), the multi-replica cluster layer that
//!     shards workflow streams across engines, the tiered KV snapshot
//!     store shared across replicas (see `store`), the per-replica
//!     cooperative task runtime that overlaps modeled store/swap
//!     transfers with compute (see `runtime::exec`; `--overlap on`),
//!     the serving front end — an Inference-Protocol-style HTTP
//!     service with streaming responses, admission control, and an
//!     open-loop heavy-tailed traffic generator (see `serve`) — the
//!     unified observability layer (deterministic virtual-time spans,
//!     Perfetto export, per-phase latency attribution; see `obs`;
//!     `--obs on`) — and the PJRT runtime that executes the artifacts.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! python invocation; the `icarus` binary is self-contained afterwards.
//!
//! Reproduction docs: EXPERIMENTS.md maps every paper figure to the
//! bench that regenerates it and records how the simulator is
//! calibrated against the real PJRT runtime.

#![warn(missing_docs)]

pub mod bench_util;
pub mod cluster;
pub mod config;
pub mod disagg;
pub mod engine;
pub mod json;
pub mod kvcache;
pub mod metrics;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod store;
pub mod tokenizer;
pub mod tokens;
pub mod trace;
pub mod workload;

pub use cluster::{Cluster, ClusterStats};
pub use disagg::ReplicaRole;
pub use config::{
    AgentPattern, ClusterRouting, EvictionPolicy, Routing, SchedPolicy, ServingConfig,
    ServingMode, WorkloadConfig,
};
pub use engine::executor::{CostModel, Executor, SimExecutor};
pub use engine::Engine;
pub use kvcache::KvCacheManager;
pub use metrics::ServingStats;
pub use obs::ObsRecorder;
pub use sched::Scheduler;
pub use serve::{AdmissionLimits, Frontend, LiveGate, OpenLoopConfig, OpenLoopGen};
pub use store::{SnapshotStore, StoreStats, StoreTier, TieredStore};
pub use tokens::TokenBuf;
