//! Multi-replica cluster serving: shard one workload across R engine
//! replicas and reconcile their stats.
//!
//! One [`Engine`] is single-threaded by design (a discrete-event loop
//! whose virtual clock advances by executor-reported durations).  The
//! cluster layer is how the system scales past one core: R replicas,
//! each on its own OS thread with its own [`KvCacheManager`] and KV
//! pool, serve disjoint shards of the workflow stream.  Workflows — not
//! turns — are the sharding unit: every turn of a workflow revisits its
//! accumulated context, so splitting a workflow across replicas would
//! forfeit exactly the intra-workflow prefix reuse ICaRus exists to
//! exploit.
//!
//! Routing ([`ClusterRouting`]) is pluggable:
//!
//!   * `RoundRobin` — workflow k to replica k mod R; uniform count.
//!   * `LeastLoaded` — greedy assignment on estimated token footprint
//!     (prompt + planned generation + observations); evens out skewed
//!     workflow sizes.
//!   * `HashPrefix` — hash the leading prompt blocks with the same
//!     rolling block hash the radix prefix cache uses, so workflows
//!     opening with the same context land on the replica that already
//!     holds that cache: the cluster-level analogue of ICaRus's
//!     cross-model reuse.  The hash spans up to
//!     [`HASH_PREFIX_BLOCKS`] blocks rather than only the first:
//!     real agent prompts open with a system preamble shared by *all*
//!     workflows, and hashing only that block would degenerate to
//!     routing every workflow to one replica.
//!
//! Clock reconciliation: each replica runs its own virtual timeline
//! with the original absolute arrival times, so per-replica stats are
//! directly comparable.  [`ServingStats::merge`] folds them into
//! cluster-level P50/P95/P99 (exact histogram merges), total
//! throughput, wall clock = slowest replica, and KV footprint = sum of
//! the per-replica pools.
//!
//! With `overlap` enabled in the config, each replica owns one pinned
//! cooperative task executor (`crate::runtime::exec`, built inside
//! `Engine::new` on the replica's own thread) that overlaps its
//! modeled store/swap transfers with compute.  The executor is as
//! replica-local as the KV pool — tasks never migrate — so the
//! [`ClockFence`] ordering between replicas is untouched: every store
//! operation still fences at the virtual clock it uses, whether the
//! transfer it prices is charged inline or flown as a task.
//!
//! [`KvCacheManager`]: crate::kvcache::KvCacheManager

use std::sync::Arc;
use std::thread;

use crate::config::{ClusterRouting, SchedPolicy, ServingConfig};
use crate::disagg::{DisaggHandle, DisaggShared, ReplicaRole};
use crate::engine::executor::{CostModel, Executor, SimExecutor};
use crate::engine::Engine;
use crate::json::{self, Value};
use crate::kvcache::block::{hash_block, ROOT_HASH};
use crate::metrics::ServingStats;
use crate::obs::ObsRecorder;
use crate::store::{ClockFence, ShardStats, SnapshotStore, StoreHandle, StoreStats, TieredStore};
use crate::trace::{Trace, TurnEvent};
use crate::workload::Workflow;

/// Prompt blocks covered by `HashPrefix` routing.  Wide enough to reach
/// past a shared system preamble (48 tokens at the default 16-token
/// blocks) into the first workflow-specific block, narrow enough that
/// workflows sharing a meaningful opening context still collide.
pub const HASH_PREFIX_BLOCKS: usize = 4;

/// Minimum prefill chunk forced onto prefill-role replicas under
/// `--disagg`: they exist to encode long prompts without head-of-line
/// blocking, so atomic prefill (or a degenerate chunk) would defeat the
/// point.  Decode-role and hybrid replicas keep the configured value.
pub const PREFILL_ROLE_CHUNK: usize = 256;

/// Replica index for every workflow in `workload`, under `routing`.
///
/// Pure function of the workload (not of arrival timing beyond its
/// order), so a cluster run is as reproducible as the single-engine
/// run: same seed, same assignment, same per-replica timelines.
pub fn assign_replicas(
    workload: &[Workflow],
    replicas: usize,
    routing: ClusterRouting,
    block_tokens: usize,
) -> Vec<usize> {
    let r = replicas.max(1);
    match routing {
        ClusterRouting::RoundRobin => (0..workload.len()).map(|i| i % r).collect(),
        // Workflow *ownership* under prefill/decode disaggregation is
        // plain round robin; the disagg-aware part — routing only
        // across the decode tier, with prefill replicas fed through the
        // handoff edge — lives in `Cluster::shard`, which passes this
        // function the decode-tier width.  Outside `--disagg` the
        // policy therefore degenerates to `RoundRobin` exactly.
        ClusterRouting::PrefillDecode => (0..workload.len()).map(|i| i % r).collect(),
        ClusterRouting::LeastLoaded => {
            let mut loads = vec![0u64; r];
            workload
                .iter()
                .map(|wf| {
                    let est = wf.prompt.len() as u64
                        + wf.turns.iter().map(|t| (t.gen_len + t.obs.len()) as u64).sum::<u64>();
                    let dst = (0..r).min_by_key(|&i| loads[i]).expect("r >= 1");
                    loads[dst] += est;
                    dst
                })
                .collect()
        }
        ClusterRouting::HashPrefix => workload
            .iter()
            .map(|wf| {
                let span = &wf.prompt[..wf.prompt.len().min(block_tokens * HASH_PREFIX_BLOCKS)];
                let mut h = ROOT_HASH;
                for chunk in span.chunks(block_tokens.max(1)) {
                    h = hash_block(h, chunk);
                }
                (h % r as u64) as usize
            })
            .collect(),
    }
}

/// Outcome of a cluster run: reconciled cluster-level stats plus the
/// per-replica breakdown.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    /// Cluster-level stats (see [`ServingStats::merge`] for semantics).
    /// With heterogeneous roles this is still the plain merge of every
    /// replica: counters stay run-wide totals, and the latency
    /// histograms are untainted because prefill-role replicas record no
    /// decode-side samples — but per-replica *averages* derived from it
    /// would be skewed by the prefill tier's zeroes; use
    /// [`ClusterStats::merged_for_role`] for those.
    pub merged: ServingStats,
    /// Each replica's own run stats, indexed by replica id.
    pub per_replica: Vec<ServingStats>,
    /// Role each replica played (all `Hybrid` outside `--disagg`),
    /// indexed by replica id.
    pub roles: Vec<ReplicaRole>,
    /// Aggregate counters of the shared snapshot store (`None` when the
    /// config leaves the store disabled).  Global, not per-replica —
    /// per-replica restore counters live in each `ServingStats`.
    pub store: Option<StoreStats>,
    /// Per-shard counters of the shared store's lock stripes — hits,
    /// publishes, evictions, lock takes/contention per stripe (see
    /// `store::ShardStats`).  Empty unless `--obs on` *and* the store is
    /// enabled, so the obs-off results JSON keeps its exact shape.
    pub store_shards: Vec<ShardStats>,
    /// Per-replica obs recorders in replica order (empty unless
    /// `--obs on`) — the input to [`crate::obs::export_chrome_trace`].
    pub obs: Vec<ObsRecorder>,
}

impl ClusterStats {
    fn from_replicas(
        per_replica: Vec<ServingStats>,
        roles: Vec<ReplicaRole>,
        store: Option<StoreStats>,
    ) -> ClusterStats {
        debug_assert_eq!(per_replica.len(), roles.len());
        let mut merged = ServingStats::new();
        for s in &per_replica {
            merged.merge(s);
        }
        ClusterStats {
            merged,
            per_replica,
            roles,
            store,
            store_shards: Vec::new(),
            obs: Vec::new(),
        }
    }

    /// True when this run's replicas play heterogeneous roles
    /// (`--disagg`): the per-role stat views are then meaningful.
    pub fn is_disaggregated(&self) -> bool {
        self.roles.iter().any(|&r| r != ReplicaRole::Hybrid)
    }

    /// Merge of only the replicas that played `role` — the honest
    /// basis for per-role reporting under `--disagg` (e.g. decode-tier
    /// P95 or prefill-tier token throughput), where the all-replica
    /// merge would average heterogeneous replicas together.  `None`
    /// when no replica played the role.
    pub fn merged_for_role(&self, role: ReplicaRole) -> Option<ServingStats> {
        if !self.roles.contains(&role) {
            return None;
        }
        let mut m = ServingStats::new();
        for (s, &r) in self.per_replica.iter().zip(&self.roles) {
            if r == role {
                m.merge(s);
            }
        }
        Some(m)
    }

    /// Merged stats plus the per-replica breakdown, for results files.
    /// Heterogeneous runs additionally carry the role map and per-role
    /// merged views; homogeneous output is byte-identical to before.
    pub fn to_json(&self) -> Value {
        let mut entries = vec![
            ("replicas", json::num(self.per_replica.len() as f64)),
            ("stats", self.merged.to_json()),
            (
                "per_replica",
                Value::Arr(self.per_replica.iter().map(ServingStats::to_json).collect()),
            ),
        ];
        if self.is_disaggregated() {
            entries.push((
                "roles",
                Value::Arr(self.roles.iter().map(|r| json::s(r.as_str())).collect()),
            ));
            let mut per_role = Vec::new();
            for role in [ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Hybrid] {
                if let Some(m) = self.merged_for_role(role) {
                    per_role.push((role.as_str(), m.to_json()));
                }
            }
            entries.push(("per_role", json::obj(per_role)));
        }
        if let Some(store) = &self.store {
            entries.push(("store", store.to_json()));
        }
        if !self.store_shards.is_empty() {
            entries.push((
                "store_shards",
                Value::Arr(self.store_shards.iter().map(ShardStats::to_json).collect()),
            ));
        }
        json::obj(entries)
    }
}

/// A fixed fleet of engine replicas serving sharded workloads.
///
/// Construction is cheap (no threads are held between runs); each call
/// to a `run_*` method spawns one OS thread per replica, runs every
/// shard to completion and reconciles the results.
///
/// ```
/// use icarus::cluster::Cluster;
/// use icarus::config::ServingConfig;
/// use icarus::engine::executor::CostModel;
/// use icarus::config::WorkloadConfig;
/// use icarus::workload::generate;
///
/// let scfg = ServingConfig { replicas: 2, ..Default::default() };
/// let wl = generate(&WorkloadConfig { n_requests: 8, ..Default::default() });
/// let out = Cluster::new(scfg, 2048, 4).run_sim(CostModel::default(), wl);
/// assert_eq!(out.merged.completed_requests, 8);
/// assert_eq!(out.per_replica.len(), 2);
/// ```
pub struct Cluster {
    scfg: ServingConfig,
    kv_bytes_per_token: u64,
    n_models: usize,
}

impl Cluster {
    /// A cluster of `scfg.replicas` engines, each configured exactly
    /// like the single engine `Engine::new(scfg, ..)` would be.
    pub fn new(scfg: ServingConfig, kv_bytes_per_token: u64, n_models: usize) -> Self {
        Cluster { scfg, kv_bytes_per_token, n_models }
    }

    /// Number of replicas this cluster runs (at least 1).
    pub fn replicas(&self) -> usize {
        self.scfg.replicas.max(1)
    }

    /// Prefill-role replicas under `--disagg`; 0 in homogeneous mode.
    /// Clamped so at least one replica serves each role.
    pub fn prefill_count(&self) -> usize {
        if !self.scfg.disagg {
            return 0;
        }
        let r = self.replicas();
        assert!(r >= 2, "disaggregation requires at least 2 replicas");
        self.scfg.prefill_replicas.clamp(1, r - 1)
    }

    /// Role each replica index plays: replicas `0..prefill_count()` are
    /// prefill, the rest decode; all hybrid outside `--disagg`.
    pub fn roles(&self) -> Vec<ReplicaRole> {
        let p = self.prefill_count();
        (0..self.replicas())
            .map(|i| {
                if p == 0 {
                    ReplicaRole::Hybrid
                } else if i < p {
                    ReplicaRole::Prefill
                } else {
                    ReplicaRole::Decode
                }
            })
            .collect()
    }

    fn shard(&self, workload: Vec<Workflow>) -> Vec<Vec<Workflow>> {
        let r = self.replicas();
        let prefill = self.prefill_count();
        // Disagg: workflows are owned by the decode tier only — route
        // across it with the configured policy (prefill replicas get
        // their work over the handoff edge, not from the router) and
        // leave the prefill shards empty.  `prefill == 0` reduces to
        // the homogeneous path untouched.
        let decode = r - prefill;
        let assignment =
            assign_replicas(&workload, decode, self.scfg.cluster_routing, self.scfg.block_tokens);
        let mut shards: Vec<Vec<Workflow>> = (0..r).map(|_| Vec::new()).collect();
        for (wf, &rep) in workload.into_iter().zip(&assignment) {
            shards[prefill + rep].push(wf);
        }
        shards
    }

    /// The shared tiered snapshot store this cluster's config asks for
    /// (`None` with both budgets zero — the store then stays entirely
    /// out of the engines' code paths).  Lock striping defaults to
    /// [`TieredStore::auto_shards`] over the replica count;
    /// `--store-shards` overrides (rounded up to a power of two).
    /// Either way stats and traces are shard-count-invariant — the knob
    /// only moves lock contention.
    fn make_store(&self) -> Option<Arc<TieredStore>> {
        if self.scfg.store_host_bytes + self.scfg.store_disk_bytes == 0 {
            return None;
        }
        let shards = match self.scfg.store_shards {
            0 => TieredStore::auto_shards(self.replicas()),
            n => n,
        };
        Some(Arc::new(TieredStore::with_shards(
            self.scfg.store_host_bytes,
            self.scfg.store_disk_bytes,
            self.scfg.block_tokens,
            self.kv_bytes_per_token,
            shards,
        )))
    }

    /// Spawn one scoped thread per shard, build a fresh engine on each
    /// with `factory`, drive it with `run`, and join the results in
    /// replica order.  The one place replica threads are constructed —
    /// traced and untraced runs differ only in the closure they pass.
    /// With a shared `store`, every engine gets a per-replica handle
    /// plus a common [`ClockFence`] so cross-replica store visibility
    /// is causal in virtual time.
    fn run_replicas<T, E, F, G>(
        &self,
        store: &Option<Arc<TieredStore>>,
        factory: F,
        workload: Vec<Workflow>,
        run: G,
    ) -> Vec<T>
    where
        T: Send,
        E: Executor,
        F: Fn() -> E + Sync,
        G: Fn(Engine<E>, Vec<Workflow>) -> T + Sync,
    {
        let prefill = self.prefill_count();
        let disagg = if prefill > 0 {
            assert!(
                store.is_some(),
                "disaggregation requires a shared store (non-zero --store-host/--store-disk): \
                 the handoff artifact is the published KV prefix"
            );
            // Every turn of every workflow crosses the handoff edge
            // exactly once — the run-wide termination token for the
            // prefill tier.
            let total_turns: usize = workload.iter().map(|wf| wf.turns.len()).sum();
            Some(DisaggShared::new(self.replicas(), prefill, total_turns))
        } else {
            None
        };
        let shards = self.shard(workload);
        let fence = match store {
            Some(_) if shards.len() > 1 => Some(Arc::new(ClockFence::new(shards.len()))),
            _ => None,
        };
        thread::scope(|s| {
            let handles: Vec<_> = shards
                .into_iter()
                .enumerate()
                .map(|(replica, shard)| {
                    let factory = &factory;
                    let run = &run;
                    let store = store.clone();
                    let fence = fence.clone();
                    let disagg = disagg.clone();
                    s.spawn(move || {
                        let role = match &disagg {
                            Some(_) if replica < prefill => ReplicaRole::Prefill,
                            Some(_) => ReplicaRole::Decode,
                            None => ReplicaRole::Hybrid,
                        };
                        let mut scfg = self.scfg.clone();
                        if role == ReplicaRole::Prefill {
                            // The prefill tier's whole job is encoding
                            // long prompts side by side: force chunked
                            // prefill and shortest-job-first over the
                            // handoff backlog.
                            scfg.prefill_chunk = scfg.prefill_chunk.max(PREFILL_ROLE_CHUNK);
                            scfg.sched_policy = SchedPolicy::Sjf;
                        }
                        let mut engine = Engine::new(
                            scfg,
                            self.kv_bytes_per_token,
                            self.n_models,
                            factory(),
                        );
                        // Obs lanes are keyed by replica id (no-op off).
                        engine.set_obs_replica(replica);
                        if let Some(st) = store {
                            let st: Arc<dyn SnapshotStore> = st;
                            engine.attach_store(StoreHandle::new(st, fence, replica));
                        }
                        if let Some(shared) = disagg {
                            engine.attach_disagg(DisaggHandle::new(shared, replica, role));
                        }
                        run(engine, shard)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("replica thread panicked")).collect()
        })
    }

    /// Per-shard store counters for the results JSON — collected only
    /// under `--obs` (they are diagnostics; the obs-off JSON keeps its
    /// exact pre-obs shape), and only when the store exists.
    fn collect_shard_stats(&self, store: &Option<Arc<TieredStore>>) -> Vec<ShardStats> {
        match store {
            Some(st) if self.scfg.obs => st.shard_stats(),
            _ => Vec::new(),
        }
    }

    /// Run the workload across the replica fleet, building one executor
    /// per replica with `factory`.  Blocks until every replica drains.
    pub fn run_with<E, F>(&self, factory: F, workload: Vec<Workflow>) -> ClusterStats
    where
        E: Executor,
        F: Fn() -> E + Sync,
    {
        let store = self.make_store();
        let outcomes = self.run_replicas(&store, factory, workload, |e, w| e.run_obs(w));
        let mut per_replica = Vec::with_capacity(outcomes.len());
        let mut obs = Vec::new();
        for (stats, rec) in outcomes {
            per_replica.push(stats);
            obs.extend(rec);
        }
        let store_shards = self.collect_shard_stats(&store);
        let mut out =
            ClusterStats::from_replicas(per_replica, self.roles(), store.map(|s| s.stats()));
        out.store_shards = store_shards;
        out.obs = obs;
        out
    }

    /// Like [`Cluster::run_with`], but each replica also records a
    /// per-turn trace; the merged trace is reconciled into one global
    /// completion-ordered timeline.
    pub fn run_with_traced<E, F>(
        &self,
        factory: F,
        workload: Vec<Workflow>,
    ) -> (ClusterStats, Trace)
    where
        E: Executor,
        F: Fn() -> E + Sync,
    {
        let store = self.make_store();
        let outcomes = self.run_replicas(&store, factory, workload, |e, w| e.run_traced_obs(w));
        let mut per_replica = Vec::with_capacity(outcomes.len());
        let mut events: Vec<TurnEvent> = Vec::new();
        let mut obs = Vec::new();
        for (stats, trace, rec) in outcomes {
            per_replica.push(stats);
            events.extend(trace.events);
            obs.extend(rec);
        }
        // Reconcile the per-replica virtual clocks into one timeline.
        // The sort is stable, so a single replica's trace (already in
        // completion order) passes through unchanged.
        events.sort_by(|a, b| a.completed_at.total_cmp(&b.completed_at));
        let store_shards = self.collect_shard_stats(&store);
        let mut out =
            ClusterStats::from_replicas(per_replica, self.roles(), store.map(|s| s.stats()));
        out.store_shards = store_shards;
        out.obs = obs;
        (out, Trace { events })
    }

    /// Run with one [`SimExecutor`] per replica — the configuration the
    /// sweep benches use.
    pub fn run_sim(&self, cost: CostModel, workload: Vec<Workflow>) -> ClusterStats {
        let mode = self.scfg.mode;
        self.run_with(move || SimExecutor::new(cost.clone(), mode), workload)
    }

    /// Traced variant of [`Cluster::run_sim`].
    pub fn run_sim_traced(
        &self,
        cost: CostModel,
        workload: Vec<Workflow>,
    ) -> (ClusterStats, Trace) {
        let mode = self.scfg.mode;
        self.run_with_traced(move || SimExecutor::new(cost.clone(), mode), workload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ServingMode, WorkloadConfig};
    use crate::workload::generate;

    fn workload(n: usize, qps: f64, seed: u64) -> Vec<Workflow> {
        generate(&WorkloadConfig { n_requests: n, qps, seed, ..Default::default() })
    }

    #[test]
    fn replicas_1_bit_identical_to_single_engine() {
        let wl = workload(32, 0.8, 21);
        let scfg = ServingConfig { replicas: 1, ..Default::default() };

        let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let (single, single_trace) =
            Engine::new(scfg.clone(), 2048, 4, exec).run_traced(wl.clone());

        let cluster = Cluster::new(scfg, 2048, 4);
        let (out, trace) = cluster.run_sim_traced(CostModel::default(), wl);
        assert_eq!(out.merged, single, "merged stats must be bit-identical");
        assert_eq!(out.per_replica.len(), 1);
        assert_eq!(out.per_replica[0], single);
        assert_eq!(trace.events, single_trace.events, "trace must be bit-identical");
    }

    #[test]
    fn all_workflows_complete_across_replicas() {
        for routing in [
            ClusterRouting::RoundRobin,
            ClusterRouting::LeastLoaded,
            ClusterRouting::HashPrefix,
        ] {
            let scfg =
                ServingConfig { replicas: 4, cluster_routing: routing, ..Default::default() };
            let cluster = Cluster::new(scfg, 2048, 4);
            let out = cluster.run_sim(CostModel::default(), workload(64, 1.0, 3));
            assert_eq!(out.merged.completed_requests, 64, "{routing:?}");
            assert_eq!(out.per_replica.len(), 4);
            let sum: u64 = out.per_replica.iter().map(|s| s.completed_requests).sum();
            assert_eq!(sum, 64);
        }
    }

    #[test]
    fn round_robin_assignment_cycles() {
        let wl = workload(10, 1.0, 0);
        let a = assign_replicas(&wl, 3, ClusterRouting::RoundRobin, 16);
        assert_eq!(a, vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn least_loaded_uses_every_replica_and_balances() {
        let wl = workload(64, 1.0, 7);
        let a = assign_replicas(&wl, 4, ClusterRouting::LeastLoaded, 16);
        let mut loads = vec![0u64; 4];
        for (wf, &rep) in wl.iter().zip(&a) {
            loads[rep] += wf.prompt.len() as u64
                + wf.turns.iter().map(|t| (t.gen_len + t.obs.len()) as u64).sum::<u64>();
        }
        assert!(loads.iter().all(|&l| l > 0), "every replica used: {loads:?}");
        let max = *loads.iter().max().unwrap() as f64;
        let min = *loads.iter().min().unwrap() as f64;
        assert!(max / min < 1.5, "estimated load imbalance: {loads:?}");
    }

    #[test]
    fn hash_prefix_is_deterministic_and_prefix_keyed() {
        let wl = workload(48, 1.0, 9);
        let a = assign_replicas(&wl, 4, ClusterRouting::HashPrefix, 16);
        let b = assign_replicas(&wl, 4, ClusterRouting::HashPrefix, 16);
        assert_eq!(a, b);
        assert!(a.iter().all(|&r| r < 4));
        // Two workflows with identical leading blocks land together.
        let mut wl2 = wl.clone();
        wl2[1].prompt = wl[0].prompt.clone();
        let c = assign_replicas(&wl2, 4, ClusterRouting::HashPrefix, 16);
        assert_eq!(c[0], c[1], "identical prefixes colocate");
        // The synthetic workload's unique bodies must spread the fleet
        // (i.e. the hash reaches past the shared 48-token preamble).
        let used: std::collections::BTreeSet<usize> = a.into_iter().collect();
        assert!(used.len() > 1, "hash-prefix routing degenerated to one replica");
    }

    #[test]
    fn sched_policy_and_chunking_thread_through_replicas() {
        use crate::config::SchedPolicy;
        for policy in [SchedPolicy::Fcfs, SchedPolicy::CacheAware, SchedPolicy::Sjf] {
            let scfg = ServingConfig {
                replicas: 3,
                sched_policy: policy,
                prefill_chunk: 128,
                ..Default::default()
            };
            let cluster = Cluster::new(scfg, 2048, 4);
            let out = cluster.run_sim(CostModel::default(), workload(48, 1.0, 17));
            assert_eq!(out.merged.completed_requests, 48, "{policy:?}");
            assert!(
                out.merged.prefill_chunks > 0,
                "{policy:?}: every replica must run chunked prefill"
            );
            assert!(
                out.per_replica.iter().all(|s| s.prefill_chunks > 0),
                "{policy:?}: chunk counts must come from every replica"
            );
        }
    }

    #[test]
    fn shared_store_cross_replica_hits_beat_hash_prefix_affinity() {
        // Workflow groups share a long identical opening (system
        // prompt + retrieval doc); tails are unique.  Round-robin
        // scatters every group across all four replicas, so without a
        // store each replica re-prefills the opening cold;
        // hash-prefix affinity instead colocates each group on one
        // replica (the PR-3 answer, at the price of imbalance).  The
        // shared snapshot store gives plain round robin the reuse AND
        // the balance: a context prefilled on replica 0 is a warm
        // transfer-priced hit on replicas 1..3.
        let mut wl = workload(48, 0.8, 41);
        let groups = 5u32; // coprime with 4 replicas: groups spread
        for (i, wf) in wl.iter_mut().enumerate() {
            let g = i as u32 % groups;
            let mut p: Vec<u32> =
                (0..512u32).map(|t| 32 + ((t * 37 + g * 7919) % 1900)).collect();
            p.extend((0..32u32).map(|t| 32 + ((t * 13 + i as u32 * 101) % 1900)));
            wf.prompt = p.into();
        }
        let mk = |routing: ClusterRouting, host_bytes: u64| {
            let scfg = ServingConfig {
                replicas: 4,
                cluster_routing: routing,
                kv_pool_bytes: 32 << 20,
                store_host_bytes: host_bytes,
                ..Default::default()
            };
            Cluster::new(scfg, 2048, 4).run_sim(CostModel::default(), wl.clone())
        };
        let store_rr = mk(ClusterRouting::RoundRobin, 512 << 20);
        let affinity = mk(ClusterRouting::HashPrefix, 0);
        assert_eq!(store_rr.merged.completed_requests, 48);
        assert_eq!(affinity.merged.completed_requests, 48);
        assert!(
            store_rr.merged.store_remote_hits > 0,
            "a context prefilled on one replica must hit on another"
        );
        let st = store_rr.store.as_ref().expect("store stats present");
        assert!(st.remote_hits > 0 && st.publishes > 0);
        assert!(affinity.store.is_none(), "baseline runs store-less");
        let p_rr = store_rr.merged.turn_latency.as_ref().unwrap().p95();
        let p_aff = affinity.merged.turn_latency.as_ref().unwrap().p95();
        assert!(
            p_rr <= p_aff,
            "shared-store round robin must match prefix affinity: {p_rr} vs {p_aff}"
        );
    }

    #[test]
    fn replicas_cut_tail_latency_under_pressure() {
        // Baseline mode, 8 models, small pool: one engine thrashes its
        // KV pool and queues; four replicas each see a quarter of the
        // load with a full pool of their own.
        let wcfg = WorkloadConfig {
            n_models: 8,
            qps: 2.0,
            n_requests: 96,
            seed: 5,
            ..Default::default()
        };
        let wl = generate(&wcfg);
        let mk = |replicas: usize| {
            let scfg = ServingConfig {
                mode: ServingMode::Baseline,
                replicas,
                kv_pool_bytes: 16 << 20,
                ..Default::default()
            };
            Cluster::new(scfg, 2048, 8).run_sim(CostModel::default(), wl.clone())
        };
        let r1 = mk(1);
        let r4 = mk(4);
        assert_eq!(r4.merged.completed_requests, r1.merged.completed_requests);
        let p1 = r1.merged.turn_latency.as_ref().unwrap().p95();
        let p4 = r4.merged.turn_latency.as_ref().unwrap().p95();
        assert!(p4 < p1, "4 replicas should cut P95 under load: {p4} vs {p1}");
        // The fleet's memory footprint is additive.
        assert!(r4.merged.peak_kv_bytes >= r1.merged.peak_kv_bytes);
    }

    #[test]
    fn overlap_threads_through_replicas_with_shared_store() {
        // Each replica pins its own cooperative executor; the shared
        // store still fences between them.  Small per-replica pools +
        // Recompute eviction force store traffic that the overlap
        // runtime can hide behind other sequences' steps.
        let scfg = ServingConfig {
            replicas: 4,
            kv_pool_bytes: 12 << 20,
            store_host_bytes: 256 << 20,
            store_prefetch: true,
            overlap: true,
            ..Default::default()
        };
        let cluster = Cluster::new(scfg, 2048, 4);
        let out = cluster.run_sim(CostModel::default(), workload(48, 1.2, 29));
        assert_eq!(out.merged.completed_requests, 48);
        assert!(out.merged.store_hits > 0, "store traffic expected at this pool size");
        assert!(
            out.merged.tasks_spawned > 0,
            "every replica's executor should have flown transfer tasks"
        );
        assert!(
            out.merged.overlapped_transfer_time > 0.0,
            "some transfer time must hide behind compute"
        );
        // Merged counters are sums of per-replica counters.
        let sum: u64 = out.per_replica.iter().map(|s| s.tasks_spawned).sum();
        assert_eq!(out.merged.tasks_spawned, sum);
    }

    #[test]
    fn disaggregated_cluster_completes_and_hands_off() {
        let scfg = ServingConfig {
            replicas: 4,
            disagg: true,
            prefill_replicas: 2,
            cluster_routing: ClusterRouting::PrefillDecode,
            kv_pool_bytes: 32 << 20,
            store_host_bytes: 512 << 20,
            ..Default::default()
        };
        let cluster = Cluster::new(scfg, 2048, 4);
        assert_eq!(
            cluster.roles(),
            vec![
                ReplicaRole::Prefill,
                ReplicaRole::Prefill,
                ReplicaRole::Decode,
                ReplicaRole::Decode
            ]
        );
        let out = cluster.run_sim(CostModel::default(), workload(48, 1.0, 19));
        assert_eq!(out.merged.completed_requests, 48);
        assert!(out.is_disaggregated());
        // Every turn crossed the edge exactly once, in each direction.
        let handed: u64 = out.per_replica.iter().map(|s| s.prefill_handoffs).sum();
        let consumed: u64 = out.per_replica.iter().map(|s| s.decode_handoffs).sum();
        assert_eq!(handed, out.merged.completed_turns, "one handoff per turn");
        assert_eq!(consumed, out.merged.completed_turns, "every handoff consumed");
        // Role separation holds all the way down.
        for (s, &r) in out.per_replica.iter().zip(&out.roles) {
            match r {
                ReplicaRole::Prefill => {
                    assert_eq!(s.generated_tokens, 0, "prefill replicas never decode");
                    assert!(s.prefill_handoffs > 0, "round robin feeds both prefills");
                    assert!(s.prefill_chunks > 0, "prefill tier runs chunked");
                }
                ReplicaRole::Decode => {
                    assert!(s.generated_tokens > 0, "decode tier decodes");
                    assert!(s.decode_handoffs > 0, "decode tier consumes handoffs");
                }
                ReplicaRole::Hybrid => unreachable!("disagg run has no hybrids"),
            }
        }
        // Handed-off prefixes came back over the store's transfer path,
        // not via local re-prefill, and the pin ledger closed out.
        let decode = out.merged_for_role(ReplicaRole::Decode).expect("decode tier present");
        assert!(decode.store_restored_tokens > 0, "handoffs restore from the store");
        assert!(decode.turn_latency.as_ref().unwrap().count() > 0);
        let prefill = out.merged_for_role(ReplicaRole::Prefill).expect("prefill tier present");
        assert_eq!(prefill.turn_latency.as_ref().unwrap().count(), 0);
        let st = out.store.as_ref().expect("disagg requires the store");
        assert!(st.handoff_pins > 0, "handoff chains were pinned");
        assert_eq!(st.pinned_blocks, 0, "every handoff pin released by run end");
    }

    #[test]
    fn disagg_off_ignores_prefill_replica_knob() {
        // The knob is inert without --disagg: same roles, same stats.
        let wl = workload(24, 1.0, 23);
        let mk = |prefill_replicas: usize| {
            let scfg = ServingConfig {
                replicas: 2,
                prefill_replicas,
                store_host_bytes: 128 << 20,
                ..Default::default()
            };
            Cluster::new(scfg, 2048, 4).run_sim(CostModel::default(), wl.clone())
        };
        let a = mk(1);
        let b = mk(7);
        assert_eq!(a.roles, vec![ReplicaRole::Hybrid; 2]);
        assert!(!a.is_disaggregated());
        assert_eq!(a.merged, b.merged);
        assert_eq!(a.merged_for_role(ReplicaRole::Decode), None);
    }

    #[test]
    fn obs_threads_through_replicas_and_stays_empty_when_off() {
        let wl = workload(24, 1.0, 11);
        let scfg = ServingConfig {
            replicas: 2,
            obs: true,
            store_host_bytes: 128 << 20,
            ..Default::default()
        };
        let out = Cluster::new(scfg, 2048, 4).run_sim(CostModel::default(), wl.clone());
        assert_eq!(out.obs.len(), 2, "one recorder per replica");
        let lanes: Vec<usize> = out.obs.iter().map(|r| r.replica()).collect();
        assert_eq!(lanes, vec![0, 1], "recorders tagged in replica order");
        assert!(out.obs.iter().all(|r| !r.spans().is_empty()), "every lane recorded");
        assert!(!out.store_shards.is_empty(), "per-shard counters collected under obs");
        assert!(out.to_json().to_string_pretty().contains("store_shards"));
        // Off (default): no recorders, no shard block, JSON shape as
        // before the obs layer existed.
        let scfg =
            ServingConfig { replicas: 2, store_host_bytes: 128 << 20, ..Default::default() };
        let out = Cluster::new(scfg, 2048, 4).run_sim(CostModel::default(), wl);
        assert!(out.obs.is_empty() && out.store_shards.is_empty());
        assert!(!out.to_json().to_string_pretty().contains("store_shards"));
    }

    #[test]
    fn merged_wall_clock_is_slowest_replica() {
        let scfg = ServingConfig { replicas: 3, ..Default::default() };
        let cluster = Cluster::new(scfg, 2048, 4);
        let out = cluster.run_sim(CostModel::default(), workload(48, 1.0, 13));
        let max_wall = out.per_replica.iter().map(|s| s.wall_seconds).fold(0.0f64, f64::max);
        assert_eq!(out.merged.wall_seconds, max_wall);
    }
}
