//! Radix (block-granular trie) prefix cache, one tree per namespace.
//!
//! Mirrors vLLM/SGLang prefix caching: completed contexts are inserted
//! at block granularity; new prompts walk the trie to find the longest
//! cached prefix.  Nodes carry an opaque `payload` the engine uses to
//! locate the device-side cache snapshot for the matched context.
//!
//! In ICaRus mode every model shares namespace 0 — a context produced
//! while serving model A is a cache hit for model B (the paper's
//! cross-model prefix caching).  In baseline mode each model gets its own
//! tree and re-prefills identical prompts (the paper's Fig 1a problem).
//!
//! Hot-path layout (production scale; `benches/micro_hotpath.rs` has the
//! radix-churn numbers):
//!
//!   * Children are indexed by `(parent, rolling block hash)` in one
//!     flat `HashMap` — a lookup is O(blocks) hash probes, with token
//!     comparison only to reject hash collisions (vLLM-style block
//!     hashing instead of per-node candidate scans).
//!   * Eviction candidates live in lazily-invalidated min-heaps keyed on
//!     `(last_access, creation order)`, maintained incrementally on
//!     insert/touch/pin/unpin/evict — evicting one block is O(log n),
//!     not an O(nodes) arena scan per block.
//!   * Dead nodes are recycled through a free list, so long-running
//!     churn does not grow the arena without bound.
//!
//! Victim selection is bit-identical to the naive scan (least
//! `last_access` first, creation order as the tie-break), which
//! `tests/property_invariants.rs` checks differentially against a
//! reference model.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use super::block::{hash_block, BlockId, BlockPool, ROOT_HASH};

/// Index of a node in the tree's arena (recycled through a free list).
pub type NodeId = usize;

/// Lazily-invalidated eviction-heap entry: `(last_access, creation seq,
/// node)`.  An entry is stale (and discarded on pop) unless the node's
/// current `last_access`/`seq` still match.
type HeapEntry = Reverse<(u64, u64, NodeId)>;

#[derive(Debug)]
struct Node {
    /// Token span this node covers (exactly one block; empty for the
    /// root and for free-listed slots).
    span: Box<[u32]>,
    /// Rolling hash chain from the root through this span — the child
    /// index key under `parent`.
    hash: u64,
    block: Option<BlockId>,
    parent: Option<NodeId>,
    /// Sequences currently pinning this node (prefix in active use).
    pins: u32,
    last_access: u64,
    /// Creation order (never recycled): eviction tie-break, and the
    /// staleness check that makes free-list slot reuse safe.
    seq: u64,
    /// Opaque engine payload (cache snapshot id) covering the context
    /// from the root through this node.
    payload: Option<u64>,
    /// Block released to the pool but context preserved in the swap
    /// tier — still matchable; a hit must re-allocate and swap in.
    swapped: bool,
    dead: bool,
    /// Live (non-dead) children, resident or swapped.
    live_children: u32,
    /// Live children currently holding a block.
    resident_children: u32,
}

/// Result of a prefix match.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Match {
    /// Total prompt tokens covered by cached blocks.
    pub matched_tokens: usize,
    /// Node ids along the matched path (for pin/unpin).
    pub path: Vec<NodeId>,
    /// Deepest payload on the path and the token count it covers.
    pub payload: Option<(u64, usize)>,
    /// Nodes on the path whose blocks live in the swap tier — the
    /// manager must re-allocate + swap them in before use.
    pub swapped_nodes: Vec<NodeId>,
}

/// Block-granular prefix trie for one cache namespace (see the module
/// docs for the hot-path layout).
#[derive(Debug)]
pub struct RadixCache {
    nodes: Vec<Node>,
    /// Flat child index: `(parent, chain hash)` -> children with that
    /// hash.  More than one entry only on a hash collision.
    children: HashMap<(NodeId, u64), Vec<NodeId>>,
    /// Recycled node slots.
    free_list: Vec<NodeId>,
    /// Evictable-leaf heap for `evict` (no live children).
    evict_heap: BinaryHeap<HeapEntry>,
    /// Evictable-leaf heap for `evict_swap` (no block-holding children).
    swap_heap: BinaryHeap<HeapEntry>,
    root: NodeId,
    clock: u64,
    next_seq: u64,
    /// Number of resident (block-holding, live) nodes.
    resident: usize,
    /// Tokens per block; 0 until learned from the pool on first insert.
    block_tokens: usize,
}

impl Default for RadixCache {
    fn default() -> Self {
        Self::new()
    }
}

impl RadixCache {
    /// Tree with a known block size (hash-chain granularity).
    pub fn with_block_tokens(block_tokens: usize) -> Self {
        let root = Node {
            span: Box::default(),
            hash: ROOT_HASH,
            block: None,
            parent: None,
            pins: 0,
            last_access: 0,
            seq: 0,
            payload: None,
            swapped: false,
            dead: false,
            live_children: 0,
            resident_children: 0,
        };
        RadixCache {
            nodes: vec![root],
            children: HashMap::new(),
            free_list: Vec::new(),
            evict_heap: BinaryHeap::new(),
            swap_heap: BinaryHeap::new(),
            root: 0,
            clock: 0,
            next_seq: 1,
            resident: 0,
            block_tokens,
        }
    }

    /// Tree that learns its block size from the pool on first insert.
    pub fn new() -> Self {
        Self::with_block_tokens(0)
    }

    /// Live nodes currently holding a block (one block each).
    pub fn resident_nodes(&self) -> usize {
        self.resident
    }

    /// Arena slots allocated (live + free-listed) — diagnostics for the
    /// free list; stays bounded under insert/evict churn.
    pub fn arena_len(&self) -> usize {
        self.nodes.len()
    }

    /// Free-listed (recyclable) arena slots.
    pub fn free_nodes(&self) -> usize {
        self.free_list.len()
    }

    /// Live nodes whose block currently lives in the swap tier —
    /// matchable but needing re-allocation + swap-in on a hit.  Each
    /// accounts for exactly one block of swap-tier occupancy, which is
    /// what the byte-conservation property test pins.
    pub fn swapped_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| !n.dead && n.swapped).count()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Push `id` into whichever eviction heaps it currently qualifies
    /// for.  Called whenever a node's key or eligibility may have
    /// changed; stale entries are discarded on pop.
    fn reindex(&mut self, id: NodeId) {
        let n = &self.nodes[id];
        if n.dead || id == self.root || n.pins != 0 || n.block.is_none() {
            return;
        }
        let entry = Reverse((n.last_access, n.seq, id));
        let hard = n.live_children == 0;
        let swap = n.resident_children == 0;
        if hard {
            self.evict_heap.push(entry);
        }
        if swap {
            self.swap_heap.push(entry);
        }
        if hard || swap {
            self.maybe_compact();
        }
    }

    /// Bound lazy-heap garbage: when a heap outgrows a small multiple of
    /// the arena, rebuild both from current state (amortized O(1)/op).
    fn maybe_compact(&mut self) {
        let cap = 64 + 4 * self.nodes.len();
        if self.evict_heap.len() <= cap && self.swap_heap.len() <= cap {
            return;
        }
        self.evict_heap.clear();
        self.swap_heap.clear();
        for id in 0..self.nodes.len() {
            let n = &self.nodes[id];
            if n.dead || id == self.root || n.pins != 0 || n.block.is_none() {
                continue;
            }
            let entry = Reverse((n.last_access, n.seq, id));
            if n.live_children == 0 {
                self.evict_heap.push(entry);
            }
            if n.resident_children == 0 {
                self.swap_heap.push(entry);
            }
        }
    }

    /// Pop the LRU evictable leaf (hard eviction when `swap` is false,
    /// swap-tier eviction otherwise), discarding stale entries.
    fn pop_victim(&mut self, swap: bool) -> Option<NodeId> {
        loop {
            let heap = if swap { &mut self.swap_heap } else { &mut self.evict_heap };
            let Reverse((ts, seq, id)) = heap.pop()?;
            let n = &self.nodes[id];
            let current = !n.dead && n.seq == seq && n.last_access == ts;
            let eligible = current
                && n.pins == 0
                && n.block.is_some()
                && if swap { n.resident_children == 0 } else { n.live_children == 0 };
            if eligible {
                return Some(id);
            }
        }
    }

    /// Longest cached prefix of `prompt` (block-aligned).  Touches the
    /// path for LRU purposes but does not pin it.
    pub fn lookup(&mut self, prompt: &[u32]) -> Match {
        let now = self.tick();
        let mut m = Match {
            matched_tokens: 0,
            path: Vec::new(),
            payload: None,
            swapped_nodes: Vec::new(),
        };
        let bt = self.block_tokens;
        if bt == 0 {
            return m; // nothing inserted yet
        }
        let mut cur = self.root;
        let mut hash = ROOT_HASH;
        while m.matched_tokens + bt <= prompt.len() {
            let span = &prompt[m.matched_tokens..m.matched_tokens + bt];
            hash = hash_block(hash, span);
            let next = match self.children.get(&(cur, hash)) {
                // Token comparison only as the collision guard.
                Some(cands) => cands.iter().copied().find(|&c| self.nodes[c].span[..] == span[..]),
                None => None,
            };
            let Some(c) = next else { break };
            m.matched_tokens += bt;
            m.path.push(c);
            let n = &mut self.nodes[c];
            n.last_access = now;
            if n.swapped {
                m.swapped_nodes.push(c);
            }
            if let Some(p) = n.payload {
                m.payload = Some((p, m.matched_tokens));
            }
            self.reindex(c); // LRU key changed
            cur = c;
        }
        m
    }

    /// Read-only coverage probe: tokens of `prompt` that an admission
    /// could actually serve from cache — the match depth through the
    /// deepest *payload-bearing* node, mirroring how the engine caps
    /// coverage at the matched snapshot (blocks matched beyond the last
    /// payload have no snapshot to prefill from).  Like
    /// [`RadixCache::lookup`] but with **no side effects** — no LRU
    /// touch, no heap reindex, no clock tick — so schedulers can rank
    /// waiting turns every step without perturbing eviction order
    /// (which is what keeps probe-free policies bit-identical to the
    /// pre-scheduler engine).
    pub fn peek(&self, prompt: &[u32]) -> usize {
        let bt = self.block_tokens;
        if bt == 0 {
            return 0; // nothing inserted yet
        }
        let mut matched = 0usize;
        let mut covered = 0usize; // through the deepest payload
        let mut cur = self.root;
        let mut hash = ROOT_HASH;
        while matched + bt <= prompt.len() {
            let span = &prompt[matched..matched + bt];
            hash = hash_block(hash, span);
            let next = match self.children.get(&(cur, hash)) {
                Some(cands) => cands.iter().copied().find(|&c| self.nodes[c].span[..] == span[..]),
                None => None,
            };
            let Some(c) = next else { break };
            matched += bt;
            if self.nodes[c].payload.is_some() {
                covered = matched;
            }
            cur = c;
        }
        covered
    }

    /// [`RadixCache::peek`] with the prompt's rolling block-hash chain
    /// precomputed (see `TokenBuf::block_chain`): the probe walks the
    /// child index on the memoized hashes instead of re-hashing the
    /// whole prefix — the per-step scheduler probe of a growing context
    /// becomes O(depth) lookups with zero hashing.  `chain[i]` must be
    /// the chain key of `prompt[..(i + 1) * block_tokens]`; token spans
    /// are still compared as the collision guard.
    pub fn peek_with_chain(&self, prompt: &[u32], chain: &[(u64, usize)]) -> usize {
        let bt = self.block_tokens;
        if bt == 0 {
            return 0; // nothing inserted yet
        }
        let mut matched = 0usize;
        let mut covered = 0usize; // through the deepest payload
        let mut cur = self.root;
        for key in chain {
            if matched + bt > prompt.len() {
                break;
            }
            debug_assert_eq!(key.1, matched + bt, "chain keyed at this tree's block size");
            let span = &prompt[matched..matched + bt];
            let next = match self.children.get(&(cur, key.0)) {
                Some(cands) => cands.iter().copied().find(|&c| self.nodes[c].span[..] == span[..]),
                None => None,
            };
            let Some(c) = next else { break };
            matched += bt;
            if self.nodes[c].payload.is_some() {
                covered = matched;
            }
            cur = c;
        }
        covered
    }

    /// Live (non-dead) nodes currently carrying a payload — i.e. cache
    /// snapshots the tree is keeping alive.  With the engine dropping
    /// every snapshot it is handed back, the executor's live-handle
    /// count must equal this at end of run (the no-leak invariant
    /// `tests/property_invariants.rs` checks per policy).
    pub fn live_payloads(&self) -> usize {
        self.nodes.iter().filter(|n| !n.dead && n.payload.is_some()).count()
    }

    /// Pin every node on a matched path so an active sequence's prefix
    /// can't be evicted underneath it.  Pins are advisory counters that
    /// `evict`/`evict_swap` respect; block refcounts stay owned by the
    /// tree alone (a node's residency may legitimately change between
    /// pin and unpin via the swap tier, so pins must not alias them).
    pub fn pin(&mut self, m: &Match, _pool: &mut BlockPool) {
        for &n in &m.path {
            self.nodes[n].pins += 1;
        }
    }

    /// Release the pins [`RadixCache::pin`] took on a matched path.
    pub fn unpin(&mut self, m: &Match, _pool: &mut BlockPool) {
        for &n in &m.path {
            debug_assert!(self.nodes[n].pins > 0);
            self.nodes[n].pins -= 1;
            // Dropping the last pin can re-expose an evictable leaf.
            self.reindex(n);
        }
    }

    fn alloc_node(
        &mut self,
        span: &[u32],
        hash: u64,
        parent: NodeId,
        block: BlockId,
        now: u64,
    ) -> NodeId {
        let seq = self.next_seq;
        self.next_seq += 1;
        let node = Node {
            span: span.into(),
            hash,
            block: Some(block),
            parent: Some(parent),
            pins: 0,
            last_access: now,
            seq,
            payload: None,
            swapped: false,
            dead: false,
            live_children: 0,
            resident_children: 0,
        };
        match self.free_list.pop() {
            Some(id) => {
                debug_assert!(self.nodes[id].dead);
                self.nodes[id] = node;
                id
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// Insert a completed context.  Only full blocks are cached.  Blocks
    /// for the uncached portion are allocated from the pool (returns
    /// false and inserts nothing on pool exhaustion — callers should
    /// evict and retry or skip caching).  `payload` is attached to the
    /// deepest inserted/matched node.
    pub fn insert(&mut self, tokens: &[u32], payload: u64, pool: &mut BlockPool) -> bool {
        self.insert_with_displaced(tokens, payload, pool).0
    }

    /// Like [`RadixCache::insert`], but also reports the payload this
    /// insert displaced (a fully-matched re-insert — e.g. a preempted
    /// turn re-publishing an identical context — overwrites the node's
    /// existing payload).  The caller owns the displaced snapshot and
    /// must drop it, or its device buffers leak for the rest of the
    /// run.  Displacement can only happen on a successful insert.
    pub fn insert_with_displaced(
        &mut self,
        tokens: &[u32],
        payload: u64,
        pool: &mut BlockPool,
    ) -> (bool, Option<u64>) {
        if self.block_tokens == 0 {
            self.block_tokens = pool.block_tokens;
        }
        debug_assert_eq!(self.block_tokens, pool.block_tokens, "one pool per tree");
        let bt = self.block_tokens;
        let full = (tokens.len() / bt) * bt;
        let m = self.lookup(&tokens[..full]);
        let mut cur = *m.path.last().unwrap_or(&self.root);
        let mut off = m.matched_tokens;
        debug_assert_eq!(off % bt, 0);
        let needed = (full - off) / bt;
        if pool.free_blocks() < needed {
            return (false, None);
        }
        let now = self.tick();
        let mut hash = if cur == self.root { ROOT_HASH } else { self.nodes[cur].hash };
        while off < full {
            let span = &tokens[off..off + bt];
            hash = hash_block(hash, span);
            let block = pool.alloc(1).expect("checked free_blocks")[0];
            let id = self.alloc_node(span, hash, cur, block, now);
            self.children.entry((cur, hash)).or_default().push(id);
            let parent = &mut self.nodes[cur];
            parent.live_children += 1;
            parent.resident_children += 1;
            self.resident += 1;
            self.reindex(id); // fresh leaf: immediately evictable
            cur = id;
            off += bt;
        }
        let mut displaced = None;
        if cur != self.root {
            displaced = self.nodes[cur].payload.replace(payload);
            self.nodes[cur].last_access = now;
            self.reindex(cur);
        }
        (true, displaced)
    }

    /// Kill one evictable leaf: release its block, collect its payload,
    /// unlink it from the child index and recycle the slot.  Returns the
    /// number of blocks freed (1 for a validated hard victim).
    fn kill_node(&mut self, v: NodeId, pool: &mut BlockPool, dropped: &mut Vec<u64>) -> usize {
        let node = &mut self.nodes[v];
        debug_assert!(!node.dead && node.live_children == 0 && node.pins == 0);
        node.dead = true;
        node.span = Box::default();
        let mut freed = 0;
        if let Some(b) = node.block.take() {
            pool.release(b);
            freed = 1;
            self.resident -= 1;
        }
        if let Some(p) = node.payload.take() {
            dropped.push(p);
        }
        // Payloads on surviving ancestors stay valid: they cover shorter
        // prefixes that are still resident.
        let parent = node.parent;
        let hash = node.hash;
        if let Some(p) = parent {
            if let Some(list) = self.children.get_mut(&(p, hash)) {
                list.retain(|&c| c != v);
                if list.is_empty() {
                    self.children.remove(&(p, hash));
                }
            }
            let pn = &mut self.nodes[p];
            pn.live_children -= 1;
            if freed == 1 {
                pn.resident_children -= 1;
            }
            // The parent may have just become an evictable leaf.
            self.reindex(p);
        }
        self.free_list.push(v);
        freed
    }

    /// Evict up to `want` unpinned leaf blocks, least-recently-used
    /// first.  Returns (blocks_freed, payloads_of_dropped_nodes) so the
    /// engine can drop the matching cache snapshots (or swap them out).
    /// O(log nodes) per evicted block via the evictable-leaf heap.
    pub fn evict(&mut self, want: usize, pool: &mut BlockPool) -> (usize, Vec<u64>) {
        let mut freed = 0;
        let mut dropped = Vec::new();
        while freed < want {
            let Some(v) = self.pop_victim(false) else { break };
            freed += self.kill_node(v, pool, &mut dropped);
        }
        (freed, dropped)
    }

    /// Full context (root-to-node token chain) a node covers.  Valid
    /// for any live node: ancestors of a live node are always live
    /// (children pin parents against `kill_node`), and swapped
    /// ancestors keep their spans.
    fn context_of(&self, v: NodeId) -> Vec<u32> {
        let mut chain = Vec::new();
        let mut cur = Some(v);
        while let Some(id) = cur {
            if id == self.root {
                break;
            }
            chain.push(id);
            cur = self.nodes[id].parent;
        }
        let total: usize = chain.iter().map(|&id| self.nodes[id].span.len()).sum();
        let mut out = Vec::with_capacity(total);
        for &id in chain.iter().rev() {
            out.extend_from_slice(&self.nodes[id].span);
        }
        out
    }

    /// Like [`RadixCache::evict`], but additionally reconstructs the
    /// full context of every payload-bearing victim so the caller can
    /// demote it into the tiered snapshot store (GPU → host) instead of
    /// losing it outright.  Victim order is identical to `evict` (same
    /// heap pop loop); the only extra cost is the context walk, paid
    /// per *payload* victim, so callers without a store should keep
    /// calling `evict`.
    pub fn evict_demoting(
        &mut self,
        want: usize,
        pool: &mut BlockPool,
    ) -> (usize, Vec<u64>, Vec<Vec<u32>>) {
        let mut freed = 0;
        let mut dropped = Vec::new();
        let mut demoted = Vec::new();
        while freed < want {
            let Some(v) = self.pop_victim(false) else { break };
            if self.nodes[v].payload.is_some() {
                demoted.push(self.context_of(v));
            }
            freed += self.kill_node(v, pool, &mut dropped);
        }
        (freed, dropped, demoted)
    }

    /// Evict every unpinned resident node (used on engine reset between
    /// runs).  The explicit drain-all entry point — `evict` with a large
    /// `want` would also work, but intent beats sentinel values.
    pub fn evict_all(&mut self, pool: &mut BlockPool) -> (usize, Vec<u64>) {
        let mut freed = 0;
        let mut dropped = Vec::new();
        while let Some(v) = self.pop_victim(false) {
            freed += self.kill_node(v, pool, &mut dropped);
        }
        (freed, dropped)
    }

    /// Swap-mode eviction: free up to `want` unpinned leaf blocks but
    /// keep the nodes matchable (context preserved in the swap tier).
    /// Returns blocks freed.  Payloads are NOT dropped — the engine's
    /// snapshot handles stay alive, acting as the host-side copy.
    /// Leaf-first among block-holding nodes: children that still hold
    /// blocks pin their ancestors in place.
    pub fn evict_swap(&mut self, want: usize, pool: &mut BlockPool) -> usize {
        let mut freed = 0;
        while freed < want {
            let Some(v) = self.pop_victim(true) else { break };
            let node = &mut self.nodes[v];
            let b = node.block.take().expect("victim validated as resident");
            pool.release(b);
            node.swapped = true;
            let parent = node.parent;
            freed += 1;
            self.resident -= 1;
            if let Some(p) = parent {
                self.nodes[p].resident_children -= 1;
                // The parent may have just become swap-evictable.
                self.reindex(p);
            }
        }
        freed
    }

    /// Restore swapped nodes on a matched path: re-allocate one block
    /// per node and clear the swapped flag.  All-or-nothing; returns
    /// the number of blocks restored (0 if the pool lacks room).
    pub fn restore(&mut self, nodes: &[NodeId], pool: &mut BlockPool) -> usize {
        if pool.free_blocks() < nodes.len() {
            return 0;
        }
        for &v in nodes {
            debug_assert!(self.nodes[v].swapped && self.nodes[v].block.is_none());
            let b = pool.alloc(1).expect("checked free_blocks")[0];
            let node = &mut self.nodes[v];
            node.block = Some(b);
            node.swapped = false;
            let parent = node.parent;
            self.resident += 1;
            if let Some(p) = parent {
                self.nodes[p].resident_children += 1;
            }
            // Back in the resident set: eligible for eviction again.
            self.reindex(v);
        }
        nodes.len()
    }

    /// Drop everything unpinned (used on engine reset between runs).
    pub fn clear(&mut self, pool: &mut BlockPool) -> Vec<u64> {
        self.evict_all(pool).1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> BlockPool {
        BlockPool::new(1024 * 16 * 64, 16, 64) // 1024 blocks of 16 tokens
    }

    fn toks(n: usize, salt: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 7 + salt).collect()
    }

    #[test]
    fn miss_on_empty() {
        let mut r = RadixCache::new();
        let m = r.lookup(&toks(32, 0));
        assert_eq!(m.matched_tokens, 0);
        assert!(m.path.is_empty());
    }

    #[test]
    fn insert_then_full_hit() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let t = toks(48, 0);
        assert!(r.insert(&t, 99, &mut p));
        assert_eq!(p.used(), 3);
        let m = r.lookup(&t);
        assert_eq!(m.matched_tokens, 48);
        assert_eq!(m.payload, Some((99, 48)));
    }

    #[test]
    fn partial_block_not_cached() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let t = toks(40, 0); // 2.5 blocks -> 2 cached
        assert!(r.insert(&t, 1, &mut p));
        assert_eq!(p.used(), 2);
        let m = r.lookup(&t);
        assert_eq!(m.matched_tokens, 32);
    }

    #[test]
    fn shared_prefix_single_storage() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        let mut b = a.clone();
        b.extend(toks(16, 500)); // same first 32, diverges after
        assert!(r.insert(&a, 1, &mut p));
        let before = p.used();
        assert!(r.insert(&b, 2, &mut p));
        assert_eq!(p.used(), before + 1, "only divergent block allocated");
        let m = r.lookup(&b);
        assert_eq!(m.matched_tokens, 48);
        assert_eq!(m.payload, Some((2, 48)));
    }

    #[test]
    fn payload_nearest_on_partial_match() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        assert!(r.insert(&a, 7, &mut p));
        // prompt extends beyond cached context
        let mut b = a.clone();
        b.extend(toks(20, 900));
        let m = r.lookup(&b);
        assert_eq!(m.matched_tokens, 32);
        assert_eq!(m.payload, Some((7, 32)));
    }

    #[test]
    fn peek_matches_lookup_without_touching_lru() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        let b = toks(32, 1000);
        assert!(r.insert(&a, 1, &mut p));
        assert!(r.insert(&b, 2, &mut p));
        // Coverage agrees with lookup (full, partial and miss cases).
        assert_eq!(r.peek(&a), 32);
        let mut ext = a.clone();
        ext.extend(toks(20, 7777));
        assert_eq!(r.peek(&ext), 32);
        assert_eq!(r.peek(&toks(32, 5555)), 0);
        assert_eq!(r.peek(&a[..8]), 0, "sub-block prefix matches nothing");
        // a was inserted first; peeks at it must NOT refresh it, so it
        // is still the LRU victim (a lookup here would protect it).
        for _ in 0..4 {
            let _ = r.peek(&a);
        }
        let _ = r.lookup(&b); // touch b
        let (freed, dropped) = r.evict(2, &mut p);
        assert_eq!(freed, 2);
        assert_eq!(dropped, vec![1], "peeked-only entry stayed LRU");
    }

    #[test]
    fn peek_reports_only_payload_usable_coverage() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        assert!(r.insert(&a, 1, &mut p));
        assert_eq!(r.peek(&a), 32);
        // Evict the tip leaf: its payload goes with it; the surviving
        // interior block still matches but no snapshot covers it, so an
        // admission could not use it — peek must say 0, not 16.
        let (freed, dropped) = r.evict(1, &mut p);
        assert_eq!((freed, dropped), (1, vec![1]));
        assert_eq!(r.lookup(&a).matched_tokens, 16, "block still matchable");
        assert_eq!(r.peek(&a), 0, "admission-usable coverage is zero");
    }

    #[test]
    fn reinsert_reports_displaced_payload() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let t = toks(32, 0);
        assert_eq!(r.insert_with_displaced(&t, 5, &mut p), (true, None));
        assert_eq!(r.live_payloads(), 1);
        // Identical context re-published: new payload in, old reported.
        assert_eq!(r.insert_with_displaced(&t, 9, &mut p), (true, Some(5)));
        assert_eq!(r.live_payloads(), 1);
        assert_eq!(r.lookup(&t).payload, Some((9, 32)));
        // Payload count drops with eviction.
        let (_, dropped) = r.evict(10, &mut p);
        assert_eq!(dropped, vec![9]);
        assert_eq!(r.live_payloads(), 0);
    }

    #[test]
    fn pinned_blocks_survive_eviction() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        let b = toks(32, 1000);
        assert!(r.insert(&a, 1, &mut p));
        assert!(r.insert(&b, 2, &mut p));
        let m = r.lookup(&a);
        r.pin(&m, &mut p);
        let (freed, dropped) = r.evict(100, &mut p);
        assert_eq!(freed, 2, "only b's two blocks evictable");
        assert_eq!(dropped, vec![2]);
        let m2 = r.lookup(&a);
        assert_eq!(m2.matched_tokens, 32);
        r.unpin(&m, &mut p);
    }

    #[test]
    fn eviction_is_lru_leaf_first() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        let b = toks(32, 1000);
        assert!(r.insert(&a, 1, &mut p));
        assert!(r.insert(&b, 2, &mut p));
        let _ = r.lookup(&a); // touch a — b becomes LRU
        let (freed, dropped) = r.evict(1, &mut p);
        assert_eq!(freed, 1);
        assert!(dropped.is_empty() || dropped == vec![2]);
        // a still fully matchable
        assert_eq!(r.lookup(&a).matched_tokens, 32);
    }

    #[test]
    fn evict_leaf_then_parent() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let t = toks(48, 0);
        assert!(r.insert(&t, 1, &mut p));
        let (freed, _) = r.evict(3, &mut p);
        assert_eq!(freed, 3);
        assert_eq!(p.used(), 0);
        assert_eq!(r.lookup(&t).matched_tokens, 0);
    }

    #[test]
    fn insert_fails_cleanly_when_pool_full() {
        let mut r = RadixCache::new();
        let mut p = BlockPool::new(2 * 16 * 64, 16, 64); // 2 blocks
        assert!(r.insert(&toks(32, 0), 1, &mut p));
        assert!(!r.insert(&toks(32, 999), 2, &mut p));
        assert_eq!(p.used(), 2);
    }

    #[test]
    fn pin_unpin_balances_refcounts() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let t = toks(32, 0);
        assert!(r.insert(&t, 1, &mut p));
        let used = p.used();
        let m = r.lookup(&t);
        r.pin(&m, &mut p);
        r.unpin(&m, &mut p);
        assert_eq!(p.used(), used);
        // now evictable
        let (freed, _) = r.evict(10, &mut p);
        assert_eq!(freed, 2);
    }

    #[test]
    fn evict_all_drains_everything_unpinned() {
        let mut r = RadixCache::new();
        let mut p = pool();
        for salt in 0..8 {
            assert!(r.insert(&toks(48, salt * 100), u64::from(salt), &mut p));
        }
        let pinned = toks(48, 0);
        let m = r.lookup(&pinned);
        r.pin(&m, &mut p);
        let (freed, dropped) = r.evict_all(&mut p);
        assert_eq!(freed, 7 * 3, "everything but the pinned chain");
        assert_eq!(dropped.len(), 7);
        assert_eq!(r.lookup(&pinned).matched_tokens, 48);
        r.unpin(&m, &mut p);
        let dropped = r.clear(&mut p);
        assert_eq!(dropped, vec![0]);
        assert_eq!(p.used(), 0);
        assert_eq!(r.resident_nodes(), 0);
    }

    #[test]
    fn free_list_recycles_dead_nodes() {
        let mut r = RadixCache::new();
        let mut p = pool();
        // Warm up with one resident context, then churn many times its
        // size through insert/evict: the arena must not grow per cycle.
        assert!(r.insert(&toks(64, 1), 1, &mut p));
        for salt in 0..200u32 {
            assert!(r.insert(&toks(32, 10_000 + salt * 64), u64::from(salt), &mut p));
            let (freed, _) = r.evict(2, &mut p);
            assert_eq!(freed, 2);
        }
        assert!(
            r.arena_len() <= 1 + 4 + 2 + 2,
            "arena grew to {} slots under steady churn",
            r.arena_len()
        );
        assert_eq!(r.resident_nodes(), p.used());
    }

    #[test]
    fn evict_demoting_matches_evict_and_reconstructs_contexts() {
        // Two trees, same inserts: evict_demoting must free the same
        // blocks and drop the same payloads in the same order as evict,
        // and hand back the full root-to-victim context of every
        // payload-bearing victim.
        let mut a = RadixCache::new();
        let mut b = RadixCache::new();
        let mut pa = pool();
        let mut pb = pool();
        let t1 = toks(48, 0);
        let mut t2 = t1[..16].to_vec();
        t2.extend(toks(32, 5000)); // shares t1's first block
        for (r, p) in [(&mut a, &mut pa), (&mut b, &mut pb)] {
            assert!(r.insert(&t1, 1, p));
            assert!(r.insert(&t2, 2, p));
        }
        let (fa, da) = a.evict(100, &mut pa);
        let (fb, db, demoted) = b.evict_demoting(100, &mut pb);
        assert_eq!((fa, &da), (fb, &db), "victim order identical");
        // Both payload-bearing tips were demoted, each with its full
        // block-aligned context.
        assert_eq!(demoted.len(), 2);
        assert!(demoted.contains(&t1));
        assert!(demoted.contains(&t2[..48].to_vec()));
    }

    #[test]
    fn lru_order_across_many_inserts() {
        // Eviction drains strictly in last-touch order when untouched.
        let mut r = RadixCache::new();
        let mut p = pool();
        for salt in 0..6u32 {
            assert!(r.insert(&toks(16, 1000 * (salt + 1)), u64::from(salt), &mut p));
        }
        let mut order = Vec::new();
        loop {
            let (freed, dropped) = r.evict(1, &mut p);
            if freed == 0 {
                break;
            }
            order.extend(dropped);
        }
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
    }
}
