//! Radix (block-granular trie) prefix cache, one tree per namespace.
//!
//! Mirrors vLLM/SGLang prefix caching: completed contexts are inserted
//! at block granularity; new prompts walk the trie to find the longest
//! cached prefix.  Nodes carry an opaque `payload` the engine uses to
//! locate the device-side cache snapshot for the matched context.
//!
//! In ICaRus mode every model shares namespace 0 — a context produced
//! while serving model A is a cache hit for model B (the paper's
//! cross-model prefix caching).  In baseline mode each model gets its own
//! tree and re-prefills identical prompts (the paper's Fig 1a problem).

use std::collections::HashMap;

use super::block::{BlockId, BlockPool};

pub type NodeId = usize;

#[derive(Debug)]
struct Node {
    /// Token span this node covers (exactly one block, except the root).
    tokens: Vec<u32>,
    block: Option<BlockId>,
    children: HashMap<u32, Vec<NodeId>>, // first token -> candidates
    parent: Option<NodeId>,
    /// Sequences currently pinning this node (prefix in active use).
    pins: u32,
    last_access: u64,
    /// Opaque engine payload (cache snapshot id) covering the context
    /// from the root through this node.
    payload: Option<u64>,
    /// Block released to the pool but context preserved in the swap
    /// tier — still matchable; a hit must re-allocate and swap in.
    swapped: bool,
    dead: bool,
}

/// Result of a prefix match.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Match {
    /// Total prompt tokens covered by cached blocks.
    pub matched_tokens: usize,
    /// Node ids along the matched path (for pin/unpin).
    pub path: Vec<NodeId>,
    /// Deepest payload on the path and the token count it covers.
    pub payload: Option<(u64, usize)>,
    /// Nodes on the path whose blocks live in the swap tier — the
    /// manager must re-allocate + swap them in before use.
    pub swapped_nodes: Vec<NodeId>,
}

#[derive(Debug)]
pub struct RadixCache {
    nodes: Vec<Node>,
    root: NodeId,
    clock: u64,
    /// Number of resident (block-holding, live) nodes.
    resident: usize,
}

impl Default for RadixCache {
    fn default() -> Self {
        Self::new()
    }
}

impl RadixCache {
    pub fn new() -> Self {
        let root = Node {
            tokens: Vec::new(),
            block: None,
            children: HashMap::new(),
            parent: None,
            pins: 0,
            last_access: 0,
            payload: None,
            swapped: false,
            dead: false,
        };
        RadixCache { nodes: vec![root], root: 0, clock: 0, resident: 0 }
    }

    pub fn resident_nodes(&self) -> usize {
        self.resident
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest cached prefix of `prompt` (block-aligned).  Touches the
    /// path for LRU purposes but does not pin it.
    pub fn lookup(&mut self, prompt: &[u32]) -> Match {
        let now = self.tick();
        let mut cur = self.root;
        let mut matched = 0usize;
        let mut path = Vec::new();
        let mut payload = None;
        let mut swapped_nodes = Vec::new();
        loop {
            let rest = &prompt[matched..];
            if rest.is_empty() {
                break;
            }
            let Some(cands) = self.nodes[cur].children.get(&rest[0]) else {
                break;
            };
            let mut next = None;
            for &c in cands {
                let n = &self.nodes[c];
                if !n.dead && rest.len() >= n.tokens.len() && rest[..n.tokens.len()] == n.tokens[..] {
                    next = Some(c);
                    break;
                }
            }
            let Some(c) = next else { break };
            matched += self.nodes[c].tokens.len();
            self.nodes[c].last_access = now;
            path.push(c);
            if self.nodes[c].swapped {
                swapped_nodes.push(c);
            }
            if let Some(p) = self.nodes[c].payload {
                payload = Some((p, matched));
            }
            cur = c;
        }
        Match { matched_tokens: matched, path, payload, swapped_nodes }
    }

    /// Pin every node on a matched path so an active sequence's prefix
    /// can't be evicted underneath it.  Pins are advisory counters that
    /// `evict`/`evict_swap` respect; block refcounts stay owned by the
    /// tree alone (a node's residency may legitimately change between
    /// pin and unpin via the swap tier, so pins must not alias them).
    pub fn pin(&mut self, m: &Match, _pool: &mut BlockPool) {
        for &n in &m.path {
            self.nodes[n].pins += 1;
        }
    }

    pub fn unpin(&mut self, m: &Match, _pool: &mut BlockPool) {
        for &n in &m.path {
            debug_assert!(self.nodes[n].pins > 0);
            self.nodes[n].pins -= 1;
        }
    }

    /// Insert a completed context.  Only full blocks are cached.  Blocks
    /// for the uncached portion are allocated from the pool (returns
    /// false and inserts nothing on pool exhaustion — callers should
    /// evict and retry or skip caching).  `payload` is attached to the
    /// deepest inserted/matched node.
    pub fn insert(
        &mut self,
        tokens: &[u32],
        payload: u64,
        pool: &mut BlockPool,
    ) -> bool {
        let block_tokens = pool.block_tokens;
        let full = (tokens.len() / block_tokens) * block_tokens;
        let m = self.lookup(&tokens[..full]);
        let mut cur = *m.path.last().unwrap_or(&self.root);
        let mut off = m.matched_tokens;
        debug_assert_eq!(off % block_tokens, 0);
        let needed = (full - off) / block_tokens;
        if pool.free_blocks() < needed {
            return false;
        }
        let now = self.tick();
        while off < full {
            let span = &tokens[off..off + block_tokens];
            let block = pool.alloc(1).expect("checked free_blocks")[0];
            let id = self.nodes.len();
            self.nodes.push(Node {
                tokens: span.to_vec(),
                block: Some(block),
                children: HashMap::new(),
                parent: Some(cur),
                pins: 0,
                last_access: now,
                payload: None,
                swapped: false,
                dead: false,
            });
            self.nodes[cur].children.entry(span[0]).or_default().push(id);
            self.resident += 1;
            cur = id;
            off += block_tokens;
        }
        if cur != self.root {
            self.nodes[cur].payload = Some(payload);
            self.nodes[cur].last_access = now;
        }
        true
    }

    /// Evict up to `want` unpinned leaf blocks, least-recently-used
    /// first.  Returns (blocks_freed, payloads_of_dropped_nodes) so the
    /// engine can drop the matching cache snapshots (or swap them out).
    pub fn evict(&mut self, want: usize, pool: &mut BlockPool) -> (usize, Vec<u64>) {
        let mut freed = 0;
        let mut dropped = Vec::new();
        while freed < want {
            // Scan for the LRU evictable leaf.  O(nodes) per eviction;
            // fine at simulation scale (see micro_kvcache bench).
            let mut victim: Option<(u64, NodeId)> = None;
            for (i, n) in self.nodes.iter().enumerate() {
                if n.dead || i == self.root || n.pins > 0 || n.block.is_none() {
                    continue;
                }
                let has_live_children =
                    n.children.values().flatten().any(|&c| !self.nodes[c].dead);
                if has_live_children {
                    continue;
                }
                if victim.map_or(true, |(t, _)| n.last_access < t) {
                    victim = Some((n.last_access, i));
                }
            }
            let Some((_, v)) = victim else { break };
            let node = &mut self.nodes[v];
            node.dead = true;
            if let Some(b) = node.block.take() {
                pool.release(b);
                freed += 1;
                self.resident -= 1;
            }
            if let Some(p) = node.payload.take() {
                dropped.push(p);
            }
            // Also drop payloads that are now unreachable snapshots on
            // interior nodes?  No: interior payloads remain valid (they
            // cover shorter prefixes still resident).
            let parent = self.nodes[v].parent;
            if let Some(p) = parent {
                let first = self.nodes[v].tokens[0];
                if let Some(list) = self.nodes[p].children.get_mut(&first) {
                    list.retain(|&c| c != v);
                }
            }
        }
        (freed, dropped)
    }

    /// Swap-mode eviction: free up to `want` unpinned leaf blocks but
    /// keep the nodes matchable (context preserved in the swap tier).
    /// Returns blocks freed.  Payloads are NOT dropped — the engine's
    /// snapshot handles stay alive, acting as the host-side copy.
    pub fn evict_swap(&mut self, want: usize, pool: &mut BlockPool) -> usize {
        let mut freed = 0;
        while freed < want {
            let mut victim: Option<(u64, NodeId)> = None;
            for (i, n) in self.nodes.iter().enumerate() {
                if n.dead || i == self.root || n.pins > 0 || n.block.is_none() {
                    continue;
                }
                // Leaf-first among block-holding nodes: children that
                // still hold blocks pin their ancestors in place.
                let has_resident_children = n
                    .children
                    .values()
                    .flatten()
                    .any(|&c| !self.nodes[c].dead && self.nodes[c].block.is_some());
                if has_resident_children {
                    continue;
                }
                if victim.map_or(true, |(t, _)| n.last_access < t) {
                    victim = Some((n.last_access, i));
                }
            }
            let Some((_, v)) = victim else { break };
            let node = &mut self.nodes[v];
            if let Some(b) = node.block.take() {
                pool.release(b);
                freed += 1;
                self.resident -= 1;
            }
            node.swapped = true;
        }
        freed
    }

    /// Restore swapped nodes on a matched path: re-allocate one block
    /// per node and clear the swapped flag.  All-or-nothing; returns
    /// the number of blocks restored (0 if the pool lacks room).
    pub fn restore(&mut self, nodes: &[NodeId], pool: &mut BlockPool) -> usize {
        if pool.free_blocks() < nodes.len() {
            return 0;
        }
        for &n in nodes {
            debug_assert!(self.nodes[n].swapped && self.nodes[n].block.is_none());
            let b = pool.alloc(1).expect("checked free_blocks")[0];
            self.nodes[n].block = Some(b);
            self.nodes[n].swapped = false;
            self.resident += 1;
        }
        nodes.len()
    }

    /// Drop everything unpinned (used on engine reset between runs).
    pub fn clear(&mut self, pool: &mut BlockPool) -> Vec<u64> {
        let (_, dropped) = self.evict(usize::MAX - 1, pool);
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> BlockPool {
        BlockPool::new(1024 * 16 * 64, 16, 64) // 1024 blocks of 16 tokens
    }

    fn toks(n: usize, salt: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 7 + salt).collect()
    }

    #[test]
    fn miss_on_empty() {
        let mut r = RadixCache::new();
        let m = r.lookup(&toks(32, 0));
        assert_eq!(m.matched_tokens, 0);
        assert!(m.path.is_empty());
    }

    #[test]
    fn insert_then_full_hit() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let t = toks(48, 0);
        assert!(r.insert(&t, 99, &mut p));
        assert_eq!(p.used(), 3);
        let m = r.lookup(&t);
        assert_eq!(m.matched_tokens, 48);
        assert_eq!(m.payload, Some((99, 48)));
    }

    #[test]
    fn partial_block_not_cached() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let t = toks(40, 0); // 2.5 blocks -> 2 cached
        assert!(r.insert(&t, 1, &mut p));
        assert_eq!(p.used(), 2);
        let m = r.lookup(&t);
        assert_eq!(m.matched_tokens, 32);
    }

    #[test]
    fn shared_prefix_single_storage() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        let mut b = a.clone();
        b.extend(toks(16, 500)); // same first 32, diverges after
        assert!(r.insert(&a, 1, &mut p));
        let before = p.used();
        assert!(r.insert(&b, 2, &mut p));
        assert_eq!(p.used(), before + 1, "only divergent block allocated");
        let m = r.lookup(&b);
        assert_eq!(m.matched_tokens, 48);
        assert_eq!(m.payload, Some((2, 48)));
    }

    #[test]
    fn payload_nearest_on_partial_match() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        assert!(r.insert(&a, 7, &mut p));
        // prompt extends beyond cached context
        let mut b = a.clone();
        b.extend(toks(20, 900));
        let m = r.lookup(&b);
        assert_eq!(m.matched_tokens, 32);
        assert_eq!(m.payload, Some((7, 32)));
    }

    #[test]
    fn pinned_blocks_survive_eviction() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        let b = toks(32, 1000);
        assert!(r.insert(&a, 1, &mut p));
        assert!(r.insert(&b, 2, &mut p));
        let m = r.lookup(&a);
        r.pin(&m, &mut p);
        let (freed, dropped) = r.evict(100, &mut p);
        assert_eq!(freed, 2, "only b's two blocks evictable");
        assert_eq!(dropped, vec![2]);
        let m2 = r.lookup(&a);
        assert_eq!(m2.matched_tokens, 32);
        r.unpin(&m, &mut p);
    }

    #[test]
    fn eviction_is_lru_leaf_first() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let a = toks(32, 0);
        let b = toks(32, 1000);
        assert!(r.insert(&a, 1, &mut p));
        assert!(r.insert(&b, 2, &mut p));
        let _ = r.lookup(&a); // touch a — b becomes LRU
        let (freed, dropped) = r.evict(1, &mut p);
        assert_eq!(freed, 1);
        assert!(dropped.is_empty() || dropped == vec![2]);
        // a still fully matchable
        assert_eq!(r.lookup(&a).matched_tokens, 32);
    }

    #[test]
    fn evict_leaf_then_parent() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let t = toks(48, 0);
        assert!(r.insert(&t, 1, &mut p));
        let (freed, _) = r.evict(3, &mut p);
        assert_eq!(freed, 3);
        assert_eq!(p.used(), 0);
        assert_eq!(r.lookup(&t).matched_tokens, 0);
    }

    #[test]
    fn insert_fails_cleanly_when_pool_full() {
        let mut r = RadixCache::new();
        let mut p = BlockPool::new(2 * 16 * 64, 16, 64); // 2 blocks
        assert!(r.insert(&toks(32, 0), 1, &mut p));
        assert!(!r.insert(&toks(32, 999), 2, &mut p));
        assert_eq!(p.used(), 2);
    }

    #[test]
    fn pin_unpin_balances_refcounts() {
        let mut r = RadixCache::new();
        let mut p = pool();
        let t = toks(32, 0);
        assert!(r.insert(&t, 1, &mut p));
        let used = p.used();
        let m = r.lookup(&t);
        r.pin(&m, &mut p);
        r.unpin(&m, &mut p);
        assert_eq!(p.used(), used);
        // now evictable
        let (freed, _) = r.evict(10, &mut p);
        assert_eq!(freed, 2);
    }
}
