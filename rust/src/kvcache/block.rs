//! Paged KV block pool with a simulated GPU memory budget.
//!
//! The pool is the accounting layer: it owns no tensor data (tensors are
//! device buffers managed by the runtime), but every cache byte in the
//! system is represented by a block here, so admission, eviction and the
//! paper's memory-explosion dynamics (Fig 4b) are governed by this
//! budget.  Substitution note (README.md §Substitutions): the budget
//! stands in for the A100's 80 GB; what matters is the
//! footprint/budget ratio.

/// Index of a block in the pool's refcount table.
pub type BlockId = u32;

/// Chain-hash seed for the root of a prefix tree (FNV-1a offset basis).
pub const ROOT_HASH: u64 = 0xcbf2_9ce4_8422_2325;

/// Rolling per-block hash: the prefix-cache index key for one block of
/// tokens, chained on the parent block's hash (vLLM-style block
/// hashing).  Equal prefixes produce equal chains; the radix tree treats
/// equal hashes as candidates and falls back to token comparison, so
/// collisions cost a compare, never correctness.
pub fn hash_block(parent: u64, span: &[u32]) -> u64 {
    // FNV-1a over the tokens, seeded by the parent chain value...
    let mut h = parent ^ 0x9e37_79b9_7f4a_7c15;
    for &t in span {
        h ^= u64::from(t);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // ...with a splitmix64 finalizer so the HashMap sees well-mixed keys.
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// Content-address of one stored/indexed KV block: the rolling hash
/// chain through the block plus the token depth it ends at.  The depth
/// disambiguates the astronomically unlikely chain-hash collision
/// across depths; same-depth collisions cost a spurious sim hit (or a
/// token compare, in the radix tree), never memory unsafety.  Shared
/// by the radix prefix cache and the tiered snapshot store so a chain
/// hashed once (see `TokenBuf::block_chain`) serves both.
pub type BlockKey = (u64, usize);

/// The rolling chain keys of every block-aligned prefix of `prompt`,
/// ascending by depth: `[(h1, bt), (h2, 2*bt), ..]` with
/// `h1 = hash_block(ROOT_HASH, ..)` and each later hash chained on the
/// previous.  The trailing partial block (if any) gets no key.
pub fn chain_keys(prompt: &[u32], block_tokens: usize) -> Vec<BlockKey> {
    let bt = block_tokens.max(1);
    let mut keys = Vec::with_capacity(prompt.len() / bt);
    let mut h = ROOT_HASH;
    let mut off = 0;
    while off + bt <= prompt.len() {
        h = hash_block(h, &prompt[off..off + bt]);
        off += bt;
        keys.push((h, off));
    }
    keys
}

/// Fixed-capacity block pool with refcounted blocks and a free list.
#[derive(Debug)]
pub struct BlockPool {
    capacity: usize,
    refcount: Vec<u32>,
    free: Vec<BlockId>,
    used: usize,
    peak_used: usize,
    /// Bytes of KV data one block holds (block_tokens * kv_bytes_per_token).
    pub block_bytes: u64,
    /// Tokens per block.
    pub block_tokens: usize,
}

impl BlockPool {
    /// Build a pool from a byte budget and per-token cache cost.
    pub fn new(pool_bytes: u64, block_tokens: usize, kv_bytes_per_token: u64) -> Self {
        let block_bytes = block_tokens as u64 * kv_bytes_per_token;
        let capacity = (pool_bytes / block_bytes.max(1)) as usize;
        BlockPool {
            capacity,
            refcount: vec![0; capacity],
            free: (0..capacity as BlockId).rev().collect(),
            used: 0,
            peak_used: 0,
            block_bytes,
            block_tokens,
        }
    }

    /// Total blocks the byte budget affords.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Blocks currently allocated (refcount > 0).
    pub fn used(&self) -> usize {
        self.used
    }

    /// Blocks currently free.
    pub fn free_blocks(&self) -> usize {
        self.capacity - self.used
    }

    /// High-water mark of allocated blocks.
    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    /// High-water mark in bytes (the memory-explosion signal).
    pub fn peak_bytes(&self) -> u64 {
        self.peak_used as u64 * self.block_bytes
    }

    /// Current usage in bytes.
    pub fn used_bytes(&self) -> u64 {
        self.used as u64 * self.block_bytes
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Allocate `n` blocks with refcount 1.  All-or-nothing.
    pub fn alloc(&mut self, n: usize) -> Option<Vec<BlockId>> {
        if self.free.len() < n {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let id = self.free.pop().expect("checked len");
            debug_assert_eq!(self.refcount[id as usize], 0);
            self.refcount[id as usize] = 1;
            out.push(id);
        }
        self.used += n;
        self.peak_used = self.peak_used.max(self.used);
        Some(out)
    }

    /// Increment the refcount of a shared block (prefix reuse).
    pub fn retain(&mut self, id: BlockId) {
        debug_assert!(self.refcount[id as usize] > 0, "retain of free block");
        self.refcount[id as usize] += 1;
    }

    /// Decrement; frees the block when the count reaches zero.
    pub fn release(&mut self, id: BlockId) {
        let rc = &mut self.refcount[id as usize];
        assert!(*rc > 0, "release of free block {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
            self.used -= 1;
        }
    }

    /// Current refcount of `id` (0 = free).
    pub fn refcount(&self, id: BlockId) -> u32 {
        self.refcount[id as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> BlockPool {
        // 64 blocks of 16 tokens * 64 B/token.
        BlockPool::new(64 * 16 * 64, 16, 64)
    }

    #[test]
    fn capacity_from_budget() {
        let p = pool();
        assert_eq!(p.capacity(), 64);
        assert_eq!(p.block_bytes, 1024);
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut p = pool();
        let blocks = p.alloc(10).unwrap();
        assert_eq!(p.used(), 10);
        for b in blocks {
            p.release(b);
        }
        assert_eq!(p.used(), 0);
        assert_eq!(p.free_blocks(), 64);
    }

    #[test]
    fn alloc_is_all_or_nothing() {
        let mut p = pool();
        assert!(p.alloc(64).is_some());
        assert!(p.alloc(1).is_none());
        assert_eq!(p.used(), 64);
    }

    #[test]
    fn refcount_sharing() {
        let mut p = pool();
        let b = p.alloc(1).unwrap()[0];
        p.retain(b);
        p.release(b);
        assert_eq!(p.used(), 1, "still held by second ref");
        p.release(b);
        assert_eq!(p.used(), 0);
    }

    #[test]
    #[should_panic]
    fn double_release_panics() {
        let mut p = pool();
        let b = p.alloc(1).unwrap()[0];
        p.release(b);
        p.release(b);
    }

    #[test]
    fn peak_tracking() {
        let mut p = pool();
        let a = p.alloc(40).unwrap();
        for b in a {
            p.release(b);
        }
        p.alloc(5).unwrap();
        assert_eq!(p.peak_used(), 40);
        assert_eq!(p.peak_bytes(), 40 * 1024);
    }

    #[test]
    fn blocks_for_tokens_rounds_up() {
        let p = pool();
        assert_eq!(p.blocks_for_tokens(1), 1);
        assert_eq!(p.blocks_for_tokens(16), 1);
        assert_eq!(p.blocks_for_tokens(17), 2);
        assert_eq!(p.blocks_for_tokens(0), 0);
    }

    #[test]
    fn block_hash_chains_and_separates() {
        let a: Vec<u32> = (0..16).collect();
        let b: Vec<u32> = (1..17).collect();
        // Deterministic.
        assert_eq!(hash_block(ROOT_HASH, &a), hash_block(ROOT_HASH, &a));
        // Content-sensitive.
        assert_ne!(hash_block(ROOT_HASH, &a), hash_block(ROOT_HASH, &b));
        // Chain-sensitive: same block under different parents differs.
        let p1 = hash_block(ROOT_HASH, &a);
        let p2 = hash_block(ROOT_HASH, &b);
        assert_ne!(hash_block(p1, &a), hash_block(p2, &a));
    }
}
