//! Host-side swap tier accounting (paper Appendix E).
//!
//! When the eviction policy is `Swap`, victim cache bytes move to a
//! bounded host buffer instead of being dropped; restoring charges
//! simulated PCIe time in the executor cost model.  This module tracks
//! occupancy and traffic; it holds no data (the engine keeps snapshot
//! handles alive while swapped).

/// Bounded host-side swap space: occupancy + traffic accounting.
#[derive(Debug)]
pub struct SwapTier {
    capacity: u64,
    used: u64,
    /// Contexts moved out to the tier.
    pub swap_outs: u64,
    /// Contexts restored from the tier.
    pub swap_ins: u64,
    /// Total bytes swapped out.
    pub bytes_out: u64,
    /// Total bytes swapped back in.
    pub bytes_in: u64,
}

impl SwapTier {
    /// An empty tier with `capacity` bytes of host space.
    pub fn new(capacity: u64) -> Self {
        SwapTier { capacity, used: 0, swap_outs: 0, swap_ins: 0, bytes_out: 0, bytes_in: 0 }
    }

    /// Bytes currently parked in the tier.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes of remaining tier capacity.
    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    /// Reserve space for an evicted context; false -> must drop instead.
    pub fn swap_out(&mut self, bytes: u64) -> bool {
        if self.used + bytes > self.capacity {
            return false;
        }
        self.used += bytes;
        self.swap_outs += 1;
        self.bytes_out += bytes;
        true
    }

    /// Bring a context back; the space is released.
    pub fn swap_in(&mut self, bytes: u64) {
        debug_assert!(self.used >= bytes);
        self.used = self.used.saturating_sub(bytes);
        self.swap_ins += 1;
        self.bytes_in += bytes;
    }

    /// Discard a swapped context without restoring it.
    pub fn discard(&mut self, bytes: u64) {
        self.used = self.used.saturating_sub(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_in_roundtrip() {
        let mut s = SwapTier::new(100);
        assert!(s.swap_out(60));
        assert_eq!(s.free(), 40);
        s.swap_in(60);
        assert_eq!(s.used(), 0);
        assert_eq!(s.swap_outs, 1);
        assert_eq!(s.swap_ins, 1);
    }

    #[test]
    fn rejects_over_capacity() {
        let mut s = SwapTier::new(100);
        assert!(s.swap_out(80));
        assert!(!s.swap_out(30));
        assert_eq!(s.used(), 80);
    }

    #[test]
    fn discard_frees_without_counting_in() {
        let mut s = SwapTier::new(100);
        assert!(s.swap_out(50));
        s.discard(50);
        assert_eq!(s.used(), 0);
        assert_eq!(s.swap_ins, 0);
    }
}
