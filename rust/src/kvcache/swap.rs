//! Host-side swap tier accounting (paper Appendix E).
//!
//! When the eviction policy is `Swap`, victim cache bytes move to a
//! bounded host buffer instead of being dropped; restoring charges
//! simulated PCIe time in the executor cost model.  This module tracks
//! occupancy and traffic; it holds no data (the engine keeps snapshot
//! handles alive while swapped).
//!
//! Accounting is hard-errored: releasing more bytes than are parked
//! (a double restore or double discard) returns
//! [`TierAccountingError`] instead of saturating, so a caller bug can
//! no longer silently corrupt occupancy in release builds.  The
//! tiered snapshot store (`crate::store`) shares the same
//! [`TierBudget`] discipline.

use crate::store::{TierAccountingError, TierBudget};

/// Bounded host-side swap space: occupancy + traffic accounting.
#[derive(Debug)]
pub struct SwapTier {
    budget: TierBudget,
    /// Contexts moved out to the tier.
    pub swap_outs: u64,
    /// Contexts restored from the tier.
    pub swap_ins: u64,
    /// Total bytes swapped out.
    pub bytes_out: u64,
    /// Total bytes swapped back in.
    pub bytes_in: u64,
}

impl SwapTier {
    /// An empty tier with `capacity` bytes of host space.
    pub fn new(capacity: u64) -> Self {
        SwapTier {
            budget: TierBudget::new(capacity),
            swap_outs: 0,
            swap_ins: 0,
            bytes_out: 0,
            bytes_in: 0,
        }
    }

    /// Bytes currently parked in the tier.
    pub fn used(&self) -> u64 {
        self.budget.used()
    }

    /// Bytes of remaining tier capacity.
    pub fn free(&self) -> u64 {
        self.budget.free()
    }

    /// Reserve space for an evicted context; false -> must drop instead.
    pub fn swap_out(&mut self, bytes: u64) -> bool {
        if !self.budget.reserve(bytes) {
            return false;
        }
        self.swap_outs += 1;
        self.bytes_out += bytes;
        true
    }

    /// Bring a context back; the space is released.  Releasing bytes
    /// that were never parked (a double restore) is a hard error.
    pub fn swap_in(&mut self, bytes: u64) -> Result<(), TierAccountingError> {
        self.budget.release(bytes)?;
        self.swap_ins += 1;
        self.bytes_in += bytes;
        Ok(())
    }

    /// Discard a swapped context without restoring it.  A double
    /// discard is a hard error.
    pub fn discard(&mut self, bytes: u64) -> Result<(), TierAccountingError> {
        self.budget.release(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_in_roundtrip() {
        let mut s = SwapTier::new(100);
        assert!(s.swap_out(60));
        assert_eq!(s.free(), 40);
        s.swap_in(60).unwrap();
        assert_eq!(s.used(), 0);
        assert_eq!(s.swap_outs, 1);
        assert_eq!(s.swap_ins, 1);
    }

    #[test]
    fn rejects_over_capacity() {
        let mut s = SwapTier::new(100);
        assert!(s.swap_out(80));
        assert!(!s.swap_out(30));
        assert_eq!(s.used(), 80);
    }

    #[test]
    fn discard_frees_without_counting_in() {
        let mut s = SwapTier::new(100);
        assert!(s.swap_out(50));
        s.discard(50).unwrap();
        assert_eq!(s.used(), 0);
        assert_eq!(s.swap_ins, 0);
    }

    #[test]
    fn double_restore_is_a_hard_error() {
        let mut s = SwapTier::new(100);
        assert!(s.swap_out(40));
        s.swap_in(40).unwrap();
        // The release-build bug the pre-store tier hid: a second
        // restore used to saturate to zero and corrupt occupancy.
        let err = s.swap_in(40).unwrap_err();
        assert_eq!(err, TierAccountingError { released: 40, used: 0 });
        assert_eq!(s.used(), 0, "occupancy untouched");
        assert_eq!(s.swap_ins, 1, "failed restore not counted");
        assert!(s.discard(1).is_err(), "double discard equally hard");
    }
}
