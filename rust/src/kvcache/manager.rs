//! The KV cache manager: block pool + per-namespace prefix trees +
//! swap tier + per-sequence ownership.
//!
//! This is where the two serving modes differ (and the *only* place —
//! scheduler, executor and workloads are identical for both, so the
//! benches measure exactly the paper's variable):
//!
//!   * `Baseline`:  namespace per model.  N models serving the same
//!     workflow keep N copies of every context and re-prefill identical
//!     prompts per model — memory O(M + N·L_t) (paper Table 1).
//!   * `Icarus`:    single namespace.  One copy, cross-model prefix
//!     hits — memory O(M + L_t).

use std::collections::HashMap;

use crate::config::{EvictionPolicy, ServingConfig, ServingMode};

use super::block::{BlockId, BlockPool};
use super::radix::{Match, RadixCache};
use super::swap::SwapTier;

/// Outcome of trying to admit / grow a sequence.
#[derive(Debug, PartialEq, Eq)]
pub enum Alloc {
    /// Admitted; the payload says what the prefix cache covered.
    Ok(Admission),
    /// Pool exhausted even after eviction: caller must preempt a running
    /// sequence (or queue the request).
    NoSpace,
}

/// What an admission found in (and evicted from) the cache.
#[derive(Debug, PartialEq, Eq, Default)]
pub struct Admission {
    /// Prompt tokens covered by the prefix cache (no prefill needed).
    pub cached_tokens: usize,
    /// Engine payload (cache snapshot id) for the matched prefix and the
    /// token count that snapshot covers.
    pub snapshot: Option<(u64, usize)>,
    /// Snapshot ids whose radix nodes were evicted to make room — the
    /// engine must drop the corresponding device buffers.
    pub dropped_snapshots: Vec<u64>,
    /// Bytes restored from the swap tier for this admission (the engine
    /// charges PCIe time for them).
    pub swap_in_bytes: u64,
}

#[derive(Debug)]
struct SeqState {
    namespace: usize,
    /// Blocks owned exclusively by this sequence (uncached portion).
    own_blocks: Vec<BlockId>,
    /// Pinned prefix match (shared blocks).
    pinned: Option<Match>,
    /// Total tokens currently resident for this sequence.
    tokens: usize,
}

/// Cache-policy counters the manager accumulates during a run.
#[derive(Debug, Default)]
pub struct ManagerStats {
    /// Blocks evicted from the prefix trees.
    pub evicted_blocks: u64,
    /// Prefix-cache publishes that failed for lack of pool space.
    pub failed_inserts: u64,
    /// Tokens released by preemptions.
    pub preempted_tokens: u64,
    /// Evictions that wanted to swap but fell back to hard eviction
    /// because the swap tier lacked room for the shortfall.
    pub swap_tier_full: u64,
    /// Evictions that wanted to swap while the tier had room, but the
    /// tree had nothing (left) swappable — previously mislabeled as a
    /// tier rejection.
    pub swap_nothing_swappable: u64,
}

/// The façade the scheduler talks to: block pool + per-namespace prefix
/// trees + swap tier + per-sequence ownership (see the module docs).
pub struct KvCacheManager {
    /// The block pool every cache byte is accounted against.
    pub pool: BlockPool,
    trees: Vec<RadixCache>,
    seqs: HashMap<u64, SeqState>,
    mode: ServingMode,
    eviction: EvictionPolicy,
    /// Host-side swap tier (used by the `Swap` eviction policy).
    pub swap: SwapTier,
    prefix_caching: bool,
    /// Bytes per token of KV cache — pricing evictions for swap.
    kv_bytes_per_token: u64,
    /// Snapshot ids dropped by an admission/growth attempt that then
    /// failed with `NoSpace`: the eviction is not undone by the
    /// failure, so the handles are parked here for the engine to drain
    /// via `take_orphaned` (returning them inside `Alloc::NoSpace`
    /// would break every pattern match on the variant).
    orphaned: Vec<u64>,
    /// True when a tiered snapshot store is configured: hard evictions
    /// then reconstruct payload-bearing victims' contexts for demotion
    /// (GPU → host tier) instead of losing them outright.
    demote_to_store: bool,
    /// Contexts of payload-bearing nodes hard-evicted since the last
    /// [`KvCacheManager::take_demoted`] drain — the engine publishes
    /// them into the tiered store.
    demoted: Vec<Vec<u32>>,
    /// Cache-policy counters for the run.
    pub stats: ManagerStats,
}

impl KvCacheManager {
    /// Manager sized by `cfg`'s pool budget, with one prefix tree per
    /// namespace (N for baseline, 1 for ICaRus).
    pub fn new(cfg: &ServingConfig, kv_bytes_per_token: u64, n_models: usize) -> Self {
        let n_trees = match cfg.mode {
            ServingMode::Baseline => n_models,
            ServingMode::Icarus => 1,
        };
        KvCacheManager {
            pool: BlockPool::new(cfg.kv_pool_bytes, cfg.block_tokens, kv_bytes_per_token),
            trees: (0..n_trees).map(|_| RadixCache::with_block_tokens(cfg.block_tokens)).collect(),
            seqs: HashMap::new(),
            mode: cfg.mode,
            eviction: cfg.eviction,
            swap: SwapTier::new(cfg.swap_bytes),
            prefix_caching: cfg.prefix_caching,
            kv_bytes_per_token,
            orphaned: Vec::new(),
            demote_to_store: cfg.store_host_bytes + cfg.store_disk_bytes > 0,
            demoted: Vec::new(),
            stats: ManagerStats::default(),
        }
    }

    /// Cache namespace for a model: ICaRus collapses all models to 0.
    pub fn namespace_of(&self, model_id: usize) -> usize {
        match self.mode {
            ServingMode::Baseline => model_id,
            ServingMode::Icarus => 0,
        }
    }

    /// Cache-namespacing mode this manager was built with.
    pub fn mode(&self) -> ServingMode {
        self.mode
    }

    /// Sequences currently holding pool resources.
    pub fn active_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Evict from this namespace's tree (then others) until `want`
    /// blocks are free or nothing is evictable.  Dropped snapshot ids
    /// are returned; under Swap policy they are parked in the swap tier
    /// when it has room (engine restores them later), otherwise dropped.
    fn make_room(&mut self, want: usize, namespace: usize) -> Vec<u64> {
        let mut dropped_all = Vec::new();
        let order: Vec<usize> = std::iter::once(namespace)
            .chain((0..self.trees.len()).filter(|&t| t != namespace))
            .collect();
        for t in order {
            if self.pool.free_blocks() >= want {
                break;
            }
            let need = want - self.pool.free_blocks();
            if self.eviction == EvictionPolicy::Swap {
                // Swap-mode: free blocks but keep contexts matchable;
                // the engine's snapshot handles act as the host copy.
                // Bounded by the swap tier's byte budget.
                let room = (self.swap.free() / self.pool.block_bytes) as usize;
                let to_swap = need.min(room);
                let mut swapped = 0;
                if to_swap > 0 {
                    swapped = self.trees[t].evict_swap(to_swap, &mut self.pool);
                    self.stats.evicted_blocks += swapped as u64;
                    let ok = self.swap.swap_out(swapped as u64 * self.pool.block_bytes);
                    debug_assert!(ok, "room was checked");
                }
                if self.pool.free_blocks() >= want {
                    continue;
                }
                // Falling through to hard eviction: attribute why swap
                // could not cover the shortfall (both can apply —
                // this used to be one mislabeled `swap_rejected`).
                if room < need {
                    self.stats.swap_tier_full += 1;
                }
                if swapped < to_swap {
                    self.stats.swap_nothing_swappable += 1;
                }
            }
            let need = want.saturating_sub(self.pool.free_blocks());
            let (freed, dropped) = if self.demote_to_store {
                let (freed, dropped, demoted) = self.trees[t].evict_demoting(need, &mut self.pool);
                self.demoted.extend(demoted);
                (freed, dropped)
            } else {
                self.trees[t].evict(need, &mut self.pool)
            };
            self.stats.evicted_blocks += freed as u64;
            dropped_all.extend(dropped);
        }
        dropped_all
    }

    /// Read-only coverage probe: prompt tokens an admission for
    /// `model_id` could serve from the prefix cache right now (match
    /// depth through the deepest snapshot-bearing node), with **no
    /// side effects** (no LRU touch, no pin) — see
    /// [`RadixCache::peek`].  Schedulers use this to rank and budget
    /// waiting turns; the answer is advisory (the cache can change
    /// before admission) but exact at probe time.
    pub fn probe_cached_tokens(&self, model_id: usize, prompt: &[u32]) -> usize {
        if !self.prefix_caching {
            return 0;
        }
        self.trees[self.namespace_of(model_id)].peek(prompt)
    }

    /// [`KvCacheManager::probe_cached_tokens`] over a [`TokenBuf`],
    /// going through the buffer's memoized rolling-hash chain
    /// ([`TokenBuf::block_chain`] + [`RadixCache::peek_with_chain`]):
    /// the scheduler re-probes every waiting turn every step, and a
    /// turn's prompt never changes while it waits, so each block is
    /// hashed once for the turn's lifetime instead of once per probe.
    ///
    /// [`TokenBuf`]: crate::tokens::TokenBuf
    /// [`TokenBuf::block_chain`]: crate::tokens::TokenBuf::block_chain
    pub fn probe_cached_tokens_buf(
        &self,
        model_id: usize,
        prompt: &crate::tokens::TokenBuf,
    ) -> usize {
        if !self.prefix_caching {
            return 0;
        }
        let chain = prompt.block_chain(self.pool.block_tokens);
        self.trees[self.namespace_of(model_id)].peek_with_chain(prompt, &chain)
    }

    /// Cache snapshots the prefix trees currently keep alive (payload
    /// count across namespaces).  The executor's live-handle count must
    /// match this at end of run if the engine dropped every handle it
    /// was handed back — the no-leak invariant the property tests pin.
    pub fn live_payloads(&self) -> usize {
        self.trees.iter().map(RadixCache::live_payloads).sum()
    }

    /// Admit a sequence: match its prompt against the prefix cache, pin
    /// the match, and allocate blocks for the uncached remainder.
    pub fn begin_sequence(&mut self, seq_id: u64, model_id: usize, prompt: &[u32]) -> Alloc {
        assert!(!self.seqs.contains_key(&seq_id), "duplicate seq {seq_id}");
        let ns = self.namespace_of(model_id);
        let m = if self.prefix_caching {
            self.trees[ns].lookup(prompt)
        } else {
            Match { matched_tokens: 0, path: vec![], payload: None, swapped_nodes: vec![] }
        };
        let uncached = prompt.len() - m.matched_tokens;
        // Pin the matched path *before* making room so eviction can
        // neither drop nor swap it between lookup and use.
        self.trees[ns].pin(&m, &mut self.pool);
        // Blocks needed: the uncached remainder plus re-materializing any
        // matched blocks currently parked in the swap tier.
        let restore_blocks = m.swapped_nodes.len();
        let need = self.pool.blocks_for_tokens(uncached) + restore_blocks;
        let mut dropped = Vec::new();
        if self.pool.free_blocks() < need {
            dropped = self.make_room(need, ns);
        }
        if self.pool.free_blocks() < need {
            self.trees[ns].unpin(&m, &mut self.pool);
            self.orphaned.extend(dropped);
            return Alloc::NoSpace;
        }
        let mut swap_in_bytes = 0;
        if restore_blocks > 0 {
            let restored = self.trees[ns].restore(&m.swapped_nodes, &mut self.pool);
            debug_assert_eq!(restored, restore_blocks, "free space was checked");
            swap_in_bytes = restored as u64 * self.pool.block_bytes;
            self.swap.swap_in(swap_in_bytes).expect("swap tier accounting");
        }
        let Some(own) = self.pool.alloc(self.pool.blocks_for_tokens(uncached)) else {
            self.trees[ns].unpin(&m, &mut self.pool);
            self.orphaned.extend(dropped);
            return Alloc::NoSpace;
        };
        let adm = Admission {
            cached_tokens: m.matched_tokens,
            snapshot: m.payload,
            dropped_snapshots: dropped,
            swap_in_bytes,
        };
        self.seqs.insert(
            seq_id,
            SeqState { namespace: ns, own_blocks: own, pinned: Some(m), tokens: prompt.len() },
        );
        Alloc::Ok(adm)
    }

    /// Grow a sequence by `n` decoded tokens, allocating blocks on
    /// boundary crossings.  `NoSpace` -> the scheduler must preempt.
    pub fn append_tokens(&mut self, seq_id: u64, n: usize) -> Alloc {
        let ns;
        let need;
        {
            let st = self.seqs.get(&seq_id).expect("unknown seq");
            ns = st.namespace;
            let pinned_tokens = st.pinned.as_ref().map_or(0, |m| m.matched_tokens);
            let have = pinned_tokens / self.pool.block_tokens + st.own_blocks.len();
            let want_total = self.pool.blocks_for_tokens(st.tokens + n);
            need = want_total.saturating_sub(have);
        }
        let mut dropped = Vec::new();
        if need > 0 && self.pool.free_blocks() < need {
            dropped = self.make_room(need, ns);
        }
        if need > 0 {
            let Some(mut blocks) = self.pool.alloc(need) else {
                self.orphaned.extend(dropped);
                return Alloc::NoSpace;
            };
            let st = self.seqs.get_mut(&seq_id).unwrap();
            st.own_blocks.append(&mut blocks);
        }
        let st = self.seqs.get_mut(&seq_id).unwrap();
        st.tokens += n;
        Alloc::Ok(Admission {
            cached_tokens: 0,
            snapshot: None,
            dropped_snapshots: dropped,
            swap_in_bytes: 0,
        })
    }

    /// Finish a sequence: release its resources and (optionally) publish
    /// its full context into the prefix cache under `snapshot` so later
    /// turns — from any model in ICaRus mode — hit it.
    pub fn finish_sequence(
        &mut self,
        seq_id: u64,
        full_context: &[u32],
        snapshot: Option<u64>,
    ) -> Vec<u64> {
        let st = self.seqs.remove(&seq_id).expect("unknown seq");
        if let Some(m) = &st.pinned {
            self.trees[st.namespace].unpin(m, &mut self.pool);
        }
        for b in st.own_blocks {
            self.pool.release(b);
        }
        let mut dropped = Vec::new();
        if self.prefix_caching {
            if let Some(snap) = snapshot {
                let need = self.pool.blocks_for_tokens(
                    (full_context.len() / self.pool.block_tokens) * self.pool.block_tokens,
                );
                if self.pool.free_blocks() < need {
                    dropped = self.make_room(need, st.namespace);
                }
                let tree = &mut self.trees[st.namespace];
                let (inserted, displaced) =
                    tree.insert_with_displaced(full_context, snap, &mut self.pool);
                if !inserted {
                    self.stats.failed_inserts += 1;
                    dropped.push(snap); // engine should drop the snapshot
                }
                if let Some(old) = displaced {
                    // A re-published identical context displaced the
                    // node's previous snapshot; hand it back for drop.
                    dropped.push(old);
                }
            }
        } else if let Some(snap) = snapshot {
            dropped.push(snap);
        }
        dropped
    }

    /// Preempt a running sequence: all its resources are released; under
    /// `Recompute` its tokens will be re-prefilled on resume.
    pub fn preempt(&mut self, seq_id: u64) -> usize {
        let st = self.seqs.remove(&seq_id).expect("unknown seq");
        if let Some(m) = &st.pinned {
            self.trees[st.namespace].unpin(m, &mut self.pool);
        }
        for b in st.own_blocks {
            self.pool.release(b);
        }
        self.stats.preempted_tokens += st.tokens as u64;
        st.tokens
    }

    /// Drain snapshot ids whose radix nodes were evicted by an
    /// admission/growth attempt that subsequently failed with
    /// [`Alloc::NoSpace`].  The failure does not undo the eviction, so
    /// the engine must drop these handles or they leak for the rest of
    /// the run (the per-policy no-leak property tests pin this).
    pub fn take_orphaned(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.orphaned)
    }

    /// Drain the contexts of payload-bearing nodes hard-evicted since
    /// the last call, for demotion into the tiered snapshot store
    /// (always empty unless the store is configured).
    pub fn take_demoted(&mut self) -> Vec<Vec<u32>> {
        std::mem::take(&mut self.demoted)
    }

    /// KV cache cost per token this manager prices evictions with.
    pub fn kv_bytes_per_token(&self) -> u64 {
        self.kv_bytes_per_token
    }

    /// Total resident cache tokens across namespaces (diagnostics).
    pub fn resident_blocks(&self) -> usize {
        self.pool.used()
    }

    /// Blocks held by the prefix trees themselves (one per resident
    /// node) — the pool remainder is owned by active sequences, so
    /// `resident_blocks() == resident_cache_blocks()` iff no sequence
    /// state leaked.
    pub fn resident_cache_blocks(&self) -> usize {
        self.trees.iter().map(RadixCache::resident_nodes).sum()
    }

    /// Tree nodes currently parked in the swap tier (one tier block
    /// each): `swap.used()` must equal this times the block size as
    /// long as only tree swaps charge the tier.
    pub fn swapped_cache_blocks(&self) -> usize {
        self.trees.iter().map(RadixCache::swapped_nodes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: ServingMode, pool_blocks: u64) -> ServingConfig {
        ServingConfig {
            mode,
            kv_pool_bytes: pool_blocks * 16 * 64,
            block_tokens: 16,
            ..Default::default()
        }
    }

    fn prompt(n: usize, salt: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 3 + salt).collect()
    }

    #[test]
    fn icarus_shares_across_models() {
        let mut m = KvCacheManager::new(&cfg(ServingMode::Icarus, 256), 64, 4);
        let p = prompt(64, 0);
        // model 0 serves the context and publishes it
        assert!(matches!(m.begin_sequence(1, 0, &p), Alloc::Ok(_)));
        m.finish_sequence(1, &p, Some(42));
        // model 3 now hits the same cache — the paper's headline
        match m.begin_sequence(2, 3, &p) {
            Alloc::Ok(adm) => {
                assert_eq!(adm.cached_tokens, 64);
                assert_eq!(adm.snapshot, Some((42, 64)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn baseline_does_not_share_across_models() {
        let mut m = KvCacheManager::new(&cfg(ServingMode::Baseline, 256), 64, 4);
        let p = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p), Alloc::Ok(_)));
        m.finish_sequence(1, &p, Some(42));
        match m.begin_sequence(2, 3, &p) {
            Alloc::Ok(adm) => assert_eq!(adm.cached_tokens, 0),
            other => panic!("{other:?}"),
        }
        // but the same model does share
        match m.begin_sequence(3, 0, &p) {
            Alloc::Ok(adm) => assert_eq!(adm.cached_tokens, 64),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn baseline_memory_is_n_times_icarus() {
        let p = prompt(128, 0);
        let mut usage = Vec::new();
        for mode in [ServingMode::Icarus, ServingMode::Baseline] {
            let mut m = KvCacheManager::new(&cfg(mode, 1024), 64, 4);
            for model in 0..4 {
                let sid = model as u64;
                assert!(matches!(m.begin_sequence(sid, model, &p), Alloc::Ok(_)));
                m.finish_sequence(sid, &p, Some(sid));
            }
            usage.push(m.pool.used());
        }
        assert_eq!(usage[1], 4 * usage[0], "Table 1: O(M+N*Lt) vs O(M+Lt)");
    }

    #[test]
    fn eviction_frees_space_for_new_sequences() {
        let mut m = KvCacheManager::new(&cfg(ServingMode::Icarus, 8), 64, 1);
        let p1 = prompt(64, 0); // 4 blocks
        assert!(matches!(m.begin_sequence(1, 0, &p1), Alloc::Ok(_)));
        m.finish_sequence(1, &p1, Some(1));
        assert_eq!(m.pool.used(), 4);
        // second distinct prompt needs 8 blocks -> must evict p1's tree
        let p2 = prompt(128, 900);
        match m.begin_sequence(2, 0, &p2) {
            Alloc::Ok(adm) => {
                assert_eq!(adm.cached_tokens, 0);
                assert!(adm.dropped_snapshots.contains(&1));
            }
            other => panic!("{other:?}"),
        }
        assert!(m.stats.evicted_blocks >= 4);
    }

    #[test]
    fn no_space_when_pinned_everywhere() {
        let mut m = KvCacheManager::new(&cfg(ServingMode::Icarus, 4), 64, 1);
        let p1 = prompt(64, 0); // takes all 4 blocks, active (pinned via own)
        assert!(matches!(m.begin_sequence(1, 0, &p1), Alloc::Ok(_)));
        let p2 = prompt(32, 500);
        assert_eq!(m.begin_sequence(2, 0, &p2), Alloc::NoSpace);
        // preempting seq 1 releases space
        assert_eq!(m.preempt(1), 64);
        assert!(matches!(m.begin_sequence(2, 0, &p2), Alloc::Ok(_)));
    }

    #[test]
    fn append_allocates_on_block_boundary() {
        let mut m = KvCacheManager::new(&cfg(ServingMode::Icarus, 16), 64, 1);
        let p = prompt(16, 0); // exactly 1 block
        assert!(matches!(m.begin_sequence(1, 0, &p), Alloc::Ok(_)));
        assert_eq!(m.pool.used(), 1);
        for _ in 0..16 {
            assert!(matches!(m.append_tokens(1, 1), Alloc::Ok(_)));
        }
        assert_eq!(m.pool.used(), 2, "crossed one boundary");
    }

    #[test]
    fn finish_releases_everything_without_snapshot() {
        let mut m = KvCacheManager::new(&cfg(ServingMode::Icarus, 16), 64, 1);
        let p = prompt(48, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p), Alloc::Ok(_)));
        m.finish_sequence(1, &p, None);
        assert_eq!(m.pool.used(), 0);
    }

    #[test]
    fn prefix_hit_pins_against_eviction() {
        let mut m = KvCacheManager::new(&cfg(ServingMode::Icarus, 8), 64, 1);
        let p = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p), Alloc::Ok(_)));
        m.finish_sequence(1, &p, Some(9));
        // active hit
        let adm = match m.begin_sequence(2, 0, &p) {
            Alloc::Ok(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(adm.cached_tokens, 64);
        // new prompt that would need the whole pool cannot evict pinned
        let p2 = prompt(128, 700);
        assert_eq!(m.begin_sequence(3, 0, &p2), Alloc::NoSpace);
    }

    #[test]
    fn swap_policy_preserves_matchability() {
        let mut c = cfg(ServingMode::Icarus, 8);
        c.eviction = EvictionPolicy::Swap;
        let mut m = KvCacheManager::new(&c, 64, 1);
        let p1 = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p1), Alloc::Ok(_)));
        m.finish_sequence(1, &p1, Some(5));
        // Force p1's blocks out to the swap tier.
        let p2 = prompt(128, 300);
        match m.begin_sequence(2, 0, &p2) {
            Alloc::Ok(adm) => {
                assert!(adm.dropped_snapshots.is_empty(), "swapped, not dropped");
            }
            other => panic!("{other:?}"),
        }
        assert!(m.swap.swap_outs > 0);
        assert!(m.swap.used() > 0);
        m.preempt(2);
        // p1 is still matchable; admitting it restores from swap and
        // charges swap-in bytes.
        match m.begin_sequence(3, 0, &p1) {
            Alloc::Ok(adm) => {
                assert_eq!(adm.cached_tokens, 64);
                assert_eq!(adm.snapshot, Some((5, 64)));
                assert!(adm.swap_in_bytes > 0, "restore must charge PCIe");
            }
            other => panic!("{other:?}"),
        }
        assert!(m.swap.swap_ins > 0);
    }

    #[test]
    fn swap_shortfall_attribution_tier_full() {
        // Zero-capacity tier: falling through to hard eviction is a
        // tier-full case, not "nothing swappable".
        let mut c = cfg(ServingMode::Icarus, 8);
        c.eviction = EvictionPolicy::Swap;
        c.swap_bytes = 0;
        let mut m = KvCacheManager::new(&c, 64, 1);
        let p1 = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p1), Alloc::Ok(_)));
        m.finish_sequence(1, &p1, Some(1));
        let p2 = prompt(128, 900); // needs the whole pool
        assert!(matches!(m.begin_sequence(2, 0, &p2), Alloc::Ok(_)));
        assert!(m.stats.swap_tier_full > 0);
        assert_eq!(m.stats.swap_nothing_swappable, 0);
    }

    #[test]
    fn swap_shortfall_attribution_nothing_swappable() {
        // Roomy tier but every cached node is already swapped: the old
        // accounting called this a tier rejection; it is not.
        let mut c = cfg(ServingMode::Icarus, 8);
        c.eviction = EvictionPolicy::Swap;
        let mut m = KvCacheManager::new(&c, 64, 1);
        let p1 = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p1), Alloc::Ok(_)));
        m.finish_sequence(1, &p1, Some(1));
        // p2 takes the whole pool; p1's 4 blocks go to the swap tier.
        let p2 = prompt(128, 900);
        assert!(matches!(m.begin_sequence(2, 0, &p2), Alloc::Ok(_)));
        assert_eq!(m.stats.swap_tier_full, 0);
        assert_eq!(m.stats.swap_nothing_swappable, 0);
        // A third prompt finds no free blocks, a roomy tier, and
        // nothing left to swap (p1 is swapped, p2 is active).
        let p3 = prompt(32, 500);
        assert_eq!(m.begin_sequence(3, 0, &p3), Alloc::NoSpace);
        assert_eq!(m.stats.swap_tier_full, 0);
        assert!(m.stats.swap_nothing_swappable > 0);
    }

    #[test]
    fn store_config_collects_demoted_contexts() {
        let mut c = cfg(ServingMode::Icarus, 8);
        c.store_host_bytes = 1 << 20;
        let mut m = KvCacheManager::new(&c, 64, 1);
        let p1 = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p1), Alloc::Ok(_)));
        m.finish_sequence(1, &p1, Some(7));
        let p2 = prompt(128, 900);
        assert!(matches!(m.begin_sequence(2, 0, &p2), Alloc::Ok(_))); // evicts p1
        assert_eq!(m.take_demoted(), vec![p1.clone()]);
        assert!(m.take_demoted().is_empty(), "drain is one-shot");
        // Without a store configured, eviction collects nothing.
        let mut m2 = KvCacheManager::new(&cfg(ServingMode::Icarus, 8), 64, 1);
        assert!(matches!(m2.begin_sequence(1, 0, &p1), Alloc::Ok(_)));
        m2.finish_sequence(1, &p1, Some(7));
        assert!(matches!(m2.begin_sequence(2, 0, &p2), Alloc::Ok(_)));
        assert!(m2.take_demoted().is_empty());
    }

    #[test]
    fn probe_reports_coverage_per_namespace_without_side_effects() {
        let mut m = KvCacheManager::new(&cfg(ServingMode::Baseline, 256), 64, 4);
        let p = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p), Alloc::Ok(_)));
        m.finish_sequence(1, &p, Some(42));
        assert_eq!(m.probe_cached_tokens(0, &p), 64, "same model covered");
        assert_eq!(m.probe_cached_tokens(3, &p), 0, "baseline: no cross-model");
        // Probing must not pin: an admission that needs the whole pool
        // can still evict the probed context afterwards.
        let big = prompt(256 * 16, 900);
        assert!(matches!(m.begin_sequence(2, 1, &big), Alloc::Ok(_)));
        assert_eq!(m.probe_cached_tokens(0, &p), 0, "probed context was evictable");
    }

    #[test]
    fn failed_admission_surfaces_orphaned_drops() {
        // Pool of 8 blocks; publish a 4-block context, then try to
        // admit a prompt needing more than the whole pool: the eviction
        // happens anyway, the admission still fails, and the dropped
        // payload must surface for the engine to release.
        let mut m = KvCacheManager::new(&cfg(ServingMode::Icarus, 8), 64, 1);
        let p = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p), Alloc::Ok(_)));
        m.finish_sequence(1, &p, Some(5));
        let big = prompt(16 * 16, 700); // 16 blocks > capacity
        assert_eq!(m.begin_sequence(2, 0, &big), Alloc::NoSpace);
        assert_eq!(m.take_orphaned(), vec![5], "evicted payload must surface");
        assert!(m.take_orphaned().is_empty(), "drain is one-shot");
        assert_eq!(m.live_payloads(), 0);
    }

    #[test]
    fn republish_hands_back_displaced_snapshot() {
        let mut m = KvCacheManager::new(&cfg(ServingMode::Icarus, 256), 64, 1);
        let p = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p), Alloc::Ok(_)));
        assert!(m.finish_sequence(1, &p, Some(10)).is_empty());
        assert_eq!(m.live_payloads(), 1);
        // The same context published again (a preempted turn rerun):
        // the displaced snapshot must come back for dropping.
        assert!(matches!(m.begin_sequence(2, 0, &p), Alloc::Ok(_)));
        let dropped = m.finish_sequence(2, &p, Some(11));
        assert_eq!(dropped, vec![10], "old snapshot handed back");
        assert_eq!(m.live_payloads(), 1);
    }

    #[test]
    fn disabled_prefix_caching_never_hits() {
        let mut c = cfg(ServingMode::Icarus, 256);
        c.prefix_caching = false;
        let mut m = KvCacheManager::new(&c, 64, 1);
        let p = prompt(64, 0);
        assert!(matches!(m.begin_sequence(1, 0, &p), Alloc::Ok(_)));
        let dropped = m.finish_sequence(1, &p, Some(3));
        assert_eq!(dropped, vec![3], "snapshot dropped immediately");
        match m.begin_sequence(2, 0, &p) {
            Alloc::Ok(adm) => assert_eq!(adm.cached_tokens, 0),
            other => panic!("{other:?}"),
        }
    }
}
