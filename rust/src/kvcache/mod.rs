//! Paged KV-cache management (the policy layer of the serving system).
//!
//! Layout mirrors vLLM: a block pool under a (simulated-GPU) byte budget,
//! per-namespace radix prefix trees, LRU eviction with recompute or swap,
//! and per-sequence block ownership.  `KvCacheManager` is the façade the
//! scheduler talks to; `ServingMode` decides whether all models share one
//! namespace (ICaRus) or get one each (baseline).

pub mod block;
pub mod manager;
pub mod radix;
pub mod swap;

pub use block::{BlockId, BlockPool};
pub use manager::{Admission, Alloc, KvCacheManager};
pub use radix::{Match, RadixCache};
pub use swap::SwapTier;
