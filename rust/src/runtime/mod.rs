//! Runtime: AOT artifact loading + PJRT execution (the xla crate).
//!
//! `Manifest` describes what `make artifacts` produced; `PjrtExecutor`
//! implements the engine's `Executor` trait over the compiled HLO.
//!
//! The real PJRT executor needs the `xla` PJRT bindings, which are not
//! available in the offline build; it is gated behind the `pjrt` cargo
//! feature.  Without the feature a stub with the same public surface is
//! compiled whose `load` fails, so every caller (CLI, benches, examples)
//! still builds and degrades gracefully at runtime.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use manifest::{Manifest, ModelSpec};
pub use pjrt::{PjrtExecutor, PjrtStats};
