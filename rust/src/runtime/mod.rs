//! Runtime: AOT artifact loading + PJRT execution (the xla crate).
//!
//! `Manifest` describes what `make artifacts` produced; `PjrtExecutor`
//! implements the engine's `Executor` trait over the compiled HLO.

pub mod manifest;
pub mod pjrt;

pub use manifest::{Manifest, ModelSpec};
pub use pjrt::{PjrtExecutor, PjrtStats};
