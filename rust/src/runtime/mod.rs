//! Runtime: the cooperative task executor, AOT artifact loading and
//! PJRT execution (the xla crate).
//!
//! [`exec`] is the deterministic per-replica cooperative task runtime
//! (local executor + virtual-time reactor) the serving engine uses to
//! overlap modeled store/swap transfers with compute (`--overlap on`).
//!
//! `Manifest` describes what `make artifacts` produced; `PjrtExecutor`
//! implements the engine's `Executor` trait over the compiled HLO.
//!
//! The real PJRT executor needs the `xla` PJRT bindings, which are not
//! available in the offline build; it is gated behind the `pjrt` cargo
//! feature.  Without the feature a stub with the same public surface is
//! compiled whose `load` fails, so every caller (CLI, benches, examples)
//! still builds and degrades gracefully at runtime.

pub mod exec;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use manifest::{Manifest, ModelSpec};
pub use pjrt::{PjrtExecutor, PjrtStats};
