//! Stub PJRT executor, compiled when the `pjrt` feature is off.
//!
//! Mirrors the public surface of `pjrt.rs` (the parts the CLI, benches,
//! examples and tests call) so the crate builds without the `xla` PJRT
//! bindings.  `load` always fails; since that is the only constructor,
//! every other method is statically unreachable.

use anyhow::{bail, Result};

use crate::config::ServingMode;
use crate::engine::executor::{ChunkSlot, DecodeSlot, Executor, PrefillOut, SnapshotId};

use super::manifest::{Manifest, ModelSpec};

/// Mirror of `pjrt::PjrtStats` (all zeros; never populated in the stub).
#[derive(Debug, Default, Clone)]
pub struct PjrtStats {
    /// Prefill invocations.
    pub prefill_calls: u64,
    /// Prefill chunks encoded (chunked-prefill path).
    pub prefill_chunk_calls: u64,
    /// Wall seconds spent in prefill.
    pub prefill_secs: f64,
    /// Decode steps executed.
    pub decode_calls: u64,
    /// Total sequence-slots across decode steps.
    pub decode_slots: u64,
    /// Wall seconds spent in decode.
    pub decode_secs: f64,
    /// Tokens decoded to catch a snapshot up to a deeper cached prefix.
    pub suffix_decode_tokens: u64,
}

/// Unconstructable stand-in for the real executor.
pub struct PjrtExecutor {
    mode: ServingMode,
    /// Mirror of the real executor's counters (never populated).
    pub stats: PjrtStats,
}

impl PjrtExecutor {
    /// Always fails: the `pjrt` feature (and the `xla` dependency) is
    /// required for the real runtime.
    pub fn load(
        _manifest: &Manifest,
        _config: &str,
        _mode: ServingMode,
        _n_models: usize,
    ) -> Result<Self> {
        bail!(
            "PJRT runtime unavailable: this binary was built without the `pjrt` \
             feature (the xla PJRT bindings are not vendored). Rebuild with \
             `cargo build --features pjrt` after adding the xla dependency, or \
             use `--executor sim`."
        )
    }

    /// Mirror of the real executor's accessor (statically unreachable).
    pub fn spec(&self) -> &ModelSpec {
        unreachable!("stub PjrtExecutor cannot be constructed")
    }

    /// Mirror of the real executor's accessor (statically unreachable).
    pub fn live_snapshots(&self) -> usize {
        unreachable!("stub PjrtExecutor cannot be constructed")
    }
}

impl Executor for PjrtExecutor {
    fn prefill(
        &mut self,
        _model_id: usize,
        _prompt: &[u32],
        _cached_tokens: usize,
        _base: Option<SnapshotId>,
    ) -> Result<PrefillOut> {
        unreachable!("stub PjrtExecutor cannot be constructed")
    }

    fn prefill_chunk(&mut self, _chunk: &mut ChunkSlot<'_>) -> Result<f64> {
        unreachable!("stub PjrtExecutor cannot be constructed")
    }

    fn decode(&mut self, _batch: &mut [DecodeSlot]) -> Result<f64> {
        unreachable!("stub PjrtExecutor cannot be constructed")
    }

    fn snapshot(&mut self, _cache: SnapshotId) -> SnapshotId {
        unreachable!("stub PjrtExecutor cannot be constructed")
    }

    fn drop_snapshot(&mut self, _snap: SnapshotId) {
        unreachable!("stub PjrtExecutor cannot be constructed")
    }

    fn swap_in_cost(&self, _bytes: u64) -> f64 {
        unreachable!("stub PjrtExecutor cannot be constructed")
    }

    fn mode(&self) -> ServingMode {
        self.mode
    }
}
