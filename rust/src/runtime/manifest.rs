//! AOT artifact manifest: what `python -m compile.aot` wrote and how to
//! call it (argument orders, shapes, file names).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::Value;

/// One serving config's artifacts + architecture numbers.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Config name (e.g. `serve-small`).
    pub name: String,
    /// Vocabulary size.
    pub vocab: usize,
    /// Model (residual-stream) width.
    pub d_model: usize,
    /// Transformer layers.
    pub layers: usize,
    /// Query heads.
    pub heads: usize,
    /// KV heads (GQA when < `heads`).
    pub kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// FFN hidden width.
    pub ffn: usize,
    /// Longest supported context.
    pub max_seq: usize,
    /// LoRA adapter rank.
    pub lora_rank: usize,
    /// LoRA scaling factor.
    pub lora_alpha: f64,
    /// KV cache cost per token (all layers, K+V).
    pub kv_bytes_per_token: u64,
    /// Total base-model parameters.
    pub param_count: u64,
    /// npz file holding the base weights.
    pub weights_file: String,
    /// npz key order matching the artifact's flat parameter arguments.
    pub param_names: Vec<String>,
    /// Flat LoRA argument names (layers.i.target.{A,B}) — the baseline
    /// decode / prefill artifact argument order.
    pub lora_names: Vec<String>,
    /// Subset taken by the ICaRus decode artifact (no k/v: the logical
    /// encoder is frozen, so jax prunes those parameters).
    pub lora_names_icarus: Vec<String>,
    /// Prefill bucket length -> artifact file.
    pub prefill: BTreeMap<usize, String>,
    /// Baseline decode artifact file.
    pub decode_baseline: String,
    /// ICaRus (paired-execution) decode artifact file.
    pub decode_icarus: String,
}

impl ModelSpec {
    /// Smallest prefill bucket that fits `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.prefill.keys().copied().find(|&b| b >= len)
    }

    /// Per-layer KV width (KV heads x head dim).
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim
    }
}

/// The artifact directory's index: what `make artifacts` produced.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Kernel lowering path the artifacts were built with (pallas/ref).
    pub kernels: String,
    /// Serving configs by name.
    pub configs: BTreeMap<String, ModelSpec>,
}

fn get_usize(v: &Value, key: &str) -> Result<usize> {
    v.get(key).and_then(Value::as_usize).ok_or_else(|| anyhow!("manifest missing {key}"))
}

impl Manifest {
    /// Read and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let v = Value::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let kernels = v
            .get("kernels")
            .and_then(Value::as_str)
            .unwrap_or("unknown")
            .to_string();
        let mut configs = BTreeMap::new();
        let cfgs = v.get("configs").and_then(Value::as_obj).ok_or_else(|| anyhow!("no configs"))?;
        for (name, c) in cfgs {
            let mut prefill = BTreeMap::new();
            if let Some(p) = c.get("prefill").and_then(Value::as_obj) {
                for (bucket, file) in p {
                    prefill.insert(
                        bucket.parse::<usize>().context("bucket key")?,
                        file.as_str().ok_or_else(|| anyhow!("bad prefill file"))?.to_string(),
                    );
                }
            }
            let names = |key: &str| -> Result<Vec<String>> {
                Ok(c.get(key)
                    .and_then(Value::as_arr)
                    .ok_or_else(|| anyhow!("missing {key}"))?
                    .iter()
                    .filter_map(|x| x.as_str().map(str::to_string))
                    .collect())
            };
            configs.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    vocab: get_usize(c, "vocab")?,
                    d_model: get_usize(c, "d_model")?,
                    layers: get_usize(c, "layers")?,
                    heads: get_usize(c, "heads")?,
                    kv_heads: get_usize(c, "kv_heads")?,
                    head_dim: get_usize(c, "head_dim")?,
                    ffn: get_usize(c, "ffn")?,
                    max_seq: get_usize(c, "max_seq")?,
                    lora_rank: get_usize(c, "lora_rank")?,
                    lora_alpha: c
                        .get("lora_alpha")
                        .and_then(Value::as_f64)
                        .ok_or_else(|| anyhow!("lora_alpha"))?,
                    kv_bytes_per_token: get_usize(c, "kv_bytes_per_token")? as u64,
                    param_count: get_usize(c, "param_count")? as u64,
                    weights_file: c
                        .get("weights")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("weights"))?
                        .to_string(),
                    param_names: names("param_names")?,
                    lora_names: names("lora_names")?,
                    lora_names_icarus: names("lora_names_icarus")
                        .unwrap_or_default(),
                    prefill,
                    decode_baseline: c
                        .get("decode_baseline")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("decode_baseline"))?
                        .to_string(),
                    decode_icarus: c
                        .get("decode_icarus")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("decode_icarus"))?
                        .to_string(),
                },
            );
        }
        Ok(Manifest { dir, kernels, configs })
    }

    /// The named config's spec, or an error listing what exists.
    pub fn spec(&self, name: &str) -> Result<&ModelSpec> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("config {name} not in manifest ({:?})", self.configs.keys()))
    }

    /// Absolute path of an artifact file named in the manifest.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> String {
        r#"{
          "kernels": "pallas",
          "configs": {
            "serve-small": {
              "vocab": 2048, "d_model": 128, "layers": 4, "heads": 8,
              "kv_heads": 4, "head_dim": 16, "ffn": 352, "max_seq": 1024,
              "lora_rank": 8, "lora_alpha": 16.0,
              "kv_bytes_per_token": 2048, "param_count": 1000000,
              "weights": "weights_serve-small.npz",
              "param_names": ["embed", "norm"],
              "lora_names": ["layers.0.q.A"],
              "lora_names_icarus": ["layers.0.q.A"],
              "prefill": {"32": "p32.hlo.txt", "128": "p128.hlo.txt"},
              "decode_baseline": "db.hlo.txt",
              "decode_icarus": "di.hlo.txt"
            }
          }
        }"#
        .to_string()
    }

    #[test]
    fn parse_and_bucket_selection() {
        let dir = std::env::temp_dir().join(format!("icarus_mani_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let s = m.spec("serve-small").unwrap();
        assert_eq!(s.layers, 4);
        assert_eq!(s.bucket_for(10), Some(32));
        assert_eq!(s.bucket_for(33), Some(128));
        assert_eq!(s.bucket_for(1000), None);
        assert_eq!(s.kv_dim(), 64);
        assert!(m.spec("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
