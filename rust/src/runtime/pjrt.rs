//! PJRT executor: loads the AOT HLO-text artifacts and runs real
//! prefill/decode on the request path (python is long gone by now).
//!
//! Compiled only with the `pjrt` cargo feature (needs the `xla` PJRT
//! bindings, which are not vendored in the offline build); the default
//! build substitutes `pjrt_stub.rs`, whose `load` fails at runtime.
//!
//! Cache representation: the published `xla` crate (0.1.6 / xla_extension
//! 0.5.1) returns a tuple-rooted computation as ONE tuple buffer and has
//! no buffer-level untuple, so cache state round-trips through host
//! `Literal`s between steps (on the CPU PJRT client the "device" is host
//! memory, so these are memcpys; see EXPERIMENTS.md §Perf for the
//! measured cost and README.md §Substitutions for the TPU story).
//! Base weights and
//! LoRA adapters are uploaded once and stay device-resident across steps
//! (§Perf iteration 2: re-uploading them per step dominated decode).
//!
//! Snapshot ids map to `Rc<CacheLits>`: publishing a prefix-cache
//! snapshot is a refcount bump, not a copy.

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::config::ServingMode;
use crate::engine::executor::{ChunkSlot, DecodeSlot, Executor, PrefillOut, SnapshotId};
use crate::rng::Rng;

use super::manifest::{Manifest, ModelSpec};

/// K/V cache literals for one context ([L, max_seq, KV, dh] f32 each).
pub struct CacheLits {
    /// Key cache literal.
    pub k: Literal,
    /// Value cache literal.
    pub v: Literal,
}

/// Executor over the AOT HLO artifacts on the PJRT CPU client (see the
/// module docs for the cache representation).
pub struct PjrtExecutor {
    client: PjRtClient,
    spec: ModelSpec,
    mode: ServingMode,
    prefill_exes: BTreeMap<usize, PjRtLoadedExecutable>,
    decode_exe: PjRtLoadedExecutable,
    /// Base weights in artifact argument order, resident as device
    /// buffers (uploaded once — re-uploading ~5 MB of literals per
    /// decode step costs more than the step's compute; §Perf).
    weights: Vec<PjRtBuffer>,
    /// Backing literals for `weights` — BufferFromHostLiteral copies
    /// asynchronously, so the source must outlive the executor.
    _weights_backing: Vec<Literal>,
    /// Per-model LoRA buffers in artifact argument order.
    adapters: Vec<Vec<PjRtBuffer>>,
    /// All-zero adapter — ICaRus prefill must be pure logical encoder.
    zero_adapter: Vec<PjRtBuffer>,
    /// Indices into the full adapter list forming the ICaRus decode
    /// artifact's argument subset (jax prunes the unused k/v params).
    icarus_lora_idx: Vec<usize>,
    snapshots: HashMap<SnapshotId, Rc<CacheLits>>,
    next_id: SnapshotId,
    /// Modeled host<->device bandwidth for swap restores (bytes/sec).
    pub swap_bandwidth: f64,
    /// Call/time counters for the run.
    pub stats: PjrtStats,
}

/// Call/time counters the PJRT executor accumulates.
#[derive(Debug, Default, Clone)]
pub struct PjrtStats {
    /// Prefill invocations.
    pub prefill_calls: u64,
    /// Prefill chunks encoded (chunked-prefill path).
    pub prefill_chunk_calls: u64,
    /// Wall seconds spent in prefill.
    pub prefill_secs: f64,
    /// Decode steps executed.
    pub decode_calls: u64,
    /// Total sequence-slots across decode steps.
    pub decode_slots: u64,
    /// Wall seconds spent in decode.
    pub decode_secs: f64,
    /// Tokens decoded to catch a snapshot up to a deeper cached prefix.
    pub suffix_decode_tokens: u64,
}

fn compile(client: &PjRtClient, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e}", path.display()))
}

fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

impl PjrtExecutor {
    /// Load artifacts for `config` and build `n_models` LoRA adapters.
    ///
    /// Adapter values are deterministic pseudo-random per model id —
    /// serving behaviour depends on their shape/motion, not their
    /// training state; trained adapters from `compile/train.py` can be
    /// dropped in via the same npz path.
    pub fn load(
        manifest: &Manifest,
        config: &str,
        mode: ServingMode,
        n_models: usize,
    ) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let spec = manifest.spec(config)?.clone();

        let mut prefill_exes = BTreeMap::new();
        for (&bucket, file) in &spec.prefill {
            prefill_exes.insert(bucket, compile(&client, &manifest.path(file))?);
        }
        let decode_file = match mode {
            ServingMode::Baseline => &spec.decode_baseline,
            ServingMode::Icarus => &spec.decode_icarus,
        };
        let decode_exe = compile(&client, &manifest.path(decode_file))?;

        // Load weights as literals, then upload once.  (Not
        // `PjRtBuffer::read_npz`: the 0.1.6 crate's raw-bytes path maps
        // ElementType to the wrong PrimitiveType id and produces
        // wrongly-typed buffers.)
        let loaded = Literal::read_npz(manifest.path(&spec.weights_file), &())
            .map_err(|e| anyhow!("weights npz: {e}"))?;
        let mut by_name: HashMap<String, Literal> = loaded.into_iter().collect();
        let mut weights = Vec::with_capacity(spec.param_names.len());
        let mut weights_backing = Vec::with_capacity(spec.param_names.len());
        for name in &spec.param_names {
            let lit =
                by_name.remove(name).ok_or_else(|| anyhow!("weights npz missing {name}"))?;
            weights.push(
                client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e| anyhow!("weight upload {name}: {e}"))?,
            );
            // The copy is async (kImmutableUntilTransferCompletes is not
            // what the wrapper uses); keep the literal alive.
            weights_backing.push(lit);
        }

        let adapters = (0..n_models)
            .map(|m| Self::make_adapter(&client, &spec, m as u64, false))
            .collect::<Result<Vec<_>>>()?;
        let zero_adapter = Self::make_adapter(&client, &spec, 0, true)?;
        let icarus_lora_idx = spec
            .lora_names_icarus
            .iter()
            .map(|n| {
                spec.lora_names
                    .iter()
                    .position(|x| x == n)
                    .ok_or_else(|| anyhow!("icarus lora name {n} not in lora_names"))
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(PjrtExecutor {
            client,
            spec,
            mode,
            prefill_exes,
            decode_exe,
            weights,
            _weights_backing: weights_backing,
            adapters,
            zero_adapter,
            icarus_lora_idx,
            snapshots: HashMap::new(),
            next_id: 1,
            swap_bandwidth: 16.0e9,
            stats: PjrtStats::default(),
        })
    }

    /// The model spec the executor was loaded for.
    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Cache handles currently alive (leak check for tests).
    pub fn live_snapshots(&self) -> usize {
        self.snapshots.len()
    }

    /// The underlying PJRT client.
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Replace model `model_id`'s adapter with trained LoRA factors from
    /// an npz written by `compile.train.export_adapter` (same
    /// `layers.<i>.<target>.{A,B}` key convention as the artifacts).
    pub fn load_adapter_npz(
        &mut self,
        model_id: usize,
        path: impl AsRef<std::path::Path>,
    ) -> Result<()> {
        anyhow::ensure!(model_id < self.adapters.len(), "model {model_id} out of range");
        let loaded = Literal::read_npz(path.as_ref(), &())
            .map_err(|e| anyhow!("adapter npz: {e}"))?;
        let mut by_name: HashMap<String, Literal> = loaded.into_iter().collect();
        let mut bufs = Vec::with_capacity(self.spec.lora_names.len());
        let mut backing = Vec::new();
        for name in &self.spec.lora_names {
            let lit = by_name
                .remove(name)
                .ok_or_else(|| anyhow!("adapter npz missing {name}"))?;
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e| anyhow!("adapter upload {name}: {e}"))?,
            );
            backing.push(lit);
        }
        self.adapters[model_id] = bufs;
        self._weights_backing.extend(backing); // keep async-copy sources alive
        Ok(())
    }

    /// Deterministic LoRA literals for model `id` in artifact order.
    /// k/v adapters are always zero (the logical encoder is frozen; the
    /// baseline artifact *does* read them, so zeroing keeps the two
    /// modes' caches comparable in tests while q/o/mlp still differ).
    fn make_adapter(
        client: &PjRtClient,
        spec: &ModelSpec,
        id: u64,
        all_zero: bool,
    ) -> Result<Vec<PjRtBuffer>> {
        let mut rng = Rng::new((0x1ca2u64 << 32) | id);
        let d = spec.d_model;
        let (h, kvd, f, r) =
            (spec.heads * spec.head_dim, spec.kv_dim(), spec.ffn, spec.lora_rank);
        let dims_of = |target: &str| -> (usize, usize) {
            match target {
                "q" => (d, h),
                "k" | "v" => (d, kvd),
                "o" => (h, d),
                "gate" | "up" => (d, f),
                "down" => (f, d),
                other => panic!("unknown lora target {other}"),
            }
        };
        let mut out = Vec::with_capacity(spec.lora_names.len());
        for name in &spec.lora_names {
            // name = layers.<i>.<target>.<A|B>
            let parts: Vec<&str> = name.split('.').collect();
            let target = parts[parts.len() - 2];
            let ab = parts[parts.len() - 1];
            let (din, dout) = dims_of(target);
            let dims = if ab == "A" { [din, r] } else { [r, dout] };
            let n: usize = dims.iter().product();
            let zero = all_zero || matches!(target, "k" | "v");
            let data: Vec<f32> = (0..n)
                .map(|_| if zero { 0.0 } else { (rng.f64() as f32 - 0.5) * 0.02 })
                .collect();
            out.push(
                client
                    .buffer_from_host_buffer(&data, &dims, None)
                    .map_err(|e| anyhow!("adapter buffer: {e}"))?,
            );
        }
        Ok(out)
    }

    fn insert_snapshot(&mut self, lits: Rc<CacheLits>) -> SnapshotId {
        let id = self.next_id;
        self.next_id += 1;
        self.snapshots.insert(id, lits);
        id
    }

    fn adapter_for(&self, model_id: usize, prefill: bool) -> &Vec<PjRtBuffer> {
        if prefill && self.mode == ServingMode::Icarus {
            // ICaRus prefill is the pure logical encoder: any adapter
            // would leak into hidden states and thus into k/v of later
            // layers, breaking cache identity across models.
            &self.zero_adapter
        } else {
            &self.adapters[model_id]
        }
    }

    /// Fresh bucketized prefill of `tokens[..head_len]` at positions
    /// `0..head_len`: pick the smallest bucket fitting `head_len`, pad,
    /// execute, and return the resulting cache plus the next-token
    /// prediction after position `head_len - 1`.
    fn fresh_prefill_head(
        &self,
        model_id: usize,
        tokens: &[u32],
        head_len: usize,
    ) -> Result<(Rc<CacheLits>, u32)> {
        let bucket = self
            .spec
            .bucket_for(head_len)
            .ok_or_else(|| anyhow!("prompt head {head_len} exceeds buckets"))?;
        let mut toks = vec![0i32; bucket];
        for (i, &t) in tokens[..head_len].iter().enumerate() {
            toks[i] = t as i32;
        }
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&toks, &[bucket], None)
            .map_err(|e| anyhow!("{e}"))?;
        let len_buf = self
            .client
            .buffer_from_host_buffer(&[head_len as i32], &[], None)
            .map_err(|e| anyhow!("{e}"))?;
        let mut args: Vec<&PjRtBuffer> =
            Vec::with_capacity(2 + self.weights.len() + self.zero_adapter.len());
        args.push(&tok_buf);
        args.push(&len_buf);
        args.extend(self.weights.iter());
        args.extend(self.adapter_for(model_id, true).iter());
        let exe = &self.prefill_exes[&bucket];
        let result = exe.execute_b(&args).map_err(|e| anyhow!("prefill execute: {e}"))?;
        let out = result
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("no output"))?;
        let tuple = out
            .to_literal_sync()
            .map_err(|e| anyhow!("{e}"))?
            .to_tuple()
            .map_err(|e| anyhow!("{e}"))?;
        let mut it = tuple.into_iter();
        let k = it.next().context("k")?;
        let v = it.next().context("v")?;
        let logits = it.next().context("logits")?;
        let tok = argmax(&logits.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?);
        Ok((Rc::new(CacheLits { k, v }), tok))
    }

    /// One decode-artifact call: (token, pos, cache) -> (token', cache').
    fn decode_once(
        &mut self,
        model_id: usize,
        token: u32,
        pos: usize,
        cache: &CacheLits,
    ) -> Result<(u32, CacheLits)> {
        // Scalars go through buffer_from_host_buffer: it copies
        // synchronously (kImmutableOnlyDuringCall), unlike the literal
        // path whose async copy would race a temporary's drop.
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&[token as i32], &[], None)
            .map_err(|e| anyhow!("token buf: {e}"))?;
        let pos_buf = self
            .client
            .buffer_from_host_buffer(&[pos as i32], &[], None)
            .map_err(|e| anyhow!("pos buf: {e}"))?;
        // Safe with the async literal path: `cache` is kept alive by the
        // caller's Rc until after the output transfer below forces the
        // whole chain (copy -> execute -> readback) to completion.
        let k_buf = self
            .client
            .buffer_from_host_literal(None, &cache.k)
            .map_err(|e| anyhow!("k buf: {e}"))?;
        let v_buf = self
            .client
            .buffer_from_host_literal(None, &cache.v)
            .map_err(|e| anyhow!("v buf: {e}"))?;
        let mut args: Vec<&PjRtBuffer> =
            Vec::with_capacity(4 + self.weights.len() + self.adapters[model_id].len());
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&k_buf);
        args.push(&v_buf);
        args.extend(self.weights.iter());
        let adapter = self.adapter_for(model_id, false);
        match self.mode {
            ServingMode::Baseline => args.extend(adapter.iter()),
            ServingMode::Icarus => {
                args.extend(self.icarus_lora_idx.iter().map(|&i| &adapter[i]))
            }
        }
        let result =
            self.decode_exe.execute_b(&args).map_err(|e| anyhow!("decode execute: {e}"))?;
        let out = result
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("no output"))?;
        let tuple = out
            .to_literal_sync()
            .map_err(|e| anyhow!("output transfer: {e}"))?
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e}"))?;
        let mut it = tuple.into_iter();
        let logits = it.next().ok_or_else(|| anyhow!("logits"))?;
        let k = it.next().ok_or_else(|| anyhow!("k"))?;
        let v = it.next().ok_or_else(|| anyhow!("v"))?;
        let tok = argmax(&logits.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?);
        Ok((tok, CacheLits { k, v }))
    }
}

impl Executor for PjrtExecutor {
    fn prefill(
        &mut self,
        model_id: usize,
        prompt: &[u32],
        cached_tokens: usize,
        base: Option<SnapshotId>,
    ) -> Result<PrefillOut> {
        let t0 = Instant::now();
        self.stats.prefill_calls += 1;
        anyhow::ensure!(
            prompt.len() < self.spec.max_seq,
            "prompt {} exceeds max_seq {}",
            prompt.len(),
            self.spec.max_seq
        );
        let (cache_id, first) = if let Some(base_id) = base.filter(|_| cached_tokens > 0) {
            // Suffix encode: the logical encoder (decode artifact)
            // extends the snapshot's cache over the uncached tokens.
            let snap = self
                .snapshots
                .get(&base_id)
                .ok_or_else(|| anyhow!("unknown snapshot {base_id}"))?
                .clone();
            let mut cache: Rc<CacheLits> = snap;
            let mut next = 0u32;
            for pos in cached_tokens..prompt.len() {
                let (tok, new_cache) =
                    self.decode_once(model_id, prompt[pos], pos, &cache)?;
                next = tok;
                cache = Rc::new(new_cache);
                self.stats.suffix_decode_tokens += 1;
            }
            (self.insert_snapshot(cache), next)
        } else {
            // Fresh bucketized prefill.  Prompts longer than the largest
            // bucket (e.g. a recompute-preempted turn whose context has
            // grown) prefill the largest bucket and suffix-encode the
            // remainder through the decode artifact.
            let max_bucket = *self.spec.prefill.keys().last().expect("no buckets");
            let head_len = prompt.len().min(max_bucket);
            let (mut cache, mut tok) = self.fresh_prefill_head(model_id, prompt, head_len)?;
            // Overflow beyond the largest bucket: logical encoder
            // extends the cache token by token.
            for pos in head_len..prompt.len() {
                let (t, new_cache) = self.decode_once(model_id, prompt[pos], pos, &cache)?;
                tok = t;
                cache = Rc::new(new_cache);
                self.stats.suffix_decode_tokens += 1;
            }
            (self.insert_snapshot(cache), tok)
        };
        let dur = t0.elapsed().as_secs_f64();
        self.stats.prefill_secs += dur;
        Ok(PrefillOut { duration: dur, cache: cache_id, first_token: first })
    }

    fn prefill_chunk(&mut self, c: &mut ChunkSlot<'_>) -> Result<f64> {
        let t0 = Instant::now();
        self.stats.prefill_chunk_calls += 1;
        let end = c.end();
        anyhow::ensure!(
            c.prompt_len < self.spec.max_seq,
            "prompt {} exceeds max_seq {}",
            c.prompt_len,
            self.spec.max_seq
        );
        let mut last = 0u32;
        // Resume from the partial cache, fork from the prefix-cache
        // base, or open fresh with a bucketized prefill of the head.
        let (mut cache, from) = match (c.cache, c.base) {
            (Some(id), _) => {
                let lits = self
                    .snapshots
                    .get(&id)
                    .ok_or_else(|| anyhow!("unknown partial cache {id}"))?
                    .clone();
                (lits, c.start)
            }
            (None, Some(b)) => {
                let lits = self
                    .snapshots
                    .get(&b)
                    .ok_or_else(|| anyhow!("unknown base snapshot {b}"))?
                    .clone();
                (lits, c.start)
            }
            (None, None) => {
                anyhow::ensure!(
                    c.start == 0 && end > 0,
                    "first chunk without a base must start at 0 and be non-empty"
                );
                let max_bucket = *self.spec.prefill.keys().last().expect("no buckets");
                let head_len = end.min(max_bucket);
                let (lits, tok) = self.fresh_prefill_head(c.model_id, c.tokens, head_len)?;
                last = tok;
                (lits, head_len)
            }
        };
        // Positions not covered above go through the logical encoder
        // (decode artifact) one token at a time, same as suffix encode.
        for pos in from..end {
            let (t, new_cache) =
                self.decode_once(c.model_id, c.tokens[pos - c.start], pos, &cache)?;
            last = t;
            cache = Rc::new(new_cache);
            self.stats.suffix_decode_tokens += 1;
        }
        match c.cache {
            Some(id) => {
                // Replace the partial handle in place; the engine keeps
                // using the same id across this sequence's chunks.
                self.snapshots.insert(id, cache);
            }
            None => c.cache = Some(self.insert_snapshot(cache)),
        }
        if c.is_final() {
            // Zero-token final chunk (fully cached prompt): no decode
            // ran, so `last` is still 0 — the same placeholder the
            // atomic path's suffix-encode produces when `cached_tokens
            // == prompt.len()`.  The engine treats the token opaquely;
            // a real fix needs re-scoring the last prompt position,
            // which the snapshot layout does not expose.
            c.first_token = Some(last);
        }
        let dur = t0.elapsed().as_secs_f64();
        self.stats.prefill_secs += dur;
        Ok(dur)
    }

    fn decode(&mut self, batch: &mut [DecodeSlot]) -> Result<f64> {
        let t0 = Instant::now();
        self.stats.decode_calls += 1;
        self.stats.decode_slots += batch.len() as u64;
        for slot in batch.iter_mut() {
            anyhow::ensure!(
                slot.context_len < self.spec.max_seq,
                "context {} at max_seq {}",
                slot.context_len,
                self.spec.max_seq
            );
            let cache = self
                .snapshots
                .get(&slot.cache)
                .ok_or_else(|| anyhow!("unknown cache {}", slot.cache))?
                .clone();
            let (tok, new_cache) =
                self.decode_once(slot.model_id, slot.last_token, slot.context_len, &cache)?;
            slot.next_token = tok;
            // Replace the live handle; published snapshots sharing the
            // old Rc stay alive through their own ids.
            self.snapshots.insert(slot.cache, Rc::new(new_cache));
        }
        let dur = t0.elapsed().as_secs_f64();
        self.stats.decode_secs += dur;
        Ok(dur)
    }

    fn snapshot(&mut self, cache: SnapshotId) -> SnapshotId {
        let lits = self.snapshots.get(&cache).expect("snapshot of unknown cache").clone();
        self.insert_snapshot(lits)
    }

    fn drop_snapshot(&mut self, snap: SnapshotId) {
        self.snapshots.remove(&snap);
    }

    fn swap_in_cost(&self, bytes: u64) -> f64 {
        bytes as f64 / self.swap_bandwidth
    }

    fn mode(&self) -> ServingMode {
        self.mode
    }
}
