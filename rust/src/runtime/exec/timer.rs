//! The virtual-time reactor: a timer wheel keyed on the engine's
//! discrete-event clock.
//!
//! Nothing here reads wall-clock time.  Futures register deadlines in
//! *virtual* seconds via [`Timers::sleep_until`]; the executor drives
//! the wheel forward with `advance_to(now)` whenever the engine's
//! clock moves, firing every due timer's waker.  Determinism falls out
//! of the key order: timers fire sorted by `(deadline, registration
//! seq)`, so equal deadlines resolve in registration order and no
//! pointer or hash order ever influences the schedule.

use std::collections::BTreeMap;
use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Waker};

/// Handle on an executor's virtual-time timer wheel.  Clones share the
/// wheel: futures hold one to register sleeps, the executor holds one
/// to advance the clock.
#[derive(Clone)]
pub struct Timers {
    inner: Arc<Mutex<Wheel>>,
}

struct Wheel {
    /// Pending timers keyed by `(deadline bits, registration seq)`:
    /// `f64::to_bits` is order-preserving for the non-negative virtual
    /// times the engine produces, and the seq breaks deadline ties in
    /// registration order.
    pending: BTreeMap<(u64, u64), Arc<TimerShared>>,
    next_seq: u64,
    /// Virtual time the wheel was last advanced to (monotonicity pin:
    /// `advance_to` panics if the clock runs backwards).
    now: f64,
    registered: u64,
    fired: u64,
}

/// State shared between one pending timer entry and its [`Sleep`].
struct TimerShared {
    fired: AtomicBool,
    waker: Mutex<Option<Waker>>,
}

impl Timers {
    pub(crate) fn new() -> Timers {
        Timers {
            inner: Arc::new(Mutex::new(Wheel {
                pending: BTreeMap::new(),
                next_seq: 0,
                now: 0.0,
                registered: 0,
                fired: 0,
            })),
        }
    }

    /// A future that completes when the virtual clock reaches
    /// `deadline`.  A deadline at or before the wheel's current time
    /// fires on the next `advance_to` (which is re-entrant at equal
    /// time), so "sleep until the past" resolves promptly instead of
    /// hanging.
    pub fn sleep_until(&self, deadline: f64) -> Sleep {
        let shared =
            Arc::new(TimerShared { fired: AtomicBool::new(false), waker: Mutex::new(None) });
        let mut w = self.inner.lock().expect("timer wheel poisoned");
        let seq = w.next_seq;
        w.next_seq += 1;
        w.registered += 1;
        w.pending.insert((deadline.max(0.0).to_bits(), seq), Arc::clone(&shared));
        Sleep { shared }
    }

    /// Earliest pending deadline, if any timer is registered.
    pub fn next_deadline(&self) -> Option<f64> {
        let w = self.inner.lock().expect("timer wheel poisoned");
        w.pending.keys().next().map(|&(bits, _)| f64::from_bits(bits))
    }

    /// `(registered, fired)` lifetime counters (invariant: equal once
    /// the wheel is drained — no timer fires twice, none is lost).
    pub fn counters(&self) -> (u64, u64) {
        let w = self.inner.lock().expect("timer wheel poisoned");
        (w.registered, w.fired)
    }

    /// Advance the virtual clock to `now` and fire every timer with
    /// `deadline <= now`, in `(deadline, registration)` order.  Panics
    /// if the virtual clock runs backwards.
    pub(crate) fn advance_to(&self, now: f64) {
        let due: Vec<Arc<TimerShared>> = {
            let mut w = self.inner.lock().expect("timer wheel poisoned");
            assert!(now >= w.now, "virtual clock ran backwards: {} -> {now}", w.now);
            w.now = now;
            let mut due = Vec::new();
            loop {
                let key = match w.pending.keys().next() {
                    Some(&k) if f64::from_bits(k.0) <= now => k,
                    _ => break,
                };
                due.push(w.pending.remove(&key).expect("key just observed"));
            }
            w.fired += due.len() as u64;
            due
        };
        // Wake outside the wheel lock: a woken task may immediately
        // register its next sleep.
        for t in due {
            let was_fired = t.fired.swap(true, Ordering::AcqRel);
            debug_assert!(!was_fired, "timer fired twice");
            if let Some(wk) = t.waker.lock().expect("waker slot poisoned").take() {
                wk.wake();
            }
        }
    }
}

/// Future returned by [`Timers::sleep_until`]: pending until the
/// executor advances the virtual clock past the deadline.
pub struct Sleep {
    shared: Arc<TimerShared>,
}

impl Future for Sleep {
    type Output = ();

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.shared.fired.load(Ordering::Acquire) {
            Poll::Ready(())
        } else {
            *self.shared.waker.lock().expect("waker slot poisoned") = Some(cx.waker().clone());
            Poll::Pending
        }
    }
}
