//! Deterministic cooperative task runtime, one per engine replica.
//!
//! The serving engine is a discrete-event simulation: virtual time
//! advances by the durations the executor model reports.  Historically
//! every modeled transfer (store restore, swap-in, write-back,
//! prefetch staging) was charged *inline* on that clock — a PCIe/NVMe
//! restore issued at admission stalled the whole replica.  This module
//! provides the machinery to overlap those transfers with compute
//! instead, without giving up determinism:
//!
//!   * [`LocalExecutor`] — a single-threaded executor: spawned futures
//!     run cooperatively from a FIFO run queue, in spawn/wake order.
//!   * [`Timers`] — the virtual-time reactor: a timer wheel keyed on
//!     the engine's discrete-event clock.  [`Timers::sleep_until`]
//!     yields until the engine's clock reaches the deadline; the
//!     engine drives the wheel with [`LocalExecutor::advance_to`] as
//!     its own clock moves.  No wall-clock time anywhere.
//!
//! Determinism is structural rather than incidental: the run queue is
//! FIFO, timers fire in `(deadline, registration)` order, task ids are
//! assigned in spawn order, and the wheel panics if virtual time ever
//! runs backwards.  Given the same spawn sequence and the same clock
//! sequence, the schedule is identical — which is what lets
//! `--overlap on` runs stay run-to-run bit-identical (same seed →
//! identical stats and trace) even though transfers and compute now
//! interleave.
//!
//! The engine-facing half (what a transfer task *is*, how completions
//! rejoin the batch) lives in `engine::overlap`; this module knows
//! nothing about serving.

mod local;
mod task;
mod timer;

pub use local::{ExecMetrics, LocalExecutor};
pub use task::TaskId;
pub use timer::{Sleep, Timers};
