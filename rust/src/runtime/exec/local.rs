//! The per-replica local executor: task storage, the FIFO run queue
//! and the glue that drives tasks from the virtual clock.

use std::collections::{HashMap, VecDeque};
use std::future::Future;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::task::{Context, Waker};

use super::task::{RunQueue, Task, TaskId, WakeState};
use super::timer::Timers;

/// Executor lifetime counters, for `ServingStats::tasks_spawned` and
/// the executor-invariant property test.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExecMetrics {
    /// Tasks ever spawned on this executor.
    pub spawned: u64,
    /// Tasks polled to completion (invariant after a drain: equals
    /// `spawned` — no task leaked).
    pub completed: u64,
    /// Individual future polls executed.
    pub polls: u64,
    /// Timers registered via [`Timers::sleep_until`].
    pub timers_registered: u64,
    /// Timers fired by clock advances (invariant after a drain: equals
    /// `timers_registered` — no timer lost, none fired twice).
    pub timers_fired: u64,
}

/// A deterministic single-threaded cooperative executor driven by a
/// virtual clock.
///
/// Unlike a wall-clock async runtime there is no I/O and no
/// preemption: tasks only ever block on [`Timers::sleep_until`], and
/// the owner advances the clock explicitly with
/// [`LocalExecutor::advance_to`] — which fires due timers and then
/// polls every runnable task until quiescent.  Scheduling is a pure
/// function of the spawn order and the clock sequence (FIFO run queue,
/// timers fired in `(deadline, registration)` order), which is what
/// lets the serving engine keep its bit-identical determinism
/// guarantees while overlapping modeled transfers with compute.
///
/// ```
/// use icarus::runtime::exec::LocalExecutor;
///
/// let mut ex = LocalExecutor::new();
/// let timers = ex.timers();
/// ex.spawn(async move {
///     timers.sleep_until(2.0).await;
/// });
/// ex.advance_to(1.0);
/// assert_eq!(ex.live_tasks(), 1); // still sleeping
/// ex.advance_to(2.0);
/// assert_eq!(ex.live_tasks(), 0); // fired, ran to completion
/// assert_eq!(ex.metrics().spawned, ex.metrics().completed);
/// ```
pub struct LocalExecutor {
    tasks: HashMap<TaskId, Task>,
    ready: RunQueue,
    timers: Timers,
    next_id: TaskId,
    spawned: u64,
    completed: u64,
    polls: u64,
}

impl Default for LocalExecutor {
    fn default() -> Self {
        LocalExecutor::new()
    }
}

impl LocalExecutor {
    /// Fresh executor with an empty run queue and timer wheel, virtual
    /// clock at 0.
    pub fn new() -> Self {
        LocalExecutor {
            tasks: HashMap::new(),
            ready: Arc::new(Mutex::new(VecDeque::new())),
            timers: Timers::new(),
            next_id: 0,
            spawned: 0,
            completed: 0,
            polls: 0,
        }
    }

    /// Handle on this executor's timer wheel, for futures to register
    /// sleeps against.
    pub fn timers(&self) -> Timers {
        self.timers.clone()
    }

    /// Spawn a task.  It is polled for the first time on the next
    /// [`LocalExecutor::advance_to`] (or [`LocalExecutor::run_ready`]),
    /// in spawn order relative to other runnable tasks.
    pub fn spawn(&mut self, fut: impl Future<Output = ()> + 'static) {
        let id = self.next_id;
        self.next_id += 1;
        let wake = Arc::new(WakeState {
            id,
            queued: AtomicBool::new(true),
            queue: Arc::clone(&self.ready),
        });
        self.ready.lock().expect("run queue poisoned").push_back(id);
        self.tasks.insert(id, Task { fut: Box::pin(fut), wake });
        self.spawned += 1;
    }

    /// Advance the virtual clock to `now` (firing due timers) and poll
    /// runnable tasks until the executor is quiescent *at `now`*: a
    /// polled task may register a new sleep at or before `now` (e.g. a
    /// chained hop into the past), which must fire within this same
    /// advance — hence the fire/poll loop.  Panics if the clock runs
    /// backwards — per-replica virtual time is monotone by
    /// construction, and silently tolerating regressions would mask
    /// engine bugs.
    pub fn advance_to(&mut self, now: f64) {
        loop {
            self.timers.advance_to(now);
            self.run_ready();
            match self.timers.next_deadline() {
                Some(d) if d <= now => continue,
                _ => break,
            }
        }
    }

    /// Poll every runnable task (in FIFO wake order) until the run
    /// queue is empty, without advancing the clock.
    pub fn run_ready(&mut self) {
        loop {
            let id = self.ready.lock().expect("run queue poisoned").pop_front();
            let Some(id) = id else { break };
            let Some(task) = self.tasks.get_mut(&id) else {
                continue; // stale wake for a completed task
            };
            // Clear `queued` before polling so a wake arriving during
            // the poll re-enqueues the task instead of being lost.
            task.wake.queued.store(false, Ordering::Release);
            let waker = Waker::from(Arc::clone(&task.wake));
            let mut cx = Context::from_waker(&waker);
            self.polls += 1;
            if task.fut.as_mut().poll(&mut cx).is_ready() {
                self.tasks.remove(&id);
                self.completed += 1;
            }
        }
    }

    /// Earliest pending timer deadline — the next virtual time at
    /// which some task becomes runnable.
    pub fn next_deadline(&self) -> Option<f64> {
        self.timers.next_deadline()
    }

    /// Tasks spawned but not yet run to completion.
    pub fn live_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Snapshot of the lifetime counters.
    pub fn metrics(&self) -> ExecMetrics {
        let (timers_registered, timers_fired) = self.timers.counters();
        ExecMetrics {
            spawned: self.spawned,
            completed: self.completed,
            polls: self.polls,
            timers_registered,
            timers_fired,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn tasks_run_in_spawn_order() {
        let mut ex = LocalExecutor::new();
        let order: Rc<RefCell<Vec<u32>>> = Rc::default();
        for i in 0..4u32 {
            let order = Rc::clone(&order);
            ex.spawn(async move { order.borrow_mut().push(i) });
        }
        ex.run_ready();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 3]);
        assert_eq!(ex.live_tasks(), 0);
        assert_eq!(ex.metrics().completed, 4);
    }

    #[test]
    fn equal_deadlines_fire_in_registration_order() {
        let mut ex = LocalExecutor::new();
        let order: Rc<RefCell<Vec<u32>>> = Rc::default();
        let timers = ex.timers();
        for i in 0..3u32 {
            let order = Rc::clone(&order);
            let timers = timers.clone();
            ex.spawn(async move {
                timers.sleep_until(5.0).await;
                order.borrow_mut().push(i);
            });
        }
        ex.advance_to(4.999);
        assert!(order.borrow().is_empty());
        ex.advance_to(5.0);
        assert_eq!(*order.borrow(), vec![0, 1, 2]);
    }

    #[test]
    fn chained_sleeps_and_counters_balance() {
        let mut ex = LocalExecutor::new();
        let timers = ex.timers();
        ex.spawn(async move {
            timers.sleep_until(1.0).await;
            timers.sleep_until(3.0).await;
            timers.sleep_until(2.0).await; // already past once reached
        });
        ex.advance_to(1.0);
        assert_eq!(ex.next_deadline(), Some(3.0));
        ex.advance_to(10.0);
        assert_eq!(ex.live_tasks(), 0);
        let m = ex.metrics();
        assert_eq!(m.spawned, m.completed);
        assert_eq!(m.timers_registered, m.timers_fired);
        assert_eq!(m.timers_registered, 3);
    }

    #[test]
    fn sleep_until_the_past_resolves() {
        let mut ex = LocalExecutor::new();
        let timers = ex.timers();
        ex.advance_to(7.0);
        ex.spawn(async move { timers.sleep_until(1.0).await });
        ex.advance_to(7.0); // re-entrant at equal time
        assert_eq!(ex.live_tasks(), 0);
    }

    #[test]
    #[should_panic(expected = "virtual clock ran backwards")]
    fn clock_regression_panics() {
        let mut ex = LocalExecutor::new();
        ex.advance_to(5.0);
        ex.advance_to(4.0);
    }
}
