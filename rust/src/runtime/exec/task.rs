//! Tasks and wakers for the cooperative executor.
//!
//! A task is a pinned boxed future plus its wake state.  The wake
//! state implements [`std::task::Wake`], so the executor never touches
//! a raw waker vtable: waking a task pushes its id onto the executor's
//! FIFO run queue, with an atomic `queued` flag coalescing duplicate
//! wakes — a task is enqueued (and later polled) at most once per
//! wake-up, which is one of the invariants the executor property test
//! pins (`tests/property_invariants.rs`).

use std::collections::VecDeque;
use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::task::Wake;

/// Identifier of a spawned task, unique within its executor (ids are
/// never reused, so stale wakes are detectable).
pub type TaskId = u64;

/// The executor's FIFO run queue, shared with every task's waker.
pub(crate) type RunQueue = Arc<Mutex<VecDeque<TaskId>>>;

/// Per-task wake state: marks the task runnable by pushing its id onto
/// the shared run queue.
pub(crate) struct WakeState {
    pub id: TaskId,
    /// True while the task sits in the run queue awaiting its poll;
    /// the swap in [`Wake::wake_by_ref`] coalesces duplicate wakes.
    pub queued: AtomicBool,
    pub queue: RunQueue,
}

impl Wake for WakeState {
    fn wake(self: Arc<Self>) {
        self.wake_by_ref();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        if !self.queued.swap(true, Ordering::AcqRel) {
            self.queue.lock().expect("run queue poisoned").push_back(self.id);
        }
    }
}

/// A spawned task: the future and the wake state its `Waker`s share.
pub(crate) struct Task {
    pub fut: Pin<Box<dyn Future<Output = ()> + 'static>>,
    pub wake: Arc<WakeState>,
}
