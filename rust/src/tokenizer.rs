//! Deterministic synthetic tokenizer.
//!
//! The serving stack needs a text<->token bridge for the examples and
//! workload generator.  Real tokenizers (BPE) are out of scope — the
//! models are trained on synthetic token streams anyway — so this hashes
//! whitespace-separated words into the model's vocab deterministically
//! (same word -> same id, stable across runs and processes).

/// Ids below this are reserved (PAD/BOS/EOS/... mirror python tasks.py).
pub const RESERVED: u32 = 32;
/// Padding token id.
pub const PAD: u32 = 0;
/// Beginning-of-sequence token id.
pub const BOS: u32 = 1;
/// End-of-sequence token id.
pub const EOS: u32 = 2;
/// Separator token id.
pub const SEP: u32 = 3;

/// Deterministic word-hashing tokenizer (see the module docs).
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: u32,
}

impl Tokenizer {
    /// Tokenizer for a `vocab`-sized model (must clear the reserved
    /// range with room to spare).
    pub fn new(vocab: u32) -> Self {
        assert!(vocab > RESERVED * 2, "vocab too small: {vocab}");
        Tokenizer { vocab }
    }

    /// Vocabulary size this tokenizer maps into.
    pub fn vocab(&self) -> u32 {
        self.vocab
    }

    fn hash_word(&self, w: &str) -> u32 {
        // FNV-1a, folded into the non-reserved id range.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in w.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        RESERVED + (h % (self.vocab - RESERVED) as u64) as u32
    }

    /// Encode text as BOS + word tokens (no EOS — callers append).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = vec![BOS];
        out.extend(text.split_whitespace().map(|w| self.hash_word(w)));
        out
    }

    /// Decode is lossy by construction; emits `w<id>` placeholders.
    pub fn decode(&self, tokens: &[u32]) -> String {
        tokens
            .iter()
            .map(|&t| match t {
                PAD => "<pad>".to_string(),
                BOS => "<s>".to_string(),
                EOS => "</s>".to_string(),
                SEP => "<sep>".to_string(),
                t if t < RESERVED => format!("<r{t}>"),
                t => format!("w{t}"),
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stable() {
        let t = Tokenizer::new(2048);
        assert_eq!(t.encode("hello world"), t.encode("hello world"));
        let ids = t.encode("hello hello");
        assert_eq!(ids[1], ids[2]);
        assert_eq!(ids[0], BOS);
    }

    #[test]
    fn ids_in_range() {
        let t = Tokenizer::new(128);
        for w in ["a", "bb", "ccc", "zq", "🦀"] {
            let id = t.encode(w)[1];
            assert!((RESERVED..128).contains(&id));
        }
    }

    #[test]
    fn different_words_usually_differ() {
        let t = Tokenizer::new(4096);
        let a = t.encode("alpha")[1];
        let b = t.encode("beta")[1];
        assert_ne!(a, b);
    }

    #[test]
    fn decode_roundtrip_shape() {
        let t = Tokenizer::new(2048);
        let ids = t.encode("x y z");
        let s = t.decode(&ids);
        assert!(s.starts_with("<s> w"));
        assert_eq!(s.split(' ').count(), 4);
    }
}
