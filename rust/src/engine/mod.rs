//! The serving engine: continuous-batching event loop over an Executor.
//!
//! Single-threaded discrete-event design: virtual time advances by the
//! durations the executor reports (measured wall time for PJRT, cost
//! model for sim), so the identical scheduler / KV-manager code path is
//! exercised in both.  The engine is a thin driver: *what* to admit and
//! in which order is decided by the pluggable scheduling policy in
//! `crate::sched` (which also owns the waiting/delayed/running queues);
//! the engine supplies the mechanics.  Per iteration (one "engine
//! step"):
//!
//!   1. surface newly-arrived workflows as pending turns;
//!   2. admit turns the scheduler picks while the KV pool and batch
//!      have room (prefix-cache lookup -> pin -> prefill the uncached
//!      suffix); on `NoSpace`, preempt the newest running sequence
//!      (recompute or swap per config) and retry, else leave queued;
//!   3. run one step: with chunked prefill disabled, prefills happen
//!      atomically at admission and the step is one decode over the
//!      running batch (the pre-scheduler behavior, bit-identical under
//!      the `Fcfs` policy); with `prefill_chunk > 0`, the step is a
//!      *fused* step — up to `max_prefill_tokens` of prompt encoding,
//!      bounded per sequence by `prefill_chunk`, co-scheduled with the
//!      decode batch so one long prompt no longer stalls every running
//!      sequence (no head-of-line blocking on the time axis);
//!   4. retire finished turns: publish their context to the prefix cache
//!      (cross-model-visible in ICaRus mode), record latency, enqueue
//!      the workflow's next turn.
//!
//! Transfer/compute overlap (`--overlap on`): by default every modeled
//! transfer — store restore, swap-in, write-back — is charged *inline*
//! on the virtual clock, serializing against compute.  With overlap
//! enabled, admission-time restores are issued as tasks on a
//! per-replica cooperative executor (`crate::runtime::exec`) instead:
//! the admitted turn's KV is reserved immediately, but the sequence
//! joins the running batch only when the clock passes the transfer's
//! virtual completion, while other sequences keep decoding — and the
//! decode batch re-forms each step around whatever has landed
//! (continuous batching across transfers).  The serial path remains
//! the default and stays bit-identical to the pre-overlap engine
//! (stats and trace), pinned by a differential property test; see
//! `overlap` for the task/stall accounting model.

pub mod executor;
mod overlap;
pub mod sequence;

use std::collections::{HashSet, VecDeque};

use crate::config::{EvictionPolicy, ServingConfig};
use crate::disagg::{DisaggHandle, Handoff, PrefillRequest, PrefillResponse, ReplicaRole};
use crate::kvcache::{Alloc, KvCacheManager};
use crate::metrics::ServingStats;
use crate::obs::{ObsRecorder, SpanKind};
use crate::sched::{self, CacheProbe, Queues, Scheduler};
use crate::store::StoreHandle;
use crate::trace::{Trace, TurnEvent};
use crate::workload::Workflow;

use executor::{ChunkSlot, DecodeSlot, Executor, PrefillOut};
use overlap::{Overlap, TransferKind};
use sequence::{PendingTurn, PrefillState, RunningSeq, WfState};

/// The single-threaded continuous-batching serving engine (see the
/// module docs for the event loop; `cluster::Cluster` shards workloads
/// across several of these).
pub struct Engine<E: Executor> {
    cfg: ServingConfig,
    exec: E,
    kv: KvCacheManager,
    /// Admission policy (built from `cfg.sched_policy`).
    sched: Box<dyn Scheduler>,
    now: f64,
    next_seq_id: u64,
    wfs: Vec<WfState>,
    /// Workflows not yet arrived (indices into wfs, ascending arrival).
    future: VecDeque<usize>,
    /// Scheduler-owned turn queues (waiting / delayed / running).
    q: Queues,
    /// This replica's handle on the shared tiered snapshot store
    /// (`None` — the default — leaves every store code path dormant,
    /// which is what keeps store-less runs bit-identical to pre-store
    /// behavior).
    store: Option<StoreHandle>,
    /// Cooperative-overlap state: `Some` iff `cfg.overlap` — the
    /// per-replica task executor plus the ledger of in-flight gating
    /// transfers.  `None` leaves every overlap branch dormant, which
    /// is what keeps `--overlap off` runs bit-identical to the serial
    /// loop.
    ovl: Option<Overlap>,
    /// Disaggregated-mode handle on the prefill/decode handoff edge
    /// (`None` — the default — leaves every disagg branch dormant,
    /// which is what keeps `--disagg off` runs bit-identical to the
    /// homogeneous engine).
    disagg: Option<DisaggHandle>,
    /// Prefill role: side table of handoff jobs in flight on this
    /// replica.  A forwarded turn's `wf_idx` indexes this table instead
    /// of `wfs` (prefill replicas own no workflows, and their sequences
    /// never reach `finish_turn`).
    prefill_jobs: Vec<PrefillJob>,
    /// Decode role: turns prefilled remotely, held (with their
    /// store-visibility horizon) until this replica's clock passes it —
    /// the causality half of the handoff protocol.
    pending_handoffs: Vec<(f64, PendingTurn)>,
    /// Decode role: turns forwarded to prefill replicas and not yet
    /// returned.  While nonzero and idle, the replica parks on its
    /// mailbox instead of jumping its clock (a jump would overshoot
    /// responses landing before the next local event).
    outstanding_prefills: usize,
    /// Prefetch-scan memo: turns (keyed by workflow, turn index and
    /// context length — stable, deterministic identity) already probed
    /// for staging since the last local store publish.  Stops
    /// `issue_prefetches` from re-walking the same candidates' block
    /// hashes and re-taking the store mutex every engine step.
    prefetch_seen: HashSet<(usize, usize, usize)>,
    stats: ServingStats,
    trace: Option<Trace>,
    /// Observability recorder: `Some` iff `cfg.obs` — per-replica
    /// virtual-time spans, counter samples and per-sequence phase
    /// bookkeeping (see `crate::obs`).  `None` — the default — leaves
    /// every obs branch dormant, which is what keeps `--obs off` runs
    /// bit-identical (stats *and* trace) to the pre-obs engine.
    obs: Option<ObsRecorder>,
}

/// Waiting-queue prefix scanned for prefetch candidates per step: deep
/// enough to cover what the next admission rounds will look at, bounded
/// so a long queue cannot make the step O(queue x prompt).
const PREFETCH_SCAN: usize = 16;

/// Prefill-role bookkeeping for one handoff in flight: everything the
/// eventual [`PrefillResponse`] must echo back to the owning decode
/// replica.  A forwarded turn's `wf_idx` indexes the engine's
/// `prefill_jobs` table of these.
struct PrefillJob {
    /// Replica index to send the response to.
    reply_to: usize,
    /// Workflow index on the owning decode replica (opaque here).
    wf_idx: usize,
    /// Turn index within that workflow (opaque here).
    turn_idx: usize,
    /// Decode tokens still owed after prefill (carried through).
    remaining_gen: usize,
    /// Original latency-clock origin (carried through).
    ready_at: f64,
}

impl<E: Executor> Engine<E> {
    /// Engine over `exec`, with a fresh KV manager sized by `cfg` and
    /// the scheduling policy `cfg.sched_policy` selects.
    /// Panics if `cfg.mode` and the executor's mode disagree.
    pub fn new(cfg: ServingConfig, kv_bytes_per_token: u64, n_models: usize, exec: E) -> Self {
        assert_eq!(cfg.mode, exec.mode(), "engine/executor mode mismatch");
        let kv = KvCacheManager::new(&cfg, kv_bytes_per_token, n_models);
        let sched = sched::make(cfg.sched_policy);
        let ovl = cfg.overlap.then(Overlap::new);
        let obs = cfg.obs.then(|| ObsRecorder::new(0));
        Engine {
            cfg,
            exec,
            kv,
            sched,
            now: 0.0,
            next_seq_id: 1,
            wfs: Vec::new(),
            future: VecDeque::new(),
            q: Queues::new(),
            store: None,
            ovl,
            disagg: None,
            prefill_jobs: Vec::new(),
            pending_handoffs: Vec::new(),
            outstanding_prefills: 0,
            prefetch_seen: HashSet::new(),
            stats: ServingStats::new(),
            trace: None,
            obs,
        }
    }

    /// Record a per-turn event trace during `run` (see `trace::Trace`).
    pub fn enable_trace(&mut self) {
        self.trace = Some(Trace::new());
    }

    /// Cluster runs: tag the obs recorder's lane with this replica's
    /// index (spans are exported one Perfetto process per replica).
    /// No-op when `--obs off`.
    pub fn set_obs_replica(&mut self, replica: usize) {
        if let Some(o) = self.obs.as_mut() {
            o.set_replica(replica);
        }
    }

    /// Attach this engine's handle on a (possibly shared) tiered
    /// snapshot store.  From then on the engine restores store-resident
    /// prefixes instead of re-prefilling them, writes finished contexts
    /// back, demotes hard-evicted contexts into the store, and — in
    /// cluster runs — fences its virtual clock against the other
    /// replicas (see `crate::store`).
    pub fn attach_store(&mut self, handle: StoreHandle) {
        self.store = Some(handle);
    }

    /// Attach this engine's handle on the disaggregated handoff edge
    /// and take up its role (see `crate::disagg`).  Decode replicas
    /// forward every fresh turn to a prefill replica and re-admit it as
    /// a store restore once the published prefix is visible; prefill
    /// replicas serve forwarded prefills and never decode.  Requires an
    /// attached store (the handoff artifact lives there), and —
    /// prefill role — chunked prefill (the final-chunk landing is the
    /// handoff point).
    pub fn attach_disagg(&mut self, handle: DisaggHandle) {
        assert!(self.store.is_some(), "disaggregation requires a shared snapshot store");
        if handle.role() == ReplicaRole::Prefill {
            assert!(self.cfg.prefill_chunk > 0, "prefill replicas require chunked prefill");
        }
        self.disagg = Some(handle);
    }

    /// Like `run`, but also returns the recorded trace.
    pub fn run_traced(mut self, workload: Vec<Workflow>) -> (ServingStats, Trace) {
        self.enable_trace();
        let stats = self.run_inner(workload);
        (stats, self.trace.take().unwrap_or_default())
    }

    /// Like `run`, but also returns the obs recorder (`None` unless
    /// the config enables `--obs`).
    pub fn run_obs(mut self, workload: Vec<Workflow>) -> (ServingStats, Option<ObsRecorder>) {
        let stats = self.run_inner(workload);
        (stats, self.obs.take())
    }

    /// Like `run_traced`, but also returns the obs recorder (`None`
    /// unless the config enables `--obs`).
    pub fn run_traced_obs(
        mut self,
        workload: Vec<Workflow>,
    ) -> (ServingStats, Trace, Option<ObsRecorder>) {
        self.enable_trace();
        let stats = self.run_inner(workload);
        (stats, self.trace.take().unwrap_or_default(), self.obs.take())
    }

    /// The engine's KV cache manager (post-run inspection).
    pub fn kv(&self) -> &KvCacheManager {
        &self.kv
    }

    /// The engine's executor (post-run inspection).
    pub fn executor(&self) -> &E {
        &self.exec
    }

    /// Run a full workload to completion and return the serving stats.
    pub fn run(mut self, workload: Vec<Workflow>) -> ServingStats {
        self.run_inner(workload)
    }

    /// Like `run`, but borrows the engine so post-run state (the KV
    /// manager, the executor) stays inspectable — used by tests and
    /// diagnostics to assert that nothing leaked past the run.
    pub fn run_in_place(&mut self, workload: Vec<Workflow>) -> ServingStats {
        self.run_inner(workload)
    }

    fn run_inner(&mut self, workload: Vec<Workflow>) -> ServingStats {
        // Engines are single-use: the clock, sequence ids and KV/prefix
        // state are not reset between runs, so a second run would report
        // corrupted stats.  `run`/`run_traced` enforce this by consuming
        // self; `run_in_place` must enforce it explicitly.
        assert!(
            self.wfs.is_empty() && self.now == 0.0,
            "Engine::run/run_in_place is single-use; build a fresh Engine per run"
        );
        let mut idx: Vec<usize> = (0..workload.len()).collect();
        idx.sort_by(|&a, &b| workload[a].arrival.total_cmp(&workload[b].arrival));
        self.wfs = workload.into_iter().map(WfState::new).collect();
        self.future = idx.into();

        loop {
            // Cluster runs with a shared store: heartbeat this
            // replica's fence clock once per step so laggards are
            // released even when this step touches no store path (the
            // store handle additionally fences before every operation,
            // at the exact clock the operation uses — the clock
            // advances *within* steps).  No-op for single-engine runs.
            if let Some(h) = &self.store {
                h.sync(self.now);
            }
            self.surface_arrivals();
            self.q.surface_delayed(self.now);
            // Disaggregated mode: exchange handoffs with the other side
            // of the prefill/decode edge (no-op otherwise).
            self.disagg_step();
            // Overlap mode: integrate every transfer whose virtual
            // completion the clock has passed — their sequences join
            // the batch before this step's admission and decode, so
            // the decode batch re-forms around them each tick.
            self.integrate_transfers();
            if self.q.waiting.is_empty() && self.q.running.is_empty() {
                // Disagg: a replica idle but waiting on the *other
                // side* of the handoff edge parks its fence clock and
                // blocks on its mailbox instead of jumping — a clock
                // jump would overshoot responses whose visibility lands
                // before the next local event, inflating handoff
                // latency with idle time the replica never spent.
                if self.disagg_park_wait() {
                    continue;
                }
                // Idle: jump to the next arrival, tool completion,
                // (overlap mode) transfer completion or (disagg mode)
                // held handoff's visibility horizon.
                let next_arrival =
                    self.future.front().map(|&w| self.wfs[w].spec.arrival);
                let next_ready = self.q.next_ready();
                let next_xfer = self.ovl.as_ref().and_then(Overlap::next_gating);
                let next_handoff =
                    self.pending_handoffs.iter().map(|&(t, _)| t).min_by(f64::total_cmp);
                match [next_arrival, next_ready, next_xfer, next_handoff]
                    .into_iter()
                    .flatten()
                    .min_by(f64::total_cmp)
                {
                    Some(t) => {
                        if next_xfer.is_some_and(|x| x <= t) {
                            // The jump is (co-)bound by a transfer:
                            // this wait is transfer stall, the time
                            // the serial path charges inline.
                            self.record_stall(t);
                        }
                        self.now = self.now.max(t);
                        continue;
                    }
                    None => break,
                }
            }
            self.stats
                .queue_depth
                .as_mut()
                .unwrap()
                .record(self.q.waiting.len() as f64);
            // Counter samples use engine-local values only (queue depth,
            // batch size, this replica's cumulative restored bytes) —
            // never mid-run shared-store gauges, whose values depend on
            // cross-replica interleaving and would break determinism.
            if let Some(o) = self.obs.as_mut() {
                o.counter(self.now, "queue_depth", self.q.waiting.len() as f64);
                o.counter(self.now, "running", self.q.running.len() as f64);
                o.counter(self.now, "restored_bytes", self.stats.store_restored_bytes as f64);
            }
            let step_start = self.now;
            if self.cfg.overlap {
                self.admit_overlap();
            } else {
                self.admit();
            }
            self.issue_prefetches();
            if self.cfg.prefill_chunk == 0 {
                self.decode_step();
            } else {
                self.chunked_step();
            }
            // Overlap guard: the step made no progress (batch empty,
            // clock parked) because every admissible turn is gated on
            // KV that in-flight restores hold — jump to the next
            // completion instead of spinning.  Stall time, same as an
            // idle-jump bound by a transfer.
            if self.cfg.overlap && self.q.running.is_empty() && self.now == step_start {
                if let Some(t) = self.ovl.as_ref().and_then(Overlap::next_gating) {
                    self.record_stall(t);
                    self.now = self.now.max(t);
                }
            }
            // Admission/growth attempts that failed with NoSpace may
            // still have evicted prefix-cache payloads (the failure
            // does not undo the eviction); release their handles.
            let orphaned = self.kv.take_orphaned();
            self.drop_snapshots(&orphaned);
            // Hard-evicted payload contexts demote into the snapshot
            // store (GPU -> host; the store cascades host -> disk ->
            // drop).  Deduped content-addressed publishes make the
            // common already-written-back case a refresh, not a copy.
            let demoted = self.kv.take_demoted();
            if self.store.is_some() {
                for ctx in demoted {
                    // Demoted contexts come back as plain vectors (the
                    // radix tree reconstructs them block by block); wrap
                    // without copying to reach the chain-memoized path.
                    self.publish_to_store(&crate::tokens::TokenBuf::from_vec(ctx));
                }
            }
        }
        debug_assert!(self.q.is_drained(), "queues must drain by end of run");
        debug_assert!(self.pending_handoffs.is_empty(), "held handoffs must drain");
        debug_assert_eq!(self.outstanding_prefills, 0, "forwarded prefills must return");
        // This replica no longer constrains the cluster's clock fence.
        if let Some(h) = &self.store {
            h.finish();
        }
        // Overlap teardown: run remaining background tasks (write-back
        // and staging completions past the last retirement) to their
        // deadlines and fold the executor's counters into the stats.
        // Asserts every gating transfer was integrated and no task
        // leaked.
        if let Some(mut o) = self.ovl.take() {
            self.stats.tasks_spawned = o.finish().spawned;
        }
        self.stats.wall_seconds = self.now;
        self.stats.peak_kv_bytes = self.kv.pool.peak_bytes();
        self.stats.swap_outs = self.kv.swap.swap_outs;
        self.stats.swap_ins = self.kv.swap.swap_ins;
        self.stats.evictions = self.kv.stats.evicted_blocks;
        std::mem::replace(&mut self.stats, ServingStats::new())
    }

    fn surface_arrivals(&mut self) {
        while let Some(&w) = self.future.front() {
            if self.wfs[w].spec.arrival > self.now {
                break;
            }
            self.future.pop_front();
            // Serving-front-end admission gate: with either bound
            // enabled, an arrival that finds the waiting queue over
            // depth/token budget is load-shed here (the virtual-time
            // analogue of the front end's 503), before it touches KV or
            // scheduler state.  Both bounds 0 (the default) skips the
            // whole block, leaving the counters at 0 and the arrival
            // path bit-identical to the pre-front-end engine.
            if self.cfg.admit_queue > 0 || self.cfg.admit_tokens > 0 {
                self.stats.submitted_requests += 1;
                let depth_over =
                    self.cfg.admit_queue > 0 && self.q.waiting.len() >= self.cfg.admit_queue;
                let tokens_over = self.cfg.admit_tokens > 0
                    && self.q.queued_prompt_tokens() >= self.cfg.admit_tokens;
                if depth_over || tokens_over {
                    self.stats.rejected_requests += 1;
                    self.wfs[w].done = true;
                    continue;
                }
            }
            let wf = &mut self.wfs[w];
            // Park the context in the turn (wf.context goes empty) so
            // the buffer stays uniquely owned and later appends are
            // zero-copy; finish_turn re-derives it from the prompt.
            let prompt = std::mem::take(&mut wf.context);
            self.q.waiting.push_back(PendingTurn {
                wf_idx: w,
                turn_idx: 0,
                model_id: wf.spec.turns[0].model_id,
                ready_at: wf.spec.arrival,
                prompt,
                remaining_gen: wf.spec.turns[0].gen_len,
                was_preempted: false,
                swapped: None,
                from_handoff: false,
                local_only: false,
            });
        }
    }

    /// Per-step handoff exchange (no-op outside `--disagg`).  Decode
    /// replicas ingest returned prefills, forward every fresh turn to a
    /// prefill replica, and surface held handoffs whose visibility
    /// horizon the clock has passed; prefill replicas ingest forwarded
    /// requests into the waiting queue.
    fn disagg_step(&mut self) {
        let Some(dh) = &self.disagg else { return };
        let role = dh.role();
        let mail = dh.drain();
        self.ingest_handoffs(mail);
        if role != ReplicaRole::Decode {
            return;
        }
        // Forward every fresh turn.  Handoff returns (restored
        // locally), preemption re-admissions and swap-parked contexts
        // stay local: each turn crosses the edge exactly once — the
        // run-wide termination counter depends on it.
        let mut i = 0;
        while i < self.q.waiting.len() {
            let t = &self.q.waiting[i];
            if t.from_handoff || t.local_only || t.swapped.is_some() {
                i += 1;
                continue;
            }
            let turn = self.q.waiting.remove(i).expect("index in range");
            let dh = self.disagg.as_mut().expect("decode role checked above");
            dh.forward(PrefillRequest {
                reply_to: dh.replica(),
                prompt: turn.prompt,
                model_id: turn.model_id,
                remaining_gen: turn.remaining_gen,
                wf_idx: turn.wf_idx,
                turn_idx: turn.turn_idx,
                ready_at: turn.ready_at,
                sent_at: self.now,
            });
            self.outstanding_prefills += 1;
        }
        // Surface held handoffs the clock has caught up with, in
        // arrival order (the admission policy reorders from there).
        let mut j = 0;
        while j < self.pending_handoffs.len() {
            if self.pending_handoffs[j].0 <= self.now {
                let (_, turn) = self.pending_handoffs.remove(j);
                self.q.waiting.push_back(turn);
            } else {
                j += 1;
            }
        }
    }

    /// Fold drained mailbox messages into engine state: requests become
    /// waiting turns backed by `prefill_jobs` (prefill role), responses
    /// become held handoffs awaiting their visibility horizon (decode
    /// role).
    fn ingest_handoffs(&mut self, mail: Vec<Handoff>) {
        for msg in mail {
            match msg {
                Handoff::Request(r) => {
                    // Virtual causality: a prefill replica's clock
                    // cannot lag the dispatch time of work it serves.
                    self.now = self.now.max(r.sent_at);
                    let job = self.prefill_jobs.len();
                    self.prefill_jobs.push(PrefillJob {
                        reply_to: r.reply_to,
                        wf_idx: r.wf_idx,
                        turn_idx: r.turn_idx,
                        remaining_gen: r.remaining_gen,
                        ready_at: r.ready_at,
                    });
                    self.q.waiting.push_back(PendingTurn {
                        wf_idx: job,
                        turn_idx: r.turn_idx,
                        model_id: r.model_id,
                        ready_at: r.ready_at,
                        prompt: r.prompt,
                        remaining_gen: r.remaining_gen,
                        was_preempted: false,
                        swapped: None,
                        from_handoff: false,
                        local_only: true,
                    });
                }
                Handoff::Response(r) => {
                    self.outstanding_prefills = self
                        .outstanding_prefills
                        .checked_sub(1)
                        .expect("response without an outstanding prefill");
                    self.pending_handoffs.push((
                        r.admissible_at,
                        PendingTurn {
                            wf_idx: r.wf_idx,
                            turn_idx: r.turn_idx,
                            model_id: r.model_id,
                            ready_at: r.ready_at,
                            prompt: r.prompt,
                            remaining_gen: r.remaining_gen,
                            was_preempted: false,
                            swapped: None,
                            from_handoff: true,
                            local_only: true,
                        },
                    ));
                }
            }
        }
    }

    /// Idle with nothing locally runnable: when the replica is waiting
    /// on the *other side* of the handoff edge (decode role: prefills
    /// in flight; prefill role: turns still owed run-wide), park the
    /// fence clock, block on the mailbox and ingest what arrives.
    /// Parking is safe because `ClockFence::sync` blocks the *prober*
    /// until laggards catch up, so the ordinary top-of-loop re-sync
    /// cannot miss anything that became visible meanwhile.  Returns
    /// false when the replica is not waiting on anything (run over, or
    /// not in disagg mode).
    fn disagg_park_wait(&mut self) -> bool {
        let waiting = match &self.disagg {
            Some(dh) => match dh.role() {
                ReplicaRole::Decode => self.outstanding_prefills > 0,
                ReplicaRole::Prefill => dh.remaining() > 0,
                ReplicaRole::Hybrid => false,
            },
            None => return false,
        };
        if !waiting {
            return false;
        }
        if let Some(h) = &self.store {
            h.finish();
        }
        let mail = self.disagg.as_ref().expect("checked above").wait();
        self.ingest_handoffs(mail);
        true
    }

    /// Store coverage of every waiting turn, memoized once per
    /// admission round (see [`sched::StoreCoverage`]): policies probe
    /// the whole queue on every pick, and each store peek takes the
    /// shared mutex + clock fence — once per turn per round is enough,
    /// since coverage is advisory anyway.
    fn store_coverage_memo(&self) -> Option<sched::StoreCoverage> {
        if self.cfg.sched_policy == crate::config::SchedPolicy::Fcfs {
            return None; // FCFS never probes: skip the queue walk
        }
        let h = self.store.as_ref()?;
        let mut memo = sched::StoreCoverage::new();
        for turn in &self.q.waiting {
            if turn.swapped.is_some() {
                continue; // fully resident on its parked handle
            }
            memo.entry((turn.prompt.as_ptr() as usize, turn.prompt.len()))
                .or_insert_with(|| h.peek(&turn.prompt, self.now));
        }
        Some(memo)
    }

    /// Admit turns in the order the scheduling policy picks, until the
    /// batch, KV pool or prefill-budget limits are hit.
    fn admit(&mut self) {
        let mut prefill_budget = self.cfg.max_prefill_tokens;
        let store_coverage = self.store_coverage_memo();
        // Bound one admission round to the initial queue length so
        // requeued (preempted) turns cannot cycle within a single round.
        let mut attempts = self.q.waiting.len();
        while self.q.running.len() < self.cfg.max_batch && attempts > 0 {
            attempts -= 1;
            let probe = match &store_coverage {
                Some(memo) => CacheProbe::with_store(&self.kv, memo),
                None => CacheProbe::new(&self.kv),
            };
            let Some(pick) = self.sched.pick_next(&self.q.waiting, &probe) else { break };
            let idx = pick.idx;
            if pick.uncached_estimate > prefill_budget
                && prefill_budget < self.cfg.max_prefill_tokens
            {
                break; // budget partially consumed; try next step
            }
            let mut turn = self.q.waiting.remove(idx).expect("pick_next index in range");
            let model_id = turn.model_id;
            let seq_id = self.next_seq_id;

            // Swap-restored turns: their whole context is still cached
            // on the device handle parked in the swap tier.
            if let Some((handle, bytes)) = turn.swapped.take() {
                match self.kv.begin_sequence(seq_id, model_id, &turn.prompt) {
                    Alloc::Ok(adm) => {
                        self.drop_snapshots(&adm.dropped_snapshots);
                        self.kv.swap.swap_in(bytes).expect("swap tier accounting");
                        let picked_at = self.now;
                        self.now += self.exec.swap_in_cost(bytes);
                        self.next_seq_id += 1;
                        let tokens = turn.prompt.len() as u64;
                        self.obs_admit(seq_id, model_id, turn.ready_at, picked_at, tokens);
                        self.spawn_running(seq_id, turn, model_id, handle);
                        continue;
                    }
                    Alloc::NoSpace => {
                        // Wait for running sequences to drain (no
                        // admission-time preemption — it can livelock
                        // by ping-ponging two swapped turns).
                        turn.swapped = Some((handle, bytes));
                        self.check_admissible_when_idle(&turn);
                        self.q.waiting.insert(idx, turn);
                        break;
                    }
                }
            }

            match self.kv.begin_sequence(seq_id, model_id, &turn.prompt) {
                Alloc::Ok(adm) => {
                    self.next_seq_id += 1;
                    self.drop_snapshots(&adm.dropped_snapshots);
                    let picked_at = self.now;
                    // Charge PCIe time for blocks restored from swap.
                    if adm.swap_in_bytes > 0 {
                        self.now += self.exec.swap_in_cost(adm.swap_in_bytes);
                    }
                    let (base, cached) = match adm.snapshot {
                        Some((snap, covered)) => (Some(snap), covered),
                        None => (None, 0),
                    };
                    // Note: `adm.cached_tokens` may exceed the snapshot
                    // coverage (blocks cached deeper than the snapshot);
                    // the executor must recompute from the snapshot tip.
                    let mut cached = cached.min(adm.cached_tokens);
                    // Tiered-store restore: when the store holds a
                    // longer prefix of this prompt than the local radix
                    // cache covers, download the KV over the tier's
                    // modeled transfer path instead of recomputing it.
                    // `begin_sequence` already allocated blocks for the
                    // restored span (it is part of the uncached
                    // remainder), so only the transfer is charged.
                    if let Some(h) = &self.store {
                        if let Some(hit) = h.begin_restore(&turn.prompt, cached, self.now) {
                            let cost =
                                self.exec.store_restore_cost(hit.host_bytes, hit.disk_bytes);
                            self.now += cost;
                            self.stats.store_restored_tokens += (hit.tokens - cached) as u64;
                            self.stats.store_restored_bytes += hit.bytes();
                            self.stats
                                .store_restore_latency
                                .as_mut()
                                .unwrap()
                                .record(cost);
                            if hit.disk_bytes > 0 {
                                self.stats.store_disk_hits += 1;
                            } else {
                                self.stats.store_host_hits += 1;
                            }
                            if hit.remote {
                                self.stats.store_remote_hits += 1;
                            }
                            cached = hit.tokens;
                        }
                    }
                    // Handoff consume (disagg decode role): the pinned
                    // prefix has been restored above — release the pin
                    // so the store may age the blocks out normally.
                    if turn.from_handoff {
                        if let Some(h) = &self.store {
                            h.unpin(&turn.prompt);
                        }
                        turn.from_handoff = false;
                        self.stats.decode_handoffs += 1;
                    }
                    self.obs_admit(seq_id, model_id, turn.ready_at, picked_at, cached as u64);
                    let uncached = turn.prompt.len() - cached;
                    // The budget settles against the real admission
                    // outcome regardless of the policy's estimate.
                    prefill_budget = prefill_budget.saturating_sub(uncached);
                    self.stats.prefill_tokens += uncached as u64;
                    self.stats.cached_prefill_tokens += cached as u64;
                    if turn.was_preempted {
                        self.stats.recomputed_tokens += uncached as u64;
                    }
                    if self.cfg.prefill_chunk == 0 {
                        self.admit_atomic(turn, seq_id, model_id, cached, base);
                    } else {
                        self.admit_chunked(turn, seq_id, model_id, cached, base);
                    }
                }
                Alloc::NoSpace => {
                    self.check_admissible_when_idle(&turn);
                    self.q.waiting.insert(idx, turn);
                    break;
                }
            }
        }
    }

    /// Obs: open a sequence's phase bookkeeping at admission (emits the
    /// queue span `ready_at → picked_at`) and attribute any serial
    /// admission-side transfer — the clock advance from `picked_at` to
    /// now — as a transfer span plus per-sequence stall.  No-op when
    /// `--obs off`.
    fn obs_admit(
        &mut self,
        seq_id: u64,
        model_id: usize,
        ready_at: f64,
        picked_at: f64,
        tokens: u64,
    ) {
        let now = self.now;
        if let Some(o) = self.obs.as_mut() {
            o.begin_seq(seq_id, model_id, ready_at, picked_at);
            if now > picked_at {
                o.span(SpanKind::Transfer, picked_at, now, seq_id as i64, model_id as i64, tokens);
                if let Some(s) = o.seq_mut(seq_id) {
                    s.stall += now - picked_at;
                }
            }
        }
    }

    /// Record a stall: the replica is about to jump its clock to `t`
    /// purely to wait on an in-flight gating transfer.
    fn record_stall(&mut self, t: f64) {
        let d = (t - self.now).max(0.0);
        self.stats.stalled_transfer_time += d;
        if let Some(o) = self.ovl.as_mut() {
            o.stalled += d;
        }
    }

    /// Overlap-mode admission: the same policy loop, KV mechanics and
    /// budget/stat accounting as [`Engine::admit`], except that
    /// admission-time transfers (swap-ins of parked contexts, swap-tier
    /// block restores, store restores) are issued as tasks on the
    /// cooperative executor instead of being charged inline — the turn
    /// reserves its KV and a batch slot now, and joins the running
    /// batch when the clock passes the transfer's completion
    /// ([`Engine::integrate_transfers`]).  Transfer-free admissions
    /// take exactly the serial tail, so a run with no transfers is
    /// step-for-step identical to `--overlap off`.
    fn admit_overlap(&mut self) {
        let mut prefill_budget = self.cfg.max_prefill_tokens;
        let store_coverage = self.store_coverage_memo();
        let mut attempts = self.q.waiting.len();
        // In-flight gating transfers hold reserved batch slots: count
        // them against `max_batch` so integration never overfills the
        // decode batch.
        while self.q.running.len() + self.ovl.as_ref().map_or(0, |o| o.gating_count())
            < self.cfg.max_batch
            && attempts > 0
        {
            attempts -= 1;
            let probe = match &store_coverage {
                Some(memo) => CacheProbe::with_store(&self.kv, memo),
                None => CacheProbe::new(&self.kv),
            };
            let Some(pick) = self.sched.pick_next(&self.q.waiting, &probe) else { break };
            let idx = pick.idx;
            if pick.uncached_estimate > prefill_budget
                && prefill_budget < self.cfg.max_prefill_tokens
            {
                break;
            }
            let mut turn = self.q.waiting.remove(idx).expect("pick_next index in range");
            let model_id = turn.model_id;
            let seq_id = self.next_seq_id;

            // Swap-restored turns: issue the PCIe restore as a gating
            // transfer; the turn rejoins the batch with its parked
            // handle once the transfer lands.
            if let Some((handle, bytes)) = turn.swapped.take() {
                match self.kv.begin_sequence(seq_id, model_id, &turn.prompt) {
                    Alloc::Ok(adm) => {
                        self.drop_snapshots(&adm.dropped_snapshots);
                        self.kv.swap.swap_in(bytes).expect("swap tier accounting");
                        self.next_seq_id += 1;
                        let dur = self.exec.swap_in_cost(bytes);
                        let now = self.now;
                        self.obs_admit(seq_id, model_id, turn.ready_at, now, 0);
                        self.ovl
                            .as_mut()
                            .expect("overlap admission requires overlap state")
                            .issue(TransferKind::SwapIn { turn, seq_id, handle }, now, dur);
                        continue;
                    }
                    Alloc::NoSpace => {
                        turn.swapped = Some((handle, bytes));
                        self.check_admissible_when_idle(&turn);
                        self.q.waiting.insert(idx, turn);
                        break;
                    }
                }
            }

            match self.kv.begin_sequence(seq_id, model_id, &turn.prompt) {
                Alloc::Ok(adm) => {
                    self.next_seq_id += 1;
                    self.drop_snapshots(&adm.dropped_snapshots);
                    // Accumulate every transfer this admission needs
                    // into one gating task: swap-tier block restores
                    // plus the store restore ride the same window.
                    let mut transfer = 0.0f64;
                    if adm.swap_in_bytes > 0 {
                        transfer += self.exec.swap_in_cost(adm.swap_in_bytes);
                    }
                    let (base, cached) = match adm.snapshot {
                        Some((snap, covered)) => (Some(snap), covered),
                        None => (None, 0),
                    };
                    let mut cached = cached.min(adm.cached_tokens);
                    // The store hit is consumed at issue time (blocks
                    // touched, stats recorded) — only the time charge
                    // moves off the critical path.
                    if let Some(h) = &self.store {
                        if let Some(hit) = h.begin_restore(&turn.prompt, cached, self.now) {
                            let cost =
                                self.exec.store_restore_cost(hit.host_bytes, hit.disk_bytes);
                            transfer += cost;
                            self.stats.store_restored_tokens += (hit.tokens - cached) as u64;
                            self.stats.store_restored_bytes += hit.bytes();
                            self.stats
                                .store_restore_latency
                                .as_mut()
                                .unwrap()
                                .record(cost);
                            if hit.disk_bytes > 0 {
                                self.stats.store_disk_hits += 1;
                            } else {
                                self.stats.store_host_hits += 1;
                            }
                            if hit.remote {
                                self.stats.store_remote_hits += 1;
                            }
                            cached = hit.tokens;
                        }
                    }
                    // Handoff consume — as in the serial path; the hit
                    // was taken above, so the pin has done its job even
                    // though the transfer lands later.
                    if turn.from_handoff {
                        if let Some(h) = &self.store {
                            h.unpin(&turn.prompt);
                        }
                        turn.from_handoff = false;
                        self.stats.decode_handoffs += 1;
                    }
                    let uncached = turn.prompt.len() - cached;
                    prefill_budget = prefill_budget.saturating_sub(uncached);
                    self.stats.prefill_tokens += uncached as u64;
                    self.stats.cached_prefill_tokens += cached as u64;
                    if turn.was_preempted {
                        self.stats.recomputed_tokens += uncached as u64;
                    }
                    self.obs_admit(seq_id, model_id, turn.ready_at, self.now, cached as u64);
                    if transfer > 0.0 {
                        // Privatize the prefix-cache snapshot across
                        // the in-flight window: a payload displacement
                        // (identical context re-published) before
                        // integration must not invalidate it.  Exactly
                        // what chunked admission does across steps.
                        let base = base.map(|b| self.exec.snapshot(b));
                        let now = self.now;
                        self.ovl
                            .as_mut()
                            .expect("overlap admission requires overlap state")
                            .issue(
                                TransferKind::StoreRestore { turn, seq_id, cached, base },
                                now,
                                transfer,
                            );
                    } else if self.cfg.prefill_chunk == 0 {
                        self.admit_atomic(turn, seq_id, model_id, cached, base);
                    } else {
                        self.admit_chunked(turn, seq_id, model_id, cached, base);
                    }
                }
                Alloc::NoSpace => {
                    self.check_admissible_when_idle(&turn);
                    self.q.waiting.insert(idx, turn);
                    break;
                }
            }
        }
    }

    /// Drive the cooperative runtime to the engine's clock and
    /// integrate every gating transfer that has completed: swap-ins
    /// rejoin the batch with their parked handle; store restores run
    /// their (compute) prefill tail and join.  Loops because an
    /// integration prefill advances the clock, which can carry further
    /// transfers past their completion times.
    fn integrate_transfers(&mut self) {
        if self.ovl.is_none() {
            return;
        }
        loop {
            let (done, stalled_total) = {
                let ovl = self.ovl.as_mut().expect("overlap state present");
                (ovl.drain(self.now), ovl.stalled)
            };
            if done.is_empty() {
                return;
            }
            for t in done {
                // The portion of the flight that genuinely hid behind
                // compute: full duration minus any replica stall that
                // accrued while it flew.
                let stalled_in_flight = stalled_total - t.stall_mark;
                self.stats.overlapped_transfer_time +=
                    ((t.complete_at - t.issued_at) - stalled_in_flight).max(0.0);
                if let Some(o) = self.obs.as_mut() {
                    let (seq_id, model_id) = match &t.kind {
                        TransferKind::SwapIn { turn, seq_id, .. }
                        | TransferKind::StoreRestore { turn, seq_id, .. } => {
                            (*seq_id, turn.model_id)
                        }
                    };
                    o.span(
                        SpanKind::Transfer,
                        t.issued_at,
                        t.complete_at,
                        seq_id as i64,
                        model_id as i64,
                        0,
                    );
                    // The sequence waited out the whole flight, even
                    // where other sequences' compute hid it replica-wide.
                    if let Some(s) = o.seq_mut(seq_id) {
                        s.stall += t.complete_at - t.issued_at;
                    }
                }
                match t.kind {
                    TransferKind::SwapIn { turn, seq_id, handle } => {
                        let model_id = turn.model_id;
                        self.spawn_running(seq_id, turn, model_id, handle);
                    }
                    TransferKind::StoreRestore { turn, seq_id, cached, base } => {
                        let model_id = turn.model_id;
                        if self.cfg.prefill_chunk == 0 {
                            self.admit_atomic(turn, seq_id, model_id, cached, base);
                        } else {
                            self.admit_chunked(turn, seq_id, model_id, cached, base);
                        }
                        // Integration consumed the transfer's private
                        // base fork (atomic prefill forked from it;
                        // chunked admission took its own): release it.
                        if let Some(b) = base {
                            self.exec.drop_snapshot(b);
                        }
                    }
                }
            }
        }
    }

    /// Pre-scheduler admission tail: prefill the whole uncached suffix
    /// in one executor call, charged to the clock before anything else
    /// runs (the head-of-line behavior chunked prefill removes).
    fn admit_atomic(
        &mut self,
        mut turn: PendingTurn,
        seq_id: u64,
        model_id: usize,
        cached: usize,
        base: Option<u64>,
    ) {
        let PrefillOut { duration, cache, first_token } = self
            .exec
            .prefill(model_id, &turn.prompt, cached, base)
            .expect("prefill failed");
        self.now += duration;
        if let Some(o) = self.obs.as_mut() {
            o.span(
                SpanKind::Prefill,
                self.now - duration,
                self.now,
                seq_id as i64,
                model_id as i64,
                (turn.prompt.len() - cached) as u64,
            );
            if let Some(s) = o.seq_mut(seq_id) {
                s.prefill_start = self.now - duration;
                s.prefill_end = self.now;
            }
        }
        self.stats
            .time_to_first_token
            .as_mut()
            .unwrap()
            .record((self.now - turn.ready_at).max(0.0));
        turn.remaining_gen = turn.remaining_gen.saturating_sub(1);
        let seq = RunningSeq {
            seq_id,
            wf_idx: turn.wf_idx,
            turn_idx: turn.turn_idx,
            model_id,
            prompt: turn.prompt,
            generated: vec![first_token],
            remaining_gen: turn.remaining_gen,
            cache,
            cached_tokens: cached,
            ready_at: turn.ready_at,
            admitted_at: self.now,
            last_token_at: self.now,
            prefill: None,
        };
        // The prefill's first token occupies one slot; under extreme
        // pressure the freshly-admitted sequence is itself preempted
        // (its prefill is not wasted under swap; under recompute it
        // re-prefills later).
        match self.kv.append_tokens(seq_id, 1) {
            Alloc::Ok(adm) => {
                self.drop_snapshots(&adm.dropped_snapshots);
                self.q.running.push(seq);
            }
            Alloc::NoSpace => {
                self.kv.preempt(seq.seq_id);
                self.stats.preemptions += 1;
                self.requeue_preempted(seq);
            }
        }
    }

    /// Chunked admission tail: allocate KV for the whole prompt (as the
    /// atomic path does) but defer the encoding — the sequence joins
    /// the running set in the prefilling phase and contributes chunks
    /// to subsequent fused steps.
    fn admit_chunked(
        &mut self,
        turn: PendingTurn,
        seq_id: u64,
        model_id: usize,
        cached: usize,
        base: Option<u64>,
    ) {
        // Privatize the prefix-cache snapshot for the chunks to fork
        // from: a payload displacement (identical context re-published)
        // between now and the first chunk must not invalidate it.
        let base = base.map(|b| self.exec.snapshot(b));
        // Obs: chunked prefill runs from admission to final-chunk
        // promotion; fused-step compute spans are batch-level, so the
        // per-sequence window lives in the bookkeeping alone.
        if let Some(o) = self.obs.as_mut() {
            if let Some(s) = o.seq_mut(seq_id) {
                s.prefill_start = self.now;
                s.prefill_end = self.now;
            }
        }
        self.q.running.push(RunningSeq {
            seq_id,
            wf_idx: turn.wf_idx,
            turn_idx: turn.turn_idx,
            model_id,
            prompt: turn.prompt,
            generated: Vec::new(),
            remaining_gen: turn.remaining_gen,
            cache: 0, // assigned when the final chunk lands
            cached_tokens: cached,
            ready_at: turn.ready_at,
            admitted_at: self.now,
            last_token_at: self.now,
            prefill: Some(PrefillState { next: cached, start: cached, base, cache: None }),
        });
    }

    /// Issue background prefetches: stage disk-tier store entries that
    /// cover queued turns' prompts into host memory, so the eventual
    /// admission-time restore pays PCIe instead of NVMe.  The staging
    /// transfer runs off the critical path (it charges no engine time;
    /// the entry flips to host-priced once the requester's clock passes
    /// the transfer completion).
    fn issue_prefetches(&mut self) {
        if !self.cfg.store_prefetch || self.cfg.store_disk_bytes == 0 {
            // Staging moves disk blocks into host memory; without a
            // disk tier there is never anything to stage, so skip the
            // per-turn hash walks and store-mutex round trips entirely.
            return;
        }
        let Some(h) = &self.store else { return };
        // Staging completion times, to spawn background tasks for once
        // the queue walk (and its borrows) ends.
        let mut staged: Vec<f64> = Vec::new();
        for turn in self.q.waiting.iter().take(PREFETCH_SCAN) {
            if turn.swapped.is_some() {
                continue; // fully resident on its parked handle
            }
            // Scan memo: a candidate probed once — staged, or found
            // unstageable — is not re-probed on every subsequent step;
            // the memo clears whenever this replica publishes to the
            // store, since new contents can overturn a "nothing
            // stageable" verdict.  (Cross-replica publishes are not
            // observed; a candidate they would unblock is re-probed
            // after the next local publish — a deliberately cheap
            // approximation for a purely advisory optimization.)  The
            // key is the turn's deterministic identity; the length
            // distinguishes a requeued turn whose context grew.
            let key = (turn.wf_idx, turn.turn_idx, turn.prompt.len());
            if self.prefetch_seen.contains(&key) {
                self.stats.store_prefetch_skips += 1;
                continue;
            }
            self.prefetch_seen.insert(key);
            // `stage` finds the unstaged disk blocks, prices the
            // transfer and marks them in one locked pass; false means
            // nothing was stageable (or another replica beat us), so
            // the prefetch counter stays exact.
            let cost = std::cell::Cell::new(0.0f64);
            let priced = &|bytes| {
                let c = self.exec.store_stage_cost(bytes);
                cost.set(c);
                c
            };
            if h.stage(&turn.prompt, self.now, priced) {
                self.stats.store_prefetches += 1;
                staged.push(self.now + cost.get());
            }
        }
        // Overlap mode: model each staging transfer as a background
        // task on the cooperative executor.  The store's staged-until
        // bookkeeping already prices the latency; the task makes the
        // NVMe traffic visible to the runtime's counters and counts as
        // overlapped time (staging never blocks the replica).
        if let Some(ovl) = self.ovl.as_mut() {
            for until in staged {
                self.stats.overlapped_transfer_time += (until - self.now).max(0.0);
                ovl.spawn_background(until);
            }
        }
    }

    /// Write a context back into the snapshot store (background D2H
    /// transfer: the entry becomes probe-visible once the write-back
    /// completes, so publishing charges no engine time).  Returns the
    /// virtual time the published prefix becomes visible to probes —
    /// including the store's causality-window clamp — or `None` when
    /// nothing was published (no store, or a sub-block context).
    fn publish_to_store(&mut self, ctx: &crate::tokens::TokenBuf) -> Option<f64> {
        let Some(h) = &self.store else { return None };
        let bt = self.cfg.block_tokens;
        let aligned = (ctx.len() / bt) * bt;
        if aligned == 0 {
            return None;
        }
        let bytes = aligned as u64 * self.kv.kv_bytes_per_token();
        // Write-back is the PCIe hop in the other direction.
        let visible_at = self.now + self.exec.store_restore_cost(bytes, 0);
        h.publish(ctx, self.now, visible_at);
        // New store contents invalidate the prefetch scan's
        // already-probed verdicts (see `issue_prefetches`).
        self.prefetch_seen.clear();
        // Obs: the write-back span covers submit → probe-visibility
        // (context-level, not sequence-level: demotions publish too).
        if let Some(o) = self.obs.as_mut() {
            o.span(SpanKind::WriteBack, self.now, visible_at, -1, -1, aligned as u64);
        }
        // Overlap mode: the D2H write-back becomes a background task —
        // visibility timing is unchanged (the store models it), but
        // the transfer shows up in the runtime's task counters and as
        // overlapped time, since it never blocked the replica.
        if let Some(ovl) = self.ovl.as_mut() {
            self.stats.overlapped_transfer_time += (visible_at - self.now).max(0.0);
            ovl.spawn_background(visible_at);
        }
        // Report the horizon the *store* will enforce: it clamps every
        // visibility time at least one causality window into the future
        // (see `crate::store`), so an unclamped value would make a
        // handoff's `admissible_at` land just before the prefix is
        // probe-visible and silently degrade to a full re-prefill.
        Some(visible_at.max(self.now + crate::store::DEFAULT_WINDOW))
    }

    /// Fatal-misconfiguration guard: if the system is idle (nothing
    /// running, so every unpinned block is evictable) and a turn still
    /// cannot be admitted, it never will be — fail loudly instead of
    /// spinning.
    fn check_admissible_when_idle(&self, turn: &PendingTurn) {
        // Overlap mode: in-flight gating transfers hold KV and batch
        // slots but are invisible in `running` — their integration
        // frees capacity, so the system is not actually wedged.
        if self.ovl.as_ref().is_some_and(Overlap::has_gating) {
            return;
        }
        if self.q.running.is_empty() {
            panic!(
                "KV pool ({} blocks of {} tokens) cannot hold a {}-token prompt \
                 even when idle; increase kv_pool_bytes",
                self.kv.pool.capacity(),
                self.kv.pool.block_tokens,
                turn.prompt.len()
            );
        }
    }

    fn spawn_running(&mut self, seq_id: u64, turn: PendingTurn, model_id: usize, cache: u64) {
        self.q.running.push(RunningSeq {
            seq_id,
            wf_idx: turn.wf_idx,
            turn_idx: turn.turn_idx,
            model_id,
            prompt: turn.prompt,
            generated: Vec::new(),
            remaining_gen: turn.remaining_gen,
            cache,
            cached_tokens: 0,
            ready_at: turn.ready_at,
            admitted_at: self.now,
            last_token_at: self.now,
            prefill: None,
        });
    }

    fn requeue_preempted(&mut self, mut victim: RunningSeq) {
        if let Some(st) = victim.prefill.take() {
            // A sequence preempted mid-chunked-prefill: no snapshot
            // covers a half-encoded prompt, so partial caches are not
            // swappable — always take the recompute path.
            if let Some(c) = st.cache {
                self.exec.drop_snapshot(c);
            }
            if let Some(b) = st.base {
                self.exec.drop_snapshot(b);
            }
            let turn = PendingTurn {
                wf_idx: victim.wf_idx,
                turn_idx: victim.turn_idx,
                model_id: victim.model_id,
                ready_at: victim.ready_at,
                remaining_gen: victim.remaining_gen,
                // Only actually-encoded chunks count as wasted compute.
                was_preempted: st.next > st.start,
                swapped: None,
                // Disagg: a preempted turn re-admits locally — its
                // prefill debt was already retired (or, prefill role,
                // the job is still this replica's to finish).
                from_handoff: false,
                local_only: true,
                // No tokens generated yet: the context is the prompt.
                prompt: victim.into_context(),
            };
            self.q.waiting.push_back(turn);
            return;
        }
        let cache = victim.cache;
        let context_len = victim.context_len();
        let mut turn = PendingTurn {
            wf_idx: victim.wf_idx,
            turn_idx: victim.turn_idx,
            model_id: victim.model_id,
            ready_at: victim.ready_at,
            remaining_gen: victim.remaining_gen,
            was_preempted: true,
            swapped: None,
            // Disagg: preempted mid-decode — the context now includes
            // generated tokens no prefill replica has seen; re-admit
            // locally (and never re-forward: the termination counter
            // charges each turn once).
            from_handoff: false,
            local_only: true,
            // Restart prompt = prompt + generated-so-far; appends in
            // place (the victim owns its buffer), no context copy.
            prompt: victim.into_context(),
        };
        match self.cfg.eviction {
            EvictionPolicy::Recompute => {
                self.exec.drop_snapshot(cache);
            }
            EvictionPolicy::Swap => {
                let bytes = context_len as u64 * self.kv.kv_bytes_per_token();
                if self.kv.swap.swap_out(bytes) {
                    turn.swapped = Some((cache, bytes));
                    turn.was_preempted = false;
                } else {
                    self.kv.stats.swap_tier_full += 1;
                    self.exec.drop_snapshot(cache);
                }
            }
        }
        // Preempted turns go to the back: freshly-arrived work is not
        // starved, matching vLLM's recompute-requeue behaviour.
        self.q.waiting.push_back(turn);
    }

    /// One decode step over the running batch (chunking disabled: every
    /// running sequence is decoding).
    ///
    /// Deliberately kept as a verbatim copy of the pre-scheduler loop
    /// rather than folded into `chunked_step`'s chunk-free path: this
    /// is the surface the FCFS bit-identity property test pins, and
    /// keeping it byte-for-byte auditable against the frozen legacy
    /// port is worth the duplication.
    fn decode_step(&mut self) {
        if self.q.running.is_empty() {
            return;
        }
        // Grow every sequence by one token slot; preempt on pressure.
        let mut i = 0;
        while i < self.q.running.len() {
            let seq_id = self.q.running[i].seq_id;
            match self.kv.append_tokens(seq_id, 1) {
                Alloc::Ok(adm) => {
                    self.drop_snapshots(&adm.dropped_snapshots);
                    i += 1;
                }
                Alloc::NoSpace => {
                    if !self.preempt_other(i) {
                        // This sequence itself is the victim.
                        let victim = self.q.running.swap_remove(i);
                        self.kv.preempt(victim.seq_id);
                        self.stats.preemptions += 1;
                        self.requeue_preempted(victim);
                    }
                }
            }
        }
        if self.q.running.is_empty() {
            return;
        }
        let mut slots: Vec<DecodeSlot> = self
            .q
            .running
            .iter()
            .map(|s| DecodeSlot {
                seq_id: s.seq_id,
                model_id: s.model_id,
                cache: s.cache,
                context_len: s.context_len(),
                last_token: *s.generated.last().unwrap_or(&1),
                next_token: 0,
            })
            .collect();
        let dur = self.exec.decode(&mut slots).expect("decode failed");
        self.now += dur;
        if let Some(o) = self.obs.as_mut() {
            o.span(SpanKind::Decode, self.now - dur, self.now, -1, -1, slots.len() as u64);
        }
        for (seq, slot) in self.q.running.iter_mut().zip(&slots) {
            debug_assert_eq!(seq.seq_id, slot.seq_id);
            seq.cache = slot.cache;
            seq.generated.push(slot.next_token);
            seq.remaining_gen = seq.remaining_gen.saturating_sub(1);
            // The inter-token gap includes whatever else the engine did
            // since this sequence's previous token (e.g. other turns'
            // atomic prefills) — the stall signal, not just step cost.
            let gap = (self.now - seq.last_token_at).max(0.0);
            seq.last_token_at = self.now;
            self.stats.generated_tokens += 1;
            self.stats.inter_token_latency.as_mut().unwrap().record(gap);
        }
        // Retire finished turns.
        let mut j = 0;
        while j < self.q.running.len() {
            if self.q.running[j].remaining_gen == 0 {
                let seq = self.q.running.swap_remove(j);
                self.finish_turn(seq);
            } else {
                j += 1;
            }
        }
    }

    /// One fused step under chunked prefill: co-schedule up to
    /// `max_prefill_tokens` of prompt encoding (bounded per sequence by
    /// `prefill_chunk`) with one decode step over the decoding batch,
    /// so running sequences keep emitting tokens while long prompts
    /// encode incrementally.
    fn chunked_step(&mut self) {
        if self.q.running.is_empty() {
            return;
        }
        // Grow decoding sequences by one token slot; preempt on
        // pressure (prefilling sequences reserved their prompt blocks
        // at admission and grow nothing here).  Iterate by id, not
        // index: preemption's swap_remove reorders the vec, and an
        // index cursor could then skip a sequence's reservation while
        // still decoding it (a latent legacy quirk decode_step keeps
        // for bit-identity; this path is new and need not).
        let grow_ids: Vec<u64> = self
            .q
            .running
            .iter()
            .filter(|s| s.prefill.is_none())
            .map(|s| s.seq_id)
            .collect();
        for seq_id in grow_ids {
            // Retry after successful third-party preemption; stop if
            // this sequence itself got preempted as an earlier victim.
            loop {
                let Some(pos) = self.q.running.iter().position(|s| s.seq_id == seq_id) else {
                    break;
                };
                match self.kv.append_tokens(seq_id, 1) {
                    Alloc::Ok(adm) => {
                        self.drop_snapshots(&adm.dropped_snapshots);
                        break;
                    }
                    Alloc::NoSpace => {
                        if !self.preempt_other(pos) {
                            let victim = self.q.running.remove(pos);
                            self.kv.preempt(victim.seq_id);
                            self.stats.preemptions += 1;
                            self.requeue_preempted(victim);
                            break;
                        }
                    }
                }
            }
        }
        // Plan this step's chunks in admission order: per-sequence cap
        // `prefill_chunk`, shared per-step budget `max_prefill_tokens`.
        // Floor the budget at one token: with a degenerate
        // `max_prefill_tokens = 0` the atomic path still prefills via
        // admission's budget-bypassing first slot, so the chunked path
        // must likewise guarantee progress instead of spinning forever.
        let mut budget = self.cfg.max_prefill_tokens.max(1);
        let mut plan: Vec<(u64, usize, usize)> = Vec::new(); // (seq, start, end)
        for s in &self.q.running {
            let Some(st) = &s.prefill else { continue };
            let remaining = s.prompt.len() - st.next;
            if remaining == 0 {
                // Fully-cached prompt: a zero-token "chunk" still runs,
                // forking the base cache and emitting the first token.
                plan.push((s.seq_id, st.next, st.next));
                continue;
            }
            if budget == 0 {
                continue; // later prefills wait for the next step
            }
            let take = remaining.min(self.cfg.prefill_chunk.max(1)).min(budget);
            plan.push((s.seq_id, st.next, st.next + take));
            budget -= take;
        }
        let mut slots: Vec<DecodeSlot> = self
            .q
            .running
            .iter()
            .filter(|s| s.prefill.is_none())
            .map(|s| DecodeSlot {
                seq_id: s.seq_id,
                model_id: s.model_id,
                cache: s.cache,
                context_len: s.context_len(),
                last_token: *s.generated.last().unwrap_or(&1),
                next_token: 0,
            })
            .collect();
        if plan.is_empty() && slots.is_empty() {
            return;
        }
        let mut chunks: Vec<ChunkSlot<'_>> = plan
            .iter()
            .map(|&(seq_id, start, end)| {
                let s = self
                    .q
                    .running
                    .iter()
                    .find(|s| s.seq_id == seq_id)
                    .expect("planned seq is running");
                let st = s.prefill.as_ref().expect("planned seq is prefilling");
                ChunkSlot {
                    seq_id,
                    model_id: s.model_id,
                    tokens: &s.prompt[start..end],
                    start,
                    prompt_len: s.prompt.len(),
                    base: st.base,
                    cache: st.cache,
                    first_token: None,
                }
            })
            .collect();
        let dur = self.exec.fused_step(&mut chunks, &mut slots).expect("fused step failed");
        self.now += dur;
        self.stats.prefill_chunks += chunks.len() as u64;
        // Obs: one batch-level compute span per fused step, labelled by
        // the dominant work (any chunk ⇒ prefill; else pure decode).
        if let Some(o) = self.obs.as_mut() {
            let kind = if chunks.is_empty() { SpanKind::Decode } else { SpanKind::Prefill };
            o.span(kind, self.now - dur, self.now, -1, -1, (chunks.len() + slots.len()) as u64);
        }
        let chunk_out: Vec<(u64, usize, Option<u64>, Option<u32>)> =
            chunks.iter().map(|c| (c.seq_id, c.end(), c.cache, c.first_token)).collect();
        drop(chunks);
        // Apply decode results, keyed by sequence id (the growth-phase
        // preemptions above may have reordered the running vec).
        for slot in &slots {
            let seq = self
                .q
                .running
                .iter_mut()
                .find(|s| s.seq_id == slot.seq_id)
                .expect("decoded seq is running");
            seq.cache = slot.cache;
            seq.generated.push(slot.next_token);
            seq.remaining_gen = seq.remaining_gen.saturating_sub(1);
            let gap = (self.now - seq.last_token_at).max(0.0);
            seq.last_token_at = self.now;
            self.stats.generated_tokens += 1;
            self.stats.inter_token_latency.as_mut().unwrap().record(gap);
        }
        // Apply chunk results; final chunks promote their sequence to
        // the decode batch.
        for (seq_id, new_next, cache, first) in chunk_out {
            let Some(pos) = self.q.running.iter().position(|s| s.seq_id == seq_id) else {
                continue; // defensively tolerate a vanished sequence
            };
            {
                let seq = &mut self.q.running[pos];
                let st = seq.prefill.as_mut().expect("chunk applied to prefilling seq");
                st.next = new_next;
                st.cache = cache;
            }
            // The first chunk forked off the base snapshot; release the
            // engine-private handle.
            if self.q.running[pos].prefill.as_ref().is_some_and(|st| st.cache.is_some()) {
                let base = self.q.running[pos].prefill.as_mut().and_then(|st| st.base.take());
                if let Some(b) = base {
                    self.exec.drop_snapshot(b);
                }
            }
            let done = {
                let s = &self.q.running[pos];
                s.prefill.as_ref().expect("still prefilling").next == s.prompt.len()
            };
            if !done {
                continue;
            }
            // Prefill role: the finished prompt encode *is* the
            // product.  Publish and hand off instead of joining the
            // decode batch — no first token here; the decode replica
            // emits it after restoring the prefix.
            if self.disagg.as_ref().is_some_and(|d| d.role() == ReplicaRole::Prefill) {
                let seq = self.q.running.remove(pos);
                self.finish_prefill_handoff(seq);
                continue;
            }
            let ready_at = {
                let seq = &mut self.q.running[pos];
                let st = seq.prefill.take().expect("completed prefill state");
                seq.cache = st.cache.expect("completed prefill built a cache");
                seq.generated.push(first.expect("final chunk emits the first token"));
                seq.remaining_gen = seq.remaining_gen.saturating_sub(1);
                seq.last_token_at = self.now;
                seq.ready_at
            };
            self.stats
                .time_to_first_token
                .as_mut()
                .unwrap()
                .record((self.now - ready_at).max(0.0));
            if let Some(o) = self.obs.as_mut() {
                if let Some(s) = o.seq_mut(seq_id) {
                    s.prefill_end = self.now;
                }
            }
            // The first token occupies one slot, exactly like the
            // atomic path; under extreme pressure the sequence preempts
            // itself (prefill is complete here, so the normal
            // recompute/swap eviction policy applies).
            match self.kv.append_tokens(seq_id, 1) {
                Alloc::Ok(adm) => self.drop_snapshots(&adm.dropped_snapshots),
                Alloc::NoSpace => {
                    let victim = self.q.running.remove(pos);
                    self.kv.preempt(victim.seq_id);
                    self.stats.preemptions += 1;
                    self.requeue_preempted(victim);
                }
            }
        }
        // Retire finished turns (decoding sequences only).
        let mut j = 0;
        while j < self.q.running.len() {
            let s = &self.q.running[j];
            if s.prefill.is_none() && s.remaining_gen == 0 {
                let seq = self.q.running.swap_remove(j);
                self.finish_turn(seq);
            } else {
                j += 1;
            }
        }
    }

    /// Prefill-role retirement: the sequence's prompt is fully encoded.
    /// Publish the KV into the shared store (write-through, exactly the
    /// artifact a decode replica restores), pin the chain against
    /// demotion until the decode side consumes it, and hand the turn
    /// back to its owner stamped with the store-visibility horizon.
    /// The sequence also publishes to the local radix cache, so later
    /// handoffs sharing the prefix skip the re-encode.
    fn finish_prefill_handoff(&mut self, mut seq: RunningSeq) {
        let st = seq.prefill.take().expect("handoff seq completed its prefill");
        let cache = st.cache.expect("completed prefill built a cache");
        debug_assert!(st.base.is_none(), "base snapshot consumed by the first chunk");
        debug_assert!(seq.generated.is_empty(), "prefill role never decodes");
        let snap = self.exec.snapshot(cache);
        self.exec.drop_snapshot(cache);
        let dropped = self.kv.finish_sequence(seq.seq_id, &seq.prompt, Some(snap));
        self.drop_snapshots(&dropped);
        let visible_at = self.publish_to_store(&seq.prompt);
        // A sub-block prompt publishes nothing: the decode side will
        // simply re-encode it (a few tokens) at admission.
        let admissible_at = visible_at.map_or(self.now, |v| v.max(self.now));
        if let Some(h) = &self.store {
            h.pin(&seq.prompt);
        }
        self.stats.prefill_handoffs += 1;
        // Obs: the handoff span covers respond → the decode side's
        // admissibility horizon.  Prefill-role sequences never reach
        // `finish_turn`, so their bookkeeping closes here (the decode
        // replica attributes the turn's phases on its side).
        if let Some(o) = self.obs.as_mut() {
            if let Some(s) = o.seq_mut(seq.seq_id) {
                s.prefill_end = self.now;
            }
            o.span(
                SpanKind::Handoff,
                self.now,
                admissible_at,
                seq.seq_id as i64,
                seq.model_id as i64,
                seq.prompt.len() as u64,
            );
            o.finish_seq(seq.seq_id);
        }
        let job = &self.prefill_jobs[seq.wf_idx];
        self.disagg.as_ref().expect("prefill handoff requires disagg").respond(
            job.reply_to,
            PrefillResponse {
                prompt: seq.prompt,
                model_id: seq.model_id,
                remaining_gen: job.remaining_gen,
                wf_idx: job.wf_idx,
                turn_idx: job.turn_idx,
                ready_at: job.ready_at,
                admissible_at,
            },
        );
    }

    /// Preempt the newest running sequence other than index `keep`.
    fn preempt_other(&mut self, keep: usize) -> bool {
        let Some(pos) = self
            .q
            .running
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != keep)
            .max_by(|a, b| a.1.admitted_at.total_cmp(&b.1.admitted_at))
            .map(|(i, _)| i)
        else {
            return false;
        };
        let victim = self.q.running.swap_remove(pos);
        self.kv.preempt(victim.seq_id);
        self.stats.preemptions += 1;
        self.requeue_preempted(victim);
        true
    }

    fn finish_turn(&mut self, seq: RunningSeq) {
        debug_assert!(seq.prefill.is_none(), "prefilling seq cannot retire");
        self.stats.completed_turns += 1;
        // Obs: close the sequence's bookkeeping and attribute the turn's
        // latency across queue/prefill/stall/decode.  `None` (obs off)
        // leaves the trace event's breakdown at 0.0 — the legacy
        // serialization shape.
        let now = self.now;
        let phases = self.obs.as_mut().and_then(|o| o.finish_seq(seq.seq_id)).map(|s| {
            (
                (s.picked_at - s.ready_at).max(0.0),
                (s.prefill_end - s.prefill_start).max(0.0),
                s.stall,
                (now - s.prefill_end).max(0.0),
            )
        });
        if let Some(trace) = &mut self.trace {
            trace.record(TurnEvent {
                wf_id: self.wfs[seq.wf_idx].spec.id,
                turn_idx: seq.turn_idx,
                model_id: seq.model_id,
                ready_at: seq.ready_at,
                completed_at: self.now,
                prompt_tokens: seq.prompt.len(),
                cached_tokens: seq.cached_tokens,
                generated_tokens: seq.generated.len(),
                queue_wait: phases.map_or(0.0, |p| p.0),
                prefill_time: phases.map_or(0.0, |p| p.1),
                stall_time: phases.map_or(0.0, |p| p.2),
            });
        }
        self.stats
            .turn_latency
            .as_mut()
            .unwrap()
            .record((self.now - seq.ready_at).max(0.0));
        if let Some((queue, prefill, stall, decode)) = phases {
            self.stats.record_phases(seq.model_id, queue, prefill, stall, decode);
        }
        let seq_id = seq.seq_id;
        let wf_idx = seq.wf_idx;
        let turn_idx = seq.turn_idx;
        let cache = seq.cache;
        // Publish the full turn context so the workflow's next turn
        // (possibly on another model) hits the prefix cache.  The append
        // happens in place — the sequence owns the context buffer.
        let full = seq.into_context();
        let snap = self.exec.snapshot(cache);
        // The published snapshot keeps the cache alive; the sequence's
        // live handle is done (leaving it would leak one handle per
        // turn for the rest of the run).
        self.exec.drop_snapshot(cache);
        let dropped = self.kv.finish_sequence(seq_id, &full, Some(snap));
        self.drop_snapshots(&dropped);
        // Write-through into the snapshot store: the context becomes a
        // restorable artifact for every replica (and survives local
        // eviction) once the background write-back completes.
        self.publish_to_store(&full);

        let wf = &mut self.wfs[wf_idx];
        let spec_turn = &wf.spec.turns[turn_idx];
        // Context for the next turn: append the tool observation, again
        // in place (`full` is the sole owner after finish_sequence).
        let ctx = full.extended(&spec_turn.obs);
        wf.next_turn = turn_idx + 1;
        if wf.next_turn < wf.spec.turns.len() {
            let next = &wf.spec.turns[wf.next_turn];
            let gen = next.gen_len;
            let ready_at = self.now + next.think_s;
            let turn = PendingTurn {
                wf_idx,
                turn_idx: wf.next_turn,
                model_id: next.model_id,
                ready_at,
                // The pending turn owns the context (wf.context stays
                // empty until the workflow's final turn completes).
                prompt: ctx,
                remaining_gen: gen,
                was_preempted: false,
                swapped: None,
                // Fresh turn: under disagg it is forwarded for prefill
                // like any other (the grown context's new suffix is
                // what the prefill fleet encodes).
                from_handoff: false,
                local_only: false,
            };
            if ready_at > self.now {
                self.q.delayed.push(turn);
            } else {
                self.q.waiting.push_back(turn);
            }
        } else {
            wf.context = ctx; // final context retained for inspection
            wf.done = true;
            self.stats.completed_requests += 1;
            let arrival = wf.spec.arrival;
            self.stats
                .request_latency
                .as_mut()
                .unwrap()
                .record((self.now - arrival).max(0.0));
        }
    }

    fn drop_snapshots(&mut self, snaps: &[u64]) {
        for &s in snaps {
            self.exec.drop_snapshot(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::executor::{CostModel, SimExecutor};
    use super::*;
    use crate::config::{AgentPattern, Routing, SchedPolicy, ServingMode, WorkloadConfig};
    use crate::workload::generate;

    fn run(mode: ServingMode, n_models: usize, qps: f64, pool_mb: u64) -> ServingStats {
        run_sched(mode, n_models, qps, pool_mb, SchedPolicy::Fcfs, 0)
    }

    fn run_sched(
        mode: ServingMode,
        n_models: usize,
        qps: f64,
        pool_mb: u64,
        policy: SchedPolicy,
        chunk: usize,
    ) -> ServingStats {
        let scfg = ServingConfig {
            mode,
            kv_pool_bytes: pool_mb << 20,
            sched_policy: policy,
            prefill_chunk: chunk,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            pattern: AgentPattern::ReAct,
            n_models,
            qps,
            n_requests: 48,
            routing: Routing::RoundRobin,
            seed: 7,
            ..Default::default()
        };
        let exec = SimExecutor::new(CostModel::default(), mode);
        // serve-small KV cost: 4 layers * 2 * 64 dims * 4B = 2048 B/token
        let engine = Engine::new(scfg, 2048, n_models, exec);
        engine.run(generate(&wcfg))
    }

    #[test]
    fn completes_all_workflows() {
        let s = run(ServingMode::Icarus, 4, 0.5, 64);
        assert_eq!(s.completed_requests, 48);
        assert!(s.completed_turns >= 48);
        assert!(s.generated_tokens > 0);
        assert!(s.wall_seconds > 0.0);
    }

    #[test]
    fn baseline_also_completes() {
        let s = run(ServingMode::Baseline, 4, 0.5, 64);
        assert_eq!(s.completed_requests, 48);
    }

    #[test]
    fn every_policy_and_chunking_completes() {
        for policy in [SchedPolicy::Fcfs, SchedPolicy::CacheAware, SchedPolicy::Sjf] {
            for chunk in [0usize, 128] {
                for mode in [ServingMode::Icarus, ServingMode::Baseline] {
                    let s = run_sched(mode, 4, 0.8, 32, policy, chunk);
                    assert_eq!(
                        s.completed_requests, 48,
                        "{policy:?} chunk={chunk} {mode:?} lost workflows"
                    );
                    if chunk > 0 {
                        assert!(s.prefill_chunks > 0, "{policy:?}: chunks must be counted");
                    } else {
                        assert_eq!(s.prefill_chunks, 0);
                    }
                }
            }
        }
    }

    #[test]
    fn obs_records_spans_counters_and_phase_attribution() {
        let wcfg = WorkloadConfig {
            pattern: AgentPattern::ReAct,
            n_models: 4,
            qps: 0.5,
            n_requests: 16,
            routing: Routing::RoundRobin,
            seed: 7,
            ..Default::default()
        };
        let scfg = ServingConfig { obs: true, ..Default::default() };
        let exec = SimExecutor::new(CostModel::default(), scfg.mode);
        let engine = Engine::new(scfg, 2048, 4, exec);
        let (stats, obs) = engine.run_obs(generate(&wcfg));
        let obs = obs.expect("obs on returns a recorder");
        for kind in [SpanKind::Queue, SpanKind::Prefill, SpanKind::Decode] {
            assert!(obs.spans().iter().any(|s| s.kind == kind), "{kind:?} span present");
        }
        assert!(!obs.counters().is_empty(), "per-step counter samples present");
        assert!(!stats.phases.is_empty(), "per-model phase histograms recorded");
        let turns: u64 = stats.phases.iter().map(|p| p.decode.count()).sum();
        assert_eq!(turns, stats.completed_turns, "one phase sample per retired turn");
        // Obs off: run_obs returns no recorder and records no phases.
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let engine = Engine::new(ServingConfig::default(), 2048, 4, exec);
        let (stats, obs) = engine.run_obs(generate(&wcfg));
        assert!(obs.is_none());
        assert!(stats.phases.is_empty());
    }

    #[test]
    fn policies_are_deterministic_given_seed() {
        for policy in [SchedPolicy::CacheAware, SchedPolicy::Sjf] {
            for chunk in [0usize, 96] {
                let a = run_sched(ServingMode::Icarus, 4, 0.5, 64, policy, chunk);
                let b = run_sched(ServingMode::Icarus, 4, 0.5, 64, policy, chunk);
                assert_eq!(a.generated_tokens, b.generated_tokens, "{policy:?}/{chunk}");
                assert_eq!(a.wall_seconds, b.wall_seconds, "{policy:?}/{chunk}");
                assert_eq!(a.preemptions, b.preemptions, "{policy:?}/{chunk}");
            }
        }
    }

    #[test]
    fn chunked_prefill_cuts_p95_with_long_prompts() {
        // Long cold prompts + short turns: atomically prefilling a
        // multi-thousand-token prompt stalls every queued/running turn
        // for whole seconds; 256-token chunks bound the stall per step.
        let mk = |chunk: usize| {
            let scfg = ServingConfig {
                mode: ServingMode::Baseline, // cold cache: worst case
                kv_pool_bytes: 256 << 20,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let wcfg = WorkloadConfig {
                n_models: 4,
                qps: 0.6,
                n_requests: 48,
                prompt_mean: 1600.0,
                prompt_std: 800.0,
                seed: 11,
                ..Default::default()
            };
            let exec = SimExecutor::new(CostModel::default(), ServingMode::Baseline);
            Engine::new(scfg, 2048, 4, exec).run(generate(&wcfg))
        };
        let atomic = mk(0);
        let chunked = mk(256);
        assert_eq!(atomic.completed_requests, chunked.completed_requests);
        let pa = atomic.turn_latency.as_ref().unwrap().p95();
        let pc = chunked.turn_latency.as_ref().unwrap().p95();
        assert!(pc < pa, "chunked p95 {pc} must beat atomic p95 {pa}");
        // The stall signal: inter-token gaps (which include other
        // turns' prefill stalls) collapse under chunking.
        let ia = atomic.inter_token_latency.as_ref().unwrap().mean();
        let ic = chunked.inter_token_latency.as_ref().unwrap().mean();
        assert!(ic < ia, "chunked mean ITL {ic} must beat atomic {ia}");
        assert!(chunked.prefill_chunks > 0);
    }

    #[test]
    fn icarus_has_higher_cache_hit_rate() {
        let i = run(ServingMode::Icarus, 4, 0.5, 64);
        let b = run(ServingMode::Baseline, 4, 0.5, 64);
        assert!(
            i.cache_hit_rate() > b.cache_hit_rate() + 0.2,
            "icarus {} vs baseline {}",
            i.cache_hit_rate(),
            b.cache_hit_rate()
        );
    }

    #[test]
    fn icarus_lower_p95_under_pressure() {
        let i = run(ServingMode::Icarus, 8, 0.6, 32);
        let b = run(ServingMode::Baseline, 8, 0.6, 32);
        let pi = i.turn_latency.as_ref().unwrap().p95();
        let pb = b.turn_latency.as_ref().unwrap().p95();
        assert!(pi < pb, "icarus p95 {pi} vs baseline p95 {pb}");
    }

    #[test]
    fn icarus_peak_memory_lower() {
        let i = run(ServingMode::Icarus, 4, 0.5, 256);
        let b = run(ServingMode::Baseline, 4, 0.5, 256);
        assert!(
            i.peak_kv_bytes < b.peak_kv_bytes,
            "icarus {} vs baseline {}",
            i.peak_kv_bytes,
            b.peak_kv_bytes
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(ServingMode::Icarus, 4, 0.5, 64);
        let b = run(ServingMode::Icarus, 4, 0.5, 64);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(a.wall_seconds, b.wall_seconds);
        assert_eq!(a.preemptions, b.preemptions);
    }

    #[test]
    fn think_time_extends_wall_clock() {
        // Tool latency must show up in wall time but not in turn latency
        // accounting (the clock starts at ready_at, after the tool).
        let mk = |think: f64| {
            let scfg = ServingConfig { kv_pool_bytes: 64 << 20, ..Default::default() };
            let wcfg = WorkloadConfig {
                n_requests: 8,
                qps: 100.0,
                think_mean: think,
                think_std: 0.0,
                seed: 5,
                ..Default::default()
            };
            let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
            Engine::new(scfg, 2048, 4, exec).run(generate(&wcfg))
        };
        let fast = mk(0.0);
        let slow = mk(5.0);
        assert!(slow.wall_seconds > fast.wall_seconds + 4.0);
        let pf = fast.turn_latency.as_ref().unwrap().p50();
        let ps = slow.turn_latency.as_ref().unwrap().p50();
        // Turn latency does not balloon by the think time itself.
        assert!(ps < pf + 2.0, "fast {pf} slow {ps}");
    }

    #[test]
    fn traced_run_matches_stats() {
        let scfg = ServingConfig { kv_pool_bytes: 64 << 20, ..Default::default() };
        let wcfg = WorkloadConfig { n_requests: 24, seed: 9, ..Default::default() };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let engine = Engine::new(scfg, 2048, 4, exec);
        let (stats, trace) = engine.run_traced(generate(&wcfg));
        assert_eq!(trace.events.len() as u64, stats.completed_turns);
        // Trace-derived P95 must agree with the histogram within bucket
        // resolution (~3%) plus the histogram's upper-edge bias.
        let h = stats.turn_latency.as_ref().unwrap().p95();
        let t = trace.latency_quantile(0.95);
        assert!((h - t).abs() / h.max(1e-9) < 0.10, "hist {h} vs trace {t}");
        // Round-robin routing shows up as near-uniform model counts.
        let counts = trace.per_model_counts();
        assert_eq!(counts.len(), 4);
    }

    #[test]
    fn tiny_pool_forces_preemptions_but_still_completes() {
        let s = run(ServingMode::Baseline, 8, 1.0, 4);
        assert_eq!(s.completed_requests, 48);
        assert!(s.preemptions > 0 || s.evictions > 0, "pressure expected");
    }

    #[test]
    fn chunked_survives_memory_pressure() {
        // Chunked prefill under a tiny pool: preemptions of sequences
        // mid-prefill must requeue and complete (recompute path).
        for policy in [SchedPolicy::Fcfs, SchedPolicy::CacheAware, SchedPolicy::Sjf] {
            let s = run_sched(ServingMode::Baseline, 8, 1.0, 4, policy, 64);
            assert_eq!(s.completed_requests, 48, "{policy:?}");
            assert!(s.preemptions > 0 || s.evictions > 0, "{policy:?}: pressure expected");
        }
    }

    #[test]
    fn swap_mode_runs_and_swaps() {
        let scfg = ServingConfig {
            mode: ServingMode::Baseline,
            kv_pool_bytes: 4 << 20,
            eviction: EvictionPolicy::Swap,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            n_models: 8,
            qps: 1.0,
            n_requests: 32,
            seed: 3,
            ..Default::default()
        };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Baseline);
        let s = Engine::new(scfg, 2048, 8, exec).run(generate(&wcfg));
        assert_eq!(s.completed_requests, 32);
    }

    fn run_with_store(
        host_bytes: u64,
        disk_bytes: u64,
        prefetch: bool,
        max_batch: usize,
        wcfg: &WorkloadConfig,
    ) -> ServingStats {
        run_with_store_overlap(host_bytes, disk_bytes, prefetch, max_batch, false, wcfg)
    }

    fn run_with_store_overlap(
        host_bytes: u64,
        disk_bytes: u64,
        prefetch: bool,
        max_batch: usize,
        overlap: bool,
        wcfg: &WorkloadConfig,
    ) -> ServingStats {
        use crate::store::{SnapshotStore, StoreHandle, TieredStore};
        use std::sync::Arc;
        let scfg = ServingConfig {
            kv_pool_bytes: 4 << 20,
            max_batch,
            store_host_bytes: host_bytes,
            store_disk_bytes: disk_bytes,
            store_prefetch: prefetch,
            overlap,
            ..Default::default()
        };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let mut engine = Engine::new(scfg.clone(), 2048, wcfg.n_models, exec);
        if host_bytes + disk_bytes > 0 {
            let store: Arc<dyn SnapshotStore> =
                Arc::new(TieredStore::new(host_bytes, disk_bytes, scfg.block_tokens, 2048));
            engine.attach_store(StoreHandle::new(store, None, 0));
        }
        engine.run(generate(wcfg))
    }

    #[test]
    fn store_restores_evicted_contexts_instead_of_recomputing() {
        // A 4 MB pool holds ~2k tokens of KV: agentic contexts are
        // constantly evicted between turns.  With a roomy host tier the
        // next turn restores its prefix over PCIe instead of
        // re-prefilling it.
        let wcfg =
            WorkloadConfig { n_models: 4, qps: 1.0, n_requests: 32, seed: 3, ..Default::default() };
        let with = run_with_store(256 << 20, 0, false, 16, &wcfg);
        let without = run_with_store(0, 0, false, 16, &wcfg);
        assert_eq!(with.completed_requests, 32);
        assert_eq!(without.completed_requests, 32);
        assert!(with.store_hits() > 0, "evicted contexts must restore from the store");
        assert!(with.store_restored_tokens > 0);
        assert!(
            with.prefill_tokens < without.prefill_tokens,
            "restores must replace recompute: {} vs {}",
            with.prefill_tokens,
            without.prefill_tokens
        );
    }

    #[test]
    fn store_disk_tier_and_prefetch_paths_run() {
        // A 2-block host tier demotes nearly everything to disk; a
        // tiny batch keeps turns queued, which is what prefetch staging
        // feeds on.
        let wcfg =
            WorkloadConfig { n_models: 4, qps: 2.0, n_requests: 24, seed: 9, ..Default::default() };
        let s = run_with_store(2 * 16 * 2048, 512 << 20, true, 2, &wcfg);
        assert_eq!(s.completed_requests, 24);
        assert!(s.store_disk_hits > 0, "demoted blocks must restore from disk");
        assert!(s.store_prefetches > 0, "queued turns must trigger staging");
        assert!(s.store_restore_latency.as_ref().unwrap().count() >= s.store_hits());
    }

    #[test]
    fn overlap_on_matches_off_without_transfers() {
        // Recompute eviction, no store: there are no modeled transfers
        // at all, so the overlap admission path degenerates to the
        // serial tail step for step — the runs must be fully
        // bit-identical, overlap counters included (all zero).
        let mk = |overlap: bool| {
            let scfg = ServingConfig {
                kv_pool_bytes: 8 << 20,
                overlap,
                ..Default::default()
            };
            let wcfg = WorkloadConfig {
                n_models: 4,
                qps: 1.0,
                n_requests: 32,
                seed: 3,
                ..Default::default()
            };
            let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
            Engine::new(scfg, 2048, 4, exec).run(generate(&wcfg))
        };
        let on = mk(true);
        let off = mk(false);
        assert_eq!(on, off, "transfer-free overlap run must be bit-identical to serial");
        assert_eq!(on.stalled_transfer_time, 0.0);
        assert_eq!(on.overlapped_transfer_time, 0.0);
        assert_eq!(on.tasks_spawned, 0);
    }

    #[test]
    fn overlap_with_store_completes_and_overlaps() {
        // Constant eviction + store restores on every next turn: the
        // overlap run must complete identically-counted work while
        // moving transfer time off the critical path.
        let wcfg =
            WorkloadConfig { n_models: 4, qps: 1.0, n_requests: 32, seed: 3, ..Default::default() };
        let on = run_with_store_overlap(256 << 20, 0, false, 16, true, &wcfg);
        let off = run_with_store_overlap(256 << 20, 0, false, 16, false, &wcfg);
        assert_eq!(on.completed_requests, 32);
        assert_eq!(off.completed_requests, 32);
        assert!(on.store_hits() > 0, "overlap run must still restore from the store");
        assert!(on.overlapped_transfer_time > 0.0, "restores must overlap with compute");
        assert!(on.tasks_spawned > 0, "transfers and write-backs must run as tasks");
        // Transfers off the critical path must not slow the run down
        // (small tolerance: scheduling divergence can shift individual
        // retirements even as total transfer stalls shrink).
        assert!(
            on.wall_seconds <= off.wall_seconds * 1.05,
            "overlap wall {} vs serial wall {}",
            on.wall_seconds,
            off.wall_seconds
        );
    }

    #[test]
    fn overlap_swap_mode_completes() {
        // Swap eviction under pressure: parked contexts ride SwapIn
        // gating transfers back into the batch.
        let scfg = ServingConfig {
            mode: ServingMode::Baseline,
            kv_pool_bytes: 4 << 20,
            eviction: EvictionPolicy::Swap,
            overlap: true,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            n_models: 8,
            qps: 1.0,
            n_requests: 32,
            seed: 3,
            ..Default::default()
        };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Baseline);
        let s = Engine::new(scfg, 2048, 8, exec).run(generate(&wcfg));
        assert_eq!(s.completed_requests, 32);
        if s.swap_ins > 0 {
            assert!(s.overlapped_transfer_time + s.stalled_transfer_time > 0.0);
        }
    }

    #[test]
    fn overlap_chunked_prefill_with_store_completes() {
        // Chunked integration path: restored turns enter the chunked
        // prefill pipeline after their transfer lands.
        use crate::store::{SnapshotStore, StoreHandle, TieredStore};
        use std::sync::Arc;
        let scfg = ServingConfig {
            kv_pool_bytes: 4 << 20,
            prefill_chunk: 96,
            store_host_bytes: 64 << 20,
            overlap: true,
            ..Default::default()
        };
        let wcfg =
            WorkloadConfig { n_models: 4, qps: 1.0, n_requests: 24, seed: 5, ..Default::default() };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let mut engine = Engine::new(scfg.clone(), 2048, 4, exec);
        let store: Arc<dyn SnapshotStore> =
            Arc::new(TieredStore::new(64 << 20, 0, scfg.block_tokens, 2048));
        engine.attach_store(StoreHandle::new(store, None, 0));
        let s = engine.run_in_place(generate(&wcfg));
        assert_eq!(s.completed_requests, 24);
        assert!(s.prefill_chunks > 0);
        assert_eq!(engine.kv().active_sequences(), 0, "leaked sequences");
        assert_eq!(
            engine.executor().live_snapshots(),
            engine.kv().live_payloads() as u64,
            "leaked snapshot handles"
        );
    }

    #[test]
    fn prefetch_scan_memo_skips_reprobes() {
        // Same config as the disk-tier/prefetch test: a tiny batch
        // keeps turns queued across many steps, so without the memo
        // the same candidates are re-probed every tick.
        let wcfg =
            WorkloadConfig { n_models: 4, qps: 2.0, n_requests: 24, seed: 9, ..Default::default() };
        let s = run_with_store(2 * 16 * 2048, 512 << 20, true, 2, &wcfg);
        assert_eq!(s.completed_requests, 24);
        assert!(s.store_prefetches > 0, "first probes must still stage");
        assert!(
            s.store_prefetch_skips > 0,
            "queued turns re-scanned across steps must hit the memo"
        );
    }

    #[test]
    fn no_leaked_sequences() {
        let scfg = ServingConfig { kv_pool_bytes: 16 << 20, ..Default::default() };
        let wcfg = WorkloadConfig { n_requests: 16, ..Default::default() };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let mut engine = Engine::new(scfg, 2048, 4, exec);
        let stats = engine.run_in_place(generate(&wcfg));
        assert_eq!(stats.completed_requests, 16);
        // Every admitted sequence must have been finished or preempted:
        // the KV manager's per-sequence bookkeeping drains to zero.
        assert_eq!(engine.kv().active_sequences(), 0, "leaked sequences");
        // The only blocks still resident belong to the prefix cache.
        assert_eq!(
            engine.kv().resident_blocks(),
            engine.kv().resident_cache_blocks(),
            "blocks owned by dead sequences"
        );
        // And the only live cache handles are the prefix cache's
        // published payloads — the engine dropped everything else.
        assert_eq!(
            engine.executor().live_snapshots(),
            engine.kv().live_payloads() as u64,
            "leaked snapshot handles"
        );
    }
}
