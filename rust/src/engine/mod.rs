//! The serving engine: continuous-batching event loop over an Executor.
//!
//! Single-threaded discrete-event design: virtual time advances by the
//! durations the executor reports (measured wall time for PJRT, cost
//! model for sim), so the identical scheduler / KV-manager code path is
//! exercised in both.  Per iteration (one "engine step", vLLM-style
//! prefill-first):
//!
//!   1. surface newly-arrived workflows as pending turns;
//!   2. admit pending turns while the KV pool and batch have room
//!      (prefix-cache lookup -> pin -> prefill the uncached suffix);
//!      on `NoSpace`, preempt the newest running sequence (recompute or
//!      swap per config) and retry, else leave queued;
//!   3. run one decode step for the running batch;
//!   4. retire finished turns: publish their context to the prefix cache
//!      (cross-model-visible in ICaRus mode), record latency, enqueue
//!      the workflow's next turn.

pub mod executor;
pub mod sequence;

use std::collections::VecDeque;

use crate::config::{EvictionPolicy, ServingConfig};
use crate::kvcache::{Alloc, KvCacheManager};
use crate::metrics::ServingStats;
use crate::trace::{Trace, TurnEvent};
use crate::workload::Workflow;

use executor::{DecodeSlot, Executor, PrefillOut};
use sequence::{PendingTurn, RunningSeq, WfState};

/// The single-threaded continuous-batching serving engine (see the
/// module docs for the event loop; `cluster::Cluster` shards workloads
/// across several of these).
pub struct Engine<E: Executor> {
    cfg: ServingConfig,
    exec: E,
    kv: KvCacheManager,
    now: f64,
    next_seq_id: u64,
    wfs: Vec<WfState>,
    /// Workflows not yet arrived (indices into wfs, ascending arrival).
    future: VecDeque<usize>,
    waiting: VecDeque<PendingTurn>,
    /// Turns whose tool call (think time) has not finished yet.
    delayed: Vec<PendingTurn>,
    running: Vec<RunningSeq>,
    stats: ServingStats,
    trace: Option<Trace>,
}

impl<E: Executor> Engine<E> {
    /// Engine over `exec`, with a fresh KV manager sized by `cfg`.
    /// Panics if `cfg.mode` and the executor's mode disagree.
    pub fn new(cfg: ServingConfig, kv_bytes_per_token: u64, n_models: usize, exec: E) -> Self {
        assert_eq!(cfg.mode, exec.mode(), "engine/executor mode mismatch");
        let kv = KvCacheManager::new(&cfg, kv_bytes_per_token, n_models);
        Engine {
            cfg,
            exec,
            kv,
            now: 0.0,
            next_seq_id: 1,
            wfs: Vec::new(),
            future: VecDeque::new(),
            waiting: VecDeque::new(),
            delayed: Vec::new(),
            running: Vec::new(),
            stats: ServingStats::new(),
            trace: None,
        }
    }

    /// Record a per-turn event trace during `run` (see `trace::Trace`).
    pub fn enable_trace(&mut self) {
        self.trace = Some(Trace::new());
    }

    /// Like `run`, but also returns the recorded trace.
    pub fn run_traced(mut self, workload: Vec<Workflow>) -> (ServingStats, Trace) {
        self.enable_trace();
        let stats = self.run_inner(workload);
        (stats, self.trace.take().unwrap_or_default())
    }

    /// The engine's KV cache manager (post-run inspection).
    pub fn kv(&self) -> &KvCacheManager {
        &self.kv
    }

    /// The engine's executor (post-run inspection).
    pub fn executor(&self) -> &E {
        &self.exec
    }

    /// Run a full workload to completion and return the serving stats.
    pub fn run(mut self, workload: Vec<Workflow>) -> ServingStats {
        self.run_inner(workload)
    }

    /// Like `run`, but borrows the engine so post-run state (the KV
    /// manager, the executor) stays inspectable — used by tests and
    /// diagnostics to assert that nothing leaked past the run.
    pub fn run_in_place(&mut self, workload: Vec<Workflow>) -> ServingStats {
        self.run_inner(workload)
    }

    fn run_inner(&mut self, workload: Vec<Workflow>) -> ServingStats {
        // Engines are single-use: the clock, sequence ids and KV/prefix
        // state are not reset between runs, so a second run would report
        // corrupted stats.  `run`/`run_traced` enforce this by consuming
        // self; `run_in_place` must enforce it explicitly.
        assert!(
            self.wfs.is_empty() && self.now == 0.0,
            "Engine::run/run_in_place is single-use; build a fresh Engine per run"
        );
        let mut idx: Vec<usize> = (0..workload.len()).collect();
        idx.sort_by(|&a, &b| workload[a].arrival.total_cmp(&workload[b].arrival));
        self.wfs = workload.into_iter().map(WfState::new).collect();
        self.future = idx.into();

        loop {
            self.surface_arrivals();
            self.surface_delayed();
            if self.waiting.is_empty() && self.running.is_empty() {
                // Idle: jump to the next arrival or tool completion.
                let next_arrival =
                    self.future.front().map(|&w| self.wfs[w].spec.arrival);
                let next_ready = self
                    .delayed
                    .iter()
                    .map(|t| t.ready_at)
                    .min_by(f64::total_cmp);
                match [next_arrival, next_ready].into_iter().flatten().min_by(f64::total_cmp) {
                    Some(t) => {
                        self.now = self.now.max(t);
                        continue;
                    }
                    None => break,
                }
            }
            self.admit();
            self.decode_step();
        }
        self.stats.wall_seconds = self.now;
        self.stats.peak_kv_bytes = self.kv.pool.peak_bytes();
        self.stats.swap_outs = self.kv.swap.swap_outs;
        self.stats.swap_ins = self.kv.swap.swap_ins;
        self.stats.evictions = self.kv.stats.evicted_blocks;
        std::mem::replace(&mut self.stats, ServingStats::new())
    }

    /// Move turns whose tool latency has elapsed into the run queue.
    fn surface_delayed(&mut self) {
        let now = self.now;
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].ready_at <= now {
                let t = self.delayed.swap_remove(i);
                self.waiting.push_back(t);
            } else {
                i += 1;
            }
        }
    }

    fn surface_arrivals(&mut self) {
        while let Some(&w) = self.future.front() {
            if self.wfs[w].spec.arrival > self.now {
                break;
            }
            self.future.pop_front();
            let wf = &mut self.wfs[w];
            // Park the context in the turn (wf.context goes empty) so
            // the buffer stays uniquely owned and later appends are
            // zero-copy; finish_turn re-derives it from the prompt.
            let prompt = std::mem::take(&mut wf.context);
            self.waiting.push_back(PendingTurn {
                wf_idx: w,
                turn_idx: 0,
                ready_at: wf.spec.arrival,
                prompt,
                remaining_gen: wf.spec.turns[0].gen_len,
                was_preempted: false,
                swapped: None,
            });
        }
    }

    /// Admit pending turns, prefill-first, until batch/pool/token limits.
    fn admit(&mut self) {
        let mut prefill_budget = self.cfg.max_prefill_tokens;
        // Bound one admission round to the initial queue length so
        // requeued (preempted) turns cannot cycle within a single round.
        let mut attempts = self.waiting.len();
        while self.running.len() < self.cfg.max_batch && attempts > 0 {
            attempts -= 1;
            let Some(turn) = self.waiting.front() else { break };
            let uncached_upper = turn.prompt.len(); // worst case
            if uncached_upper > prefill_budget && prefill_budget < self.cfg.max_prefill_tokens {
                break; // budget partially consumed; try next step
            }
            let mut turn = self.waiting.pop_front().unwrap();
            let model_id = self.wfs[turn.wf_idx].spec.turns[turn.turn_idx].model_id;
            let seq_id = self.next_seq_id;

            // Swap-restored turns: their whole context is still cached
            // on the device handle parked in the swap tier.
            if let Some((handle, bytes)) = turn.swapped.take() {
                match self.kv.begin_sequence(seq_id, model_id, &turn.prompt) {
                    Alloc::Ok(adm) => {
                        self.drop_snapshots(&adm.dropped_snapshots);
                        self.kv.swap.swap_in(bytes);
                        self.now += self.exec.swap_in_cost(bytes);
                        self.next_seq_id += 1;
                        self.spawn_running(seq_id, turn, model_id, handle);
                        continue;
                    }
                    Alloc::NoSpace => {
                        // Wait for running sequences to drain (no
                        // admission-time preemption — it can livelock
                        // by ping-ponging two swapped turns).
                        turn.swapped = Some((handle, bytes));
                        self.check_admissible_when_idle(&turn);
                        self.waiting.push_front(turn);
                        break;
                    }
                }
            }

            match self.kv.begin_sequence(seq_id, model_id, &turn.prompt) {
                Alloc::Ok(adm) => {
                    self.next_seq_id += 1;
                    self.drop_snapshots(&adm.dropped_snapshots);
                    // Charge PCIe time for blocks restored from swap.
                    if adm.swap_in_bytes > 0 {
                        self.now += self.exec.swap_in_cost(adm.swap_in_bytes);
                    }
                    let (base, cached) = match adm.snapshot {
                        Some((snap, covered)) => (Some(snap), covered),
                        None => (None, 0),
                    };
                    // Note: `adm.cached_tokens` may exceed the snapshot
                    // coverage (blocks cached deeper than the snapshot);
                    // the executor must recompute from the snapshot tip.
                    let cached = cached.min(adm.cached_tokens);
                    let uncached = turn.prompt.len() - cached;
                    prefill_budget = prefill_budget.saturating_sub(uncached);
                    let PrefillOut { duration, cache, first_token } = self
                        .exec
                        .prefill(model_id, &turn.prompt, cached, base)
                        .expect("prefill failed");
                    self.now += duration;
                    self.stats.prefill_tokens += uncached as u64;
                    self.stats.cached_prefill_tokens += cached as u64;
                    if turn.was_preempted {
                        self.stats.recomputed_tokens += uncached as u64;
                    }
                    self.stats
                        .time_to_first_token
                        .as_mut()
                        .unwrap()
                        .record((self.now - turn.ready_at).max(0.0));
                    turn.remaining_gen = turn.remaining_gen.saturating_sub(1);
                    let seq = RunningSeq {
                        seq_id,
                        wf_idx: turn.wf_idx,
                        turn_idx: turn.turn_idx,
                        model_id,
                        prompt: turn.prompt,
                        generated: vec![first_token],
                        remaining_gen: turn.remaining_gen,
                        cache,
                        cached_tokens: cached,
                        ready_at: turn.ready_at,
                        admitted_at: self.now,
                    };
                    // The prefill's first token occupies one slot; under
                    // extreme pressure the freshly-admitted sequence is
                    // itself preempted (its prefill is not wasted under
                    // swap; under recompute it re-prefills later).
                    if let Alloc::NoSpace = self.kv.append_tokens(seq_id, 1) {
                        self.kv.preempt(seq.seq_id);
                        self.stats.preemptions += 1;
                        self.requeue_preempted(seq);
                        continue;
                    }
                    self.running.push(seq);
                }
                Alloc::NoSpace => {
                    self.check_admissible_when_idle(&turn);
                    self.waiting.push_front(turn);
                    break;
                }
            }
        }
    }

    /// Fatal-misconfiguration guard: if the system is idle (nothing
    /// running, so every unpinned block is evictable) and a turn still
    /// cannot be admitted, it never will be — fail loudly instead of
    /// spinning.
    fn check_admissible_when_idle(&self, turn: &PendingTurn) {
        if self.running.is_empty() {
            panic!(
                "KV pool ({} blocks of {} tokens) cannot hold a {}-token prompt \
                 even when idle; increase kv_pool_bytes",
                self.kv.pool.capacity(),
                self.kv.pool.block_tokens,
                turn.prompt.len()
            );
        }
    }

    fn spawn_running(&mut self, seq_id: u64, turn: PendingTurn, model_id: usize, cache: u64) {
        self.running.push(RunningSeq {
            seq_id,
            wf_idx: turn.wf_idx,
            turn_idx: turn.turn_idx,
            model_id,
            prompt: turn.prompt,
            generated: Vec::new(),
            remaining_gen: turn.remaining_gen,
            cache,
            cached_tokens: 0,
            ready_at: turn.ready_at,
            admitted_at: self.now,
        });
    }

    fn requeue_preempted(&mut self, victim: RunningSeq) {
        let cache = victim.cache;
        let context_len = victim.context_len();
        let mut turn = PendingTurn {
            wf_idx: victim.wf_idx,
            turn_idx: victim.turn_idx,
            ready_at: victim.ready_at,
            remaining_gen: victim.remaining_gen,
            was_preempted: true,
            swapped: None,
            // Restart prompt = prompt + generated-so-far; appends in
            // place (the victim owns its buffer), no context copy.
            prompt: victim.into_context(),
        };
        match self.cfg.eviction {
            EvictionPolicy::Recompute => {
                self.exec.drop_snapshot(cache);
            }
            EvictionPolicy::Swap => {
                let bytes = context_len as u64 * self.kv.kv_bytes_per_token();
                if self.kv.swap.swap_out(bytes) {
                    turn.swapped = Some((cache, bytes));
                    turn.was_preempted = false;
                } else {
                    self.kv.stats.swap_rejected += 1;
                    self.exec.drop_snapshot(cache);
                }
            }
        }
        // Preempted turns go to the back: freshly-arrived work is not
        // starved, matching vLLM's recompute-requeue behaviour.
        self.waiting.push_back(turn);
    }

    /// One decode step over the running batch.
    fn decode_step(&mut self) {
        if self.running.is_empty() {
            return;
        }
        // Grow every sequence by one token slot; preempt on pressure.
        let mut i = 0;
        while i < self.running.len() {
            let seq_id = self.running[i].seq_id;
            match self.kv.append_tokens(seq_id, 1) {
                Alloc::Ok(adm) => {
                    self.drop_snapshots(&adm.dropped_snapshots);
                    i += 1;
                }
                Alloc::NoSpace => {
                    if !self.preempt_other(i) {
                        // This sequence itself is the victim.
                        let victim = self.running.swap_remove(i);
                        self.kv.preempt(victim.seq_id);
                        self.stats.preemptions += 1;
                        self.requeue_preempted(victim);
                    }
                }
            }
        }
        if self.running.is_empty() {
            return;
        }
        let mut slots: Vec<DecodeSlot> = self
            .running
            .iter()
            .map(|s| DecodeSlot {
                seq_id: s.seq_id,
                model_id: s.model_id,
                cache: s.cache,
                context_len: s.context_len(),
                last_token: *s.generated.last().unwrap_or(&1),
                next_token: 0,
            })
            .collect();
        let dur = self.exec.decode(&mut slots).expect("decode failed");
        self.now += dur;
        for (seq, slot) in self.running.iter_mut().zip(&slots) {
            debug_assert_eq!(seq.seq_id, slot.seq_id);
            seq.cache = slot.cache;
            seq.generated.push(slot.next_token);
            seq.remaining_gen = seq.remaining_gen.saturating_sub(1);
            self.stats.generated_tokens += 1;
        }
        // Retire finished turns.
        let mut j = 0;
        while j < self.running.len() {
            if self.running[j].remaining_gen == 0 {
                let seq = self.running.swap_remove(j);
                self.finish_turn(seq);
            } else {
                j += 1;
            }
        }
    }

    /// Preempt the newest running sequence other than index `keep`.
    fn preempt_other(&mut self, keep: usize) -> bool {
        let Some(pos) = self
            .running
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != keep)
            .max_by(|a, b| a.1.admitted_at.total_cmp(&b.1.admitted_at))
            .map(|(i, _)| i)
        else {
            return false;
        };
        let victim = self.running.swap_remove(pos);
        self.kv.preempt(victim.seq_id);
        self.stats.preemptions += 1;
        self.requeue_preempted(victim);
        true
    }

    fn finish_turn(&mut self, seq: RunningSeq) {
        self.stats.completed_turns += 1;
        if let Some(trace) = &mut self.trace {
            trace.record(TurnEvent {
                wf_id: self.wfs[seq.wf_idx].spec.id,
                turn_idx: seq.turn_idx,
                model_id: seq.model_id,
                ready_at: seq.ready_at,
                completed_at: self.now,
                prompt_tokens: seq.prompt.len(),
                cached_tokens: seq.cached_tokens,
                generated_tokens: seq.generated.len(),
            });
        }
        self.stats
            .turn_latency
            .as_mut()
            .unwrap()
            .record((self.now - seq.ready_at).max(0.0));
        let seq_id = seq.seq_id;
        let wf_idx = seq.wf_idx;
        let turn_idx = seq.turn_idx;
        let cache = seq.cache;
        // Publish the full turn context so the workflow's next turn
        // (possibly on another model) hits the prefix cache.  The append
        // happens in place — the sequence owns the context buffer.
        let full = seq.into_context();
        let snap = self.exec.snapshot(cache);
        let dropped = self.kv.finish_sequence(seq_id, &full, Some(snap));
        self.drop_snapshots(&dropped);

        let wf = &mut self.wfs[wf_idx];
        let spec_turn = &wf.spec.turns[turn_idx];
        // Context for the next turn: append the tool observation, again
        // in place (`full` is the sole owner after finish_sequence).
        let ctx = full.extended(&spec_turn.obs);
        wf.next_turn = turn_idx + 1;
        if wf.next_turn < wf.spec.turns.len() {
            let next = &wf.spec.turns[wf.next_turn];
            let gen = next.gen_len;
            let ready_at = self.now + next.think_s;
            let turn = PendingTurn {
                wf_idx,
                turn_idx: wf.next_turn,
                ready_at,
                // The pending turn owns the context (wf.context stays
                // empty until the workflow's final turn completes).
                prompt: ctx,
                remaining_gen: gen,
                was_preempted: false,
                swapped: None,
            };
            if ready_at > self.now {
                self.delayed.push(turn);
            } else {
                self.waiting.push_back(turn);
            }
        } else {
            wf.context = ctx; // final context retained for inspection
            wf.done = true;
            self.stats.completed_requests += 1;
            let arrival = wf.spec.arrival;
            self.stats
                .request_latency
                .as_mut()
                .unwrap()
                .record((self.now - arrival).max(0.0));
        }
    }

    fn drop_snapshots(&mut self, snaps: &[u64]) {
        for &s in snaps {
            self.exec.drop_snapshot(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::executor::{CostModel, SimExecutor};
    use super::*;
    use crate::config::{AgentPattern, Routing, ServingMode, WorkloadConfig};
    use crate::workload::generate;

    fn run(mode: ServingMode, n_models: usize, qps: f64, pool_mb: u64) -> ServingStats {
        let scfg = ServingConfig {
            mode,
            kv_pool_bytes: pool_mb << 20,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            pattern: AgentPattern::ReAct,
            n_models,
            qps,
            n_requests: 48,
            routing: Routing::RoundRobin,
            seed: 7,
            ..Default::default()
        };
        let exec = SimExecutor::new(CostModel::default(), mode);
        // serve-small KV cost: 4 layers * 2 * 64 dims * 4B = 2048 B/token
        let engine = Engine::new(scfg, 2048, n_models, exec);
        engine.run(generate(&wcfg))
    }

    #[test]
    fn completes_all_workflows() {
        let s = run(ServingMode::Icarus, 4, 0.5, 64);
        assert_eq!(s.completed_requests, 48);
        assert!(s.completed_turns >= 48);
        assert!(s.generated_tokens > 0);
        assert!(s.wall_seconds > 0.0);
    }

    #[test]
    fn baseline_also_completes() {
        let s = run(ServingMode::Baseline, 4, 0.5, 64);
        assert_eq!(s.completed_requests, 48);
    }

    #[test]
    fn icarus_has_higher_cache_hit_rate() {
        let i = run(ServingMode::Icarus, 4, 0.5, 64);
        let b = run(ServingMode::Baseline, 4, 0.5, 64);
        assert!(
            i.cache_hit_rate() > b.cache_hit_rate() + 0.2,
            "icarus {} vs baseline {}",
            i.cache_hit_rate(),
            b.cache_hit_rate()
        );
    }

    #[test]
    fn icarus_lower_p95_under_pressure() {
        let i = run(ServingMode::Icarus, 8, 0.6, 32);
        let b = run(ServingMode::Baseline, 8, 0.6, 32);
        let pi = i.turn_latency.as_ref().unwrap().p95();
        let pb = b.turn_latency.as_ref().unwrap().p95();
        assert!(pi < pb, "icarus p95 {pi} vs baseline {pb}");
    }

    #[test]
    fn icarus_peak_memory_lower() {
        let i = run(ServingMode::Icarus, 4, 0.5, 256);
        let b = run(ServingMode::Baseline, 4, 0.5, 256);
        assert!(
            i.peak_kv_bytes < b.peak_kv_bytes,
            "icarus {} vs baseline {}",
            i.peak_kv_bytes,
            b.peak_kv_bytes
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(ServingMode::Icarus, 4, 0.5, 64);
        let b = run(ServingMode::Icarus, 4, 0.5, 64);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(a.wall_seconds, b.wall_seconds);
        assert_eq!(a.preemptions, b.preemptions);
    }

    #[test]
    fn think_time_extends_wall_clock() {
        // Tool latency must show up in wall time but not in turn latency
        // accounting (the clock starts at ready_at, after the tool).
        let mk = |think: f64| {
            let scfg = ServingConfig { kv_pool_bytes: 64 << 20, ..Default::default() };
            let wcfg = WorkloadConfig {
                n_requests: 8,
                qps: 100.0,
                think_mean: think,
                think_std: 0.0,
                seed: 5,
                ..Default::default()
            };
            let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
            Engine::new(scfg, 2048, 4, exec).run(generate(&wcfg))
        };
        let fast = mk(0.0);
        let slow = mk(5.0);
        assert!(slow.wall_seconds > fast.wall_seconds + 4.0);
        let pf = fast.turn_latency.as_ref().unwrap().p50();
        let ps = slow.turn_latency.as_ref().unwrap().p50();
        // Turn latency does not balloon by the think time itself.
        assert!(ps < pf + 2.0, "fast {pf} slow {ps}");
    }

    #[test]
    fn traced_run_matches_stats() {
        let scfg = ServingConfig { kv_pool_bytes: 64 << 20, ..Default::default() };
        let wcfg = WorkloadConfig { n_requests: 24, seed: 9, ..Default::default() };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let engine = Engine::new(scfg, 2048, 4, exec);
        let (stats, trace) = engine.run_traced(generate(&wcfg));
        assert_eq!(trace.events.len() as u64, stats.completed_turns);
        // Trace-derived P95 must agree with the histogram within bucket
        // resolution (~3%) plus the histogram's upper-edge bias.
        let h = stats.turn_latency.as_ref().unwrap().p95();
        let t = trace.latency_quantile(0.95);
        assert!((h - t).abs() / h.max(1e-9) < 0.10, "hist {h} vs trace {t}");
        // Round-robin routing shows up as near-uniform model counts.
        let counts = trace.per_model_counts();
        assert_eq!(counts.len(), 4);
    }

    #[test]
    fn tiny_pool_forces_preemptions_but_still_completes() {
        let s = run(ServingMode::Baseline, 8, 1.0, 4);
        assert_eq!(s.completed_requests, 48);
        assert!(s.preemptions > 0 || s.evictions > 0, "pressure expected");
    }

    #[test]
    fn swap_mode_runs_and_swaps() {
        let scfg = ServingConfig {
            mode: ServingMode::Baseline,
            kv_pool_bytes: 4 << 20,
            eviction: EvictionPolicy::Swap,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            n_models: 8,
            qps: 1.0,
            n_requests: 32,
            seed: 3,
            ..Default::default()
        };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Baseline);
        let s = Engine::new(scfg, 2048, 8, exec).run(generate(&wcfg));
        assert_eq!(s.completed_requests, 32);
    }

    #[test]
    fn no_leaked_sequences() {
        let scfg = ServingConfig { kv_pool_bytes: 16 << 20, ..Default::default() };
        let wcfg = WorkloadConfig { n_requests: 16, ..Default::default() };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let mut engine = Engine::new(scfg, 2048, 4, exec);
        let stats = engine.run_in_place(generate(&wcfg));
        assert_eq!(stats.completed_requests, 16);
        // Every admitted sequence must have been finished or preempted:
        // the KV manager's per-sequence bookkeeping drains to zero.
        assert_eq!(engine.kv().active_sequences(), 0, "leaked sequences");
        // The only blocks still resident belong to the prefix cache.
        assert_eq!(
            engine.kv().resident_blocks(),
            engine.kv().resident_cache_blocks(),
            "blocks owned by dead sequences"
        );
    }
}
