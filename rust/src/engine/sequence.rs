//! Per-sequence and per-workflow runtime state inside the engine.
//!
//! Contexts are [`TokenBuf`]s: the workflow hands its accumulated
//! context to the pending turn, the turn hands it to the running
//! sequence, and `finish_turn` appends the generated tokens + tool
//! observation in place — no O(context) copies on the per-turn hot path.

use crate::engine::executor::SnapshotId;
use crate::tokens::TokenBuf;
use crate::workload::Workflow;

/// A turn waiting for admission.
#[derive(Debug)]
pub struct PendingTurn {
    /// Index of the owning workflow in the engine's `wfs`.
    pub wf_idx: usize,
    /// Turn position within the workflow's spec.
    pub turn_idx: usize,
    /// LoRA adapter this turn is routed to (copied from the workflow
    /// spec at enqueue time so schedulers can probe the prefix cache
    /// for the right namespace without a workflow-table lookup).
    pub model_id: usize,
    /// When this turn became runnable (workflow arrival or previous turn
    /// completion) — the latency clock starts here.
    pub ready_at: f64,
    /// Full context to prefill: accumulated workflow context (+ obs).
    /// Shared buffer; admission passes a borrowed slice downward.
    pub prompt: TokenBuf,
    /// Tokens still to generate (smaller than the spec's gen_len if the
    /// turn was preempted mid-decode and restarted).
    pub remaining_gen: usize,
    /// Set when the turn lost its cache to preemption (recompute path) —
    /// its re-prefilled tokens count as recomputation in the stats.
    pub was_preempted: bool,
    /// Live cache parked in the swap tier by a swap-mode preemption:
    /// (handle, bytes).  Restored on re-admission without recompute.
    pub swapped: Option<(SnapshotId, u64)>,
    /// `--disagg on`, decode role: this turn came back from a prefill
    /// replica with its prefix published (and pinned) in the shared
    /// store.  Admission releases the pin after consuming the restore.
    pub from_handoff: bool,
    /// `--disagg on`, decode role: never forward this turn to a prefill
    /// replica (it already went once, or was preempted after admission
    /// and must re-admit locally).  Always false outside disagg mode.
    pub local_only: bool,
}

/// Progress of a chunked prefill (only present while the sequence's
/// prompt is still being encoded; `None` once it joined the decode
/// batch — and always `None` with chunking disabled, where prefill is
/// atomic at admission).
#[derive(Debug)]
pub struct PrefillState {
    /// Next prompt position to encode (starts at the cached coverage).
    pub next: usize,
    /// Where encoding started (= cached coverage at admission) — a
    /// preempted prefill with `next > start` has wasted compute and
    /// requeues as `was_preempted`.
    pub start: usize,
    /// Engine-private snapshot of the prefix-cache hit covering
    /// `[0, start)`, consumed (and dropped) by the first chunk.  Held
    /// privately so a prefix-cache payload displacement between steps
    /// cannot invalidate it.
    pub base: Option<SnapshotId>,
    /// Partial cache built by the chunks encoded so far.
    pub cache: Option<SnapshotId>,
}

/// A sequence currently in the decode batch.
#[derive(Debug)]
pub struct RunningSeq {
    /// Engine-unique sequence id (the KV manager's key).
    pub seq_id: u64,
    /// Index of the owning workflow in the engine's `wfs`.
    pub wf_idx: usize,
    /// Turn position within the workflow's spec.
    pub turn_idx: usize,
    /// LoRA adapter this turn is routed to.
    pub model_id: usize,
    /// Prompt this turn was prefilled with (shared with nobody in the
    /// steady state — the workflow parked its context here).
    pub prompt: TokenBuf,
    /// Tokens generated so far this turn.
    pub generated: Vec<u32>,
    /// Tokens still to generate this turn.
    pub remaining_gen: usize,
    /// Live cache handle (functional: replaced every decode step).
    /// Meaningless (0) while `prefill` is `Some` — the partial cache
    /// lives in the prefill state until the final chunk lands.
    pub cache: SnapshotId,
    /// Prompt tokens served from the prefix cache at admission.
    pub cached_tokens: usize,
    /// When the turn became runnable (the latency clock's start).
    pub ready_at: f64,
    /// Admission order (preemption victims are picked newest-first).
    pub admitted_at: f64,
    /// Virtual time of this sequence's last emitted token — the
    /// inter-token-latency clock (gaps include whatever stalled the
    /// engine between this sequence's decode steps, e.g. other turns'
    /// atomic prefills; chunked prefill exists to flatten exactly
    /// those spikes).
    pub last_token_at: f64,
    /// Chunked-prefill progress; `None` once decoding (or always, with
    /// chunking disabled).
    pub prefill: Option<PrefillState>,
}

impl RunningSeq {
    /// Prompt plus generated tokens currently resident.
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// Prompt + generated tokens, consuming the sequence's buffers.
    /// Appends in place when the prompt is uniquely owned (the normal
    /// case); only a genuinely shared buffer is copied.
    pub fn into_context(self) -> TokenBuf {
        self.prompt.extended(&self.generated)
    }
}

/// Workflow progress tracking.
#[derive(Debug)]
pub struct WfState {
    /// The generator-planned workflow this state tracks.
    pub spec: Workflow,
    /// Accumulated context: prompt + per-turn (generated + obs).  While
    /// a turn for this workflow is pending or running, the context is
    /// parked in that turn (this field is empty) so the buffer stays
    /// uniquely owned and per-turn appends never copy.
    pub context: TokenBuf,
    /// Next turn index to enqueue.
    pub next_turn: usize,
    /// True once every turn has retired.
    pub done: bool,
}

impl WfState {
    /// Fresh state with the context seeded from the prompt (O(1) clone).
    pub fn new(spec: Workflow) -> Self {
        let context = spec.prompt.clone();
        WfState { spec, context, next_turn: 0, done: false }
    }
}
