//! Per-sequence and per-workflow runtime state inside the engine.

use crate::engine::executor::SnapshotId;
use crate::workload::Workflow;

/// A turn waiting for admission.
#[derive(Debug)]
pub struct PendingTurn {
    pub wf_idx: usize,
    pub turn_idx: usize,
    /// When this turn became runnable (workflow arrival or previous turn
    /// completion) — the latency clock starts here.
    pub ready_at: f64,
    /// Full context to prefill: accumulated workflow context (+ obs).
    pub prompt: Vec<u32>,
    /// Tokens still to generate (smaller than the spec's gen_len if the
    /// turn was preempted mid-decode and restarted).
    pub remaining_gen: usize,
    /// Set when the turn lost its cache to preemption (recompute path) —
    /// its re-prefilled tokens count as recomputation in the stats.
    pub was_preempted: bool,
    /// Live cache parked in the swap tier by a swap-mode preemption:
    /// (handle, bytes).  Restored on re-admission without recompute.
    pub swapped: Option<(SnapshotId, u64)>,
}

/// A sequence currently in the decode batch.
#[derive(Debug)]
pub struct RunningSeq {
    pub seq_id: u64,
    pub wf_idx: usize,
    pub turn_idx: usize,
    pub model_id: usize,
    /// Prompt this turn was prefilled with.
    pub prompt: Vec<u32>,
    /// Tokens generated so far this turn.
    pub generated: Vec<u32>,
    pub remaining_gen: usize,
    /// Live cache handle (functional: replaced every decode step).
    pub cache: SnapshotId,
    /// Prompt tokens served from the prefix cache at admission.
    pub cached_tokens: usize,
    pub ready_at: f64,
    /// Admission order (preemption victims are picked newest-first).
    pub admitted_at: f64,
}

impl RunningSeq {
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    pub fn full_context(&self) -> Vec<u32> {
        let mut out = self.prompt.clone();
        out.extend_from_slice(&self.generated);
        out
    }
}

/// Workflow progress tracking.
#[derive(Debug)]
pub struct WfState {
    pub spec: Workflow,
    /// Accumulated context: prompt + per-turn (generated + obs).
    pub context: Vec<u32>,
    pub next_turn: usize,
    pub done: bool,
}

impl WfState {
    pub fn new(spec: Workflow) -> Self {
        let context = spec.prompt.clone();
        WfState { spec, context, next_turn: 0, done: false }
    }
}
