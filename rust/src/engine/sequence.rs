//! Per-sequence and per-workflow runtime state inside the engine.
//!
//! Contexts are [`TokenBuf`]s: the workflow hands its accumulated
//! context to the pending turn, the turn hands it to the running
//! sequence, and `finish_turn` appends the generated tokens + tool
//! observation in place — no O(context) copies on the per-turn hot path.

use crate::engine::executor::SnapshotId;
use crate::tokens::TokenBuf;
use crate::workload::Workflow;

/// A turn waiting for admission.
#[derive(Debug)]
pub struct PendingTurn {
    pub wf_idx: usize,
    pub turn_idx: usize,
    /// When this turn became runnable (workflow arrival or previous turn
    /// completion) — the latency clock starts here.
    pub ready_at: f64,
    /// Full context to prefill: accumulated workflow context (+ obs).
    /// Shared buffer; admission passes a borrowed slice downward.
    pub prompt: TokenBuf,
    /// Tokens still to generate (smaller than the spec's gen_len if the
    /// turn was preempted mid-decode and restarted).
    pub remaining_gen: usize,
    /// Set when the turn lost its cache to preemption (recompute path) —
    /// its re-prefilled tokens count as recomputation in the stats.
    pub was_preempted: bool,
    /// Live cache parked in the swap tier by a swap-mode preemption:
    /// (handle, bytes).  Restored on re-admission without recompute.
    pub swapped: Option<(SnapshotId, u64)>,
}

/// A sequence currently in the decode batch.
#[derive(Debug)]
pub struct RunningSeq {
    pub seq_id: u64,
    pub wf_idx: usize,
    pub turn_idx: usize,
    pub model_id: usize,
    /// Prompt this turn was prefilled with (shared with nobody in the
    /// steady state — the workflow parked its context here).
    pub prompt: TokenBuf,
    /// Tokens generated so far this turn.
    pub generated: Vec<u32>,
    pub remaining_gen: usize,
    /// Live cache handle (functional: replaced every decode step).
    pub cache: SnapshotId,
    /// Prompt tokens served from the prefix cache at admission.
    pub cached_tokens: usize,
    pub ready_at: f64,
    /// Admission order (preemption victims are picked newest-first).
    pub admitted_at: f64,
}

impl RunningSeq {
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// Prompt + generated tokens, consuming the sequence's buffers.
    /// Appends in place when the prompt is uniquely owned (the normal
    /// case); only a genuinely shared buffer is copied.
    pub fn into_context(self) -> TokenBuf {
        self.prompt.extended(&self.generated)
    }
}

/// Workflow progress tracking.
#[derive(Debug)]
pub struct WfState {
    pub spec: Workflow,
    /// Accumulated context: prompt + per-turn (generated + obs).  While
    /// a turn for this workflow is pending or running, the context is
    /// parked in that turn (this field is empty) so the buffer stays
    /// uniquely owned and per-turn appends never copy.
    pub context: TokenBuf,
    pub next_turn: usize,
    pub done: bool,
}

impl WfState {
    pub fn new(spec: Workflow) -> Self {
        let context = spec.prompt.clone();
        WfState { spec, context, next_turn: 0, done: false }
    }
}
