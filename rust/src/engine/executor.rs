//! Executor abstraction: the engine's only way to touch model compute.
//!
//! Two implementations:
//!   * `SimExecutor` (here) — a calibrated discrete-event cost model used
//!     for the QPS x N x pattern sweeps (Figs 4/5/8/9), where thousands
//!     of serving seconds must be simulated.  Costs are calibrated
//!     against measured PJRT step times (see EXPERIMENTS.md §Calibration).
//!   * `PjrtExecutor` (`runtime/`) — loads the AOT HLO artifacts and runs
//!     real prefill/decode on the PJRT CPU client (e2e example and
//!     integration tests).
//!
//! The engine is identical for both; time always flows through the
//! durations returned here, so a simulated run and a real run exercise
//! the same scheduler/kv-cache code paths.

use crate::config::ServingMode;

/// Opaque id of an immutable cache snapshot (device buffers in PJRT,
/// bookkeeping only in sim).
pub type SnapshotId = u64;

/// Result of a prefill call.
#[derive(Debug)]
pub struct PrefillOut {
    /// Seconds the prefill took (measured or modeled).
    pub duration: f64,
    /// Live cache handle for the new sequence.
    pub cache: SnapshotId,
    /// First generated token (next-token after the prompt).
    pub first_token: u32,
}

/// One sequence's partial-prefill slot in a fused engine step: encode
/// `tokens` (= `prompt[start..start + tokens.len()]`) on top of the
/// partial cache built by earlier chunks (or fork from `base` on the
/// first chunk).  The final chunk (`start + tokens.len() ==
/// prompt_len`) also produces the turn's first generated token.
#[derive(Debug)]
pub struct ChunkSlot<'a> {
    /// Sequence this chunk belongs to.
    pub seq_id: u64,
    /// LoRA adapter the sequence is served by.
    pub model_id: usize,
    /// The chunk's tokens: a window of the sequence's prompt.
    pub tokens: &'a [u32],
    /// Absolute position of `tokens[0]` in the prompt.
    pub start: usize,
    /// Full prompt length; the chunk is final iff it reaches it.
    pub prompt_len: usize,
    /// Snapshot covering `prompt[..start]` via the prefix cache, used
    /// only when `cache` is `None` (first chunk of a cache-hit prompt).
    pub base: Option<SnapshotId>,
    /// In: partial cache from prior chunks (`None` on the first chunk).
    /// Out: the partial cache covering the prompt through this chunk.
    pub cache: Option<SnapshotId>,
    /// Out: first generated token, set only by the final chunk.
    pub first_token: Option<u32>,
}

impl ChunkSlot<'_> {
    /// One past the last prompt position this chunk encodes.
    pub fn end(&self) -> usize {
        self.start + self.tokens.len()
    }

    /// True when this chunk completes the prompt.
    pub fn is_final(&self) -> bool {
        self.end() == self.prompt_len
    }
}

/// One running sequence's slot in a decode batch.
#[derive(Debug)]
pub struct DecodeSlot {
    /// Sequence this slot belongs to.
    pub seq_id: u64,
    /// LoRA adapter the sequence is served by.
    pub model_id: usize,
    /// Live cache handle (replaced by the executor on each step).
    pub cache: SnapshotId,
    /// Current context length (position of the token being generated).
    pub context_len: usize,
    /// Last token (input to this step).
    pub last_token: u32,
    /// Output: token generated this step.
    pub next_token: u32,
}

/// The engine's only way to touch model compute (see the module docs).
pub trait Executor {
    /// Encode `prompt[cached_tokens..]` on top of `base` (the snapshot
    /// covering the cached prefix, if any) and return a live cache +
    /// the first token.  `model_id` selects the LoRA adapter; in ICaRus
    /// mode the cache that is produced is base-model cache regardless.
    fn prefill(
        &mut self,
        model_id: usize,
        prompt: &[u32],
        cached_tokens: usize,
        base: Option<SnapshotId>,
    ) -> anyhow::Result<PrefillOut>;

    /// Encode one prefill chunk (see [`ChunkSlot`]) as a standalone
    /// call, updating the slot's partial cache (and `first_token` when
    /// final).  Returns the chunk duration.  [`Executor::fused_step`]
    /// is the scheduler-facing entry point; this is the per-chunk
    /// building block it composes.
    fn prefill_chunk(&mut self, chunk: &mut ChunkSlot<'_>) -> anyhow::Result<f64>;

    /// One decode step for the whole batch.  Fills `next_token` and
    /// updates each slot's `cache`; returns the step duration.
    fn decode(&mut self, batch: &mut [DecodeSlot]) -> anyhow::Result<f64>;

    /// One fused engine step: run the prefill `chunks` co-scheduled
    /// with the decode `batch` and return the combined step duration.
    /// The default composes [`Executor::prefill_chunk`] and
    /// [`Executor::decode`] additively (what a measured backend wants);
    /// `SimExecutor` overrides it with a fused cost model in which the
    /// chunk's launch overhead is absorbed by the decode step it
    /// piggybacks on and only the `CostModel::chunk_overlap` fraction
    /// of chunk compute is exposed (memory-bound decode batches leave
    /// compute units idle for prefill FLOPs to fill).
    fn fused_step(
        &mut self,
        chunks: &mut [ChunkSlot<'_>],
        batch: &mut [DecodeSlot],
    ) -> anyhow::Result<f64> {
        let mut dur = 0.0;
        for c in chunks.iter_mut() {
            dur += self.prefill_chunk(c)?;
        }
        if !batch.is_empty() {
            dur += self.decode(batch)?;
        }
        Ok(dur)
    }

    /// Snapshot a live cache so it can be shared immutably (published to
    /// the prefix cache).  Cheap in both implementations (buffers are
    /// functional).
    fn snapshot(&mut self, cache: SnapshotId) -> SnapshotId;

    /// Release a snapshot/cache handle.
    fn drop_snapshot(&mut self, snap: SnapshotId);

    /// Cost of restoring `bytes` from the swap tier.
    fn swap_in_cost(&self, bytes: u64) -> f64;

    /// Cost of one snapshot-store restore moving `host_bytes` over
    /// PCIe only and `disk_bytes` over NVMe + PCIe (a single restore
    /// can straddle both tiers; the fixed DMA-setup latency is charged
    /// once per restore, not per tier).  The default delegates to the
    /// default [`CostModel`]'s bandwidths so executor and sim pricing
    /// cannot silently diverge; `SimExecutor` overrides with its own
    /// (possibly re-calibrated) model.
    fn store_restore_cost(&self, host_bytes: u64, disk_bytes: u64) -> f64 {
        CostModel::default().store_restore_time(host_bytes, disk_bytes)
    }

    /// Cost of staging `bytes` from the store's disk tier into host
    /// memory (the transfer a background prefetch pays, off the
    /// engine's critical path).
    fn store_stage_cost(&self, bytes: u64) -> f64 {
        bytes as f64 / CostModel::default().store_disk_bandwidth
    }

    /// Serving mode this executor is configured for (decode cost model
    /// differs; PJRT selects the decode artifact).
    fn mode(&self) -> ServingMode;
}

/// Cost-model parameters for `SimExecutor`, in seconds.  Defaults are
/// calibrated to the measured PJRT CPU step times of `serve-small`
/// (micro_hotpath bench), then uniformly scaled — only ratios matter for
/// the paper's comparisons.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Fixed cost of launching one prefill.
    pub prefill_base: f64,
    /// Per-token prefill cost (weights streaming, MLP).
    pub prefill_per_token: f64,
    /// Quadratic attention term per token^2.
    pub prefill_per_token2: f64,
    /// Fixed cost of one decode step (kernel launches, sampling).
    pub decode_base: f64,
    /// Per-sequence cost in a decode batch.
    pub decode_per_seq: f64,
    /// Per-context-token KV read cost, per sequence.
    pub decode_per_ctx_token: f64,
    /// Multiplier on decode compute for ICaRus paired execution (paper
    /// §3.3: ~1.0 because streams are parallelized and memory-bound;
    /// 2.0 would be the unoptimized sequential encoder+decoder).
    pub icarus_decode_factor: f64,
    /// Fraction of a co-scheduled prefill chunk's compute that is
    /// *exposed* on top of the decode step it rides on (Sarathi-style
    /// piggybacking: decode batches are memory-bound, so chunk FLOPs
    /// largely fill otherwise-idle compute units).  1.0 = no overlap
    /// (purely additive); chunk-only steps always pay full compute.
    pub chunk_overlap: f64,
    /// Host<->device bandwidth for swap restores (bytes/sec).
    pub swap_bandwidth: f64,
    /// Host-tier store restores: PCIe host->device bandwidth
    /// (bytes/sec); also prices background write-back and the PCIe leg
    /// of disk restores.
    pub store_host_bandwidth: f64,
    /// Disk-tier store reads: NVMe bandwidth (bytes/sec), paid on top
    /// of the PCIe leg unless a prefetch already staged the entry.
    pub store_disk_bandwidth: f64,
    /// Fixed per-restore latency (allocator + DMA setup), seconds.
    pub store_restore_base: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            prefill_base: 2.0e-3,
            prefill_per_token: 0.9e-3,
            prefill_per_token2: 1.2e-6,
            decode_base: 2.0e-3,
            decode_per_seq: 0.6e-3,
            decode_per_ctx_token: 1.5e-6,
            icarus_decode_factor: 1.05,
            chunk_overlap: 0.4,
            swap_bandwidth: 16.0e9,
            store_host_bandwidth: 16.0e9,
            store_disk_bandwidth: 3.2e9,
            store_restore_base: 0.3e-3,
        }
    }
}

impl CostModel {
    /// Modeled seconds to prefill `n_tokens` uncached tokens.
    pub fn prefill_time(&self, n_tokens: usize) -> f64 {
        let n = n_tokens as f64;
        self.prefill_base + self.prefill_per_token * n + self.prefill_per_token2 * n * n
    }

    /// Modeled seconds of compute (no launch overhead) to encode prompt
    /// positions `[start, end)` given that `[0, start)` is already in
    /// the cache.  The quadratic attention term telescopes: summing
    /// `chunk_time` over a chunking of `[0, n)` equals the quadratic +
    /// linear parts of [`CostModel::prefill_time`]`(n)`, so chunking
    /// redistributes compute across steps without discounting it.
    pub fn chunk_time(&self, start: usize, end: usize) -> f64 {
        let (s, e) = (start as f64, end as f64);
        self.prefill_per_token * (e - s) + self.prefill_per_token2 * (e * e - s * s)
    }

    /// Modeled seconds for one store restore moving `host_bytes` over
    /// PCIe only and `disk_bytes` over NVMe then PCIe: DMA setup
    /// (once), the PCIe hop for every restored byte, and the NVMe read
    /// for the unstaged disk-tier bytes.
    pub fn store_restore_time(&self, host_bytes: u64, disk_bytes: u64) -> f64 {
        self.store_restore_base
            + (host_bytes + disk_bytes) as f64 / self.store_host_bandwidth
            + disk_bytes as f64 / self.store_disk_bandwidth
    }

    /// Modeled seconds for one decode step over a batch with the given
    /// per-sequence context lengths.
    pub fn decode_time(&self, ctx_lens: &[usize], mode: ServingMode) -> f64 {
        let ctx: usize = ctx_lens.iter().sum();
        let t = self.decode_base
            + self.decode_per_seq * ctx_lens.len() as f64
            + self.decode_per_ctx_token * ctx as f64;
        match mode {
            ServingMode::Baseline => t,
            ServingMode::Icarus => t * self.icarus_decode_factor,
        }
    }
}

/// Discrete-event executor: charges model costs, fabricates tokens
/// deterministically (hash of seq id + position) so prefix-cache keys
/// behave exactly like real generation.
pub struct SimExecutor {
    cost: CostModel,
    mode: ServingMode,
    next_snapshot: SnapshotId,
    live_snapshots: u64,
    /// Call counters for the run.
    pub stats: SimStats,
}

/// Call counters the sim executor accumulates.
#[derive(Debug, Default, Clone)]
pub struct SimStats {
    /// Prefill invocations.
    pub prefill_calls: u64,
    /// Uncached tokens actually prefilled.
    pub prefill_tokens: u64,
    /// Prefill chunks encoded (chunked-prefill path).
    pub prefill_chunk_calls: u64,
    /// Decode steps executed.
    pub decode_steps: u64,
    /// Total sequence-slots across decode steps.
    pub decode_slots: u64,
    /// Snapshot handles released.
    pub dropped_snapshots: u64,
}

impl SimExecutor {
    /// Executor charging `cost` under `mode`'s decode model.
    pub fn new(cost: CostModel, mode: ServingMode) -> Self {
        SimExecutor { cost, mode, next_snapshot: 1, live_snapshots: 0, stats: SimStats::default() }
    }

    /// Snapshot handles currently alive (leak check for tests).
    pub fn live_snapshots(&self) -> u64 {
        self.live_snapshots
    }

    fn fresh(&mut self) -> SnapshotId {
        let id = self.next_snapshot;
        self.next_snapshot += 1;
        self.live_snapshots += 1;
        id
    }

    /// Chunk bookkeeping shared by `prefill_chunk` and `fused_step`:
    /// counters, partial-cache handle, final-chunk token.  Returns the
    /// chunk's modeled compute seconds (no launch overhead).
    fn apply_chunk(&mut self, c: &mut ChunkSlot<'_>) -> f64 {
        self.stats.prefill_chunk_calls += 1;
        self.stats.prefill_tokens += c.tokens.len() as u64;
        if c.cache.is_none() {
            c.cache = Some(self.fresh());
        }
        if c.is_final() {
            // Same token the atomic prefill path fabricates, so a
            // chunked and an unchunked run of one prompt agree on the
            // generated stream (only timing differs).
            c.first_token =
                Some(Self::synth_token(c.model_id, c.prompt_len as u64, c.prompt_len));
        }
        self.cost.chunk_time(c.start, c.end())
    }

    /// Deterministic pseudo-token for (model, seq, pos).
    pub fn synth_token(model_id: usize, seq_id: u64, pos: usize) -> u32 {
        let mut h = 0xcbf29ce484222325u64;
        for b in [model_id as u64, seq_id, pos as u64] {
            h ^= b;
            h = h.wrapping_mul(0x100000001b3);
        }
        // Keep out of the reserved range and inside every vocab we use.
        32 + (h % 1900) as u32
    }
}

impl Executor for SimExecutor {
    fn prefill(
        &mut self,
        model_id: usize,
        prompt: &[u32],
        cached_tokens: usize,
        _base: Option<SnapshotId>,
    ) -> anyhow::Result<PrefillOut> {
        let new_tokens = prompt.len() - cached_tokens;
        self.stats.prefill_calls += 1;
        self.stats.prefill_tokens += new_tokens as u64;
        Ok(PrefillOut {
            duration: self.cost.prefill_time(new_tokens),
            cache: self.fresh(),
            first_token: Self::synth_token(model_id, prompt.len() as u64, prompt.len()),
        })
    }

    fn prefill_chunk(&mut self, chunk: &mut ChunkSlot<'_>) -> anyhow::Result<f64> {
        let compute = self.apply_chunk(chunk);
        // A standalone chunk pays its own launch overhead; fused steps
        // absorb it into the decode launch (see `fused_step`).
        Ok(self.cost.prefill_base + compute)
    }

    fn fused_step(
        &mut self,
        chunks: &mut [ChunkSlot<'_>],
        batch: &mut [DecodeSlot],
    ) -> anyhow::Result<f64> {
        let mut compute = 0.0;
        for c in chunks.iter_mut() {
            compute += self.apply_chunk(c);
        }
        if !batch.is_empty() {
            // Co-scheduled: one launch covers both, and only the
            // `chunk_overlap` fraction of chunk compute is exposed on
            // top of the memory-bound decode step (see `CostModel`).
            Ok(self.cost.chunk_overlap * compute + self.decode(batch)?)
        } else if !chunks.is_empty() {
            // Nothing to hide behind: full compute plus the launch.
            Ok(self.cost.prefill_base + compute)
        } else {
            Ok(0.0)
        }
    }

    fn decode(&mut self, batch: &mut [DecodeSlot]) -> anyhow::Result<f64> {
        let ctx: Vec<usize> = batch.iter().map(|s| s.context_len).collect();
        self.stats.decode_steps += 1;
        self.stats.decode_slots += batch.len() as u64;
        for slot in batch.iter_mut() {
            slot.next_token = Self::synth_token(slot.model_id, slot.seq_id, slot.context_len);
            // Cache handle is conceptually replaced each functional step;
            // sim reuses the same id to avoid handle churn.
        }
        Ok(self.cost.decode_time(&ctx, self.mode))
    }

    fn snapshot(&mut self, _cache: SnapshotId) -> SnapshotId {
        self.fresh()
    }

    fn drop_snapshot(&mut self, _snap: SnapshotId) {
        self.live_snapshots = self.live_snapshots.saturating_sub(1);
        self.stats.dropped_snapshots += 1;
    }

    fn swap_in_cost(&self, bytes: u64) -> f64 {
        bytes as f64 / self.cost.swap_bandwidth
    }

    fn store_restore_cost(&self, host_bytes: u64, disk_bytes: u64) -> f64 {
        self.cost.store_restore_time(host_bytes, disk_bytes)
    }

    fn store_stage_cost(&self, bytes: u64) -> f64 {
        bytes as f64 / self.cost.store_disk_bandwidth
    }

    fn mode(&self) -> ServingMode {
        self.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_cost_monotone_in_tokens() {
        let c = CostModel::default();
        assert!(c.prefill_time(10) < c.prefill_time(100));
        assert!(c.prefill_time(100) < c.prefill_time(1000));
    }

    #[test]
    fn cached_prefix_reduces_prefill_cost() {
        let mut ex = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let prompt: Vec<u32> = (0..200).collect();
        let full = ex.prefill(0, &prompt, 0, None).unwrap().duration;
        let hit = ex.prefill(0, &prompt, 180, Some(1)).unwrap().duration;
        assert!(hit < full / 3.0, "{hit} vs {full}");
    }

    #[test]
    fn icarus_decode_overhead_is_small() {
        let c = CostModel::default();
        let ctx = vec![500usize; 8];
        let b = c.decode_time(&ctx, ServingMode::Baseline);
        let i = c.decode_time(&ctx, ServingMode::Icarus);
        assert!(i > b && i < b * 1.2, "paper §3.3: near-parity");
    }

    #[test]
    fn synth_tokens_deterministic_and_model_dependent() {
        let a = SimExecutor::synth_token(0, 5, 10);
        let b = SimExecutor::synth_token(0, 5, 10);
        let c = SimExecutor::synth_token(1, 5, 10);
        assert_eq!(a, b);
        assert_ne!(a, c, "different adapters generate different tokens");
        assert!(a >= 32);
    }

    #[test]
    fn snapshot_lifecycle_counts() {
        let mut ex = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let p = ex.prefill(0, &[1, 2, 3], 0, None).unwrap();
        let s = ex.snapshot(p.cache);
        assert_eq!(ex.live_snapshots(), 2);
        ex.drop_snapshot(s);
        ex.drop_snapshot(p.cache);
        assert_eq!(ex.live_snapshots(), 0);
    }

    #[test]
    fn chunked_prefill_compute_telescopes() {
        // Summing chunk_time over any chunking of [0, n) must equal the
        // non-constant part of prefill_time(n).
        let c = CostModel::default();
        let n = 1000usize;
        let whole = c.prefill_time(n) - c.prefill_base;
        for step in [64usize, 256, 1000] {
            let mut sum = 0.0;
            let mut s = 0;
            while s < n {
                let e = (s + step).min(n);
                sum += c.chunk_time(s, e);
                s = e;
            }
            assert!((sum - whole).abs() < 1e-9, "step {step}: {sum} vs {whole}");
        }
    }

    #[test]
    fn chunk_sequence_builds_cache_and_final_token() {
        let mut ex = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let prompt: Vec<u32> = (0..100).collect();
        let mut cache = None;
        let mut first = None;
        let mut s = 0;
        while s < prompt.len() {
            let e = (s + 40).min(prompt.len());
            let mut slot = ChunkSlot {
                seq_id: 1,
                model_id: 0,
                tokens: &prompt[s..e],
                start: s,
                prompt_len: prompt.len(),
                base: None,
                cache,
                first_token: None,
            };
            let d = ex.prefill_chunk(&mut slot).unwrap();
            assert!(d > 0.0);
            cache = slot.cache;
            first = slot.first_token;
            s = e;
        }
        let cache = cache.expect("chunks built a cache");
        assert_eq!(ex.live_snapshots(), 1, "one partial cache handle");
        let expect = SimExecutor::synth_token(0, prompt.len() as u64, prompt.len());
        assert_eq!(first, Some(expect), "final chunk produced the first token");
        ex.drop_snapshot(cache);
        assert_eq!(ex.live_snapshots(), 0);
    }

    #[test]
    fn fused_step_absorbs_chunk_launch_overhead() {
        let c = CostModel::default();
        let mut ex = SimExecutor::new(c.clone(), ServingMode::Baseline);
        let prompt: Vec<u32> = (0..64).collect();
        let mut chunk = [ChunkSlot {
            seq_id: 7,
            model_id: 0,
            tokens: &prompt[..32],
            start: 0,
            prompt_len: prompt.len(),
            base: None,
            cache: None,
            first_token: None,
        }];
        let mut batch = vec![DecodeSlot {
            seq_id: 1,
            model_id: 0,
            cache: 1,
            context_len: 10,
            last_token: 5,
            next_token: 0,
        }];
        let fused = ex.fused_step(&mut chunk, &mut batch).unwrap();
        let expect =
            c.chunk_overlap * c.chunk_time(0, 32) + c.decode_time(&[10], ServingMode::Baseline);
        assert!((fused - expect).abs() < 1e-12, "{fused} vs {expect}");
        assert!(batch[0].next_token >= 32, "decode ran in the fused step");
        assert!(chunk[0].cache.is_some(), "chunk opened a partial cache");
        // A chunk-only step has nothing to hide behind: full compute.
        let prompt2: Vec<u32> = (0..64).collect();
        let mut solo = [ChunkSlot {
            seq_id: 8,
            model_id: 0,
            tokens: &prompt2[..32],
            start: 0,
            prompt_len: prompt2.len(),
            base: None,
            cache: None,
            first_token: None,
        }];
        let alone = ex.fused_step(&mut solo, &mut []).unwrap();
        let expect_alone = c.prefill_base + c.chunk_time(0, 32);
        assert!((alone - expect_alone).abs() < 1e-12, "{alone} vs {expect_alone}");
    }

    #[test]
    fn store_restore_costs_ordered_by_tier() {
        let c = CostModel::default();
        let host = c.store_restore_time(1 << 20, 0);
        let disk = c.store_restore_time(0, 1 << 20);
        assert!(host > 0.0 && disk > host, "the NVMe leg must cost extra");
        // A mixed-tier restore charges the DMA setup once, not per
        // tier.
        let mixed = c.store_restore_time(1 << 20, 1 << 20);
        let expect = host + disk - c.store_restore_base;
        assert!((mixed - expect).abs() < 1e-12, "{mixed} vs {expect}");
        // Restoring beats recomputing by a wide margin (1 MB at
        // 2048 B/token is 512 tokens of prefill) — the reason the
        // tiered store pays off at all.
        assert!(host < c.prefill_time(512) / 10.0, "{host}");
        let mut ex = SimExecutor::new(c.clone(), ServingMode::Icarus);
        let e: &mut dyn Executor = &mut ex;
        assert_eq!(e.store_restore_cost(1 << 20, 0), host);
        assert!(e.store_stage_cost(1 << 20) > 0.0);
    }

    #[test]
    fn decode_fills_tokens() {
        let mut ex = SimExecutor::new(CostModel::default(), ServingMode::Baseline);
        let mut batch = vec![DecodeSlot {
            seq_id: 1,
            model_id: 0,
            cache: 1,
            context_len: 10,
            last_token: 5,
            next_token: 0,
        }];
        let d = ex.decode(&mut batch).unwrap();
        assert!(d > 0.0);
        assert!(batch[0].next_token >= 32);
    }
}
