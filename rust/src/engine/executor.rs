//! Executor abstraction: the engine's only way to touch model compute.
//!
//! Two implementations:
//!   * `SimExecutor` (here) — a calibrated discrete-event cost model used
//!     for the QPS x N x pattern sweeps (Figs 4/5/8/9), where thousands
//!     of serving seconds must be simulated.  Costs are calibrated
//!     against measured PJRT step times (see EXPERIMENTS.md §Calibration).
//!   * `PjrtExecutor` (`runtime/`) — loads the AOT HLO artifacts and runs
//!     real prefill/decode on the PJRT CPU client (e2e example and
//!     integration tests).
//!
//! The engine is identical for both; time always flows through the
//! durations returned here, so a simulated run and a real run exercise
//! the same scheduler/kv-cache code paths.

use crate::config::ServingMode;

/// Opaque id of an immutable cache snapshot (device buffers in PJRT,
/// bookkeeping only in sim).
pub type SnapshotId = u64;

/// Result of a prefill call.
#[derive(Debug)]
pub struct PrefillOut {
    /// Seconds the prefill took (measured or modeled).
    pub duration: f64,
    /// Live cache handle for the new sequence.
    pub cache: SnapshotId,
    /// First generated token (next-token after the prompt).
    pub first_token: u32,
}

/// One running sequence's slot in a decode batch.
#[derive(Debug)]
pub struct DecodeSlot {
    /// Sequence this slot belongs to.
    pub seq_id: u64,
    /// LoRA adapter the sequence is served by.
    pub model_id: usize,
    /// Live cache handle (replaced by the executor on each step).
    pub cache: SnapshotId,
    /// Current context length (position of the token being generated).
    pub context_len: usize,
    /// Last token (input to this step).
    pub last_token: u32,
    /// Output: token generated this step.
    pub next_token: u32,
}

/// The engine's only way to touch model compute (see the module docs).
pub trait Executor {
    /// Encode `prompt[cached_tokens..]` on top of `base` (the snapshot
    /// covering the cached prefix, if any) and return a live cache +
    /// the first token.  `model_id` selects the LoRA adapter; in ICaRus
    /// mode the cache that is produced is base-model cache regardless.
    fn prefill(
        &mut self,
        model_id: usize,
        prompt: &[u32],
        cached_tokens: usize,
        base: Option<SnapshotId>,
    ) -> anyhow::Result<PrefillOut>;

    /// One decode step for the whole batch.  Fills `next_token` and
    /// updates each slot's `cache`; returns the step duration.
    fn decode(&mut self, batch: &mut [DecodeSlot]) -> anyhow::Result<f64>;

    /// Snapshot a live cache so it can be shared immutably (published to
    /// the prefix cache).  Cheap in both implementations (buffers are
    /// functional).
    fn snapshot(&mut self, cache: SnapshotId) -> SnapshotId;

    /// Release a snapshot/cache handle.
    fn drop_snapshot(&mut self, snap: SnapshotId);

    /// Cost of restoring `bytes` from the swap tier.
    fn swap_in_cost(&self, bytes: u64) -> f64;

    /// Serving mode this executor is configured for (decode cost model
    /// differs; PJRT selects the decode artifact).
    fn mode(&self) -> ServingMode;
}

/// Cost-model parameters for `SimExecutor`, in seconds.  Defaults are
/// calibrated to the measured PJRT CPU step times of `serve-small`
/// (micro_hotpath bench), then uniformly scaled — only ratios matter for
/// the paper's comparisons.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Fixed cost of launching one prefill.
    pub prefill_base: f64,
    /// Per-token prefill cost (weights streaming, MLP).
    pub prefill_per_token: f64,
    /// Quadratic attention term per token^2.
    pub prefill_per_token2: f64,
    /// Fixed cost of one decode step (kernel launches, sampling).
    pub decode_base: f64,
    /// Per-sequence cost in a decode batch.
    pub decode_per_seq: f64,
    /// Per-context-token KV read cost, per sequence.
    pub decode_per_ctx_token: f64,
    /// Multiplier on decode compute for ICaRus paired execution (paper
    /// §3.3: ~1.0 because streams are parallelized and memory-bound;
    /// 2.0 would be the unoptimized sequential encoder+decoder).
    pub icarus_decode_factor: f64,
    /// Host<->device bandwidth for swap restores (bytes/sec).
    pub swap_bandwidth: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            prefill_base: 2.0e-3,
            prefill_per_token: 0.9e-3,
            prefill_per_token2: 1.2e-6,
            decode_base: 2.0e-3,
            decode_per_seq: 0.6e-3,
            decode_per_ctx_token: 1.5e-6,
            icarus_decode_factor: 1.05,
            swap_bandwidth: 16.0e9,
        }
    }
}

impl CostModel {
    /// Modeled seconds to prefill `n_tokens` uncached tokens.
    pub fn prefill_time(&self, n_tokens: usize) -> f64 {
        let n = n_tokens as f64;
        self.prefill_base + self.prefill_per_token * n + self.prefill_per_token2 * n * n
    }

    /// Modeled seconds for one decode step over a batch with the given
    /// per-sequence context lengths.
    pub fn decode_time(&self, ctx_lens: &[usize], mode: ServingMode) -> f64 {
        let ctx: usize = ctx_lens.iter().sum();
        let t = self.decode_base
            + self.decode_per_seq * ctx_lens.len() as f64
            + self.decode_per_ctx_token * ctx as f64;
        match mode {
            ServingMode::Baseline => t,
            ServingMode::Icarus => t * self.icarus_decode_factor,
        }
    }
}

/// Discrete-event executor: charges model costs, fabricates tokens
/// deterministically (hash of seq id + position) so prefix-cache keys
/// behave exactly like real generation.
pub struct SimExecutor {
    cost: CostModel,
    mode: ServingMode,
    next_snapshot: SnapshotId,
    live_snapshots: u64,
    /// Call counters for the run.
    pub stats: SimStats,
}

/// Call counters the sim executor accumulates.
#[derive(Debug, Default, Clone)]
pub struct SimStats {
    /// Prefill invocations.
    pub prefill_calls: u64,
    /// Uncached tokens actually prefilled.
    pub prefill_tokens: u64,
    /// Decode steps executed.
    pub decode_steps: u64,
    /// Total sequence-slots across decode steps.
    pub decode_slots: u64,
    /// Snapshot handles released.
    pub dropped_snapshots: u64,
}

impl SimExecutor {
    /// Executor charging `cost` under `mode`'s decode model.
    pub fn new(cost: CostModel, mode: ServingMode) -> Self {
        SimExecutor { cost, mode, next_snapshot: 1, live_snapshots: 0, stats: SimStats::default() }
    }

    /// Snapshot handles currently alive (leak check for tests).
    pub fn live_snapshots(&self) -> u64 {
        self.live_snapshots
    }

    fn fresh(&mut self) -> SnapshotId {
        let id = self.next_snapshot;
        self.next_snapshot += 1;
        self.live_snapshots += 1;
        id
    }

    /// Deterministic pseudo-token for (model, seq, pos).
    pub fn synth_token(model_id: usize, seq_id: u64, pos: usize) -> u32 {
        let mut h = 0xcbf29ce484222325u64;
        for b in [model_id as u64, seq_id, pos as u64] {
            h ^= b;
            h = h.wrapping_mul(0x100000001b3);
        }
        // Keep out of the reserved range and inside every vocab we use.
        32 + (h % 1900) as u32
    }
}

impl Executor for SimExecutor {
    fn prefill(
        &mut self,
        model_id: usize,
        prompt: &[u32],
        cached_tokens: usize,
        _base: Option<SnapshotId>,
    ) -> anyhow::Result<PrefillOut> {
        let new_tokens = prompt.len() - cached_tokens;
        self.stats.prefill_calls += 1;
        self.stats.prefill_tokens += new_tokens as u64;
        Ok(PrefillOut {
            duration: self.cost.prefill_time(new_tokens),
            cache: self.fresh(),
            first_token: Self::synth_token(model_id, prompt.len() as u64, prompt.len()),
        })
    }

    fn decode(&mut self, batch: &mut [DecodeSlot]) -> anyhow::Result<f64> {
        let ctx: Vec<usize> = batch.iter().map(|s| s.context_len).collect();
        self.stats.decode_steps += 1;
        self.stats.decode_slots += batch.len() as u64;
        for slot in batch.iter_mut() {
            slot.next_token = Self::synth_token(slot.model_id, slot.seq_id, slot.context_len);
            // Cache handle is conceptually replaced each functional step;
            // sim reuses the same id to avoid handle churn.
        }
        Ok(self.cost.decode_time(&ctx, self.mode))
    }

    fn snapshot(&mut self, _cache: SnapshotId) -> SnapshotId {
        self.fresh()
    }

    fn drop_snapshot(&mut self, _snap: SnapshotId) {
        self.live_snapshots = self.live_snapshots.saturating_sub(1);
        self.stats.dropped_snapshots += 1;
    }

    fn swap_in_cost(&self, bytes: u64) -> f64 {
        bytes as f64 / self.cost.swap_bandwidth
    }

    fn mode(&self) -> ServingMode {
        self.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_cost_monotone_in_tokens() {
        let c = CostModel::default();
        assert!(c.prefill_time(10) < c.prefill_time(100));
        assert!(c.prefill_time(100) < c.prefill_time(1000));
    }

    #[test]
    fn cached_prefix_reduces_prefill_cost() {
        let mut ex = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let prompt: Vec<u32> = (0..200).collect();
        let full = ex.prefill(0, &prompt, 0, None).unwrap().duration;
        let hit = ex.prefill(0, &prompt, 180, Some(1)).unwrap().duration;
        assert!(hit < full / 3.0, "{hit} vs {full}");
    }

    #[test]
    fn icarus_decode_overhead_is_small() {
        let c = CostModel::default();
        let ctx = vec![500usize; 8];
        let b = c.decode_time(&ctx, ServingMode::Baseline);
        let i = c.decode_time(&ctx, ServingMode::Icarus);
        assert!(i > b && i < b * 1.2, "paper §3.3: near-parity");
    }

    #[test]
    fn synth_tokens_deterministic_and_model_dependent() {
        let a = SimExecutor::synth_token(0, 5, 10);
        let b = SimExecutor::synth_token(0, 5, 10);
        let c = SimExecutor::synth_token(1, 5, 10);
        assert_eq!(a, b);
        assert_ne!(a, c, "different adapters generate different tokens");
        assert!(a >= 32);
    }

    #[test]
    fn snapshot_lifecycle_counts() {
        let mut ex = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let p = ex.prefill(0, &[1, 2, 3], 0, None).unwrap();
        let s = ex.snapshot(p.cache);
        assert_eq!(ex.live_snapshots(), 2);
        ex.drop_snapshot(s);
        ex.drop_snapshot(p.cache);
        assert_eq!(ex.live_snapshots(), 0);
    }

    #[test]
    fn decode_fills_tokens() {
        let mut ex = SimExecutor::new(CostModel::default(), ServingMode::Baseline);
        let mut batch = vec![DecodeSlot {
            seq_id: 1,
            model_id: 0,
            cache: 1,
            context_len: 10,
            last_token: 5,
            next_token: 0,
        }];
        let d = ex.decode(&mut batch).unwrap();
        assert!(d > 0.0);
        assert!(batch[0].next_token >= 32);
    }
}
