//! Engine-side state for `--overlap on`: in-flight modeled transfers
//! tracked as tasks on the per-replica cooperative runtime
//! (`crate::runtime::exec`).
//!
//! The division of labor: the runtime knows how to sleep until a
//! virtual deadline and wake in deterministic order; this module knows
//! what a transfer *is* to the serving engine.  Two shapes exist:
//!
//!   * **Gating** transfers ([`TransferKind`]) carry an admitted turn
//!     across the transfer window — a swap-in restoring a parked
//!     device handle, or a store restore downloading a stored prefix.
//!     The turn's KV blocks are allocated at issue; the sequence joins
//!     the running batch only when the engine's clock passes the
//!     completion time ([`Overlap::drain`]).  Until then other
//!     sequences keep decoding — that concurrency is the overlap win.
//!   * **Background** tasks (write-back, prefetch staging) model
//!     transfers whose latency the store already accounts for via
//!     visibility times; they occupy the executor (and the
//!     `tasks_spawned` counter) but gate nothing.
//!
//! Stall accounting: when the replica has nothing runnable and jumps
//! its clock to the next transfer completion, that wait is *stalled*
//! time (the serial path would have charged it inline anyway).  Each
//! transfer snapshots the cumulative stall at issue
//! ([`InFlightTransfer::stall_mark`]); on completion the engine
//! credits `(duration - stall accrued during flight).max(0)` as
//! *overlapped* time — the portion of the transfer that genuinely hid
//! behind compute.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::engine::sequence::PendingTurn;
use crate::runtime::exec::{ExecMetrics, LocalExecutor};

/// What a gating transfer delivers when it completes.
pub(crate) enum TransferKind {
    /// Swap-tier restore of a fully-cached parked context: the turn
    /// rejoins the batch with its device `handle`, no prefill needed.
    SwapIn {
        /// The admitted turn riding the transfer.
        turn: PendingTurn,
        /// Sequence id reserved (and KV allocated) at issue.
        seq_id: u64,
        /// Parked device cache handle, live across the window.
        handle: u64,
    },
    /// Snapshot-store restore (plus any swap-tier block restores the
    /// same admission charged): on completion the turn prefills its
    /// uncached suffix and joins the batch.
    StoreRestore {
        /// The admitted turn riding the transfer.
        turn: PendingTurn,
        /// Sequence id reserved (and KV allocated) at issue.
        seq_id: u64,
        /// Prompt tokens covered by cache + restore (settled at issue;
        /// the store hit was consumed then).
        cached: usize,
        /// Engine-private fork of the prefix-cache base snapshot,
        /// taken at issue so a payload displacement during the flight
        /// cannot invalidate it.  Dropped after integration.
        base: Option<u64>,
    },
}

/// One gating transfer in flight.
pub(crate) struct InFlightTransfer {
    pub kind: TransferKind,
    pub issued_at: f64,
    pub complete_at: f64,
    /// Cumulative replica stall time at issue (see module docs).
    pub stall_mark: f64,
}

/// Per-replica overlap state: the cooperative executor plus the
/// engine's ledger of gating transfers.
pub(crate) struct Overlap {
    rt: LocalExecutor,
    /// Completion order, filled by transfer tasks as their virtual
    /// deadline fires; drained by the engine each step.  (Task wake
    /// order is deterministic, so so is this.)
    outbox: Arc<Mutex<Vec<u64>>>,
    in_flight: HashMap<u64, InFlightTransfer>,
    next_id: u64,
    /// Cumulative virtual seconds this replica stalled waiting on a
    /// gating transfer (mirrors `ServingStats::stalled_transfer_time`).
    pub stalled: f64,
}

impl Overlap {
    pub fn new() -> Self {
        Overlap {
            rt: LocalExecutor::new(),
            outbox: Arc::default(),
            in_flight: HashMap::new(),
            next_id: 0,
            stalled: 0.0,
        }
    }

    /// Gating transfers currently in flight (each owns a reserved
    /// batch slot: admission counts them against `max_batch`).
    pub fn gating_count(&self) -> usize {
        self.in_flight.len()
    }

    pub fn has_gating(&self) -> bool {
        !self.in_flight.is_empty()
    }

    /// Earliest completion among gating transfers — the time an idle
    /// replica must jump to.
    pub fn next_gating(&self) -> Option<f64> {
        self.in_flight.values().map(|t| t.complete_at).min_by(f64::total_cmp)
    }

    /// Issue a gating transfer: spawn a task that sleeps until
    /// `now + duration` in virtual time and then reports completion.
    pub fn issue(&mut self, kind: TransferKind, now: f64, duration: f64) {
        let id = self.next_id;
        self.next_id += 1;
        let complete_at = now + duration;
        self.in_flight.insert(
            id,
            InFlightTransfer { kind, issued_at: now, complete_at, stall_mark: self.stalled },
        );
        let timers = self.rt.timers();
        let outbox = Arc::clone(&self.outbox);
        self.rt.spawn(async move {
            timers.sleep_until(complete_at).await;
            outbox.lock().expect("outbox poisoned").push(id);
        });
    }

    /// Spawn a non-gating background task (write-back, prefetch
    /// staging) that occupies the executor until `until`.
    pub fn spawn_background(&mut self, until: f64) {
        let timers = self.rt.timers();
        self.rt.spawn(async move {
            timers.sleep_until(until).await;
        });
    }

    /// Advance the runtime to the engine's clock and return every
    /// gating transfer that completed, in completion (wake) order.
    pub fn drain(&mut self, now: f64) -> Vec<InFlightTransfer> {
        self.rt.advance_to(now);
        let ids: Vec<u64> = self.outbox.lock().expect("outbox poisoned").drain(..).collect();
        ids.into_iter()
            .map(|id| self.in_flight.remove(&id).expect("completion matches in-flight"))
            .collect()
    }

    /// End-of-run teardown: run remaining background tasks to their
    /// deadlines (their virtual completion may lie past the last
    /// retirement, like the store's own visibility horizon) and return
    /// the executor's counters.  Gating transfers must all have been
    /// integrated by now — the run loop cannot end with a turn parked
    /// on a transfer.
    pub fn finish(&mut self) -> ExecMetrics {
        assert!(self.in_flight.is_empty(), "run ended with gating transfers in flight");
        // A task spawned after the last clock advance has not had its
        // first poll yet (so its sleep is not registered): poll ready
        // tasks first, then run the wheel dry.
        self.rt.run_ready();
        while let Some(t) = self.rt.next_deadline() {
            self.rt.advance_to(t);
        }
        debug_assert_eq!(self.rt.live_tasks(), 0, "cooperative tasks leaked past the run");
        self.rt.metrics()
    }
}
