//! Shared harness for the paper-reproduction benches: one function per
//! measurement point, aligned-table printing, JSON result dumps under
//! `bench_results/`, and a criterion-free measure loop (criterion is
//! unavailable offline).

use std::path::Path;
use std::time::Instant;

use crate::cluster::Cluster;
use crate::config::{
    AgentPattern, ClusterRouting, EvictionPolicy, Routing, SchedPolicy, ServingConfig,
    ServingMode, WorkloadConfig,
};
use crate::engine::executor::{CostModel, SimExecutor};
use crate::engine::Engine;
use crate::json::{self, Value};
use crate::metrics::ServingStats;
use crate::serve::{
    generate_open_loop, OpenLoopConfig, DEFAULT_SLO_ITL_S, DEFAULT_SLO_REQUEST_S,
    DEFAULT_SLO_TTFT_S,
};
use crate::workload::generate;

/// Plain measure loop: warmup, then median of 5 timed runs of `iters`
/// calls.  Prints an aligned row and returns seconds per call.
pub fn measure<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..iters.min(16) {
        f();
    }
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    samples.sort_by(f64::total_cmp);
    let med = samples[2];
    println!("{name:<44} {:>12.3} µs/op", med * 1e6);
    med
}

/// KV bytes/token of the `serve-small` config: the LLaMA-3.1-8B
/// stand-in (see `python/compile/model.py`).
pub const KV_BPT_SMALL: u64 = 2048;
/// KV bytes/token of the `serve-base` config: the Qwen3-14B stand-in
/// (paper Fig 5).
pub const KV_BPT_BASE: u64 = 8192;

/// One measurement point of a sweep.
#[derive(Debug, Clone)]
pub struct Point {
    /// Cache-namespacing mode under test.
    pub mode: ServingMode,
    /// Number of task-specialized models, N in the paper.
    pub n_models: usize,
    /// Offered load in workflows per second.
    pub qps: f64,
    /// Agentic pattern driving the workload.
    pub pattern: AgentPattern,
    /// Turn-to-model routing inside each workflow.
    pub routing: Routing,
    /// Eviction policy under memory pressure.
    pub eviction: EvictionPolicy,
    /// Simulated KV pool budget in bytes.
    pub kv_pool_bytes: u64,
    /// KV cache cost per token (model-size stand-in).
    pub kv_bytes_per_token: u64,
    /// Workflows per run.
    pub n_requests: usize,
    /// Workload seed.
    pub seed: u64,
    /// Prefix caching on/off (the ablation's variable).
    pub prefix_caching: bool,
    /// Admission-scheduling policy (`benches/sched_policies.rs` sweeps
    /// this).
    pub sched_policy: SchedPolicy,
    /// Chunked-prefill chunk size; 0 = atomic prefill.
    pub prefill_chunk: usize,
    /// Mean initial prompt tokens (long-prompt sweeps raise this).
    pub prompt_mean: f64,
    /// Std dev of initial prompt tokens.
    pub prompt_std: f64,
    /// Engine replicas (>1 runs the point through the cluster layer,
    /// bit-identical at 1 — `benches/store_tiers.rs` sweeps this).
    pub replicas: usize,
    /// Workflow-to-replica routing for multi-replica points.
    pub cluster_routing: ClusterRouting,
    /// Host tier of the tiered snapshot store in bytes (0 = off).
    pub store_host_bytes: u64,
    /// Disk tier of the tiered snapshot store in bytes (0 = off).
    pub store_disk_bytes: u64,
    /// Background prefetch staging for queued turns.
    pub store_prefetch: bool,
    /// Store lock-stripe count (0 = auto from the replica count;
    /// `benches/store_contention.rs` sweeps this).
    pub store_shards: usize,
    /// Cooperative overlap runtime: fly store/swap transfers as tasks
    /// instead of charging them inline (`benches/overlap.rs` sweeps
    /// this).
    pub overlap: bool,
    /// Disaggregated prefill/decode tiers over the shared store
    /// (`benches/cluster_scale.rs` sweeps the tier split).
    pub disagg: bool,
    /// Replicas serving the prefill tier when `disagg` is on.
    pub prefill_replicas: usize,
    /// Admission gate: waiting-queue depth bound (0 = gate off;
    /// `benches/serving.rs` sweeps this).
    pub admit_queue: usize,
    /// Open-loop workload: generate arrivals with the serving front
    /// end's heavy-tailed generator instead of `workload::generate`.
    pub open_loop: bool,
    /// Pareto tail index for open-loop inter-arrivals (<= 1 falls back
    /// to Poisson — the bench's tail ablation).
    pub pareto_alpha: f64,
    /// Persistent-user population for open-loop session prefixes.
    pub users: u64,
    /// Simulator cost model.
    pub cost: CostModel,
}

impl Default for Point {
    fn default() -> Self {
        Point {
            mode: ServingMode::Icarus,
            n_models: 4,
            qps: 0.4,
            pattern: AgentPattern::ReAct,
            routing: Routing::RoundRobin,
            eviction: EvictionPolicy::Recompute,
            kv_pool_bytes: 24 << 20,
            kv_bytes_per_token: KV_BPT_SMALL,
            n_requests: 128,
            seed: 0,
            prefix_caching: true,
            sched_policy: SchedPolicy::Fcfs,
            prefill_chunk: 0,
            prompt_mean: 96.0,
            prompt_std: 24.0,
            replicas: 1,
            cluster_routing: ClusterRouting::RoundRobin,
            store_host_bytes: 0,
            store_disk_bytes: 0,
            store_prefetch: false,
            store_shards: 0,
            overlap: false,
            disagg: false,
            prefill_replicas: 1,
            admit_queue: 0,
            open_loop: false,
            pareto_alpha: 1.5,
            users: 1 << 20,
            cost: CostModel::default(),
        }
    }
}

impl Point {
    /// The serving config this point encodes (public so benches that
    /// bypass [`Point::run`] — e.g. to attach a custom workload — stay
    /// consistent with it).
    pub fn serving_config(&self) -> ServingConfig {
        ServingConfig {
            mode: self.mode,
            kv_pool_bytes: self.kv_pool_bytes,
            eviction: self.eviction,
            prefix_caching: self.prefix_caching,
            sched_policy: self.sched_policy,
            prefill_chunk: self.prefill_chunk,
            replicas: self.replicas,
            cluster_routing: self.cluster_routing,
            store_host_bytes: self.store_host_bytes,
            store_disk_bytes: self.store_disk_bytes,
            store_prefetch: self.store_prefetch,
            store_shards: self.store_shards,
            overlap: self.overlap,
            disagg: self.disagg,
            prefill_replicas: self.prefill_replicas,
            admit_queue: self.admit_queue,
            ..Default::default()
        }
    }

    /// Run this point's full sim and return its stats.  Single-replica
    /// store-less points run the plain engine; anything else goes
    /// through the cluster layer (bit-identical at `replicas == 1`,
    /// pinned by the cluster property tests).
    pub fn run(&self) -> ServingStats {
        let scfg = self.serving_config();
        let wcfg = WorkloadConfig {
            pattern: self.pattern,
            n_models: self.n_models,
            qps: self.qps,
            n_requests: self.n_requests,
            routing: self.routing,
            seed: self.seed,
            prompt_mean: self.prompt_mean,
            prompt_std: self.prompt_std,
            ..Default::default()
        };
        let workload = if self.open_loop {
            let ocfg = OpenLoopConfig {
                base: wcfg,
                users: self.users,
                pareto_alpha: self.pareto_alpha,
                ..Default::default()
            };
            generate_open_loop(&ocfg)
        } else {
            generate(&wcfg)
        };
        if self.replicas > 1 || self.store_host_bytes + self.store_disk_bytes > 0 {
            let cluster = Cluster::new(scfg, self.kv_bytes_per_token, self.n_models);
            return cluster.run_sim(self.cost.clone(), workload).merged;
        }
        let exec = SimExecutor::new(self.cost.clone(), self.mode);
        Engine::new(scfg, self.kv_bytes_per_token, self.n_models, exec).run(workload)
    }

    /// Short `mode/N/qps` tag for table rows, extended with the
    /// scheduling policy, chunk size, replica count and store budgets
    /// when they differ from the defaults (so sweeps stay
    /// distinguishable).
    pub fn label(&self) -> String {
        let mut s = format!("{}/N={}/qps={:.2}", self.mode.as_str(), self.n_models, self.qps);
        if self.sched_policy != SchedPolicy::Fcfs {
            s.push('/');
            s.push_str(self.sched_policy.as_str());
        }
        if self.prefill_chunk > 0 {
            s.push_str(&format!("/chunk={}", self.prefill_chunk));
        }
        if self.replicas > 1 {
            s.push_str(&format!("/R={}", self.replicas));
        }
        if self.store_host_bytes + self.store_disk_bytes > 0 {
            s.push_str(&format!(
                "/store={}M+{}M{}",
                self.store_host_bytes >> 20,
                self.store_disk_bytes >> 20,
                if self.store_prefetch { "+pf" } else { "" }
            ));
            if self.store_shards > 0 {
                s.push_str(&format!("/sh={}", self.store_shards));
            }
        }
        if self.overlap {
            s.push_str("/ov");
        }
        if self.disagg {
            let p = self.prefill_replicas.clamp(1, self.replicas.saturating_sub(1).max(1));
            s.push_str(&format!("/pd={}:{}", p, self.replicas.saturating_sub(p)));
        }
        if self.admit_queue > 0 {
            s.push_str(&format!("/adm={}", self.admit_queue));
        }
        if self.open_loop {
            s.push_str(&format!("/ol(a={:.1})", self.pareto_alpha));
        }
        s
    }
}

/// Result row: the numbers the paper's figures plot.
#[derive(Debug, Clone)]
pub struct Row {
    /// Point label (see [`Point::label`]).
    pub label: String,
    /// Mode the point ran under.
    pub mode: ServingMode,
    /// N models of the point.
    pub n_models: usize,
    /// Offered QPS of the point.
    pub qps: f64,
    /// Admission-scheduling policy of the point.
    pub sched_policy: SchedPolicy,
    /// Chunked-prefill chunk size of the point (0 = atomic).
    pub prefill_chunk: usize,
    /// P95 turn latency in seconds.
    pub p95_s: f64,
    /// P50 turn latency in seconds.
    pub p50_s: f64,
    /// Generated-token throughput per second.
    pub tput_tok_s: f64,
    /// Prefix-cache hit rate over prompt tokens.
    pub hit_rate: f64,
    /// Peak KV pool usage in MB.
    pub peak_kv_mb: f64,
    /// Sequences preempted under pressure.
    pub preemptions: u64,
    /// Blocks evicted from the prefix cache.
    pub evictions: u64,
    /// Snapshot-store restores (host + disk tiers).
    pub store_hits: u64,
    /// Store restores of contexts another replica published.
    pub store_remote_hits: u64,
    /// Virtual seconds replicas stalled waiting on gating transfers.
    pub stalled_transfer_s: f64,
    /// Virtual seconds of transfer time hidden behind compute.
    pub overlapped_transfer_s: f64,
    /// Goodput: completed requests per second that met the default
    /// request SLO ([`DEFAULT_SLO_REQUEST_S`]).
    pub goodput_rps: f64,
    /// Fraction of requests whose TTFT met [`DEFAULT_SLO_TTFT_S`].
    pub ttft_attainment: f64,
    /// Fraction of decode steps whose ITL met [`DEFAULT_SLO_ITL_S`].
    pub itl_attainment: f64,
    /// Requests shed by the admission gate (0 when the gate is off).
    pub rejected: u64,
}

impl Row {
    /// Extract a figure row from a finished run.
    pub fn from_stats(p: &Point, s: &ServingStats) -> Row {
        let tl = s.turn_latency.as_ref().unwrap();
        Row {
            label: p.label(),
            mode: p.mode,
            n_models: p.n_models,
            qps: p.qps,
            sched_policy: p.sched_policy,
            prefill_chunk: p.prefill_chunk,
            p95_s: tl.p95(),
            p50_s: tl.p50(),
            tput_tok_s: s.throughput_tok_s(),
            hit_rate: s.cache_hit_rate(),
            peak_kv_mb: s.peak_kv_bytes as f64 / (1 << 20) as f64,
            preemptions: s.preemptions,
            evictions: s.evictions,
            store_hits: s.store_hits(),
            store_remote_hits: s.store_remote_hits,
            stalled_transfer_s: s.stalled_transfer_time,
            overlapped_transfer_s: s.overlapped_transfer_time,
            goodput_rps: s.goodput_rps(DEFAULT_SLO_REQUEST_S),
            ttft_attainment: s.slo_ttft_attainment(DEFAULT_SLO_TTFT_S),
            itl_attainment: s.slo_itl_attainment(DEFAULT_SLO_ITL_S),
            rejected: s.rejected_requests,
        }
    }

    /// Dump the row for results files.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("mode", json::s(self.mode.as_str())),
            ("n_models", json::num(self.n_models as f64)),
            ("qps", json::num(self.qps)),
            ("sched_policy", json::s(self.sched_policy.as_str())),
            ("prefill_chunk", json::num(self.prefill_chunk as f64)),
            ("p95_s", json::num(self.p95_s)),
            ("p50_s", json::num(self.p50_s)),
            ("tput_tok_s", json::num(self.tput_tok_s)),
            ("hit_rate", json::num(self.hit_rate)),
            ("peak_kv_mb", json::num(self.peak_kv_mb)),
            ("preemptions", json::num(self.preemptions as f64)),
            ("evictions", json::num(self.evictions as f64)),
            ("store_hits", json::num(self.store_hits as f64)),
            ("store_remote_hits", json::num(self.store_remote_hits as f64)),
            ("stalled_transfer_s", json::num(self.stalled_transfer_s)),
            ("overlapped_transfer_s", json::num(self.overlapped_transfer_s)),
            ("goodput_rps", json::num(self.goodput_rps)),
            ("ttft_attainment", json::num(self.ttft_attainment)),
            ("itl_attainment", json::num(self.itl_attainment)),
            ("rejected", json::num(self.rejected as f64)),
        ])
    }
}

/// Print the aligned column header matching [`print_row`].
pub fn header() {
    println!(
        "{:<34} {:>8} {:>8} {:>12} {:>8} {:>10} {:>8} {:>8} {:>7} {:>7}",
        "point",
        "p95(s)",
        "p50(s)",
        "tput(tok/s)",
        "hit",
        "peakKV(MB)",
        "preempt",
        "evict",
        "store",
        "remote"
    );
}

/// Print one aligned result row.
pub fn print_row(r: &Row) {
    println!(
        "{:<34} {:>8.3} {:>8.3} {:>12.1} {:>8.3} {:>10.1} {:>8} {:>8} {:>7} {:>7}",
        r.label,
        r.p95_s,
        r.p50_s,
        r.tput_tok_s,
        r.hit_rate,
        r.peak_kv_mb,
        r.preemptions,
        r.evictions,
        r.store_hits,
        r.store_remote_hits
    );
}

/// Run a sweep and collect rows (printing as it goes).
pub fn sweep(points: &[Point]) -> Vec<Row> {
    header();
    let mut rows = Vec::new();
    for p in points {
        let stats = p.run();
        let row = Row::from_stats(p, &stats);
        print_row(&row);
        rows.push(row);
    }
    rows
}

/// Evaluate `f(0..n)` on `threads` scoped worker threads pulling from a
/// shared work queue (so unevenly-priced items self-balance instead of
/// serializing on one worker) and return the results in index order.
/// Indices are independent work items, so parallelism changes wall
/// clock only, never results.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    let threads = threads.clamp(1, n.max(1));
    let next = AtomicUsize::new(0);
    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..threads {
            let f = &f;
            let next = &next;
            handles.push(s.spawn(move || {
                let mut out = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    out.push((i, f(i)));
                }
                out
            }));
        }
        for h in handles {
            for (i, v) in h.join().expect("worker thread panicked") {
                results[i] = Some(v);
            }
        }
    });
    results.into_iter().map(|r| r.expect("every index covered")).collect()
}

/// Run a sweep with its points spread across `threads` worker threads,
/// then print the rows in point order.  Every point is an independent
/// seeded sim, so the rows are bit-identical to [`sweep`]'s — only the
/// wall clock changes (near-linearly, until points outnumber cores;
/// `benches/cluster_scale.rs` measures the scaling).
pub fn sweep_parallel(points: &[Point], threads: usize) -> Vec<Row> {
    let rows = par_map(points.len(), threads, |i| {
        let p = &points[i];
        Row::from_stats(p, &p.run())
    });
    header();
    for r in &rows {
        print_row(r);
    }
    rows
}

/// Write rows as JSON under bench_results/<name>.json, and mirror them
/// machine-readably to `BENCH_<name>.json` at the repository root —
/// keyed by bench name, each row carrying P50/P95/throughput — so the
/// perf trajectory is tracked in-tree (CI uploads these as artifacts).
pub fn write_results(name: &str, rows: &[Row], extra: Vec<(&str, Value)>) {
    let dir = Path::new("bench_results");
    std::fs::create_dir_all(dir).ok();
    let mut obj = vec![
        ("bench", json::s(name)),
        ("rows", Value::Arr(rows.iter().map(Row::to_json).collect())),
    ];
    obj.extend(extra);
    let v = json::obj(obj);
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, v.to_string_pretty()).expect("write results");
    println!("\nwrote {}", path.display());
    // The crate lives in <repo>/rust, so the repo root is one up from
    // the manifest dir (compile-time constant: benches build in-tree).
    // Best-effort: a relocated binary or read-only checkout must not
    // turn an otherwise-successful sweep into a nonzero exit.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let bench_path = root.join(format!("BENCH_{name}.json"));
    match std::fs::write(&bench_path, v.to_string_pretty()) {
        Ok(()) => println!("wrote {}", bench_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", bench_path.display()),
    }
}

/// Speedup summary between paired baseline/icarus rows (same N & qps).
pub fn summarize_pairs(rows: &[Row]) {
    println!("\n--- ICaRus vs baseline (same N, qps) ---");
    for r in rows.iter().filter(|r| r.mode == ServingMode::Icarus) {
        if let Some(b) = rows.iter().find(|b| {
            b.mode == ServingMode::Baseline && b.n_models == r.n_models && b.qps == r.qps
        }) {
            println!(
                "N={} qps={:.2}: p95 {:.1}x lower, tput {:.2}x higher",
                r.n_models,
                r.qps,
                if r.p95_s > 0.0 { b.p95_s / r.p95_s } else { f64::INFINITY },
                if b.tput_tok_s > 0.0 { r.tput_tok_s / b.tput_tok_s } else { f64::INFINITY },
            );
        }
    }
}
