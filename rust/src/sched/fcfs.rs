//! First-come-first-served admission — the pinned-legacy policy.

use std::collections::VecDeque;

use crate::config::SchedPolicy;
use crate::engine::sequence::PendingTurn;

use super::{CacheProbe, Pick, Scheduler};

/// Strict queue-order admission with the pre-scheduler engine's
/// conservative whole-prompt budget estimate.
///
/// This policy is the compatibility anchor of the subsystem: with
/// chunked prefill disabled it is pinned **bit-identical** (stats and
/// trace) to the engine as it existed before the scheduler extraction,
/// by a differential property test against a frozen port of the old
/// loop.  That is why it keeps the worst-case `prompt.len()` budget
/// estimate instead of the probe-accurate one — the probe fix lives in
/// [`CacheAware`](super::CacheAware) and [`Sjf`](super::Sjf).
#[derive(Debug, Default, Clone, Copy)]
pub struct Fcfs;

impl Scheduler for Fcfs {
    fn policy(&self) -> SchedPolicy {
        SchedPolicy::Fcfs
    }

    fn pick_next(
        &mut self,
        waiting: &VecDeque<PendingTurn>,
        _probe: &CacheProbe<'_>,
    ) -> Option<Pick> {
        // Worst-case whole-prompt estimate: assume nothing is cached.
        waiting.front().map(|t| Pick { idx: 0, uncached_estimate: t.prompt.len() })
    }
}
