//! Shortest-remaining-prefill-first admission.

use std::collections::VecDeque;

use crate::config::SchedPolicy;
use crate::engine::sequence::PendingTurn;

use super::{CacheProbe, Pick, Scheduler};

/// Admit the waiting turn with the fewest probed-uncached prompt tokens
/// first (ties broken FCFS) — shortest-job-first over remaining prefill
/// work, the classic tail-latency heuristic.
///
/// Long cold prompts yield to short (or cache-hot) ones, which cuts P95
/// turn latency under load at the usual SJF cost: a long prompt can be
/// deferred while shorter work keeps arriving (the policy sweep in
/// `benches/sched_policies.rs` measures the trade).  The admission
/// budget uses the same probe-accurate uncached estimate as
/// [`CacheAware`](super::CacheAware).
#[derive(Debug, Default, Clone, Copy)]
pub struct Sjf;

impl Scheduler for Sjf {
    fn policy(&self) -> SchedPolicy {
        SchedPolicy::Sjf
    }

    fn pick_next(
        &mut self,
        waiting: &VecDeque<PendingTurn>,
        probe: &CacheProbe<'_>,
    ) -> Option<Pick> {
        let mut best: Option<Pick> = None;
        for (i, turn) in waiting.iter().enumerate() {
            let uncached = if turn.swapped.is_some() {
                0 // swap restore: no prefill work at all
            } else {
                probe.uncached_tokens(turn)
            };
            // Strict `<` keeps the earliest turn among ties (FCFS).
            if best.is_none_or(|p| uncached < p.uncached_estimate) {
                best = Some(Pick { idx: i, uncached_estimate: uncached });
            }
        }
        best
    }
}
