//! Cache-aware admission: highest prefix-cache coverage first.

use std::collections::VecDeque;

use crate::config::SchedPolicy;
use crate::engine::sequence::PendingTurn;

use super::{CacheProbe, Pick, Scheduler};

/// Admit the waiting turn with the highest probed prefix-cache coverage
/// *fraction* first (ties broken FCFS).
///
/// In ICaRus mode a turn whose accumulated context was just published
/// by another model is almost free to admit — serving it first drains
/// the queue fastest and returns its KV blocks soonest, which is how
/// the paper's cross-model sharing feeds back into scheduling.  The
/// admission budget is charged with the probed-uncached suffix (not the
/// whole prompt), fixing the pre-scheduler engine's conservative check
/// that blocked cache hits behind a budget they would barely consume.
#[derive(Debug, Default, Clone, Copy)]
pub struct CacheAware;

impl Scheduler for CacheAware {
    fn policy(&self) -> SchedPolicy {
        SchedPolicy::CacheAware
    }

    fn pick_next(
        &mut self,
        waiting: &VecDeque<PendingTurn>,
        probe: &CacheProbe<'_>,
    ) -> Option<Pick> {
        let mut best: Option<(f64, Pick)> = None;
        for (i, turn) in waiting.iter().enumerate() {
            // A swap-parked turn is fully resident on its parked handle:
            // treat it as complete coverage so restores drain first.
            let (covered, uncached) = if turn.swapped.is_some() {
                (1.0, 0)
            } else {
                let cached = probe.cached_tokens(turn);
                (cached as f64 / turn.prompt.len().max(1) as f64, turn.prompt.len() - cached)
            };
            // Strict `>` keeps the earliest turn among ties (FCFS).
            if best.is_none_or(|(c, _)| covered > c) {
                best = Some((covered, Pick { idx: i, uncached_estimate: uncached }));
            }
        }
        best.map(|(_, pick)| pick)
    }
}
