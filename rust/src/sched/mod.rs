//! The scheduler subsystem: queue ownership + pluggable admission
//! policies.
//!
//! The engine used to hardwire its scheduling decisions (which waiting
//! turn to admit next, how to charge the per-step prefill budget) into
//! its event loop.  This module extracts them behind the [`Scheduler`]
//! trait so policies can be varied, measured and extended without
//! touching the engine, and owns the turn queues ([`Queues`]) the
//! policies operate over.
//!
//! Three policies ship (`--sched-policy` on the CLI; see
//! `benches/sched_policies.rs` for the policy × chunk-size × QPS
//! sweep):
//!
//!   * [`Fcfs`] — strict queue order, budget charged with the
//!     worst-case whole-prompt estimate.  Pinned **bit-identical** to
//!     the pre-scheduler engine (stats and trace) by a differential
//!     property test against a frozen reference port of the old loop
//!     (`tests/property_invariants.rs`), so the refactor is provably a
//!     refactor.
//!   * [`CacheAware`] — highest probed prefix-cache coverage first.
//!     In ICaRus mode a turn whose context another model just
//!     published is nearly free to admit; serving it first shortens
//!     the queue for everyone (the paper's sharing directly feeds the
//!     scheduler).  Also fixes the pre-scheduler engine's conservative
//!     admission budget: the budget is charged with the *probed*
//!     uncached suffix, not the whole prompt, so cache hits are no
//!     longer blocked behind a budget they would barely consume.
//!   * [`Sjf`] — shortest-remaining-prefill first (probed-uncached
//!     tokens), the classic tail-latency heuristic, with the same
//!     probe-accurate budget accounting.
//!
//! Probes go through [`CacheProbe`], a read-only prefix-cache coverage
//! query (`KvCacheManager::probe_cached_tokens`) that deliberately does
//! **not** touch LRU state: policies may probe the queue every step
//! without perturbing eviction order — which is also what keeps `Fcfs`
//! runs bit-identical while other policies probe freely.
//!
//! Head-of-line blocking is attacked on both axes: policies may admit
//! from the middle of the queue (ordering axis), and chunked prefill
//! (`--prefill-chunk`, see `engine`) splits long prompts into bounded
//! chunks co-scheduled with the decode batch (time axis), so one long
//! prompt can stall neither the waiting queue nor the running batch.
//!
//! Under disaggregation (`--disagg on`, see `cluster`) the two replica
//! roles lean on different halves of this module without needing any
//! disagg-specific policy code.  Prefill replicas are forced onto
//! [`Sjf`] by the cluster (shortest prompt first minimizes mean handoff
//! wait for the decode tier; there is no decode batch to protect, so
//! SJF's only cost — long-prompt starvation under overload — is the
//! right trade).  Decode replicas keep the operator-chosen policy:
//! handed-off turns arrive with their prefix already published in the
//! shared store, so the existing [`StoreCoverage`] memo prices their
//! admission as a restore (transfer) rather than a re-prefill, and the
//! probe-accurate budget admits them nearly for free.

mod cache_aware;
mod fcfs;
mod sjf;

pub use cache_aware::CacheAware;
pub use fcfs::Fcfs;
pub use sjf::Sjf;

use std::collections::{HashMap, VecDeque};

use crate::config::SchedPolicy;
use crate::engine::sequence::{PendingTurn, RunningSeq};
use crate::kvcache::KvCacheManager;

/// Memoized snapshot-store coverage for the waiting queue, computed
/// once per admission round: keyed by the turn's prompt buffer
/// identity `(ptr, len)` — stable across `VecDeque` shuffles because
/// `TokenBuf`s are Arc-backed and waiting turns keep their buffers
/// alive for the whole round.  Policies probe every waiting turn on
/// every pick, so reading a local map here instead of taking the
/// shared store's mutex (and clock fence) per probe keeps `CacheAware`
/// admission O(queue) lock acquisitions per *step*, not per pick.
pub type StoreCoverage = HashMap<(usize, usize), usize>;

/// Read-only prefix-cache coverage probe handed to policies.
///
/// Coverage queries walk the radix index without updating access times
/// or pinning, so probing is side-effect-free: a policy may probe every
/// waiting turn every step without perturbing LRU eviction order.
///
/// With a tiered snapshot store attached ([`CacheProbe::with_store`]),
/// coverage also counts store-resident prefixes (from a per-round
/// [`StoreCoverage`] memo, so equally side-effect-free): to a
/// `CacheAware` policy, a context another replica published is as good
/// as a local radix hit — restoring it costs a transfer, not a
/// re-prefill.
pub struct CacheProbe<'a> {
    kv: &'a KvCacheManager,
    store_coverage: Option<&'a StoreCoverage>,
}

impl<'a> CacheProbe<'a> {
    /// Probe over the engine's KV manager.
    pub fn new(kv: &'a KvCacheManager) -> Self {
        CacheProbe { kv, store_coverage: None }
    }

    /// Probe that also counts snapshot-store coverage, via the memo
    /// the engine computed for this admission round.
    pub fn with_store(kv: &'a KvCacheManager, coverage: &'a StoreCoverage) -> Self {
        CacheProbe { kv, store_coverage: Some(coverage) }
    }

    /// Prompt tokens of `turn` an admission could currently serve from
    /// the prefix cache (match depth through the deepest
    /// snapshot-bearing node — blocks matched beyond the last payload
    /// have nothing to prefill from and do not count) or restore from
    /// the snapshot store, whichever covers more.
    pub fn cached_tokens(&self, turn: &PendingTurn) -> usize {
        // Memoized-chain probe: the turn's prompt is immutable while it
        // waits, so its block hashes are computed once, not per step.
        let local = self.kv.probe_cached_tokens_buf(turn.model_id, &turn.prompt);
        match self.store_coverage {
            Some(memo) => {
                let key = (turn.prompt.as_ptr() as usize, turn.prompt.len());
                local.max(memo.get(&key).copied().unwrap_or(0))
            }
            None => local,
        }
    }

    /// Prompt tokens of `turn` that would actually need prefilling.
    pub fn uncached_tokens(&self, turn: &PendingTurn) -> usize {
        turn.prompt.len().saturating_sub(self.cached_tokens(turn))
    }
}

/// A policy's admission choice: which waiting turn to try next, plus
/// the uncached-prefill estimate (computed in the same probe pass, so
/// the engine never re-probes the picked turn) that gates the attempt
/// against the per-step prefill budget.
#[derive(Debug, Clone, Copy)]
pub struct Pick {
    /// Index into the waiting queue.
    pub idx: usize,
    /// Estimated uncached prefill tokens for that turn — worst-case
    /// whole-prompt for [`Fcfs`], probed coverage for the others (the
    /// budget itself settles against the real admission outcome; this
    /// estimate only gates the attempt).
    pub uncached_estimate: usize,
}

/// An admission policy: picks which waiting turn the engine tries to
/// admit next and how much of the per-step prefill budget an admission
/// is charged for.
///
/// The engine remains responsible for the mechanics (KV allocation,
/// preemption, chunk planning); the policy only decides *order* and
/// *budget*.  `Send` because engines run on cluster replica threads.
pub trait Scheduler: Send {
    /// Which policy this scheduler implements (for labels/dumps).
    fn policy(&self) -> SchedPolicy;

    /// The next turn to attempt admitting, or `None` to stop this
    /// admission round.  Called once per admission attempt — the
    /// queue's coverage can change with every admission (pins, inserts,
    /// evictions), so probing policies deliberately re-rank each time.
    fn pick_next(
        &mut self,
        waiting: &VecDeque<PendingTurn>,
        probe: &CacheProbe<'_>,
    ) -> Option<Pick>;
}

/// Construct the scheduler implementing `policy`.
pub fn make(policy: SchedPolicy) -> Box<dyn Scheduler> {
    match policy {
        SchedPolicy::Fcfs => Box::new(Fcfs),
        SchedPolicy::CacheAware => Box::new(CacheAware),
        SchedPolicy::Sjf => Box::new(Sjf),
    }
}

/// The scheduler-owned turn queues: turns waiting for admission, turns
/// parked on tool latency, and the running batch (decoding or
/// mid-chunked-prefill).
#[derive(Debug, Default)]
pub struct Queues {
    /// Turns eligible for admission, in arrival/requeue order.
    pub waiting: VecDeque<PendingTurn>,
    /// Turns whose tool call (think time) has not finished yet.
    pub delayed: Vec<PendingTurn>,
    /// Sequences holding KV resources: the decode batch plus any
    /// sequences still mid-chunked-prefill.
    pub running: Vec<RunningSeq>,
}

impl Queues {
    /// Empty queues.
    pub fn new() -> Self {
        Queues::default()
    }

    /// Move turns whose tool latency has elapsed into the run queue.
    pub fn surface_delayed(&mut self, now: f64) {
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].ready_at <= now {
                let t = self.delayed.swap_remove(i);
                self.waiting.push_back(t);
            } else {
                i += 1;
            }
        }
    }

    /// Summed prompt tokens across the waiting queue — the backlog
    /// measure the serving front end's admission gate
    /// (`ServingConfig::admit_tokens`) sheds load against.
    pub fn queued_prompt_tokens(&self) -> usize {
        self.waiting.iter().map(|t| t.prompt.len()).sum()
    }

    /// Earliest tool-completion time among delayed turns, if any.
    pub fn next_ready(&self) -> Option<f64> {
        self.delayed.iter().map(|t| t.ready_at).min_by(f64::total_cmp)
    }

    /// True when nothing is waiting, delayed or running.
    pub fn is_drained(&self) -> bool {
        self.waiting.is_empty() && self.delayed.is_empty() && self.running.is_empty()
    }
}
