//! Serving metrics: streaming latency histograms (P50/P95/P99),
//! throughput counters and memory gauges — what the paper's figures plot.

/// Log-bucketed latency histogram.  Buckets are exponential with ~3%
/// resolution, covering 1µs .. ~1.2h, so P95 extraction is O(buckets)
/// and recording is O(1) with no allocation on the hot path.
///
/// Histograms merge exactly: bucket counts are position-wise sums, so
/// merging per-replica histograms yields bit-identical counts and
/// quantile buckets to recording every sample into one instance (the
/// property `tests/property_invariants.rs` checks).
///
/// ```
/// use icarus::metrics::Histogram;
/// let mut h = Histogram::new();
/// h.record(0.25);
/// h.record(0.75);
/// assert_eq!(h.count(), 2);
/// assert!((h.mean() - 0.5).abs() < 1e-9);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const BUCKETS: usize = 768;
const GROWTH: f64 = 1.03;
const BASE: f64 = 1e-6; // seconds

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram { counts: vec![0; BUCKETS], total: 0, sum: 0.0, min: f64::INFINITY, max: 0.0 }
    }

    fn bucket(v: f64) -> usize {
        if v <= BASE {
            return 0;
        }
        let idx = (v / BASE).ln() / GROWTH.ln();
        (idx as usize).min(BUCKETS - 1)
    }

    /// Record one latency sample, in seconds.
    pub fn record(&mut self, seconds: f64) {
        self.counts[Self::bucket(seconds)] += 1;
        self.total += 1;
        self.sum += seconds;
        self.min = self.min.min(seconds);
        self.max = self.max.max(seconds);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact mean of the recorded samples (tracked outside the buckets).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Exact sum of the recorded samples — what a Prometheus
    /// `_seconds_total` counter wants (tracked outside the buckets, so
    /// no bucket-resolution error).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile in [0,1] -> seconds (upper edge of the containing bucket).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return BASE * GROWTH.powi(i as i32 + 1);
            }
        }
        self.max
    }

    /// Median latency in seconds.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 95th-percentile latency in seconds (the paper's headline metric).
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// 99th-percentile latency in seconds.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fraction of recorded samples at or below `threshold_s` — the
    /// SLO-attainment query (what share of turns met a TTFT/ITL
    /// deadline), resolved to the histogram's ~3% log-bucket edges:
    /// samples sharing the threshold's bucket all count as within it.
    /// 1.0 for an empty histogram (a vacuously met SLO).
    pub fn fraction_below(&self, threshold_s: f64) -> f64 {
        if self.total == 0 {
            return 1.0;
        }
        let cut = Self::bucket(threshold_s);
        let within: u64 = self.counts[..=cut].iter().sum();
        within as f64 / self.total as f64
    }

    /// Fold `other`'s samples into this histogram.  Exact: bucket
    /// counts add position-wise, so quantiles of the merge equal the
    /// quantiles of recording all samples into one instance.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Per-model phase attribution of turn latency (`--obs on` only): the
/// queue / prefill / stall / decode decomposition the paper's fig4/fig5
/// latency figures are built from.  One instance per model id; all four
/// histograms merge exactly, so cluster-level phase attribution is
/// bit-identical to recording every sample on one replica.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelPhases {
    /// Ready → admission pick (scheduler queue wait).
    pub queue: Histogram,
    /// Prefill compute (atomic, or first to last chunk).
    pub prefill: Histogram,
    /// Transfer time compute did not hide (serial restores, swap-ins,
    /// gated overlap windows).
    pub stall: Histogram,
    /// First token → retirement (decode residency).
    pub decode: Histogram,
}

impl ModelPhases {
    /// Fold another model's phase histograms into this one (exact).
    pub fn merge(&mut self, other: &ModelPhases) {
        self.queue.merge(&other.queue);
        self.prefill.merge(&other.prefill);
        self.stall.merge(&other.stall);
        self.decode.merge(&other.decode);
    }

    /// Summary JSON for results files: per phase, the quantiles plus
    /// the exact time sum (the Prometheus counter form).
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::{num, obj};
        let h = |h: &Histogram| {
            obj(vec![
                ("p50", num(h.p50())),
                ("p95", num(h.p95())),
                ("mean", num(h.mean())),
                ("sum", num(h.sum())),
                ("count", num(h.count() as f64)),
            ])
        };
        obj(vec![
            ("queue", h(&self.queue)),
            ("prefill", h(&self.prefill)),
            ("stall", h(&self.stall)),
            ("decode", h(&self.decode)),
        ])
    }
}

/// Counters a serving run accumulates; the benches print these as the
/// paper's figure rows.
///
/// Stats from sharded (multi-replica) runs recombine through
/// [`ServingStats::merge`]: counters add, histograms merge exactly, the
/// wall clock reconciles to the slowest replica and the peak KV
/// footprint to the sum of the per-replica pools.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServingStats {
    /// End-to-end request latency (submit -> final token).
    pub request_latency: Option<Histogram>,
    /// Per-turn latency (turn submit -> turn done) — what Fig 4 reports.
    pub turn_latency: Option<Histogram>,
    /// Latency from a turn becoming runnable to its first token.
    pub time_to_first_token: Option<Histogram>,
    /// Gap between consecutive decoded tokens, per sequence (one sample
    /// per sequence per decode step) — the stall signal chunked prefill
    /// exists to flatten: an atomic long-prompt prefill shows up here
    /// as a multi-second spike for every co-running sequence.
    pub inter_token_latency: Option<Histogram>,
    /// Waiting-queue depth in turns, sampled once per engine step
    /// (recorded as a dimensionless count; quantiles are exact to the
    /// histogram's ~3% bucket resolution).
    pub queue_depth: Option<Histogram>,
    /// Per-restore latency charged for snapshot-store restores (PCIe
    /// for host-tier hits, NVMe + PCIe for unstaged disk hits).
    pub store_restore_latency: Option<Histogram>,
    /// Workflows that ran every turn to completion.
    pub completed_requests: u64,
    /// Turns retired across all workflows.
    pub completed_turns: u64,
    /// Tokens produced by decode steps.
    pub generated_tokens: u64,
    /// Prompt tokens actually prefilled (cache misses).
    pub prefill_tokens: u64,
    /// Prefill tokens that were served without recompute: prefix-cache
    /// hits plus snapshot-store restores (the restored subset is also
    /// tracked separately in `store_restored_tokens`).
    pub cached_prefill_tokens: u64,
    /// Tokens recomputed because their cache was evicted.
    pub recomputed_tokens: u64,
    /// Blocks evicted from the prefix cache.
    pub evictions: u64,
    /// Contexts moved out to the host swap tier.
    pub swap_outs: u64,
    /// Contexts restored from the host swap tier.
    pub swap_ins: u64,
    /// Snapshot-store restores served from the host tier (per-tier
    /// companion: `store_disk_hits`).
    pub store_host_hits: u64,
    /// Snapshot-store restores that paid the NVMe read (disk tier,
    /// not prefetch-staged).
    pub store_disk_hits: u64,
    /// Store restores of entries another replica published — the
    /// shared store's cross-replica reuse signal.
    pub store_remote_hits: u64,
    /// Prompt tokens restored from the snapshot store instead of
    /// being re-prefilled.
    pub store_restored_tokens: u64,
    /// KV bytes transferred by store restores.
    pub store_restored_bytes: u64,
    /// Background prefetch stagings this replica issued.
    pub store_prefetches: u64,
    /// Running sequences preempted under memory pressure.
    pub preemptions: u64,
    /// Prefill chunks executed (0 unless chunked prefill is enabled).
    pub prefill_chunks: u64,
    /// Prefetch-scan probes skipped because the candidate was already
    /// probed (staged or found unstageable) since the last local store
    /// publish — the scan memo's savings signal.
    pub store_prefetch_skips: u64,
    /// Virtual seconds the replica spent stalled with an empty running
    /// batch waiting on an in-flight modeled transfer (`--overlap on`
    /// only; the serial path charges transfers inline and never
    /// records a stall here).
    pub stalled_transfer_time: f64,
    /// Virtual seconds of modeled transfer time that ran concurrently
    /// with compute instead of on the replica's critical path
    /// (`--overlap on` only) — the overlap win the cooperative runtime
    /// exists for.
    pub overlapped_transfer_time: f64,
    /// Tasks spawned on the per-replica cooperative executor
    /// (`--overlap on` only): transfer completions plus background
    /// write-back/prefetch tasks.
    pub tasks_spawned: u64,
    /// Prefills this replica ran to completion and handed off to a
    /// decode replica (`--disagg on`, prefill role only; such turns do
    /// not count as `completed_turns` here — the decode side retires
    /// them).
    pub prefill_handoffs: u64,
    /// Turns this replica admitted from the handoff queue after a
    /// prefill replica published their prefix (`--disagg on`, decode
    /// role only).
    pub decode_handoffs: u64,
    /// Workflows that reached the serving front end's admission gate
    /// (arrivals observed while admission control — `--admit-queue` /
    /// `--admit-tokens` — was enabled).  Stays 0 with the gate off, so
    /// gate-off runs remain bit-identical to the pre-front-end engine
    /// (pinned by a differential property test).
    pub submitted_requests: u64,
    /// Workflows load-shed at the admission gate: rejected at arrival
    /// because the waiting queue was over its depth or token bound,
    /// never entering the scheduler.  End-to-end conservation —
    /// `submitted_requests == completed_requests + rejected_requests`
    /// — is pinned by a property test.
    pub rejected_requests: u64,
    /// Peak KV pool usage in bytes (the memory-explosion signal).
    pub peak_kv_bytes: u64,
    /// Simulated (or measured) seconds from run start to last retirement.
    pub wall_seconds: f64,
    /// Per-model phase attribution, indexed by model id (`--obs on`
    /// only; empty — and absent from the JSON dump — when obs is off,
    /// keeping obs-off stats bit-identical to the pre-obs engine).
    pub phases: Vec<ModelPhases>,
}

impl ServingStats {
    /// Fresh stats with live (empty) histograms.
    pub fn new() -> Self {
        ServingStats {
            request_latency: Some(Histogram::new()),
            turn_latency: Some(Histogram::new()),
            time_to_first_token: Some(Histogram::new()),
            inter_token_latency: Some(Histogram::new()),
            queue_depth: Some(Histogram::new()),
            store_restore_latency: Some(Histogram::new()),
            ..Default::default()
        }
    }

    /// Fold the stats of another (sharded) run into this one.
    ///
    /// Counters and histograms accumulate exactly.  Two fields have
    /// cluster semantics rather than plain sums: `wall_seconds` becomes
    /// the max (replicas run concurrently, so the cluster finishes with
    /// its slowest member) and `peak_kv_bytes` the sum (each replica
    /// owns a full KV pool, so cluster footprint is additive).  Merging
    /// one run into `ServingStats::new()` reproduces that run exactly —
    /// the `--replicas 1` bit-identity the cluster tests pin down.
    pub fn merge(&mut self, other: &ServingStats) {
        let hist = |dst: &mut Option<Histogram>, src: &Option<Histogram>| {
            if let Some(src) = src {
                match dst {
                    Some(dst) => dst.merge(src),
                    None => *dst = Some(src.clone()),
                }
            }
        };
        hist(&mut self.request_latency, &other.request_latency);
        hist(&mut self.turn_latency, &other.turn_latency);
        hist(&mut self.time_to_first_token, &other.time_to_first_token);
        hist(&mut self.inter_token_latency, &other.inter_token_latency);
        hist(&mut self.queue_depth, &other.queue_depth);
        hist(&mut self.store_restore_latency, &other.store_restore_latency);
        self.completed_requests += other.completed_requests;
        self.completed_turns += other.completed_turns;
        self.generated_tokens += other.generated_tokens;
        self.prefill_tokens += other.prefill_tokens;
        self.cached_prefill_tokens += other.cached_prefill_tokens;
        self.recomputed_tokens += other.recomputed_tokens;
        self.evictions += other.evictions;
        self.swap_outs += other.swap_outs;
        self.swap_ins += other.swap_ins;
        self.store_host_hits += other.store_host_hits;
        self.store_disk_hits += other.store_disk_hits;
        self.store_remote_hits += other.store_remote_hits;
        self.store_restored_tokens += other.store_restored_tokens;
        self.store_restored_bytes += other.store_restored_bytes;
        self.store_prefetches += other.store_prefetches;
        self.preemptions += other.preemptions;
        self.prefill_chunks += other.prefill_chunks;
        self.store_prefetch_skips += other.store_prefetch_skips;
        self.stalled_transfer_time += other.stalled_transfer_time;
        self.overlapped_transfer_time += other.overlapped_transfer_time;
        self.tasks_spawned += other.tasks_spawned;
        self.prefill_handoffs += other.prefill_handoffs;
        self.decode_handoffs += other.decode_handoffs;
        self.submitted_requests += other.submitted_requests;
        self.rejected_requests += other.rejected_requests;
        self.peak_kv_bytes += other.peak_kv_bytes;
        self.wall_seconds = self.wall_seconds.max(other.wall_seconds);
        if self.phases.len() < other.phases.len() {
            self.phases.resize_with(other.phases.len(), ModelPhases::default);
        }
        for (dst, src) in self.phases.iter_mut().zip(&other.phases) {
            dst.merge(src);
        }
    }

    /// Record one retired turn's phase decomposition under `model`,
    /// growing the per-model table on first sight (`--obs on` only —
    /// the engine never calls this with obs off).
    pub fn record_phases(
        &mut self,
        model: usize,
        queue: f64,
        prefill: f64,
        stall: f64,
        decode: f64,
    ) {
        if self.phases.len() <= model {
            self.phases.resize_with(model + 1, ModelPhases::default);
        }
        let p = &mut self.phases[model];
        p.queue.record(queue);
        p.prefill.record(prefill);
        p.stall.record(stall);
        p.decode.record(decode);
    }

    /// Generated tokens per wall-clock second.
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_seconds
        }
    }

    /// Completed workflows per wall-clock second.
    pub fn requests_per_s(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            self.completed_requests as f64 / self.wall_seconds
        }
    }

    /// Goodput: completed workflows per second whose end-to-end
    /// latency met `request_slo_s` — the completion rate scaled by the
    /// request-latency histogram's within-deadline fraction (exact to
    /// the histogram's ~3% bucket resolution).  The serving bench
    /// plots this against offered load: throughput counts everything,
    /// goodput only what a user with a deadline would call served.
    pub fn goodput_rps(&self, request_slo_s: f64) -> f64 {
        let h = self.request_latency.as_ref().expect("stats built with new()");
        self.requests_per_s() * h.fraction_below(request_slo_s)
    }

    /// SLO attainment on time-to-first-token: the fraction of turns
    /// whose TTFT met `slo_s`.
    pub fn slo_ttft_attainment(&self, slo_s: f64) -> f64 {
        let h = self.time_to_first_token.as_ref().expect("stats built with new()");
        h.fraction_below(slo_s)
    }

    /// SLO attainment on inter-token latency: the fraction of decode
    /// gaps within `slo_s`.
    pub fn slo_itl_attainment(&self, slo_s: f64) -> f64 {
        let h = self.inter_token_latency.as_ref().expect("stats built with new()");
        h.fraction_below(slo_s)
    }

    /// Snapshot-store restores across both tiers.
    pub fn store_hits(&self) -> u64 {
        self.store_host_hits + self.store_disk_hits
    }

    /// Fraction of prompt tokens served from the prefix cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.prefill_tokens + self.cached_prefill_tokens;
        if total == 0 {
            0.0
        } else {
            self.cached_prefill_tokens as f64 / total as f64
        }
    }

    /// Dump every counter plus derived rates for results files.
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::{num, obj};
        let h = |h: &Option<Histogram>| {
            let h = h.as_ref().expect("stats built with new()");
            obj(vec![
                ("p50", num(h.p50())),
                ("p95", num(h.p95())),
                ("p99", num(h.p99())),
                ("mean", num(h.mean())),
                ("max", num(h.max())),
                ("count", num(h.count() as f64)),
            ])
        };
        let mut entries = vec![
            ("request_latency", h(&self.request_latency)),
            ("turn_latency", h(&self.turn_latency)),
            ("ttft", h(&self.time_to_first_token)),
            ("inter_token_latency", h(&self.inter_token_latency)),
            ("queue_depth", h(&self.queue_depth)),
            ("completed_requests", num(self.completed_requests as f64)),
            ("completed_turns", num(self.completed_turns as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("prefill_tokens", num(self.prefill_tokens as f64)),
            ("cached_prefill_tokens", num(self.cached_prefill_tokens as f64)),
            ("recomputed_tokens", num(self.recomputed_tokens as f64)),
            ("evictions", num(self.evictions as f64)),
            ("swap_outs", num(self.swap_outs as f64)),
            ("swap_ins", num(self.swap_ins as f64)),
            ("store_host_hits", num(self.store_host_hits as f64)),
            ("store_disk_hits", num(self.store_disk_hits as f64)),
            ("store_remote_hits", num(self.store_remote_hits as f64)),
            ("store_restored_tokens", num(self.store_restored_tokens as f64)),
            ("store_restored_bytes", num(self.store_restored_bytes as f64)),
            ("store_prefetches", num(self.store_prefetches as f64)),
            ("store_restore_latency", h(&self.store_restore_latency)),
            ("preemptions", num(self.preemptions as f64)),
            ("prefill_chunks", num(self.prefill_chunks as f64)),
            ("store_prefetch_skips", num(self.store_prefetch_skips as f64)),
            ("stalled_transfer_time", num(self.stalled_transfer_time)),
            ("overlapped_transfer_time", num(self.overlapped_transfer_time)),
            ("tasks_spawned", num(self.tasks_spawned as f64)),
            ("prefill_handoffs", num(self.prefill_handoffs as f64)),
            ("decode_handoffs", num(self.decode_handoffs as f64)),
            ("submitted_requests", num(self.submitted_requests as f64)),
            ("rejected_requests", num(self.rejected_requests as f64)),
            ("peak_kv_bytes", num(self.peak_kv_bytes as f64)),
            ("throughput_tok_s", num(self.throughput_tok_s())),
            ("cache_hit_rate", num(self.cache_hit_rate())),
            ("wall_seconds", num(self.wall_seconds)),
        ];
        if !self.phases.is_empty() {
            entries.push((
                "phases",
                crate::json::Value::Arr(self.phases.iter().map(ModelPhases::to_json).collect()),
            ));
        }
        obj(entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        assert!(h.p50() <= h.p95());
        assert!(h.p95() <= h.p99());
        assert!(h.p99() <= h.max() * 1.04);
    }

    #[test]
    fn p95_accuracy() {
        let mut h = Histogram::new();
        for i in 1..=10_000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 1s uniform
        }
        let p95 = h.p95();
        assert!((p95 - 0.95).abs() / 0.95 < 0.05, "p95 {}", p95);
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        h.record(1.0);
        h.record(3.0);
        assert_eq!(h.mean(), 2.0);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.p95(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(0.1);
        b.record(0.2);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 0.15).abs() < 1e-9);
    }

    #[test]
    fn stats_merge_semantics() {
        let mut a = ServingStats::new();
        a.completed_requests = 3;
        a.peak_kv_bytes = 100;
        a.wall_seconds = 5.0;
        a.turn_latency.as_mut().unwrap().record(0.1);
        let mut b = ServingStats::new();
        b.completed_requests = 4;
        b.peak_kv_bytes = 50;
        b.wall_seconds = 9.0;
        b.turn_latency.as_mut().unwrap().record(0.3);
        a.merge(&b);
        assert_eq!(a.completed_requests, 7);
        assert_eq!(a.peak_kv_bytes, 150, "cluster footprint is additive");
        assert_eq!(a.wall_seconds, 9.0, "cluster finishes with its slowest replica");
        assert_eq!(a.turn_latency.as_ref().unwrap().count(), 2);
    }

    #[test]
    fn merge_into_fresh_is_identity() {
        let mut s = ServingStats::new();
        s.completed_requests = 5;
        s.generated_tokens = 123;
        s.wall_seconds = 2.5;
        s.peak_kv_bytes = 77;
        s.request_latency.as_mut().unwrap().record(0.4);
        s.turn_latency.as_mut().unwrap().record(0.2);
        s.time_to_first_token.as_mut().unwrap().record(0.01);
        let mut merged = ServingStats::new();
        merged.merge(&s);
        assert_eq!(merged, s);
    }

    #[test]
    fn stats_json_has_keys() {
        let mut s = ServingStats::new();
        s.generated_tokens = 10;
        s.wall_seconds = 2.0;
        let v = s.to_json();
        assert_eq!(v.get("generated_tokens").unwrap().as_u64(), Some(10));
        assert_eq!(v.get("throughput_tok_s").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn fraction_below_matches_distribution() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3); // 1ms .. 1s uniform
        }
        let f = h.fraction_below(0.5);
        assert!((f - 0.5).abs() < 0.05, "fraction {f}");
        assert_eq!(h.fraction_below(10.0), 1.0);
        assert!(h.fraction_below(1e-7) < 0.01);
        assert_eq!(Histogram::new().fraction_below(1.0), 1.0, "vacuous SLO");
    }

    #[test]
    fn admission_counters_merge_and_goodput() {
        let mut a = ServingStats::new();
        a.submitted_requests = 10;
        a.rejected_requests = 2;
        a.completed_requests = 8;
        a.wall_seconds = 4.0;
        a.request_latency.as_mut().unwrap().record(0.1);
        a.request_latency.as_mut().unwrap().record(9.0);
        let mut b = ServingStats::new();
        b.submitted_requests = 5;
        b.rejected_requests = 5;
        a.merge(&b);
        assert_eq!(a.submitted_requests, 15);
        assert_eq!(a.rejected_requests, 7);
        // goodput: 2 rps overall, half the samples within a 1s SLO.
        assert!((a.goodput_rps(1.0) - 1.0).abs() < 1e-9);
        let v = a.to_json();
        assert_eq!(v.get("submitted_requests").unwrap().as_u64(), Some(15));
        assert_eq!(v.get("rejected_requests").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn phase_attribution_merges_exactly_and_stays_out_of_json_when_empty() {
        // Empty phases (obs off): no "phases" key — obs-off stats JSON
        // is byte-identical to the pre-obs format.
        let off = ServingStats::new();
        assert!(!off.to_json().to_string_pretty().contains("phases"));
        // Recording grows the per-model table and lands per phase.
        let mut a = ServingStats::new();
        a.record_phases(2, 0.1, 0.2, 0.05, 0.4);
        a.record_phases(0, 0.3, 0.1, 0.0, 0.2);
        assert_eq!(a.phases.len(), 3);
        assert_eq!(a.phases[2].queue.count(), 1);
        assert_eq!(a.phases[1].queue.count(), 0, "untouched model stays empty");
        assert!((a.phases[2].decode.sum() - 0.4).abs() < 1e-12);
        // Merge is position-wise and extends to the longer table.
        let mut b = ServingStats::new();
        b.record_phases(2, 0.7, 0.2, 0.1, 0.3);
        let mut merged = ServingStats::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.phases[2].queue.count(), 2);
        assert!((merged.phases[2].stall.sum() - 0.15).abs() < 1e-12);
        // Identity: merging into fresh stats reproduces the phases too.
        let mut fresh = ServingStats::new();
        fresh.merge(&a);
        assert_eq!(fresh, a);
        // Non-empty phases do show up in the dump, with exact sums.
        let v = a.to_json();
        let phases = v.get("phases").and_then(crate::json::Value::as_arr).expect("phases");
        assert_eq!(phases.len(), 3);
        assert_eq!(phases[2].at(&["queue", "count"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn hit_rate() {
        let mut s = ServingStats::new();
        s.prefill_tokens = 25;
        s.cached_prefill_tokens = 75;
        assert!((s.cache_hit_rate() - 0.75).abs() < 1e-9);
    }
}
