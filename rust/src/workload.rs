//! Agentic workload generation (HotPotQA-agent stand-in, paper A.2.3).
//!
//! A *workflow* is one multi-turn agent episode (e.g. answering one
//! HotPotQA question).  Each turn sends the full accumulated context to
//! one of the N task-specialized models, generates `gen_len` tokens
//! (thought + action), then a tool observation is appended.  Arrivals
//! are Poisson at the configured QPS; routing is round-robin (§4.3) or
//! random-skewed (Appendix F).  Reflexion episodes append a
//! self-evaluation turn after each trial and carry an episodic-memory
//! suffix, growing context faster.

use crate::config::{AgentPattern, Routing, WorkloadConfig};
use crate::rng::Rng;
use crate::tokens::TokenBuf;

/// One turn of a workflow, as planned by the generator.
#[derive(Debug, Clone)]
pub struct TurnSpec {
    /// Model (LoRA adapter) this turn is routed to.
    pub model_id: usize,
    /// Tokens to generate this turn.
    pub gen_len: usize,
    /// Observation tokens appended to the context after the turn.
    pub obs: Vec<u32>,
    /// Tool-execution latency before this turn becomes runnable
    /// (seconds) — ReAct's act->observation gap.  0 for the first turn.
    pub think_s: f64,
    /// True for Reflexion's self-evaluation turns.
    pub is_reflection: bool,
}

/// One agent episode.
#[derive(Debug, Clone)]
pub struct Workflow {
    /// Stable workflow id (generation order).
    pub id: u64,
    /// Arrival time (seconds from run start).
    pub arrival: f64,
    /// Initial prompt: question + system/tool instructions.  A shared
    /// buffer: the engine seeds the workflow context from it with an
    /// O(1) clone (see `tokens::TokenBuf`).
    pub prompt: TokenBuf,
    /// The planned turns, in execution order.
    pub turns: Vec<TurnSpec>,
}

impl Workflow {
    /// Tokens this workflow will generate across all its turns.
    pub fn total_gen_tokens(&self) -> usize {
        self.turns.iter().map(|t| t.gen_len).sum()
    }
}

/// Unique-ish content tokens so distinct workflows don't alias in the
/// prefix cache, while all workflows share a common system prefix (as
/// real agent prompts do).  Crate-visible: the open-loop session
/// stream (`serve::openloop`) draws its fresh prompt bodies from the
/// same distribution.
pub(crate) fn content_tokens(rng: &mut Rng, n: usize) -> Vec<u32> {
    (0..n).map(|_| 32 + rng.below(1900) as u32).collect()
}

/// A fixed system prompt shared by every workflow (instructions + tool
/// schema) — the classic prefix-caching opportunity.
pub fn system_prefix(len: usize) -> Vec<u32> {
    (0..len).map(|i| 32 + ((i as u32 * 2654435761) % 1900)).collect()
}

/// Tokens of shared system prefix every workflow opens with.
pub const SYSTEM_PREFIX_LEN: usize = 48;

/// Generate the full workload `cfg` describes (deterministic per seed).
pub fn generate(cfg: &WorkloadConfig) -> Vec<Workflow> {
    let mut rng = Rng::new(cfg.seed);
    let mut arrival = 0.0f64;
    let sys = system_prefix(SYSTEM_PREFIX_LEN);
    let mut out = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests {
        arrival += rng.exp(cfg.qps);
        let prompt_len = rng.len_sample(cfg.prompt_mean, cfg.prompt_std, 8, 4096) as usize;
        let mut prompt = sys.clone();
        prompt.extend(content_tokens(&mut rng, prompt_len));

        let turns = plan_turns(&mut rng, cfg);
        out.push(Workflow { id: id as u64, arrival, prompt: prompt.into(), turns });
    }
    out
}

/// Plan one workflow's turn sequence: trial count, per-slot model
/// routing, generation/observation lengths and think times.  Shared by
/// the closed-loop [`generate`] above and the open-loop session stream
/// (`serve::openloop`) — both consume the rng in exactly this order,
/// which keeps `generate` bit-identical to its pre-extraction output
/// (the workload determinism tests and the engine's frozen-legacy
/// differential pin it).
pub(crate) fn plan_turns(rng: &mut Rng, cfg: &WorkloadConfig) -> Vec<TurnSpec> {
    let trials = rng.range(cfg.turns_min, cfg.turns_max) as usize;
    let mut turns = Vec::new();
    let order = plan_routing(rng, cfg, trials * 2 + 2);
    let mut slot = 0;
    for _trial in 0..trials {
        let gen_len = rng.len_sample(cfg.output_mean, cfg.output_std, 4, 512) as usize;
        let obs_len = rng.len_sample(cfg.obs_mean, cfg.obs_std, 2, 256) as usize;
        turns.push(TurnSpec {
            model_id: order[slot],
            gen_len,
            obs: content_tokens(rng, obs_len),
            think_s: if turns.is_empty() {
                0.0
            } else {
                rng.gaussian(cfg.think_mean, cfg.think_std).max(0.0)
            },
            is_reflection: false,
        });
        slot += 1;
        if cfg.pattern == AgentPattern::Reflexion {
            // Self-evaluation turn: short verdict + episodic memory
            // appended to the context (grows the shared prefix).
            let refl_len =
                rng.len_sample(cfg.output_mean * 0.5, cfg.output_std * 0.5, 4, 256) as usize;
            let memory = rng.len_sample(cfg.obs_mean * 1.5, cfg.obs_std, 4, 256) as usize;
            turns.push(TurnSpec {
                model_id: order[slot],
                gen_len: refl_len,
                obs: content_tokens(rng, memory),
                think_s: rng.gaussian(cfg.think_mean * 0.3, cfg.think_std * 0.3).max(0.0),
                is_reflection: true,
            });
            slot += 1;
        }
    }
    turns
}

/// Model id per turn slot.
fn plan_routing(rng: &mut Rng, cfg: &WorkloadConfig, slots: usize) -> Vec<usize> {
    match cfg.routing {
        Routing::RoundRobin => {
            let start = rng.below(cfg.n_models as u64) as usize;
            (0..slots).map(|k| (start + k) % cfg.n_models).collect()
        }
        Routing::Skewed { hot_p_percent } => {
            // Appendix F: one hot agent takes hot_p% of turns; the rest
            // share the remainder uniformly, order randomized.
            let hot = rng.below(cfg.n_models as u64) as usize;
            let p = hot_p_percent as f64 / 100.0;
            (0..slots)
                .map(|_| {
                    if cfg.n_models == 1 || rng.bool(p) {
                        hot
                    } else {
                        let mut m = rng.below(cfg.n_models as u64 - 1) as usize;
                        if m >= hot {
                            m += 1;
                        }
                        m
                    }
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn cfg() -> WorkloadConfig {
        WorkloadConfig { n_requests: 64, ..Default::default() }
    }

    #[test]
    fn deterministic_for_seed() {
        let a = generate(&cfg());
        let b = generate(&cfg());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.turns.len(), y.turns.len());
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_close() {
        let mut c = cfg();
        c.n_requests = 2000;
        c.qps = 2.0;
        let wf = generate(&c);
        let mut prev = 0.0;
        for w in &wf {
            assert!(w.arrival >= prev);
            prev = w.arrival;
        }
        let rate = wf.len() as f64 / prev;
        assert!((rate - 2.0).abs() < 0.2, "rate {rate}");
    }

    #[test]
    fn all_share_system_prefix() {
        let wf = generate(&cfg());
        let sys = system_prefix(SYSTEM_PREFIX_LEN);
        for w in &wf {
            assert_eq!(&w.prompt[..SYSTEM_PREFIX_LEN], &sys[..]);
        }
        // but bodies differ
        assert_ne!(wf[0].prompt, wf[1].prompt);
    }

    #[test]
    fn round_robin_cycles_models() {
        let mut c = cfg();
        c.n_models = 4;
        c.turns_min = 4;
        c.turns_max = 4;
        let wf = generate(&c);
        for w in &wf {
            let ids: Vec<usize> = w.turns.iter().map(|t| t.model_id).collect();
            for k in 1..ids.len() {
                assert_eq!(ids[k], (ids[k - 1] + 1) % 4);
            }
        }
    }

    #[test]
    fn skewed_routing_respects_hot_probability() {
        let mut c = cfg();
        c.n_models = 8;
        c.n_requests = 400;
        c.routing = Routing::Skewed { hot_p_percent: 50 };
        let wf = generate(&c);
        let mut counts = vec![0usize; 8];
        let mut total = 0;
        for w in &wf {
            for t in &w.turns {
                counts[t.model_id] += 1;
                total += 1;
            }
        }
        let hot = *counts.iter().max().unwrap() as f64 / total as f64;
        // per-workflow hot agent varies; global distribution flattens,
        // but every model must be used and no single model exceeds ~65%.
        assert!(counts.iter().all(|&c| c > 0));
        assert!(hot < 0.65, "hot share {hot}");
    }

    #[test]
    fn reflexion_has_reflection_turns_and_more_of_them() {
        let mut c = cfg();
        c.pattern = AgentPattern::Reflexion;
        let wf_r = generate(&c);
        let c2 = cfg();
        let wf_a = generate(&c2);
        let avg_r: f64 =
            wf_r.iter().map(|w| w.turns.len()).sum::<usize>() as f64 / wf_r.len() as f64;
        let avg_a: f64 =
            wf_a.iter().map(|w| w.turns.len()).sum::<usize>() as f64 / wf_a.len() as f64;
        assert!(avg_r > avg_a * 1.8, "{avg_r} vs {avg_a}");
        assert!(wf_r.iter().any(|w| w.turns.iter().any(|t| t.is_reflection)));
    }

    #[test]
    fn think_time_zero_for_first_turn_only() {
        let wf = generate(&cfg());
        for w in &wf {
            assert_eq!(w.turns[0].think_s, 0.0);
            for t in &w.turns[1..] {
                assert!(t.think_s >= 0.0);
            }
        }
        // with the default config, later turns mostly have latency
        let any_positive = wf
            .iter()
            .flat_map(|w| &w.turns[1..])
            .any(|t| t.think_s > 0.5);
        assert!(any_positive);
    }

    #[test]
    fn token_ranges_valid() {
        let wf = generate(&cfg());
        for w in &wf {
            for &t in w.prompt.iter() {
                assert!((32..2048).contains(&t));
            }
            for turn in &w.turns {
                assert!(turn.gen_len >= 4);
                for &t in &turn.obs {
                    assert!((32..2048).contains(&t));
                }
            }
        }
    }
}
