//! Serving-trace record / replay.
//!
//! A run's per-turn timeline (admission, prefill, completion, cache
//! hits) serialized to JSON — useful for debugging scheduler decisions,
//! for regression-diffing two engine versions on an identical workload,
//! and for feeding external analysis (the paper's figures are latency
//! distributions over exactly these events).

use crate::json::{self, Value};

/// One turn-level event in a serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct TurnEvent {
    /// Workflow the turn belongs to.
    pub wf_id: u64,
    /// Turn position within the workflow.
    pub turn_idx: usize,
    /// Model (LoRA adapter) the turn was routed to.
    pub model_id: usize,
    /// When the turn became runnable.
    pub ready_at: f64,
    /// When the turn retired.
    pub completed_at: f64,
    /// Prompt tokens the turn was admitted with.
    pub prompt_tokens: usize,
    /// Prompt tokens served from the prefix cache.
    pub cached_tokens: usize,
    /// Tokens the turn generated.
    pub generated_tokens: usize,
    /// Seconds spent waiting in the scheduler queue before admission.
    /// Populated only under `--obs on`; 0.0 otherwise (and when reading
    /// trace files written before the breakdown existed).
    pub queue_wait: f64,
    /// Seconds of prefill compute (atomic, or first to last chunk).
    /// Obs-only, like [`TurnEvent::queue_wait`].
    pub prefill_time: f64,
    /// Seconds of transfer time compute did not hide (serial restores,
    /// swap-ins, gated overlap windows).  Obs-only.
    pub stall_time: f64,
}

impl TurnEvent {
    /// Turn latency in seconds (ready to retired).
    pub fn latency(&self) -> f64 {
        self.completed_at - self.ready_at
    }

    /// Serialize the event for trace files.  The phase-breakdown keys
    /// are emitted only when any of them is non-zero, so obs-off traces
    /// stay byte-identical to the pre-breakdown format.
    pub fn to_json(&self) -> Value {
        let mut entries = vec![
            ("wf", json::num(self.wf_id as f64)),
            ("turn", json::num(self.turn_idx as f64)),
            ("model", json::num(self.model_id as f64)),
            ("ready_at", json::num(self.ready_at)),
            ("completed_at", json::num(self.completed_at)),
            ("prompt_tokens", json::num(self.prompt_tokens as f64)),
            ("cached_tokens", json::num(self.cached_tokens as f64)),
            ("generated_tokens", json::num(self.generated_tokens as f64)),
        ];
        if self.queue_wait != 0.0 || self.prefill_time != 0.0 || self.stall_time != 0.0 {
            entries.push(("queue_wait", json::num(self.queue_wait)));
            entries.push(("prefill_time", json::num(self.prefill_time)));
            entries.push(("stall_time", json::num(self.stall_time)));
        }
        json::obj(entries)
    }

    /// Inverse of [`TurnEvent::to_json`] (None on malformed input).
    /// Backward compatible: trace files that predate the phase
    /// breakdown simply lack the keys, which read back as 0.0.
    pub fn from_json(v: &Value) -> Option<TurnEvent> {
        let opt = |key: &str| v.get(key).and_then(Value::as_f64).unwrap_or(0.0);
        Some(TurnEvent {
            wf_id: v.get("wf")?.as_u64()?,
            turn_idx: v.get("turn")?.as_usize()?,
            model_id: v.get("model")?.as_usize()?,
            ready_at: v.get("ready_at")?.as_f64()?,
            completed_at: v.get("completed_at")?.as_f64()?,
            prompt_tokens: v.get("prompt_tokens")?.as_usize()?,
            cached_tokens: v.get("cached_tokens")?.as_usize()?,
            generated_tokens: v.get("generated_tokens")?.as_usize()?,
            queue_wait: opt("queue_wait"),
            prefill_time: opt("prefill_time"),
            stall_time: opt("stall_time"),
        })
    }
}

/// Append-only trace of one serving run.
#[derive(Debug, Default)]
pub struct Trace {
    /// Events in recording order (completion order within one engine;
    /// cluster runs reconcile replica traces into completion order).
    pub events: Vec<TurnEvent>,
}

impl Trace {
    /// An empty trace.
    pub fn new() -> Self {
        Trace::default()
    }

    /// Append one event.
    pub fn record(&mut self, e: TurnEvent) {
        self.events.push(e);
    }

    /// P-quantile of turn latency across the trace.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        if self.events.is_empty() {
            return 0.0;
        }
        let mut lats: Vec<f64> = self.events.iter().map(TurnEvent::latency).collect();
        lats.sort_by(f64::total_cmp);
        let idx = ((lats.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
        lats[idx]
    }

    /// Per-model turn counts (routing-skew verification).
    pub fn per_model_counts(&self) -> Vec<(usize, usize)> {
        let mut counts: std::collections::BTreeMap<usize, usize> = Default::default();
        for e in &self.events {
            *counts.entry(e.model_id).or_default() += 1;
        }
        counts.into_iter().collect()
    }

    /// Serialize the whole trace.
    pub fn to_json(&self) -> Value {
        json::obj(vec![(
            "events",
            Value::Arr(self.events.iter().map(TurnEvent::to_json).collect()),
        )])
    }

    /// Write the trace to `path` as pretty JSON.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Read a trace previously written by [`Trace::save`].
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("trace: {e}"))?;
        let events = v
            .get("events")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("trace: no events"))?
            .iter()
            .filter_map(TurnEvent::from_json)
            .collect();
        Ok(Trace { events })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(wf: u64, lat: f64, model: usize) -> TurnEvent {
        TurnEvent {
            wf_id: wf,
            turn_idx: 0,
            model_id: model,
            ready_at: 1.0,
            completed_at: 1.0 + lat,
            prompt_tokens: 10,
            cached_tokens: 4,
            generated_tokens: 8,
            queue_wait: 0.0,
            prefill_time: 0.0,
            stall_time: 0.0,
        }
    }

    #[test]
    fn quantiles() {
        let mut t = Trace::new();
        for i in 1..=100 {
            t.record(ev(i, i as f64 * 0.01, 0));
        }
        assert!((t.latency_quantile(0.5) - 0.5).abs() < 0.02);
        assert!((t.latency_quantile(0.95) - 0.95).abs() < 0.02);
        assert_eq!(Trace::new().latency_quantile(0.95), 0.0);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = Trace::new();
        t.record(ev(1, 0.5, 2));
        t.record(ev(2, 0.7, 3));
        let v = t.to_json();
        let parsed = Value::parse(&v.to_string()).unwrap();
        let back: Vec<TurnEvent> = parsed
            .get("events")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(TurnEvent::from_json)
            .collect();
        assert_eq!(back, t.events);
    }

    #[test]
    fn file_roundtrip() {
        let mut t = Trace::new();
        t.record(ev(1, 0.5, 0));
        let path = std::env::temp_dir().join(format!("icarus_trace_{}.json", std::process::id()));
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.events, t.events);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn breakdown_fields_round_trip_and_stay_out_of_legacy_shape() {
        // Zero breakdown (obs off): the JSON shape is the pre-breakdown
        // one — no new keys — and reads back as zeroes.
        let legacy = ev(1, 0.5, 2);
        let dump = legacy.to_json().to_string_pretty();
        assert!(!dump.contains("queue_wait") && !dump.contains("stall_time"));
        assert_eq!(TurnEvent::from_json(&legacy.to_json()).unwrap(), legacy);
        // Non-zero breakdown round-trips exactly.
        let mut full = ev(2, 0.9, 0);
        full.queue_wait = 0.125;
        full.prefill_time = 0.5;
        full.stall_time = 0.0625;
        let dump = full.to_json().to_string_pretty();
        assert!(dump.contains("queue_wait") && dump.contains("prefill_time"));
        assert_eq!(TurnEvent::from_json(&full.to_json()).unwrap(), full);
    }

    #[test]
    fn from_json_accepts_pre_breakdown_trace_files() {
        // A literal event as PR ≤ 9 trace files wrote it: no breakdown
        // keys at all.  Must parse, with the new fields defaulting to 0.
        let old = Value::parse(
            r#"{"wf": 3, "turn": 1, "model": 2, "ready_at": 1.5, "completed_at": 2.25,
                "prompt_tokens": 64, "cached_tokens": 16, "generated_tokens": 32}"#,
        )
        .unwrap();
        let e = TurnEvent::from_json(&old).expect("legacy shape parses");
        assert_eq!(e.wf_id, 3);
        assert_eq!(e.queue_wait, 0.0);
        assert_eq!(e.prefill_time, 0.0);
        assert_eq!(e.stall_time, 0.0);
    }

    #[test]
    fn per_model_counts() {
        let mut t = Trace::new();
        t.record(ev(1, 0.1, 0));
        t.record(ev(2, 0.1, 0));
        t.record(ev(3, 0.1, 1));
        assert_eq!(t.per_model_counts(), vec![(0, 2), (1, 1)]);
    }
}
