//! Unified observability: deterministic span recording, Chrome
//! trace-event (Perfetto) export, and a failure flight recorder.
//!
//! The engine is a discrete-event loop over a *virtual* clock, so every
//! span here is keyed by virtual time: two runs with the same seed and
//! config produce byte-identical exports, regardless of wall-clock
//! jitter, thread scheduling, or `--store-shards`.  That determinism is
//! pinned by `prop_obs_deterministic`; the converse — that recording
//! *nothing* costs nothing — is pinned by `prop_obs_off_bit_identical`
//! (the recorder is an `Option` on the engine, `None` unless `--obs on`,
//! exactly like the trace/store/overlap handles).
//!
//! One [`ObsRecorder`] per replica.  Spans fall on a small set of
//! per-replica tracks:
//!
//! | track | contents | event shape |
//! |-------|----------|-------------|
//! | compute | prefill + decode steps (serial in virtual time) | `B`/`E` pairs |
//! | queue | per-sequence wait from `ready_at` to admission | `X` (may overlap) |
//! | transfer | store restores, swap-ins, overlap windows | `X` |
//! | handoff | disagg prefill→decode handoff horizons | `X` |
//! | write_back | store publish visibility windows | `X` |
//!
//! plus `C` counter samples (queue depth, running batch, cumulative
//! restored bytes) — all engine-local values, never mid-run samples of
//! shared-store gauges, which would be interleaving-dependent.
//!
//! The flight recorder is the tail of the span log: when a run fails
//! (e.g. the store reports `lock_poisoned`), the last
//! [`FLIGHT_SPANS`] spans per replica are dumped as JSON so the
//! failure's immediate history is inspectable without a full trace.

use std::collections::HashMap;

use crate::json::{self, Value};

/// Spans kept per replica by the failure flight recorder (the tail of
/// the span log dumped on run failure).
pub const FLIGHT_SPANS: usize = 256;

/// Lifecycle phase a span covers.  `as_str` names are the stable
/// vocabulary shared by the Perfetto export, `tools/check_trace.py`,
/// and the docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Waiting in the scheduler queue: `ready_at` → admission pick.
    Queue,
    /// A prefill step (atomic, or one fused chunked-prefill step).
    Prefill,
    /// A modeled data movement: store restore, swap-in/out, or an
    /// overlap transfer window.
    Transfer,
    /// Disaggregated prefill→decode handoff: respond → admissible.
    Handoff,
    /// A decode step over the running batch.
    Decode,
    /// Store publish: submit → cross-replica visibility horizon.
    WriteBack,
}

impl SpanKind {
    /// Stable lowercase name used in exports and validators.
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Queue => "queue",
            SpanKind::Prefill => "prefill",
            SpanKind::Transfer => "transfer",
            SpanKind::Handoff => "handoff",
            SpanKind::Decode => "decode",
            SpanKind::WriteBack => "write_back",
        }
    }

    /// Per-replica track (Chrome `tid`) this kind renders on.  Compute
    /// steps share track 0 — they are serial in virtual time, so the
    /// lane nests `B`/`E` pairs without overlap; the other kinds get a
    /// track each and render as `X` complete events (which may overlap
    /// legitimately, e.g. many queued sequences).
    pub fn track(self) -> u64 {
        match self {
            SpanKind::Prefill | SpanKind::Decode => 0,
            SpanKind::Queue => 1,
            SpanKind::Transfer => 2,
            SpanKind::Handoff => 3,
            SpanKind::WriteBack => 4,
        }
    }
}

/// Chrome `tid` of the counter track (separate from every span track).
const COUNTER_TRACK: u64 = 5;

/// One recorded span, in virtual seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Phase this span covers.
    pub kind: SpanKind,
    /// Virtual start time (seconds).
    pub start: f64,
    /// Virtual end time (seconds); `>= start`.
    pub end: f64,
    /// Sequence id the span belongs to, or -1 for batch-level spans.
    pub seq: i64,
    /// Model id, or -1 when the span spans models (batch-level decode).
    pub model: i64,
    /// Tokens the span moved or computed (0 when not meaningful).
    pub tokens: u64,
}

impl Span {
    /// JSON form used by the flight-recorder dump.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("kind", json::s(self.kind.as_str())),
            ("start", json::num(self.start)),
            ("end", json::num(self.end)),
            ("seq", json::num(self.seq as f64)),
            ("model", json::num(self.model as f64)),
            ("tokens", json::num(self.tokens as f64)),
        ])
    }
}

/// One counter sample on a replica's counter track.
#[derive(Debug, Clone, PartialEq)]
pub struct CounterSample {
    /// Virtual sample time (seconds).
    pub t: f64,
    /// Counter name (stable static vocabulary).
    pub name: &'static str,
    /// Sampled value.
    pub value: f64,
}

/// Per-sequence phase bookkeeping, kept in a side table inside the
/// recorder (not on `RunningSeq`) so the obs-off engine layout — and
/// the frozen `legacy_engine` differential — is untouched.
#[derive(Debug, Clone, PartialEq)]
pub struct SeqObs {
    /// Model the sequence runs on.
    pub model_id: usize,
    /// Arrival time of the turn (virtual seconds).
    pub ready_at: f64,
    /// Time the scheduler picked the turn for admission (before any
    /// admission-side transfer is charged).
    pub picked_at: f64,
    /// First virtual instant of prefill compute.
    pub prefill_start: f64,
    /// Virtual instant the last prompt token was encoded (first token
    /// emitted); decode residency runs from here to completion.
    pub prefill_end: f64,
    /// Transfer time charged to this sequence that compute did not
    /// hide: serial restores, swap-ins, and the gated share of overlap
    /// windows.
    pub stall: f64,
}

/// Deterministic per-replica span/counter recorder.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsRecorder {
    replica: usize,
    spans: Vec<Span>,
    counters: Vec<CounterSample>,
    seq: HashMap<u64, SeqObs>,
}

impl ObsRecorder {
    /// Fresh recorder for `replica`'s lane.
    pub fn new(replica: usize) -> Self {
        ObsRecorder { replica, spans: Vec::new(), counters: Vec::new(), seq: HashMap::new() }
    }

    /// Replica lane this recorder feeds.
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// Re-key the lane (the cluster assigns replica ids after engine
    /// construction).
    pub fn set_replica(&mut self, replica: usize) {
        self.replica = replica;
    }

    /// Record one span.  Zero-length spans are kept (they still mark an
    /// instant); negative lengths are clamped to zero.
    pub fn span(
        &mut self,
        kind: SpanKind,
        start: f64,
        end: f64,
        seq: i64,
        model: i64,
        tokens: u64,
    ) {
        self.spans.push(Span { kind, start, end: end.max(start), seq, model, tokens });
    }

    /// Record one counter sample.
    pub fn counter(&mut self, t: f64, name: &'static str, value: f64) {
        self.counters.push(CounterSample { t, name, value });
    }

    /// Open per-sequence bookkeeping at admission pick time and emit
    /// the queue span (`ready_at` → `picked_at`).
    pub fn begin_seq(&mut self, seq_id: u64, model_id: usize, ready_at: f64, picked_at: f64) {
        self.span(SpanKind::Queue, ready_at, picked_at, seq_id as i64, model_id as i64, 0);
        self.seq.insert(
            seq_id,
            SeqObs {
                model_id,
                ready_at,
                picked_at,
                prefill_start: picked_at,
                prefill_end: picked_at,
                stall: 0.0,
            },
        );
    }

    /// Mutable view of a sequence's bookkeeping (None once finished, or
    /// for sequences admitted before `--obs` — impossible in practice).
    pub fn seq_mut(&mut self, seq_id: u64) -> Option<&mut SeqObs> {
        self.seq.get_mut(&seq_id)
    }

    /// Close out a sequence's bookkeeping, returning it for phase
    /// attribution.
    pub fn finish_seq(&mut self, seq_id: u64) -> Option<SeqObs> {
        self.seq.remove(&seq_id)
    }

    /// All recorded spans, in emission (virtual-time) order.
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// All recorded counter samples, in emission order.
    pub fn counters(&self) -> &[CounterSample] {
        &self.counters
    }
}

/// Event-phase sort rank: metadata first, then `E` before `B` so two
/// back-to-back compute spans sharing a boundary timestamp close the
/// old span before opening the new one (keeps lane depth ≤ 1 for
/// validators and viewers alike).
fn rank(ph: &str) -> u8 {
    match ph {
        "M" => 0,
        "E" => 1,
        "B" => 2,
        "X" => 3,
        _ => 4, // "C"
    }
}

/// Render recorders as one Chrome trace-event / Perfetto JSON document:
/// one process (`pid`) per replica, the track layout described in the
/// module docs, timestamps in microseconds of virtual time.  Events are
/// explicitly sorted (ts, pid, tid, phase rank) so the export is
/// byte-deterministic.
pub fn export_chrome_trace(recorders: &[ObsRecorder]) -> Value {
    // (ts_us, pid, tid, rank, event)
    let mut events: Vec<(f64, u64, u64, u8, Value)> = Vec::new();
    let meta = |pid: u64, tid: u64, what: &str, name: &str| {
        json::obj(vec![
            ("ph", json::s("M")),
            ("pid", json::num(pid as f64)),
            ("tid", json::num(tid as f64)),
            ("name", json::s(what)),
            ("args", json::obj(vec![("name", json::s(name))])),
        ])
    };
    for r in recorders {
        let pid = r.replica() as u64;
        events.push((0.0, pid, 0, 0, meta(pid, 0, "process_name", &format!("replica {pid}"))));
        for (tid, name) in
            [(0, "compute"), (1, "queue"), (2, "transfer"), (3, "handoff"), (4, "write_back")]
        {
            events.push((0.0, pid, tid, 0, meta(pid, tid, "thread_name", name)));
        }
        for sp in &r.spans {
            let tid = sp.kind.track();
            let ts = sp.start * 1e6;
            let dur = (sp.end - sp.start) * 1e6;
            let args = json::obj(vec![
                ("seq", json::num(sp.seq as f64)),
                ("model", json::num(sp.model as f64)),
                ("tokens", json::num(sp.tokens as f64)),
            ]);
            // Zero-width compute spans render as `X` (dur 0): a `B`/`E`
            // pair at one timestamp would sort E-before-B (the rank that
            // keeps *adjacent* spans' boundaries closed) and unbalance
            // the lane.
            let be = tid == 0 && sp.end > sp.start;
            let base = vec![
                ("ph", json::s(if be { "B" } else { "X" })),
                ("pid", json::num(pid as f64)),
                ("tid", json::num(tid as f64)),
                ("ts", json::num(ts)),
                ("name", json::s(sp.kind.as_str())),
                ("args", args),
            ];
            if be {
                events.push((ts, pid, tid, rank("B"), json::obj(base)));
                events.push((
                    sp.end * 1e6,
                    pid,
                    tid,
                    rank("E"),
                    json::obj(vec![
                        ("ph", json::s("E")),
                        ("pid", json::num(pid as f64)),
                        ("tid", json::num(tid as f64)),
                        ("ts", json::num(sp.end * 1e6)),
                    ]),
                ));
            } else {
                let mut ev = base;
                ev.push(("dur", json::num(dur)));
                events.push((ts, pid, tid, rank("X"), json::obj(ev)));
            }
        }
        for c in &r.counters {
            let ts = c.t * 1e6;
            events.push((
                ts,
                pid,
                COUNTER_TRACK,
                rank("C"),
                json::obj(vec![
                    ("ph", json::s("C")),
                    ("pid", json::num(pid as f64)),
                    ("tid", json::num(COUNTER_TRACK as f64)),
                    ("ts", json::num(ts)),
                    ("name", json::s(c.name)),
                    ("args", json::obj(vec![(c.name, json::num(c.value))])),
                ]),
            ));
        }
    }
    events.sort_by(|a, b| {
        a.0.total_cmp(&b.0)
            .then(a.1.cmp(&b.1))
            .then(a.2.cmp(&b.2))
            .then(a.3.cmp(&b.3))
    });
    json::obj(vec![
        ("displayTimeUnit", json::s("ms")),
        ("traceEvents", Value::Arr(events.into_iter().map(|e| e.4).collect())),
    ])
}

/// The failure flight recording: the last [`FLIGHT_SPANS`] spans per
/// replica, as a JSON document the CLI dumps to disk when a run fails.
pub fn flight_json(recorders: &[ObsRecorder]) -> Value {
    json::obj(vec![(
        "replicas",
        Value::Arr(
            recorders
                .iter()
                .map(|r| {
                    let tail = &r.spans[r.spans.len().saturating_sub(FLIGHT_SPANS)..];
                    json::obj(vec![
                        ("replica", json::num(r.replica() as f64)),
                        ("spans", Value::Arr(tail.iter().map(Span::to_json).collect())),
                    ])
                })
                .collect(),
        ),
    )])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_recorder() -> ObsRecorder {
        let mut r = ObsRecorder::new(0);
        r.begin_seq(7, 2, 0.5, 1.0);
        r.span(SpanKind::Transfer, 1.0, 1.25, 7, 2, 128);
        r.seq_mut(7).unwrap().stall += 0.25;
        r.span(SpanKind::Prefill, 1.25, 2.0, 7, 2, 512);
        r.span(SpanKind::Decode, 2.0, 2.5, -1, -1, 4);
        r.span(SpanKind::WriteBack, 2.5, 2.75, 7, 2, 512);
        r.span(SpanKind::Handoff, 2.5, 2.6, 7, 2, 0);
        r.counter(2.0, "queue_depth", 3.0);
        r
    }

    #[test]
    fn seq_bookkeeping_round_trips() {
        let mut r = sample_recorder();
        let s = r.finish_seq(7).expect("tracked");
        assert_eq!(s.model_id, 2);
        assert_eq!(s.ready_at, 0.5);
        assert_eq!(s.picked_at, 1.0);
        assert_eq!(s.stall, 0.25);
        assert!(r.finish_seq(7).is_none(), "finish removes");
        // The queue span was emitted at begin_seq.
        assert!(r.spans().iter().any(|sp| sp.kind == SpanKind::Queue && sp.start == 0.5));
    }

    #[test]
    fn export_is_sorted_balanced_and_deterministic() {
        let r = sample_recorder();
        let doc = export_chrome_trace(std::slice::from_ref(&r));
        let events = doc.get("traceEvents").and_then(Value::as_arr).expect("traceEvents");
        let mut last_ts = f64::NEG_INFINITY;
        let mut depth = 0i64;
        let (mut b, mut e) = (0, 0);
        for ev in events {
            let ts = ev.get("ts").and_then(Value::as_f64).unwrap_or(0.0);
            assert!(ts >= last_ts, "ts monotone across the export");
            last_ts = ts;
            match ev.get("ph").and_then(Value::as_str).unwrap() {
                "B" => {
                    b += 1;
                    depth += 1;
                    assert!(depth <= 1, "compute lane must not self-overlap");
                }
                "E" => {
                    e += 1;
                    depth -= 1;
                    assert!(depth >= 0);
                }
                "X" => {
                    assert!(ev.get("dur").and_then(Value::as_f64).unwrap() >= 0.0);
                }
                _ => {}
            }
        }
        assert_eq!(b, e, "B/E balanced");
        assert_eq!(b, 2, "prefill + decode compute spans");
        // Byte determinism: same recorder, same document.
        let again = export_chrome_trace(std::slice::from_ref(&r));
        assert_eq!(doc.to_string_pretty(), again.to_string_pretty());
    }

    #[test]
    fn zero_width_compute_spans_do_not_unbalance_the_lane() {
        let mut r = ObsRecorder::new(0);
        r.span(SpanKind::Prefill, 1.0, 1.0, 7, 0, 0);
        let doc = export_chrome_trace(std::slice::from_ref(&r));
        let text = doc.to_string_pretty();
        assert!(!text.contains("\"B\"") && !text.contains("\"E\""));
        let events = doc.get("traceEvents").and_then(Value::as_arr).unwrap();
        let x = events
            .iter()
            .find(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
            .expect("zero-width span exported as X");
        assert_eq!(x.get("dur").and_then(Value::as_f64), Some(0.0));
        assert_eq!(x.get("tid").and_then(Value::as_f64), Some(0.0));
    }

    #[test]
    fn export_names_every_kind_and_lane() {
        let r = sample_recorder();
        let text = export_chrome_trace(std::slice::from_ref(&r)).to_string_pretty();
        for kind in ["queue", "prefill", "transfer", "handoff", "decode", "write_back"] {
            assert!(text.contains(&format!("\"name\": \"{kind}\"")), "missing {kind}");
        }
        assert!(text.contains("replica 0"), "process lane named");
        assert!(text.contains("queue_depth"), "counter track present");
    }

    #[test]
    fn flight_ring_is_bounded_to_the_tail() {
        let mut r = ObsRecorder::new(3);
        for i in 0..(FLIGHT_SPANS + 50) {
            r.span(SpanKind::Decode, i as f64, i as f64 + 0.5, -1, -1, 1);
        }
        let doc = flight_json(std::slice::from_ref(&r));
        let spans = doc
            .at(&["replicas"])
            .and_then(Value::as_arr)
            .and_then(|rs| rs[0].get("spans"))
            .and_then(Value::as_arr)
            .expect("spans");
        assert_eq!(spans.len(), FLIGHT_SPANS);
        // The ring keeps the *most recent* spans.
        let first = spans[0].get("start").and_then(Value::as_f64).unwrap();
        assert_eq!(first, 50.0);
    }
}
