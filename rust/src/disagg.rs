//! Cross-replica plumbing for disaggregated prefill/decode serving
//! (`--disagg on`).
//!
//! In disaggregated mode the cluster's replicas are heterogeneous:
//! *prefill* replicas run chunked prefill to completion and publish the
//! finished prefix into the shared [`TieredStore`](crate::store::TieredStore)
//! (write-through, fence-stamped), *decode* replicas own the workflows
//! and run decode batches, restoring handed-off prefixes over the
//! modeled host/PCIe path.  This module is the edge between them: typed
//! request/response messages, one mailbox per replica, and the
//! termination protocol.
//!
//! ## Virtual-time causality
//!
//! Replicas advance independent virtual clocks bounded by the
//! [`ClockFence`](crate::store::ClockFence).  The handoff edge keeps
//! causality two ways:
//!
//!   * a [`PrefillResponse`] carries `admissible_at` — the store
//!     visibility horizon of the published prefix — and the decode
//!     replica surfaces the turn only once its own clock passes it, so
//!     a handoff block is never restored before its publish is visible;
//!   * a replica with nothing runnable that is *waiting on the other
//!     side* (a decode replica with prefills in flight, a prefill
//!     replica with an empty backlog) parks its fence clock
//!     ([`crate::store::StoreHandle::finish`]) and blocks on its
//!     mailbox, so the waited-on replica is free to advance past the
//!     fence window.  Re-arming the fence happens through the ordinary
//!     per-step `sync`, which blocks the *prober* until laggards catch
//!     up — the property that makes parking safe.
//!
//! Wall-clock delivery order of messages from different senders is not
//! deterministic, so disaggregated runs are schedule-dependent in tie
//! order — the same caveat the shared store already carries for
//! cross-replica LRU state.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::tokens::TokenBuf;

/// Role a cluster replica plays under `--disagg`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaRole {
    /// Runs chunked prefill to completion, publishes KV into the shared
    /// store, and hands sequences off; never decodes.
    Prefill,
    /// Owns workflows and decode batches; prefill work is forwarded to
    /// a prefill replica and re-enters as a store restore.
    Decode,
    /// The homogeneous default: interleaves prefill and decode locally.
    Hybrid,
}

impl ReplicaRole {
    /// Stable lowercase name (used in stats JSON and logs).
    pub fn as_str(self) -> &'static str {
        match self {
            ReplicaRole::Prefill => "prefill",
            ReplicaRole::Decode => "decode",
            ReplicaRole::Hybrid => "hybrid",
        }
    }
}

/// A turn dispatched by a decode replica to a prefill replica.
#[derive(Debug, Clone)]
pub struct PrefillRequest {
    /// Prompt to prefill (shared `Arc` buffer — cheap to clone).
    pub prompt: TokenBuf,
    /// Model the turn runs on.
    pub model_id: usize,
    /// Decode tokens still owed after prefill (carried through opaquely).
    pub remaining_gen: usize,
    /// Workflow index *on the owning decode replica* (opaque here).
    pub wf_idx: usize,
    /// Turn index within the workflow (opaque here).
    pub turn_idx: usize,
    /// When the turn first became runnable on the decode replica — the
    /// latency-clock origin, passed through so TTFT and turn latency
    /// still cover the prefill + handoff window.
    pub ready_at: f64,
    /// Decode replica's virtual clock at dispatch; the prefill replica
    /// starts the turn no earlier than this.
    pub sent_at: f64,
    /// Replica index to send the [`PrefillResponse`] to.
    pub reply_to: usize,
}

/// A finished prefill handed back to the owning decode replica.
#[derive(Debug, Clone)]
pub struct PrefillResponse {
    /// The prefilled prompt (same shared buffer the request carried).
    pub prompt: TokenBuf,
    /// Model the turn runs on.
    pub model_id: usize,
    /// Decode tokens owed.
    pub remaining_gen: usize,
    /// Workflow index on the decode replica (echoed from the request).
    pub wf_idx: usize,
    /// Turn index within the workflow (echoed from the request).
    pub turn_idx: usize,
    /// Original latency-clock origin (echoed from the request).
    pub ready_at: f64,
    /// Virtual time at which the published prefix is visible in the
    /// shared store; the decode replica must not admit (and so not
    /// restore) the turn before its clock passes this.
    pub admissible_at: f64,
}

/// One message on the prefill→decode edge.
#[derive(Debug)]
pub enum Handoff {
    /// Decode → prefill: please prefill this turn.
    Request(PrefillRequest),
    /// Prefill → decode: prefix published, turn is yours again.
    Response(PrefillResponse),
}

struct Mailbox {
    q: Mutex<Vec<Handoff>>,
    cv: Condvar,
}

impl Mailbox {
    fn new() -> Self {
        Mailbox { q: Mutex::new(Vec::new()), cv: Condvar::new() }
    }
}

/// Shared state for one disaggregated cluster run: a mailbox per
/// replica plus the count of turns still owed a prefill (the
/// termination token for prefill replicas, which otherwise cannot know
/// when the last request has been sent).
pub struct DisaggShared {
    mailboxes: Vec<Mailbox>,
    /// Turns not yet prefilled, across the whole run.  Every turn of
    /// every workflow is forwarded exactly once (preemption re-admits
    /// locally), so prefill replicas may exit when this reaches zero
    /// and their backlog is drained.
    remaining: AtomicUsize,
    prefill_replicas: usize,
}

impl DisaggShared {
    /// Build shared state for `replicas` total replicas, the first
    /// `prefill_replicas` of which serve prefill, with `total_turns`
    /// prefills owed across the run.
    pub fn new(replicas: usize, prefill_replicas: usize, total_turns: usize) -> Arc<Self> {
        assert!(prefill_replicas >= 1 && prefill_replicas < replicas);
        Arc::new(DisaggShared {
            mailboxes: (0..replicas).map(|_| Mailbox::new()).collect(),
            remaining: AtomicUsize::new(total_turns),
            prefill_replicas,
        })
    }

    /// Number of prefill-role replicas (indices `0..prefill_replicas`).
    pub fn prefill_replicas(&self) -> usize {
        self.prefill_replicas
    }

    /// Turns still owed a prefill.
    pub fn remaining(&self) -> usize {
        self.remaining.load(Ordering::SeqCst)
    }

    fn push(&self, replica: usize, msg: Handoff) {
        let mb = &self.mailboxes[replica];
        mb.q.lock().expect("mailbox poisoned").push(msg);
        mb.cv.notify_all();
    }

    fn drain(&self, replica: usize) -> Vec<Handoff> {
        let mb = &self.mailboxes[replica];
        std::mem::take(&mut *mb.q.lock().expect("mailbox poisoned"))
    }

    /// Block until mail arrives for `replica`, or — when `wake_on_done`
    /// (prefill replicas) — until the run has no prefills left to send.
    /// Returns the drained mailbox (possibly empty on the done wake).
    fn wait(&self, replica: usize, wake_on_done: bool) -> Vec<Handoff> {
        let mb = &self.mailboxes[replica];
        let mut q = mb.q.lock().expect("mailbox poisoned");
        loop {
            if !q.is_empty() {
                return std::mem::take(&mut *q);
            }
            if wake_on_done && self.remaining.load(Ordering::SeqCst) == 0 {
                return Vec::new();
            }
            q = mb.cv.wait(q).expect("mailbox poisoned");
        }
    }

    /// Record one completed prefill; the final completion wakes every
    /// parked prefill replica so it can observe termination.
    fn complete_one(&self) {
        if self.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            for mb in &self.mailboxes[..self.prefill_replicas] {
                let _g = mb.q.lock().expect("mailbox poisoned");
                mb.cv.notify_all();
            }
        }
    }
}

/// Per-replica view of [`DisaggShared`]: the engine's only interface to
/// the handoff edge.
pub struct DisaggHandle {
    shared: Arc<DisaggShared>,
    replica: usize,
    role: ReplicaRole,
    /// Round-robin cursor over prefill replicas for [`forward`](Self::forward).
    next_prefill: usize,
}

impl DisaggHandle {
    /// Bind `replica` (playing `role`) to the shared edge.
    pub fn new(shared: Arc<DisaggShared>, replica: usize, role: ReplicaRole) -> Self {
        // Start each decode replica's cursor at its own offset so
        // single-workflow bursts from different replicas don't all land
        // on prefill replica 0.
        let next_prefill = replica % shared.prefill_replicas;
        DisaggHandle { shared, replica, role, next_prefill }
    }

    /// This replica's role.
    pub fn role(&self) -> ReplicaRole {
        self.role
    }

    /// This replica's index (the `reply_to` decode replicas stamp on
    /// their requests).
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// Turns still owed a prefill, run-wide.
    pub fn remaining(&self) -> usize {
        self.shared.remaining()
    }

    /// Decode side: dispatch a turn to a prefill replica (round-robin).
    pub fn forward(&mut self, req: PrefillRequest) {
        debug_assert_eq!(self.role, ReplicaRole::Decode);
        let target = self.next_prefill;
        self.next_prefill = (self.next_prefill + 1) % self.shared.prefill_replicas;
        self.shared.push(target, Handoff::Request(req));
    }

    /// Prefill side: hand a finished prefix back to `to` and retire one
    /// unit of the run-wide prefill debt.
    pub fn respond(&self, to: usize, resp: PrefillResponse) {
        debug_assert_eq!(self.role, ReplicaRole::Prefill);
        self.shared.push(to, Handoff::Response(resp));
        self.shared.complete_one();
    }

    /// Non-blocking drain of this replica's mailbox.
    pub fn drain(&self) -> Vec<Handoff> {
        self.shared.drain(self.replica)
    }

    /// Block until mail arrives (prefill replicas also wake, possibly
    /// empty-handed, when no prefills remain run-wide).  Callers must
    /// park their fence clock first — see the module docs.
    pub fn wait(&self) -> Vec<Handoff> {
        self.shared.wait(self.replica, self.role == ReplicaRole::Prefill)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(reply_to: usize) -> PrefillRequest {
        PrefillRequest {
            prompt: TokenBuf::from_vec(vec![1, 2, 3]),
            model_id: 0,
            remaining_gen: 4,
            wf_idx: 7,
            turn_idx: 0,
            ready_at: 0.5,
            sent_at: 0.5,
            reply_to,
        }
    }

    #[test]
    fn round_trip_and_round_robin() {
        let shared = DisaggShared::new(4, 2, 3);
        let mut d = DisaggHandle::new(Arc::clone(&shared), 2, ReplicaRole::Decode);
        let p0 = DisaggHandle::new(Arc::clone(&shared), 0, ReplicaRole::Prefill);
        let p1 = DisaggHandle::new(Arc::clone(&shared), 1, ReplicaRole::Prefill);

        d.forward(req(2));
        d.forward(req(2));
        d.forward(req(2));
        // Cursor started at 2 % 2 == 0: targets 0, 1, 0.
        assert_eq!(p0.drain().len(), 2);
        assert_eq!(p1.drain().len(), 1);

        for _ in 0..3 {
            p0.respond(
                2,
                PrefillResponse {
                    prompt: TokenBuf::from_vec(vec![1, 2, 3]),
                    model_id: 0,
                    remaining_gen: 4,
                    wf_idx: 7,
                    turn_idx: 0,
                    ready_at: 0.5,
                    admissible_at: 1.0,
                },
            );
        }
        assert_eq!(shared.remaining(), 0);
        assert_eq!(d.drain().len(), 3);
    }

    #[test]
    fn done_broadcast_wakes_parked_prefill() {
        let shared = DisaggShared::new(2, 1, 1);
        let waiter = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let p = DisaggHandle::new(shared, 0, ReplicaRole::Prefill);
                // First wait returns the request; after responding, the
                // second wait returns empty on the done broadcast.
                let mail = p.wait();
                assert_eq!(mail.len(), 1);
                let Handoff::Request(r) = &mail[0] else { panic!("expected request") };
                p.respond(
                    r.reply_to,
                    PrefillResponse {
                        prompt: r.prompt.clone(),
                        model_id: r.model_id,
                        remaining_gen: r.remaining_gen,
                        wf_idx: r.wf_idx,
                        turn_idx: r.turn_idx,
                        ready_at: r.ready_at,
                        admissible_at: 1.0,
                    },
                );
                assert!(p.wait().is_empty());
            })
        };
        let mut d = DisaggHandle::new(Arc::clone(&shared), 1, ReplicaRole::Decode);
        d.forward(req(1));
        let mail = d.wait();
        assert_eq!(mail.len(), 1);
        assert!(matches!(mail[0], Handoff::Response(_)));
        waiter.join().unwrap();
    }
}
