//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers everything the repo needs: the AOT `manifest.json`, config
//! files, and benchmark/metric result dumps.  Full JSON spec except for
//! `\u` surrogate pairs (accepted, replaced) — fine for our machine-
//! generated documents.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are ordered (BTreeMap) so serialization is
/// deterministic — handy for golden tests and diffable results files.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64; whole values serialize without a dot).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// JSON object with deterministically ordered keys.
    Obj(BTreeMap<String, Value>),
}

/// Parse failure: where in the input and why.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// Human-readable reason.
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(s: &str) -> Result<Value, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    /// Object member by key (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to u64, if this is a number.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The members, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Fluent path access: `v.at(&["configs", "serve-small", "max_seq"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Serialize with two-space indentation (diffable results files).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder: an object from (key, value) pairs.
pub fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience builder: a number value.
pub fn num(n: f64) -> Value {
    Value::Num(n)
}

/// Convenience builder: a string value.
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

/// Convenience builder: an array value.
pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let start = self.pos - 1;
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let end = (start + len).min(self.b.len());
                    if let Ok(sl) = std::str::from_utf8(&self.b[start..end]) {
                        out.push_str(sl);
                        self.pos = end;
                    } else {
                        return Err(self.err("bad utf-8"));
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.'
            || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(Value::parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Value::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"configs":{"s":{"max_seq":1024,"files":["a.txt","b.txt"],"ok":true}}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Value::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let back = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("hello").is_err());
        assert!(Value::parse("{} extra").is_err());
    }

    #[test]
    fn builders() {
        let v = obj(vec![("x", num(1.0)), ("y", s("z")), ("a", arr(vec![num(2.0)]))]);
        assert_eq!(v.get("x").unwrap().as_u64(), Some(1));
        assert_eq!(v.to_string(), r#"{"a":[2],"x":1,"y":"z"}"#);
    }

    #[test]
    fn whole_integers_serialize_without_dot() {
        assert_eq!(num(42.0).to_string(), "42");
        assert_eq!(num(4.25).to_string(), "4.25");
    }
}
