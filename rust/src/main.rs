//! `icarus` — CLI for the ICaRus multi-model serving engine.
//!
//! Subcommands:
//!   serve  — run one workload configuration and print serving stats.
//!            `--replicas R` shards the workload across R engine
//!            replicas (own thread + KV pool each, sim executor only);
//!            `--cluster-routing` picks the workflow-to-replica policy;
//!            `--sched-policy fcfs|cache_aware|sjf` picks the admission
//!            scheduler and `--prefill-chunk N` enables chunked prefill
//!            (N tokens per sequence per fused step; 0 = atomic).
//!            `--store-host-bytes B` / `--store-disk-bytes B` enable
//!            the tiered KV snapshot store (one instance shared by all
//!            replicas; 0/0 = off) and `--store-prefetch on` stages
//!            disk-tier entries for queued turns before admission.
//!            `--store-shards N` overrides the store's lock-stripe
//!            count (power of two; default auto = 2× replicas) —
//!            contention only, stats/trace are shard-count-invariant.
//!            `--overlap on` runs modeled store/swap transfers as
//!            tasks on a per-replica cooperative executor so they
//!            overlap with compute instead of stalling the replica
//!            (off = the serial charging path, bit-identical to the
//!            pre-overlap engine).
//!            `--disagg on` splits the cluster into prefill and decode
//!            tiers over the shared store (`--prefill-replicas N` of
//!            the `--replicas R` total serve prefills; the rest
//!            decode).  Needs `--replicas >= 2`, a non-zero store, and
//!            `--cluster-routing prefill_decode` to shard the workload
//!            across the decode tier only.
//!            `--admit-queue N` / `--admit-tokens T` bound the waiting
//!            queue (depth / summed prompt tokens) and load-shed
//!            arrivals over the bound (both 0 = gate off, bit-identical
//!            to the ungated engine).  `--openloop on` swaps the
//!            workload for the open-loop generator: Pareto
//!            inter-arrivals (`--pareto-alpha`), Zipf-popular persistent
//!            user sessions (`--users`, `--zipf`, `--user-prefix`), and
//!            diurnal bursts (`--diurnal-amp`, `--diurnal-period`).
//!            `--slo-request/--slo-ttft/--slo-itl` set the SLOs behind
//!            the printed goodput and attainment report.
//!            `--obs on` records phase-attributed spans on the virtual
//!            clock (queue, prefill, transfer, handoff, decode,
//!            write-back) plus per-phase latency histograms and
//!            per-shard store counters in the stats JSON;
//!            `--trace-out t.json` additionally exports the spans as a
//!            Chrome trace-event (Perfetto) timeline, one lane per
//!            replica.  Off (the default) is bit-identical — stats and
//!            trace — to the obs-less engine.  When an obs run fails
//!            (e.g. a poisoned store shard), the tail of each replica's
//!            span log is dumped to `obs_flight.json` (override with
//!            `--flight-out`).
//!   sweep  — QPS sweep for one (mode, N) setting (the figures' rows).
//!            `--threads T` runs the sweep points across T worker
//!            threads (near-linear wall-clock speedup for the grids;
//!            `--replicas` is accepted as a fallback spelling).  Each
//!            point is a plain single-engine run either way — threads
//!            change wall clock, never the numbers.
//!   info   — show artifact manifest details.
//!   frontend — run the live Inference-Protocol HTTP front end
//!            (`--port`/`--addr`, `--models`, `--admit-queue`,
//!            `--admit-tokens`) until killed; see `serve` module docs
//!            for the endpoints.
//!
//! Both serve and sweep accept `--json out.json` to write the results
//! machine-readably alongside the stdout report.
//!
//! Examples:
//!   icarus serve --mode icarus --models 4 --qps 0.4 --executor sim
//!   icarus serve --executor pjrt --config serve-small --requests 8
//!   icarus serve --replicas 4 --cluster-routing least_loaded --qps 2.0
//!   icarus serve --sched-policy cache_aware --prefill-chunk 256 --qps 1.5
//!   icarus serve --replicas 4 --store-host-bytes 268435456 --store-prefetch on
//!   icarus serve --store-host-bytes 268435456 --overlap on --qps 1.5
//!   icarus serve --replicas 4 --disagg on --prefill-replicas 2 \
//!       --cluster-routing prefill_decode --store-host-bytes 268435456
//!   icarus serve --openloop on --qps 4.0 --requests 512 --replicas 4 \
//!       --admit-queue 64 --slo-ttft 2.0
//!   icarus serve --obs on --trace-out trace.json --replicas 2 \
//!       --store-host-bytes 268435456 --qps 1.5
//!   icarus sweep --mode baseline --models 8 --qps-list 0.2,0.4,0.6,0.8
//!   icarus sweep --threads 4 --json sweep.json
//!   icarus frontend --port 8080 --models 4 --admit-queue 128

use anyhow::{anyhow, Result};

use icarus::bench_util::par_map;
use icarus::cluster::Cluster;
use icarus::config::{
    AgentPattern, ClusterRouting, EvictionPolicy, Routing, SchedPolicy, ServingConfig,
    ServingMode, WorkloadConfig,
};
use icarus::engine::executor::{CostModel, SimExecutor};
use icarus::engine::Engine;
use icarus::json::{self, Value};
use icarus::metrics::ServingStats;
use icarus::runtime::{Manifest, PjrtExecutor};
use icarus::serve::{self, generate_open_loop, AdmissionLimits, Frontend, OpenLoopConfig, Server};
use icarus::workload::generate;

/// Tiny flag parser: --key value pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {}", argv[i]))?;
            let v = argv.get(i + 1).ok_or_else(|| anyhow!("missing value for --{k}"))?;
            flags.insert(k.to_string(), v.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow!("bad --{key}: {v}")),
            None => Ok(default),
        }
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow!("bad --{key}: {v}")),
            None => Ok(default),
        }
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| anyhow!("bad --{key}: {v}")),
            None => Ok(default),
        }
    }
}

fn serving_config(a: &Args) -> Result<ServingConfig> {
    Ok(ServingConfig {
        mode: ServingMode::parse(a.get("mode").unwrap_or("icarus"))?,
        kv_pool_bytes: a.u64("kv-pool-mb", 64)? << 20,
        block_tokens: a.usize("block-tokens", 16)?,
        max_batch: a.usize("max-batch", 16)?,
        max_prefill_tokens: a.usize("max-prefill-tokens", 2048)?,
        sched_policy: SchedPolicy::parse(a.get("sched-policy").unwrap_or("fcfs"))?,
        prefill_chunk: a.usize("prefill-chunk", 0)?,
        eviction: match a.get("eviction").unwrap_or("recompute") {
            "recompute" => EvictionPolicy::Recompute,
            "swap" => EvictionPolicy::Swap,
            other => anyhow::bail!("unknown eviction policy {other}"),
        },
        swap_bytes: a.u64("swap-mb", 4096)? << 20,
        store_host_bytes: a.u64("store-host-bytes", 0)?,
        store_disk_bytes: a.u64("store-disk-bytes", 0)?,
        store_shards: a.usize("store-shards", 0)?,
        store_prefetch: a.get("store-prefetch").unwrap_or("off") == "on",
        overlap: a.get("overlap").unwrap_or("off") == "on",
        prefix_caching: a.get("prefix-caching").unwrap_or("on") != "off",
        replicas: a.usize("replicas", 1)?,
        cluster_routing: ClusterRouting::parse(a.get("cluster-routing").unwrap_or("round_robin"))?,
        disagg: a.get("disagg").unwrap_or("off") == "on",
        prefill_replicas: a.usize("prefill-replicas", 1)?,
        admit_queue: a.usize("admit-queue", 0)?,
        admit_tokens: a.usize("admit-tokens", 0)?,
        obs: a.get("obs").unwrap_or("off") == "on",
    })
}

fn workload_config(a: &Args) -> Result<WorkloadConfig> {
    Ok(WorkloadConfig {
        pattern: AgentPattern::parse(a.get("pattern").unwrap_or("react"))?,
        n_models: a.usize("models", 4)?,
        qps: a.f64("qps", 0.4)?,
        n_requests: a.usize("requests", 128)?,
        routing: match a.get("routing").unwrap_or("round_robin") {
            "round_robin" => Routing::RoundRobin,
            "skewed" => Routing::Skewed { hot_p_percent: a.u64("hot-p", 50)? as u8 },
            other => anyhow::bail!("unknown routing {other}"),
        },
        seed: a.u64("seed", 0)?,
        ..Default::default()
    })
}

/// Open-loop generator config from the CLI knobs (see `serve::openloop`).
fn openloop_config(a: &Args, base: WorkloadConfig) -> Result<OpenLoopConfig> {
    let d = OpenLoopConfig::default();
    Ok(OpenLoopConfig {
        base,
        users: a.u64("users", d.users)?,
        zipf_s: a.f64("zipf", d.zipf_s)?,
        pareto_alpha: a.f64("pareto-alpha", d.pareto_alpha)?,
        user_prefix_tokens: a.usize("user-prefix", d.user_prefix_tokens)?,
        diurnal_amplitude: a.f64("diurnal-amp", d.diurnal_amplitude)?,
        diurnal_period_s: a.f64("diurnal-period", d.diurnal_period_s)?,
    })
}

/// Write `text` to `--json <path>` when the flag is present.
fn write_json_flag(a: &Args, text: &str) -> Result<()> {
    if let Some(path) = a.get("json") {
        std::fs::write(path, text)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<()> {
    let scfg = serving_config(a)?;
    let wcfg = workload_config(a)?;
    anyhow::ensure!(
        a.get("trace-out").is_none() || scfg.obs,
        "--trace-out requires --obs on (spans are recorded only under obs)"
    );
    let open_loop = a.get("openloop").unwrap_or("off") == "on";
    let (workload, workload_json) = if open_loop {
        let ocfg = openloop_config(a, wcfg.clone())?;
        (generate_open_loop(&ocfg), ocfg.to_json())
    } else {
        (generate(&wcfg), wcfg.to_json())
    };
    let mut per_replica_json = None;
    let mut store_json = None;
    let mut store_shards_json = None;
    let stats = match a.get("executor").unwrap_or("sim") {
        "sim" => {
            // serve-small KV bytes/token unless overridden.
            let kv_bpt = a.u64("kv-bytes-per-token", 2048)?;
            // The cluster path with --replicas 1 is bit-identical to a
            // plain single-engine run (pinned by cluster::tests), so
            // sim serving always goes through it.
            let cluster = Cluster::new(scfg.clone(), kv_bpt, wcfg.n_models);
            let out = cluster.run_sim(CostModel::default(), workload);
            if scfg.replicas > 1 {
                per_replica_json = Some(Value::Arr(
                    out.per_replica.iter().map(ServingStats::to_json).collect(),
                ));
            }
            if let Some(store) = &out.store {
                // A poisoned store shard means a replica panicked and
                // the store degraded to static misses mid-run: the
                // numbers after that point are not the configured
                // system.  Fail cleanly instead of reporting them.
                if store.lock_poisoned > 0 {
                    // Failure flight recorder: dump the tail of each
                    // replica's span log next to the error, so the
                    // failure's immediate history is inspectable
                    // without a full trace export.
                    if !out.obs.is_empty() {
                        let path = a.get("flight-out").unwrap_or("obs_flight.json");
                        let doc = icarus::obs::flight_json(&out.obs);
                        std::fs::write(path, doc.to_string_pretty())?;
                        eprintln!("wrote failure flight recording to {path}");
                    }
                    anyhow::bail!(
                        "snapshot store degraded mid-run: a replica panicked while holding \
                         a shard lock ({} poisoned-lock encounters); results are invalid",
                        store.lock_poisoned
                    );
                }
                store_json = Some(store.to_json());
            }
            if !out.store_shards.is_empty() {
                store_shards_json = Some(Value::Arr(
                    out.store_shards.iter().map(|s| s.to_json()).collect(),
                ));
            }
            if let Some(path) = a.get("trace-out") {
                let doc = icarus::obs::export_chrome_trace(&out.obs);
                std::fs::write(path, doc.to_string_pretty())?;
                println!("wrote perfetto trace to {path}");
            }
            out.merged
        }
        "pjrt" => {
            anyhow::ensure!(
                scfg.replicas <= 1,
                "--replicas > 1 needs --executor sim (one PJRT runtime instance per process)"
            );
            anyhow::ensure!(
                scfg.store_host_bytes + scfg.store_disk_bytes == 0,
                "--store-host-bytes/--store-disk-bytes need --executor sim \
                 (no PJRT store transport yet)"
            );
            anyhow::ensure!(
                !scfg.overlap,
                "--overlap on needs --executor sim (PJRT durations are measured \
                 wall time, not modeled transfers the virtual-time reactor can overlap)"
            );
            anyhow::ensure!(
                !scfg.disagg,
                "--disagg on needs --executor sim (disaggregation splits a \
                 multi-replica cluster; PJRT runs a single engine)"
            );
            anyhow::ensure!(
                !scfg.obs,
                "--obs on needs --executor sim (spans are keyed by deterministic \
                 virtual time; PJRT durations are measured wall time)"
            );
            let dir = a.get("artifacts").unwrap_or("artifacts");
            let config = a.get("config").unwrap_or("serve-small");
            let manifest = Manifest::load(dir)?;
            let kv_bpt = manifest.spec(config)?.kv_bytes_per_token;
            let exec = PjrtExecutor::load(&manifest, config, scfg.mode, wcfg.n_models)?;
            Engine::new(scfg.clone(), kv_bpt, wcfg.n_models, exec).run(workload)
        }
        other => anyhow::bail!("unknown executor {other}"),
    };
    // SLO report: goodput counts only requests finishing inside
    // --slo-request; attainment fractions come straight from the TTFT
    // and ITL histograms.
    let slo_req = a.f64("slo-request", serve::DEFAULT_SLO_REQUEST_S)?;
    let slo_ttft = a.f64("slo-ttft", serve::DEFAULT_SLO_TTFT_S)?;
    let slo_itl = a.f64("slo-itl", serve::DEFAULT_SLO_ITL_S)?;
    let slo_json = json::obj(vec![
        ("request_s", json::num(slo_req)),
        ("ttft_s", json::num(slo_ttft)),
        ("itl_s", json::num(slo_itl)),
        ("goodput_rps", json::num(stats.goodput_rps(slo_req))),
        ("ttft_attainment", json::num(stats.slo_ttft_attainment(slo_ttft))),
        ("itl_attainment", json::num(stats.slo_itl_attainment(slo_itl))),
    ]);
    println!(
        "goodput {:.3} req/s (SLO {slo_req}s) | TTFT<{slo_ttft}s {:.1}% | ITL<{slo_itl}s {:.1}%",
        stats.goodput_rps(slo_req),
        100.0 * stats.slo_ttft_attainment(slo_ttft),
        100.0 * stats.slo_itl_attainment(slo_itl),
    );
    if stats.submitted_requests > 0 {
        println!(
            "admission: {} submitted, {} rejected ({} completed)",
            stats.submitted_requests, stats.rejected_requests, stats.completed_requests
        );
    }
    let mut entries = vec![
        ("serving", scfg.to_json()),
        ("workload", workload_json),
        ("stats", stats.to_json()),
        ("slo", slo_json),
    ];
    if let Some(pr) = per_replica_json {
        entries.push(("per_replica", pr));
    }
    if let Some(store) = store_json {
        entries.push(("store", store));
    }
    if let Some(shards) = store_shards_json {
        entries.push(("store_shards", shards));
    }
    let text = json::obj(entries).to_string_pretty();
    println!("{text}");
    write_json_flag(a, &text)
}

/// `icarus frontend`: run the live HTTP front end until killed.
fn cmd_frontend(a: &Args) -> Result<()> {
    let addr = match a.get("addr") {
        Some(addr) => addr.to_string(),
        None => format!("127.0.0.1:{}", a.usize("port", 8080)?),
    };
    let limits = AdmissionLimits {
        max_queue: a.usize("admit-queue", 0)?,
        max_tokens: a.usize("admit-tokens", 0)?,
    };
    let fe = Frontend::new(limits, a.usize("models", 4)?);
    let server = Server::start(&addr, std::sync::Arc::new(fe))?;
    println!("icarus frontend listening on http://{}", server.addr());
    println!("  GET  /v2/health/ready   readiness probe");
    println!("  GET  /v2/stats          admission-gate counters");
    println!("  GET  /v2/metrics        Prometheus text exposition");
    println!("  POST /v2/models/{{m}}/infer   generate (\"stream\": true for ndjson)");
    println!("  POST /v2/jobs/simulate  run a virtual-time sim job");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Run one single-engine sim point per QPS value, spread across
/// `threads` workers.  Results come back in `qps_list` order regardless
/// of which worker ran which point (each point is an independent seeded
/// sim, so parallel execution changes wall-clock only, never the
/// numbers).
fn run_sweep_points(
    scfg: &ServingConfig,
    wcfg: &WorkloadConfig,
    qps_list: &[f64],
    kv_bpt: u64,
    threads: usize,
) -> Vec<ServingStats> {
    par_map(qps_list.len(), threads, |i| {
        let mut w = wcfg.clone();
        w.qps = qps_list[i];
        let exec = SimExecutor::new(CostModel::default(), scfg.mode);
        Engine::new(scfg.clone(), kv_bpt, w.n_models, exec).run(generate(&w))
    })
}

fn cmd_sweep(a: &Args) -> Result<()> {
    let scfg = serving_config(a)?;
    let wcfg = workload_config(a)?;
    let qps_list: Vec<f64> = a
        .get("qps-list")
        .unwrap_or("0.2,0.4,0.6,0.8")
        .split(',')
        .map(|s| s.parse().map_err(|_| anyhow!("bad qps {s}")))
        .collect::<Result<_>>()?;
    let kv_bpt = a.u64("kv-bytes-per-token", 2048)?;
    // Sweep points are independent single-engine runs; `--threads` only
    // parallelizes them.  `--replicas` is accepted as a fallback so the
    // serve/sweep flag sets stay interchangeable, but it does NOT build
    // a cluster per point (the numbers would be incomparable with
    // `serve --replicas R` otherwise — see the JSON dump below).
    let threads = a.usize("threads", scfg.replicas)?.clamp(1, qps_list.len().max(1));
    println!(
        "mode={} models={} pattern={} threads={}",
        scfg.mode.as_str(),
        wcfg.n_models,
        wcfg.pattern.as_str(),
        threads
    );
    let stats_list = run_sweep_points(&scfg, &wcfg, &qps_list, kv_bpt, threads);
    println!(
        "{:>6} {:>10} {:>10} {:>12} {:>10}",
        "qps", "p95(s)", "p50(s)", "tput(tok/s)", "hit-rate"
    );
    let mut points = Vec::new();
    for (&qps, stats) in qps_list.iter().zip(&stats_list) {
        let tl = stats.turn_latency.as_ref().unwrap();
        println!(
            "{:>6.2} {:>10.3} {:>10.3} {:>12.1} {:>10.3}",
            qps,
            tl.p95(),
            tl.p50(),
            stats.throughput_tok_s(),
            stats.cache_hit_rate()
        );
        points.push(json::obj(vec![("qps", json::num(qps)), ("stats", stats.to_json())]));
    }
    // Every sweep point runs on a plain single engine — here --replicas
    // only sizes the worker-thread pool — so the dumped config must say
    // replicas=1, with the thread count recorded separately.
    let point_scfg = ServingConfig { replicas: 1, ..scfg };
    let text = json::obj(vec![
        ("serving", point_scfg.to_json()),
        ("threads", json::num(threads as f64)),
        ("workload", wcfg.to_json()),
        ("points", Value::Arr(points)),
    ])
    .to_string_pretty();
    write_json_flag(a, &text)
}

fn cmd_info(a: &Args) -> Result<()> {
    let dir = a.get("artifacts").unwrap_or("artifacts");
    let m = Manifest::load(dir)?;
    println!("artifacts: {} (kernels={})", m.dir.display(), m.kernels);
    for (name, spec) in &m.configs {
        println!(
            "  {name}: d={} L={} H={}/{} dh={} ffn={} vocab={} max_seq={} params={} kv={}B/token",
            spec.d_model,
            spec.layers,
            spec.heads,
            spec.kv_heads,
            spec.head_dim,
            spec.ffn,
            spec.vocab,
            spec.max_seq,
            spec.param_count,
            spec.kv_bytes_per_token
        );
        println!("    prefill buckets: {:?}", spec.prefill.keys().collect::<Vec<_>>());
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => {
            eprintln!("usage: icarus <serve|sweep|info|frontend> [--flag value ...]");
            std::process::exit(2);
        }
    };
    let args = Args::parse(rest)?;
    match cmd {
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args),
        "info" => cmd_info(&args),
        "frontend" => cmd_frontend(&args),
        other => {
            eprintln!("unknown command {other}; expected serve|sweep|info|frontend");
            std::process::exit(2);
        }
    }
}
