//! Configuration types for the serving engine, workload generator and
//! benchmark sweeps.  Everything round-trips through the in-repo JSON so
//! benches can dump exact run configs alongside results.

use crate::json::{self, Value};

/// How KV caches are namespaced across the N task-specialized models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingMode {
    /// Conventional multi-model: each adapter has its own cache namespace;
    /// identical prompts are prefilled and stored once *per model*.
    Baseline,
    /// ICaRus: one shared namespace; all adapters reuse the logical
    /// encoder's cache.
    Icarus,
}

impl ServingMode {
    /// CLI / JSON spelling of the mode.
    pub fn as_str(self) -> &'static str {
        match self {
            ServingMode::Baseline => "baseline",
            ServingMode::Icarus => "icarus",
        }
    }

    /// Inverse of [`ServingMode::as_str`].
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "baseline" => Ok(ServingMode::Baseline),
            "icarus" => Ok(ServingMode::Icarus),
            other => anyhow::bail!("unknown serving mode: {other}"),
        }
    }
}

/// Admission-scheduling policy: which waiting turn the engine admits
/// next and how the per-step prefill budget is charged (see the `sched`
/// module for the policy implementations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// First-come-first-served, pinned bit-identical to the
    /// pre-scheduler engine (including its conservative whole-prompt
    /// budget estimate) by a differential property test.
    Fcfs,
    /// Highest probed prefix-cache coverage first: turns whose context
    /// is already resident (ICaRus cross-model hits) jump the queue and
    /// charge the budget only with their probed-uncached suffix.
    CacheAware,
    /// Shortest-remaining-prefill first (probed-uncached tokens); the
    /// classic SJF tail-latency policy, with the same probe-accurate
    /// budget accounting as `CacheAware`.
    Sjf,
}

impl SchedPolicy {
    /// CLI / JSON spelling of the policy.
    pub fn as_str(self) -> &'static str {
        match self {
            SchedPolicy::Fcfs => "fcfs",
            SchedPolicy::CacheAware => "cache_aware",
            SchedPolicy::Sjf => "sjf",
        }
    }

    /// Inverse of [`SchedPolicy::as_str`].
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "fcfs" => Ok(SchedPolicy::Fcfs),
            "cache_aware" => Ok(SchedPolicy::CacheAware),
            "sjf" => Ok(SchedPolicy::Sjf),
            other => anyhow::bail!("unknown sched policy: {other}"),
        }
    }
}

/// What happens to a victim's blocks when the pool is full (paper §4.3
/// vs Appendix E).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Drop the cache; the sequence re-prefills when rescheduled.
    Recompute,
    /// Copy blocks to a host-side swap tier (bounded) and restore later.
    Swap,
}

impl EvictionPolicy {
    /// CLI / JSON spelling of the policy.
    pub fn as_str(self) -> &'static str {
        match self {
            EvictionPolicy::Recompute => "recompute",
            EvictionPolicy::Swap => "swap",
        }
    }

    /// Inverse of [`EvictionPolicy::as_str`].
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "recompute" => Ok(EvictionPolicy::Recompute),
            "swap" => Ok(EvictionPolicy::Swap),
            other => anyhow::bail!("unknown eviction policy: {other}"),
        }
    }
}

/// How the cluster layer assigns workflows to engine replicas (see
/// `cluster::Cluster`).  All turns of a workflow stay on one replica —
/// the workflow's accumulated context is what the prefix cache reuses,
/// so splitting a workflow across replicas would forfeit every
/// intra-workflow cache hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterRouting {
    /// Workflow k (in arrival order) goes to replica k mod R.
    RoundRobin,
    /// Greedy least-estimated-work assignment: each workflow lands on
    /// the replica with the smallest accumulated token footprint
    /// (prompt + planned generation + observations).
    LeastLoaded,
    /// Prefix-affinity: hash the leading prompt blocks so workflows
    /// sharing an opening context land on the replica that already
    /// holds that cache — the cluster-level analogue of ICaRus's
    /// cross-model reuse.
    HashPrefix,
    /// Disaggregated pipeline: workflows are owned by decode-role
    /// replicas (sharded round-robin among them) while every turn's
    /// prefill is dispatched to a prefill-role replica and handed back
    /// through the shared KV store.  Requires `disagg` mode; outside a
    /// disaggregated cluster it degenerates to round-robin.
    PrefillDecode,
}

impl ClusterRouting {
    /// CLI / JSON spelling of the policy.
    pub fn as_str(self) -> &'static str {
        match self {
            ClusterRouting::RoundRobin => "round_robin",
            ClusterRouting::LeastLoaded => "least_loaded",
            ClusterRouting::HashPrefix => "hash_prefix",
            ClusterRouting::PrefillDecode => "prefill_decode",
        }
    }

    /// Inverse of [`ClusterRouting::as_str`].
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "round_robin" => Ok(ClusterRouting::RoundRobin),
            "least_loaded" => Ok(ClusterRouting::LeastLoaded),
            "hash_prefix" => Ok(ClusterRouting::HashPrefix),
            "prefill_decode" => Ok(ClusterRouting::PrefillDecode),
            other => anyhow::bail!("unknown cluster routing: {other}"),
        }
    }
}

/// Serving engine configuration (the vLLM-equivalent knobs).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Cache-namespacing mode: the paper's baseline-vs-ICaRus variable.
    pub mode: ServingMode,
    /// Simulated GPU memory budget for the KV pool, in bytes.  This is
    /// the A100-80GB stand-in: the eviction dynamics the paper measures
    /// depend on footprint/budget ratios, which this controls.
    pub kv_pool_bytes: u64,
    /// Tokens per KV block (vLLM uses 16).
    pub block_tokens: usize,
    /// Max sequences decoded per engine step.
    pub max_batch: usize,
    /// Max prefill tokens admitted per engine step.  With chunked
    /// prefill enabled this is also the per-step budget shared by all
    /// in-flight prefill chunks.
    pub max_prefill_tokens: usize,
    /// Admission-scheduling policy (see [`SchedPolicy`]).
    pub sched_policy: SchedPolicy,
    /// Chunked-prefill chunk size in tokens per sequence per engine
    /// step; 0 (the default) disables chunking and prefills each prompt
    /// atomically at admission, exactly like the pre-scheduler engine.
    /// When enabled, prompt encoding is split into chunks co-scheduled
    /// with the decode batch in fused steps, so one long prompt can no
    /// longer stall every running sequence (head-of-line blocking).
    pub prefill_chunk: usize,
    /// What happens to a victim's blocks when the pool is full.
    pub eviction: EvictionPolicy,
    /// Swap tier capacity in bytes (Appendix E uses 4 GB).
    pub swap_bytes: u64,
    /// Host tier of the tiered KV snapshot store, in bytes.  0 together
    /// with `store_disk_bytes` = 0 (the default) disables the store
    /// entirely: the engine is then bit-identical to pre-store
    /// Drop/Swap behavior (pinned by a differential property test).
    pub store_host_bytes: u64,
    /// Disk (NVMe) tier of the tiered KV snapshot store, in bytes.
    pub store_disk_bytes: u64,
    /// Lock-striped shard count for the shared snapshot store.  0 (the
    /// default) = automatic: the next power of two ≥ 2× the replica
    /// count.  Explicit values round up to a power of two (capped at
    /// 64).  Purely a contention knob: stats and traces are
    /// bit-identical for every value (pinned by
    /// `prop_store_shards_bit_identical`).
    pub store_shards: usize,
    /// Issue background prefetches that stage disk-tier store entries
    /// into host memory for queued turns before admission, so their
    /// eventual restore pays PCIe instead of NVMe.
    pub store_prefetch: bool,
    /// Overlap modeled store/swap transfers with compute on the
    /// per-replica cooperative task runtime (`runtime::exec`): a
    /// restore issued at admission completes in virtual time while
    /// other sequences keep decoding, instead of being charged inline
    /// on the replica's critical path.  `false` (the default) keeps
    /// the serial charging path, bit-identical to the pre-overlap
    /// engine (pinned by a differential property test).
    pub overlap: bool,
    /// Enable per-namespace prefix caching (on in both systems; the
    /// ablation bench turns it off).
    pub prefix_caching: bool,
    /// Engine replicas the cluster layer shards across.  1 (the
    /// default) is plain single-engine serving; each extra replica gets
    /// its own OS thread, `KvCacheManager` and KV pool of
    /// `kv_pool_bytes`.
    pub replicas: usize,
    /// Workflow-to-replica assignment policy (ignored for `replicas`
    /// = 1).
    pub cluster_routing: ClusterRouting,
    /// Disaggregated prefill/decode serving: the first
    /// `prefill_replicas` replicas run chunked prefill only, publishing
    /// finished prefixes into the shared KV store; the rest own
    /// workflows and decode, restoring handed-off prefixes over the
    /// modeled host/PCIe path.  Requires `replicas >= 2` and a
    /// non-zero store budget (the store *is* the handoff path).
    /// `false` (the default) keeps every replica hybrid and is
    /// bit-identical to the pre-disaggregation cluster (pinned by a
    /// differential property test).
    pub disagg: bool,
    /// Number of prefill-role replicas under `disagg` (clamped to
    /// `1..=replicas-1`); ignored when `disagg` is off.
    pub prefill_replicas: usize,
    /// Serving-front-end admission control (`serve::AdmissionLimits`):
    /// a workflow arriving while the replica's waiting queue already
    /// holds at least this many turns is load-shed at the gate
    /// (counted in `rejected_requests`, like a 503 from a live front
    /// end) instead of enqueued.  0 (the default) disables the depth
    /// bound; with `admit_tokens` also 0 the gate is off entirely and
    /// the engine is bit-identical to the pre-front-end arrival path
    /// (pinned by a differential property test).
    pub admit_queue: usize,
    /// Token-budget companion to `admit_queue`: reject arrivals while
    /// the waiting queue's summed prompt tokens are at or above this.
    /// 0 (the default) disables the token bound.
    pub admit_tokens: usize,
    /// Observability (`--obs on`): per-request lifecycle spans keyed by
    /// virtual time, per-phase latency attribution in the stats, and
    /// per-shard store counters.  Off (the default) records nothing and
    /// is bit-identical — stats *and* trace — to the pre-obs engine
    /// (pinned by a differential property test).
    pub obs: bool,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            mode: ServingMode::Icarus,
            kv_pool_bytes: 64 << 20,
            block_tokens: 16,
            max_batch: 16,
            max_prefill_tokens: 2048,
            sched_policy: SchedPolicy::Fcfs,
            prefill_chunk: 0,
            eviction: EvictionPolicy::Recompute,
            swap_bytes: 4 << 30,
            store_host_bytes: 0,
            store_disk_bytes: 0,
            store_shards: 0,
            store_prefetch: false,
            overlap: false,
            prefix_caching: true,
            replicas: 1,
            cluster_routing: ClusterRouting::RoundRobin,
            disagg: false,
            prefill_replicas: 1,
            admit_queue: 0,
            admit_tokens: 0,
            obs: false,
        }
    }
}

impl ServingConfig {
    /// Dump the exact run configuration for results files.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("mode", json::s(self.mode.as_str())),
            ("kv_pool_bytes", json::num(self.kv_pool_bytes as f64)),
            ("block_tokens", json::num(self.block_tokens as f64)),
            ("max_batch", json::num(self.max_batch as f64)),
            ("max_prefill_tokens", json::num(self.max_prefill_tokens as f64)),
            ("sched_policy", json::s(self.sched_policy.as_str())),
            ("prefill_chunk", json::num(self.prefill_chunk as f64)),
            ("eviction", json::s(self.eviction.as_str())),
            ("swap_bytes", json::num(self.swap_bytes as f64)),
            ("store_host_bytes", json::num(self.store_host_bytes as f64)),
            ("store_disk_bytes", json::num(self.store_disk_bytes as f64)),
            ("store_shards", json::num(self.store_shards as f64)),
            ("store_prefetch", Value::Bool(self.store_prefetch)),
            ("overlap", Value::Bool(self.overlap)),
            ("prefix_caching", Value::Bool(self.prefix_caching)),
            ("replicas", json::num(self.replicas as f64)),
            ("cluster_routing", json::s(self.cluster_routing.as_str())),
            ("disagg", Value::Bool(self.disagg)),
            ("prefill_replicas", json::num(self.prefill_replicas as f64)),
            ("admit_queue", json::num(self.admit_queue as f64)),
            ("admit_tokens", json::num(self.admit_tokens as f64)),
            ("obs", Value::Bool(self.obs)),
        ])
    }

    /// Inverse of [`ServingConfig::to_json`], with defaults for absent
    /// keys — how the serving front end's job endpoint accepts run
    /// configurations over the wire.  Unknown keys are ignored; known
    /// keys with the wrong type or spelling are errors.
    pub fn from_json(v: &Value) -> anyhow::Result<ServingConfig> {
        let d = ServingConfig::default();
        let s = |key: &str| -> anyhow::Result<Option<&str>> {
            match v.get(key) {
                None => Ok(None),
                Some(x) => {
                    Ok(Some(x.as_str().ok_or_else(|| anyhow::anyhow!("{key}: want string"))?))
                }
            }
        };
        let n = |key: &str, default: f64| -> anyhow::Result<f64> {
            match v.get(key) {
                None => Ok(default),
                Some(x) => x.as_f64().ok_or_else(|| anyhow::anyhow!("{key}: want number")),
            }
        };
        let b = |key: &str, default: bool| -> anyhow::Result<bool> {
            match v.get(key) {
                None => Ok(default),
                Some(x) => x.as_bool().ok_or_else(|| anyhow::anyhow!("{key}: want bool")),
            }
        };
        Ok(ServingConfig {
            mode: match s("mode")? {
                Some(m) => ServingMode::parse(m)?,
                None => d.mode,
            },
            kv_pool_bytes: n("kv_pool_bytes", d.kv_pool_bytes as f64)? as u64,
            block_tokens: n("block_tokens", d.block_tokens as f64)? as usize,
            max_batch: n("max_batch", d.max_batch as f64)? as usize,
            max_prefill_tokens: n("max_prefill_tokens", d.max_prefill_tokens as f64)? as usize,
            sched_policy: match s("sched_policy")? {
                Some(p) => SchedPolicy::parse(p)?,
                None => d.sched_policy,
            },
            prefill_chunk: n("prefill_chunk", d.prefill_chunk as f64)? as usize,
            eviction: match s("eviction")? {
                Some(e) => EvictionPolicy::parse(e)?,
                None => d.eviction,
            },
            swap_bytes: n("swap_bytes", d.swap_bytes as f64)? as u64,
            store_host_bytes: n("store_host_bytes", d.store_host_bytes as f64)? as u64,
            store_disk_bytes: n("store_disk_bytes", d.store_disk_bytes as f64)? as u64,
            store_shards: n("store_shards", d.store_shards as f64)? as usize,
            store_prefetch: b("store_prefetch", d.store_prefetch)?,
            overlap: b("overlap", d.overlap)?,
            prefix_caching: b("prefix_caching", d.prefix_caching)?,
            replicas: n("replicas", d.replicas as f64)? as usize,
            cluster_routing: match s("cluster_routing")? {
                Some(r) => ClusterRouting::parse(r)?,
                None => d.cluster_routing,
            },
            disagg: b("disagg", d.disagg)?,
            prefill_replicas: n("prefill_replicas", d.prefill_replicas as f64)? as usize,
            admit_queue: n("admit_queue", d.admit_queue as f64)? as usize,
            admit_tokens: n("admit_tokens", d.admit_tokens as f64)? as usize,
            obs: b("obs", d.obs)?,
        })
    }
}

/// Agentic pattern driving the multi-turn workflow (paper §4.1/A.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentPattern {
    /// Thought -> Act -> Observation cycles.
    ReAct,
    /// ReAct plus self-evaluation turns and episodic memory growth.
    Reflexion,
}

impl AgentPattern {
    /// CLI / JSON spelling of the pattern.
    pub fn as_str(self) -> &'static str {
        match self {
            AgentPattern::ReAct => "react",
            AgentPattern::Reflexion => "reflexion",
        }
    }

    /// Inverse of [`AgentPattern::as_str`].
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "react" => Ok(AgentPattern::ReAct),
            "reflexion" => Ok(AgentPattern::Reflexion),
            other => anyhow::bail!("unknown agent pattern: {other}"),
        }
    }
}

/// How successive turns of a workflow are routed across the N models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Paper §4.3: turn k goes to model k mod N.
    RoundRobin,
    /// Appendix F: one hot model gets `hot_p`, the rest share the
    /// remainder, order randomized.
    Skewed {
        /// Share of turns (in percent) routed to the hot model.
        hot_p_percent: u8,
    },
}

impl Routing {
    /// CLI / JSON spelling of the policy.
    pub fn as_str(self) -> &'static str {
        match self {
            Routing::RoundRobin => "round_robin",
            Routing::Skewed { .. } => "skewed",
        }
    }
}

/// Workload generator configuration (HotPotQA-agent stand-in; A.2.3).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Agentic pattern driving each workflow's turn structure.
    pub pattern: AgentPattern,
    /// Number of task-specialized models (LoRA adapters), N in the paper.
    pub n_models: usize,
    /// Offered load in workflows per second.
    pub qps: f64,
    /// Total workflows in the run (paper fixes 128).
    pub n_requests: usize,
    /// How successive turns are routed across the N models.
    pub routing: Routing,
    /// Mean initial prompt tokens (shared prefix: question + instructions).
    pub prompt_mean: f64,
    /// Std dev of initial prompt tokens.
    pub prompt_std: f64,
    /// Minimum turns per workflow (thought/act/obs cycles).
    pub turns_min: u64,
    /// Maximum turns per workflow.
    pub turns_max: u64,
    /// Mean generated tokens per turn.
    pub output_mean: f64,
    /// Std dev of generated tokens per turn.
    pub output_std: f64,
    /// Observation tokens appended after each tool call.
    pub obs_mean: f64,
    /// Std dev of observation tokens.
    pub obs_std: f64,
    /// Tool-execution latency between turns (seconds) — while an agent
    /// waits on its tool, its context sits in the cache aging toward
    /// eviction (this is what makes recompute-vs-swap matter).
    pub think_mean: f64,
    /// Std dev of tool-execution latency.
    pub think_std: f64,
    /// Workload generator seed; runs are bit-reproducible per seed.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            pattern: AgentPattern::ReAct,
            n_models: 4,
            qps: 0.4,
            n_requests: 128,
            routing: Routing::RoundRobin,
            prompt_mean: 96.0,
            prompt_std: 24.0,
            turns_min: 2,
            turns_max: 5,
            output_mean: 48.0,
            output_std: 16.0,
            obs_mean: 24.0,
            obs_std: 8.0,
            think_mean: 1.5,
            think_std: 0.5,
            seed: 0,
        }
    }
}

impl WorkloadConfig {
    /// Dump the exact workload configuration for results files.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("pattern", json::s(self.pattern.as_str())),
            ("n_models", json::num(self.n_models as f64)),
            ("qps", json::num(self.qps)),
            ("n_requests", json::num(self.n_requests as f64)),
            ("routing", json::s(self.routing.as_str())),
            ("prompt_mean", json::num(self.prompt_mean)),
            ("turns_max", json::num(self.turns_max as f64)),
            ("output_mean", json::num(self.output_mean)),
            ("seed", json::num(self.seed as f64)),
        ])
    }

    /// Build a workload config from a (possibly partial) JSON object,
    /// with defaults for absent keys — the serving front end's job
    /// endpoint accepts workload descriptions in this form.  `routing`
    /// is `"round_robin"` or `"skewed"`; the latter reads the hot share
    /// from `hot_p_percent` (default 80).
    pub fn from_json(v: &Value) -> anyhow::Result<WorkloadConfig> {
        let d = WorkloadConfig::default();
        let n = |key: &str, default: f64| -> anyhow::Result<f64> {
            match v.get(key) {
                None => Ok(default),
                Some(x) => x.as_f64().ok_or_else(|| anyhow::anyhow!("{key}: want number")),
            }
        };
        let pattern = match v.get("pattern") {
            None => d.pattern,
            Some(x) => AgentPattern::parse(
                x.as_str().ok_or_else(|| anyhow::anyhow!("pattern: want string"))?,
            )?,
        };
        let routing = match v.get("routing").and_then(|x| x.as_str()) {
            None => d.routing,
            Some("round_robin") => Routing::RoundRobin,
            Some("skewed") => {
                Routing::Skewed { hot_p_percent: n("hot_p_percent", 80.0)?.clamp(0.0, 100.0) as u8 }
            }
            Some(other) => anyhow::bail!("unknown routing: {other}"),
        };
        Ok(WorkloadConfig {
            pattern,
            n_models: n("n_models", d.n_models as f64)? as usize,
            qps: n("qps", d.qps)?,
            n_requests: n("n_requests", d.n_requests as f64)? as usize,
            routing,
            prompt_mean: n("prompt_mean", d.prompt_mean)?,
            prompt_std: n("prompt_std", d.prompt_std)?,
            turns_min: n("turns_min", d.turns_min as f64)? as u64,
            turns_max: n("turns_max", d.turns_max as f64)? as u64,
            output_mean: n("output_mean", d.output_mean)?,
            output_std: n("output_std", d.output_std)?,
            obs_mean: n("obs_mean", d.obs_mean)?,
            obs_std: n("obs_std", d.obs_std)?,
            think_mean: n("think_mean", d.think_mean)?,
            think_std: n("think_std", d.think_std)?,
            seed: n("seed", d.seed as f64)? as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_roundtrip() {
        for m in [ServingMode::Baseline, ServingMode::Icarus] {
            assert_eq!(ServingMode::parse(m.as_str()).unwrap(), m);
        }
        assert!(ServingMode::parse("nope").is_err());
    }

    #[test]
    fn pattern_roundtrip() {
        for p in [AgentPattern::ReAct, AgentPattern::Reflexion] {
            assert_eq!(AgentPattern::parse(p.as_str()).unwrap(), p);
        }
    }

    #[test]
    fn sched_policy_roundtrip() {
        for p in [SchedPolicy::Fcfs, SchedPolicy::CacheAware, SchedPolicy::Sjf] {
            assert_eq!(SchedPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(SchedPolicy::parse("nope").is_err());
    }

    #[test]
    fn cluster_routing_roundtrip() {
        for r in [
            ClusterRouting::RoundRobin,
            ClusterRouting::LeastLoaded,
            ClusterRouting::HashPrefix,
            ClusterRouting::PrefillDecode,
        ] {
            assert_eq!(ClusterRouting::parse(r.as_str()).unwrap(), r);
        }
        assert!(ClusterRouting::parse("nope").is_err());
    }

    #[test]
    fn defaults_sane() {
        let s = ServingConfig::default();
        assert!(s.kv_pool_bytes > 0 && s.block_tokens > 0);
        assert_eq!(s.replicas, 1, "plain single-engine serving by default");
        assert_eq!(s.sched_policy, SchedPolicy::Fcfs, "legacy-pinned policy by default");
        assert_eq!(s.prefill_chunk, 0, "atomic prefill by default");
        assert_eq!(s.store_host_bytes + s.store_disk_bytes, 0, "store off by default");
        assert_eq!(s.store_shards, 0, "automatic store sharding by default");
        assert!(!s.store_prefetch);
        assert!(!s.overlap, "serial transfer charging by default");
        assert!(!s.disagg, "homogeneous replicas by default");
        assert_eq!(s.prefill_replicas, 1);
        assert_eq!(s.admit_queue + s.admit_tokens, 0, "admission gate off by default");
        assert!(!s.obs, "observability off (and bit-identical) by default");
        let w = WorkloadConfig::default();
        assert!(w.turns_min <= w.turns_max);
        assert!(w.qps > 0.0);
    }

    #[test]
    fn json_dump_contains_mode() {
        let s = ServingConfig::default().to_json();
        assert_eq!(s.get("mode").unwrap().as_str(), Some("icarus"));
    }

    #[test]
    fn eviction_roundtrip() {
        for e in [EvictionPolicy::Recompute, EvictionPolicy::Swap] {
            assert_eq!(EvictionPolicy::parse(e.as_str()).unwrap(), e);
        }
        assert!(EvictionPolicy::parse("nope").is_err());
    }

    #[test]
    fn serving_config_json_roundtrip() {
        let cfg = ServingConfig {
            mode: ServingMode::Baseline,
            sched_policy: SchedPolicy::Sjf,
            eviction: EvictionPolicy::Swap,
            prefill_chunk: 256,
            store_host_bytes: 1 << 20,
            store_shards: 4,
            overlap: true,
            replicas: 3,
            cluster_routing: ClusterRouting::HashPrefix,
            admit_queue: 64,
            admit_tokens: 8192,
            obs: true,
            ..Default::default()
        };
        let back = ServingConfig::from_json(&cfg.to_json()).unwrap();
        // Struct is not PartialEq (holds enums only, but keep it light):
        // compare via the canonical JSON dump.
        assert_eq!(back.to_json().to_string_pretty(), cfg.to_json().to_string_pretty());
    }

    #[test]
    fn serving_config_from_partial_and_bad_json() {
        let v = Value::parse(r#"{"replicas": 4, "admit_queue": 32}"#).unwrap();
        let cfg = ServingConfig::from_json(&v).unwrap();
        assert_eq!(cfg.replicas, 4);
        assert_eq!(cfg.admit_queue, 32);
        assert_eq!(cfg.block_tokens, ServingConfig::default().block_tokens);
        let bad = Value::parse(r#"{"mode": "warp"}"#).unwrap();
        assert!(ServingConfig::from_json(&bad).is_err());
        let wrong_type = Value::parse(r#"{"replicas": "four"}"#).unwrap();
        assert!(ServingConfig::from_json(&wrong_type).is_err());
    }

    #[test]
    fn workload_config_from_json() {
        let v = Value::parse(
            r#"{"pattern": "reflexion", "qps": 2.5, "n_requests": 42,
                "routing": "skewed", "hot_p_percent": 60, "seed": 7}"#,
        )
        .unwrap();
        let cfg = WorkloadConfig::from_json(&v).unwrap();
        assert_eq!(cfg.pattern, AgentPattern::Reflexion);
        assert_eq!(cfg.qps, 2.5);
        assert_eq!(cfg.n_requests, 42);
        assert_eq!(cfg.routing, Routing::Skewed { hot_p_percent: 60 });
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.n_models, WorkloadConfig::default().n_models);
        assert!(WorkloadConfig::from_json(&Value::parse(r#"{"routing":"x"}"#).unwrap()).is_err());
    }
}
