//! Shared, cheaply-clonable token buffers for agentic contexts.
//!
//! A workflow's context only ever grows by appending (generated tokens,
//! then the tool observation), and every turn hands the full context
//! from the workflow to a pending turn to a running sequence and back.
//! With plain `Vec<u32>` each handoff deep-copies O(context) tokens —
//! O(L²) per workflow.  [`TokenBuf`] makes the handoffs O(1) clones of a
//! shared `Arc` buffer and the appends copy-on-extend: when the buffer
//! is uniquely owned (the steady state in the engine, which parks the
//! context in whichever turn owns it) an append writes in place; only a
//! genuinely shared buffer is copied.

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

/// Immutable view of the first `len` tokens of a shared buffer.
///
/// Cloning is O(1).  [`TokenBuf::extended`] appends, reusing the
/// allocation when this is the only owner viewing the whole buffer.
#[derive(Clone, Default)]
pub struct TokenBuf {
    data: Arc<Vec<u32>>,
    len: usize,
}

impl TokenBuf {
    /// An empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Wrap an owned vector without copying.
    pub fn from_vec(v: Vec<u32>) -> Self {
        TokenBuf { len: v.len(), data: Arc::new(v) }
    }

    /// The visible tokens as a slice.
    pub fn as_slice(&self) -> &[u32] {
        &self.data[..self.len]
    }

    /// Number of visible tokens.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the view holds no tokens.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when this is the sole owner of the underlying allocation —
    /// i.e. `extended` will append in place instead of copying.
    pub fn is_unique(&self) -> bool {
        Arc::strong_count(&self.data) == 1
    }

    /// Append `extra`, consuming self.  In place when uniquely owned;
    /// otherwise copies the visible prefix plus `extra` into a fresh
    /// buffer (copy-on-extend).
    pub fn extended(mut self, extra: &[u32]) -> TokenBuf {
        if let Some(v) = Arc::get_mut(&mut self.data) {
            v.truncate(self.len); // drop any tail beyond our view
            v.extend_from_slice(extra);
            self.len = v.len();
            return self;
        }
        let mut v = Vec::with_capacity(self.len + extra.len());
        v.extend_from_slice(&self.data[..self.len]);
        v.extend_from_slice(extra);
        TokenBuf { len: v.len(), data: Arc::new(v) }
    }

    /// Copy the visible tokens into an owned vector.
    pub fn to_vec(&self) -> Vec<u32> {
        self.as_slice().to_vec()
    }
}

impl Deref for TokenBuf {
    type Target = [u32];

    fn deref(&self) -> &[u32] {
        self.as_slice()
    }
}

impl From<Vec<u32>> for TokenBuf {
    fn from(v: Vec<u32>) -> Self {
        Self::from_vec(v)
    }
}

impl From<&[u32]> for TokenBuf {
    fn from(s: &[u32]) -> Self {
        Self::from_vec(s.to_vec())
    }
}

impl PartialEq for TokenBuf {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for TokenBuf {}

impl PartialEq<[u32]> for TokenBuf {
    fn eq(&self, other: &[u32]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<Vec<u32>> for TokenBuf {
    fn eq(&self, other: &Vec<u32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl fmt::Debug for TokenBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TokenBuf({} tokens", self.len)?;
        if !self.is_unique() {
            write!(f, ", shared")?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_is_shallow_and_equal() {
        let a = TokenBuf::from_vec(vec![1, 2, 3]);
        let b = a.clone();
        assert_eq!(a, b);
        assert!(!a.is_unique());
        assert_eq!(b.as_slice(), &[1, 2, 3]);
    }

    #[test]
    fn extend_in_place_when_unique() {
        let a = TokenBuf::from_vec(vec![1, 2]);
        let ptr = a.as_slice().as_ptr();
        let b = a.extended(&[3, 4]);
        assert_eq!(b.as_slice(), &[1, 2, 3, 4]);
        // Unique owner: same allocation (capacity growth aside, the Vec
        // had no spare capacity so the data may move — assert semantics
        // via uniqueness instead of pointer identity when it moved).
        assert!(b.is_unique());
        let _ = ptr; // pointer identity is not guaranteed across growth
    }

    #[test]
    fn extend_copies_when_shared() {
        let a = TokenBuf::from_vec(vec![1, 2]);
        let shared = a.clone();
        let b = a.extended(&[3]);
        assert_eq!(b.as_slice(), &[1, 2, 3]);
        assert_eq!(shared.as_slice(), &[1, 2], "sharer unaffected");
        assert!(b.is_unique());
    }

    #[test]
    fn truncated_view_does_not_leak_tail() {
        // A shared buffer extended twice from the same base: the second
        // extension must not see the first extension's tail.
        let base = TokenBuf::from_vec(vec![1, 2]);
        let x = base.clone().extended(&[10]);
        let y = base.extended(&[20]);
        assert_eq!(x.as_slice(), &[1, 2, 10]);
        assert_eq!(y.as_slice(), &[1, 2, 20]);
    }

    #[test]
    fn deref_gives_slice_ops() {
        let a = TokenBuf::from_vec((0..10).collect());
        assert_eq!(a.len(), 10);
        assert_eq!(&a[..3], &[0, 1, 2]);
        assert_eq!(a.iter().sum::<u32>(), 45);
    }
}
