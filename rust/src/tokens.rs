//! Shared, cheaply-clonable token buffers for agentic contexts.
//!
//! A workflow's context only ever grows by appending (generated tokens,
//! then the tool observation), and every turn hands the full context
//! from the workflow to a pending turn to a running sequence and back.
//! With plain `Vec<u32>` each handoff deep-copies O(context) tokens —
//! O(L²) per workflow.  [`TokenBuf`] makes the handoffs O(1) clones of a
//! shared `Arc` buffer and the appends copy-on-extend: when the buffer
//! is uniquely owned (the steady state in the engine, which parks the
//! context in whichever turn owns it) an append writes in place; only a
//! genuinely shared buffer is copied.

use std::fmt;
use std::ops::Deref;
use std::sync::{Arc, Mutex};

use crate::kvcache::block::{hash_block, BlockKey, ROOT_HASH};

/// Memoized rolling block-hash chain over a buffer's leading tokens
/// (see [`TokenBuf::block_chain`]).  Shared across clones through an
/// `Arc<Mutex<..>>`: clones view the same tokens, so they share the
/// same chain, and whichever handle probes first hashes for all of
/// them.  The keys vector is itself `Arc`-wrapped so the hot path
/// returns an O(1) handle instead of copying the chain per probe.
#[derive(Debug, Default)]
struct ChainMemo {
    /// Block size the memoized keys were computed at (0 = empty memo).
    block_tokens: usize,
    /// Chain keys of the leading `keys.len()` blocks, ascending depth.
    keys: Arc<Vec<BlockKey>>,
}

/// Immutable view of the first `len` tokens of a shared buffer.
///
/// Cloning is O(1).  [`TokenBuf::extended`] appends, reusing the
/// allocation when this is the only owner viewing the whole buffer.
///
/// Buffers also memoize their rolling block-hash chain
/// ([`TokenBuf::block_chain`]): the radix prefix cache and the tiered
/// snapshot store both probe by the same content-addressed chain, and
/// agentic contexts only grow, so repeated probes of a growing context
/// rehash only the new tokens instead of the whole prefix.
#[derive(Clone, Default)]
pub struct TokenBuf {
    data: Arc<Vec<u32>>,
    len: usize,
    /// Chain memo, shared with clones (equal tokens ⇒ equal chain).
    /// Equality/hashing/debug ignore it: it is a cache, not state.
    chain: Arc<Mutex<ChainMemo>>,
}

impl TokenBuf {
    /// An empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Wrap an owned vector without copying.
    pub fn from_vec(v: Vec<u32>) -> Self {
        TokenBuf { len: v.len(), data: Arc::new(v), chain: Arc::default() }
    }

    /// The visible tokens as a slice.
    pub fn as_slice(&self) -> &[u32] {
        &self.data[..self.len]
    }

    /// Number of visible tokens.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the view holds no tokens.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when this is the sole owner of the underlying allocation —
    /// i.e. `extended` will append in place instead of copying.
    pub fn is_unique(&self) -> bool {
        Arc::strong_count(&self.data) == 1
    }

    /// Append `extra`, consuming self.  In place when uniquely owned;
    /// otherwise copies the visible prefix plus `extra` into a fresh
    /// buffer (copy-on-extend).  Either way the rolling-hash memo of
    /// the surviving prefix carries over, so the chain over the old
    /// tokens is never rehashed (see [`TokenBuf::block_chain`]).
    pub fn extended(mut self, extra: &[u32]) -> TokenBuf {
        if let Some(v) = Arc::get_mut(&mut self.data) {
            if v.len() > self.len {
                // Dropping a tail beyond our view invalidates any memo
                // keys hashed over it (data uniqueness implies no live
                // clone shares the memo, so truncating is safe).
                v.truncate(self.len);
                Self::truncate_memo(&self.chain, self.len);
            }
            v.extend_from_slice(extra);
            self.len = v.len();
            return self;
        }
        let mut v = Vec::with_capacity(self.len + extra.len());
        v.extend_from_slice(&self.data[..self.len]);
        v.extend_from_slice(extra);
        // The copied prefix is identical, so its chain keys still hold;
        // the sharer we split from keeps the original memo.
        let chain = Arc::new(Mutex::new(Self::memo_prefix(&self.chain, self.len)));
        TokenBuf { len: v.len(), data: Arc::new(v), chain }
    }

    /// Copy the visible tokens into an owned vector.
    pub fn to_vec(&self) -> Vec<u32> {
        self.as_slice().to_vec()
    }

    /// The rolling block-hash chain keys of this buffer's block-aligned
    /// prefixes (ascending depth; see
    /// [`chain_keys`](crate::kvcache::block::chain_keys)), memoized:
    /// the first call hashes the whole prefix, later calls on this
    /// buffer — or any clone, or any `extended` descendant — hash only
    /// blocks beyond what was already memoized.  Returns a shared
    /// handle, so a probe-heavy hot path (scheduler coverage probes,
    /// store peeks) pays O(1) per probe after the first.
    ///
    /// A `block_tokens` different from the memo's discards and rebuilds
    /// it (the engine uses one block size per run, so in practice the
    /// memo is built once and only ever extended).
    pub fn block_chain(&self, block_tokens: usize) -> Arc<Vec<BlockKey>> {
        let bt = block_tokens.max(1);
        let mut memo = self.chain.lock().unwrap_or_else(|e| e.into_inner());
        if memo.block_tokens != bt {
            memo.block_tokens = bt;
            memo.keys = Arc::new(Vec::new());
        }
        let want = self.len / bt;
        let have = memo.keys.len();
        if have < want {
            let keys = Arc::make_mut(&mut memo.keys);
            let mut h = keys.last().map_or(ROOT_HASH, |k| k.0);
            for b in have..want {
                h = hash_block(h, &self.data[b * bt..(b + 1) * bt]);
                keys.push((h, (b + 1) * bt));
            }
        }
        if memo.keys.len() > want {
            // Defensive: no current path constructs a view shorter than
            // its memo (clones share `len`; `extended` truncates), but a
            // probe must never see keys past the view — copy, not trust.
            return Arc::new(memo.keys[..want].to_vec());
        }
        Arc::clone(&memo.keys)
    }

    /// Drop memo keys hashed beyond the first `len` tokens.
    fn truncate_memo(chain: &Arc<Mutex<ChainMemo>>, len: usize) {
        let mut memo = chain.lock().unwrap_or_else(|e| e.into_inner());
        if memo.block_tokens > 0 {
            let keep = len / memo.block_tokens;
            if memo.keys.len() > keep {
                Arc::make_mut(&mut memo.keys).truncate(keep);
            }
        }
    }

    /// A fresh memo carrying `chain`'s keys over the first `len` tokens.
    fn memo_prefix(chain: &Arc<Mutex<ChainMemo>>, len: usize) -> ChainMemo {
        let memo = chain.lock().unwrap_or_else(|e| e.into_inner());
        if memo.block_tokens == 0 {
            return ChainMemo::default();
        }
        let keep = (len / memo.block_tokens).min(memo.keys.len());
        let keys = if keep == memo.keys.len() {
            Arc::clone(&memo.keys)
        } else {
            Arc::new(memo.keys[..keep].to_vec())
        };
        ChainMemo { block_tokens: memo.block_tokens, keys }
    }
}

impl Deref for TokenBuf {
    type Target = [u32];

    fn deref(&self) -> &[u32] {
        self.as_slice()
    }
}

impl From<Vec<u32>> for TokenBuf {
    fn from(v: Vec<u32>) -> Self {
        Self::from_vec(v)
    }
}

impl From<&[u32]> for TokenBuf {
    fn from(s: &[u32]) -> Self {
        Self::from_vec(s.to_vec())
    }
}

impl PartialEq for TokenBuf {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for TokenBuf {}

impl PartialEq<[u32]> for TokenBuf {
    fn eq(&self, other: &[u32]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<Vec<u32>> for TokenBuf {
    fn eq(&self, other: &Vec<u32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl fmt::Debug for TokenBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TokenBuf({} tokens", self.len)?;
        if !self.is_unique() {
            write!(f, ", shared")?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_is_shallow_and_equal() {
        let a = TokenBuf::from_vec(vec![1, 2, 3]);
        let b = a.clone();
        assert_eq!(a, b);
        assert!(!a.is_unique());
        assert_eq!(b.as_slice(), &[1, 2, 3]);
    }

    #[test]
    fn extend_in_place_when_unique() {
        let a = TokenBuf::from_vec(vec![1, 2]);
        let ptr = a.as_slice().as_ptr();
        let b = a.extended(&[3, 4]);
        assert_eq!(b.as_slice(), &[1, 2, 3, 4]);
        // Unique owner: same allocation (capacity growth aside, the Vec
        // had no spare capacity so the data may move — assert semantics
        // via uniqueness instead of pointer identity when it moved).
        assert!(b.is_unique());
        let _ = ptr; // pointer identity is not guaranteed across growth
    }

    #[test]
    fn extend_copies_when_shared() {
        let a = TokenBuf::from_vec(vec![1, 2]);
        let shared = a.clone();
        let b = a.extended(&[3]);
        assert_eq!(b.as_slice(), &[1, 2, 3]);
        assert_eq!(shared.as_slice(), &[1, 2], "sharer unaffected");
        assert!(b.is_unique());
    }

    #[test]
    fn truncated_view_does_not_leak_tail() {
        // A shared buffer extended twice from the same base: the second
        // extension must not see the first extension's tail.
        let base = TokenBuf::from_vec(vec![1, 2]);
        let x = base.clone().extended(&[10]);
        let y = base.extended(&[20]);
        assert_eq!(x.as_slice(), &[1, 2, 10]);
        assert_eq!(y.as_slice(), &[1, 2, 20]);
    }

    #[test]
    fn deref_gives_slice_ops() {
        let a = TokenBuf::from_vec((0..10).collect());
        assert_eq!(a.len(), 10);
        assert_eq!(&a[..3], &[0, 1, 2]);
        assert_eq!(a.iter().sum::<u32>(), 45);
    }

    #[test]
    fn block_chain_matches_unmemoized_hashing() {
        let toks: Vec<u32> = (0..50).collect();
        let buf = TokenBuf::from_vec(toks.clone());
        for bt in [1usize, 4, 16, 64] {
            assert_eq!(
                *buf.block_chain(bt),
                crate::kvcache::block::chain_keys(&toks, bt),
                "bt {bt}: memoized chain equals the direct hash walk"
            );
        }
    }

    #[test]
    fn block_chain_extends_incrementally_and_shares_across_clones() {
        let buf = TokenBuf::from_vec((0..32).collect());
        let c1 = buf.block_chain(16);
        assert_eq!(c1.len(), 2);
        // A clone reuses the exact same memoized vector.
        let clone = buf.clone();
        assert!(Arc::ptr_eq(&c1, &clone.block_chain(16)), "clones share the memo");
        // Growing the context extends the chain from the memoized tail;
        // the leading keys are bit-identical (same Arc contents).
        drop(clone);
        let grown = buf.extended(&(32..70).collect::<Vec<_>>());
        let c2 = grown.block_chain(16);
        assert_eq!(c2.len(), 4, "70 tokens = 4 full blocks");
        assert_eq!(c2[..2], c1[..], "old prefix keys unchanged");
        assert_eq!(*c2, crate::kvcache::block::chain_keys(grown.as_slice(), 16));
    }

    #[test]
    fn block_chain_survives_copy_on_extend_without_stale_keys() {
        // A shared buffer extended two ways: each descendant's chain
        // must hash its own tokens, with the common prefix carried over.
        let base = TokenBuf::from_vec((0..32).collect());
        let _warm = base.block_chain(16); // memoize before the split
        let x = base.clone().extended(&[100; 16]);
        let y = base.extended(&[200; 16]);
        assert_eq!(*x.block_chain(16), crate::kvcache::block::chain_keys(x.as_slice(), 16));
        assert_eq!(*y.block_chain(16), crate::kvcache::block::chain_keys(y.as_slice(), 16));
        assert_eq!(x.block_chain(16)[..2], y.block_chain(16)[..2], "shared prefix, same keys");
        assert_ne!(x.block_chain(16)[2], y.block_chain(16)[2], "divergent tails, different keys");
    }

    #[test]
    fn block_chain_rebuilds_on_block_size_change() {
        let buf = TokenBuf::from_vec((0..64).collect());
        assert_eq!(buf.block_chain(16).len(), 4);
        assert_eq!(buf.block_chain(32).len(), 2, "new block size rebuilds");
        assert_eq!(*buf.block_chain(32), crate::kvcache::block::chain_keys(buf.as_slice(), 32));
    }
}
