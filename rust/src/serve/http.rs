//! Minimal HTTP/1.1 transport over `std::net` — the offline stand-in
//! for a real server crate (hyper/axum are unavailable without
//! crates.io).
//!
//! Scope: exactly what the serving front end needs.  One request per
//! connection (the server answers with `Connection: close`), request
//! bodies sized by `Content-Length`, responses either sized
//! (`Content-Length`) or streamed with `Transfer-Encoding: chunked` —
//! the transport under token streaming.  A tiny blocking client
//! ([`http_request`]) rides along for loopback tests and examples; it
//! de-chunks transparently.
//!
//! The accept loop runs on its own OS thread and spawns a short-lived
//! thread per connection (connections here are loopback test/demo
//! traffic, not C10K).  [`Server::stop`] flips a shutdown flag and
//! pokes the listener with a wake-up connection so `accept` observes
//! it promptly.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Context as _;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Request method (`GET`, `POST`, ...), uppercased by the client.
    pub method: String,
    /// Request path including any query string, e.g. `/v2/stats`.
    pub path: String,
    /// Header name/value pairs in arrival order; names lowercased.
    pub headers: Vec<(String, String)>,
    /// Request body (empty unless `Content-Length` said otherwise).
    pub body: Vec<u8>,
}

impl Request {
    /// First header value for `name` (case-insensitive), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Body parsed as UTF-8 (empty string for an empty body).
    pub fn body_str(&self) -> anyhow::Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }
}

/// Response payload: sized or streamed.
pub enum Body {
    /// Whole payload, sent with `Content-Length`.
    Full(Vec<u8>),
    /// Streamed payload, sent with `Transfer-Encoding: chunked`; each
    /// yielded buffer becomes one chunk (empty buffers are skipped —
    /// an empty chunk would terminate the stream early).
    Chunks(Box<dyn Iterator<Item = Vec<u8>> + Send>),
}

/// An HTTP response under construction.
pub struct Response {
    /// Status code (the reason phrase is derived).
    pub status: u16,
    /// Extra headers beyond the transport-owned ones.
    pub headers: Vec<(String, String)>,
    /// Payload.
    pub body: Body,
}

impl Response {
    /// A sized response with a `Content-Type` header.
    pub fn full(status: u16, content_type: &str, body: impl Into<Vec<u8>>) -> Response {
        Response {
            status,
            headers: vec![("content-type".into(), content_type.into())],
            body: Body::Full(body.into()),
        }
    }

    /// A JSON response.
    pub fn json(status: u16, body: &str) -> Response {
        Response::full(status, "application/json", body.as_bytes().to_vec())
    }

    /// A chunk-streamed response (newline-delimited JSON events here).
    pub fn stream(status: u16, chunks: Box<dyn Iterator<Item = Vec<u8>> + Send>) -> Response {
        Response {
            status,
            headers: vec![("content-type".into(), "application/x-ndjson".into())],
            body: Body::Chunks(chunks),
        }
    }

    /// Add a header.
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_ascii_lowercase(), value.to_string()));
        self
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

/// Request handler implemented by the front end.  `Send + Sync` because
/// connections are served from short-lived threads.
pub trait Handler: Send + Sync {
    /// Produce the response for one request.
    fn handle(&self, req: Request) -> Response;
}

/// A running HTTP server; dropping it (or calling [`Server::stop`])
/// shuts the accept loop down.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (use `127.0.0.1:0` for an ephemeral test port) and
    /// serve `handler` until [`Server::stop`] or drop.
    pub fn start(addr: &str, handler: Arc<dyn Handler>) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let handler = Arc::clone(&handler);
                std::thread::spawn(move || {
                    // Connection errors (peer hangup, bad request
                    // framing) end this connection only.
                    let _ = serve_connection(stream, handler.as_ref());
                });
            }
        });
        Ok(Server { addr, shutdown, accept_thread: Some(accept_thread) })
    }

    /// The bound address (resolves the ephemeral port for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections and join the accept loop.
    pub fn stop(&mut self) {
        if self.accept_thread.is_none() {
            return;
        }
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_connection(stream: TcpStream, handler: &dyn Handler) -> anyhow::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let req = match read_request(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            let resp = Response::json(400, &format!("{{\"error\": \"{e}\"}}"));
            return write_response(stream, resp);
        }
    };
    let resp = handler.handle(req);
    write_response(stream, resp)
}

fn read_request(reader: &mut BufReader<TcpStream>) -> anyhow::Result<Request> {
    let mut line = String::new();
    reader.read_line(&mut line).context("read request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    anyhow::ensure!(version.starts_with("HTTP/1."), "unsupported version {version}");

    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).context("read header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (name, value) = h.split_once(':').context("malformed header")?;
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim().to_string();
        if name == "content-length" {
            content_length = value.parse().context("bad content-length")?;
        }
        headers.push((name, value));
    }
    anyhow::ensure!(content_length <= 16 << 20, "body too large");
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("read body")?;
    Ok(Request { method, path, headers, body })
}

fn write_response(mut stream: TcpStream, resp: Response) -> anyhow::Result<()> {
    let mut head = format!("HTTP/1.1 {} {}\r\n", resp.status, reason(resp.status));
    for (k, v) in &resp.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("connection: close\r\n");
    match resp.body {
        Body::Full(bytes) => {
            head.push_str(&format!("content-length: {}\r\n\r\n", bytes.len()));
            stream.write_all(head.as_bytes())?;
            stream.write_all(&bytes)?;
        }
        Body::Chunks(chunks) => {
            head.push_str("transfer-encoding: chunked\r\n\r\n");
            stream.write_all(head.as_bytes())?;
            for chunk in chunks {
                if chunk.is_empty() {
                    continue;
                }
                // Flush per chunk so a streaming client sees tokens as
                // they are produced, not at stream end.
                stream.write_all(format!("{:x}\r\n", chunk.len()).as_bytes())?;
                stream.write_all(&chunk)?;
                stream.write_all(b"\r\n")?;
                stream.flush()?;
            }
            stream.write_all(b"0\r\n\r\n")?;
        }
    }
    stream.flush()?;
    Ok(())
}

/// Blocking loopback HTTP client for tests and examples: sends one
/// request, reads the full (de-chunked) response.  Returns
/// `(status, headers, body)`.
pub fn http_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> anyhow::Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let mut w = stream.try_clone()?;
    let body = body.unwrap_or("");
    w.write_all(
        format!(
            "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    w.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).context("read status line")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line: {status_line:?}"))?;

    let mut headers = Vec::new();
    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value.parse().ok();
            }
            if name == "transfer-encoding" && value.eq_ignore_ascii_case("chunked") {
                chunked = true;
            }
            headers.push((name, value));
        }
    }

    let mut body = Vec::new();
    if chunked {
        loop {
            let mut size_line = String::new();
            reader.read_line(&mut size_line)?;
            let size = usize::from_str_radix(size_line.trim(), 16).context("bad chunk size")?;
            if size == 0 {
                let mut trailer = String::new();
                reader.read_line(&mut trailer)?;
                break;
            }
            let mut chunk = vec![0u8; size];
            reader.read_exact(&mut chunk)?;
            body.extend_from_slice(&chunk);
            let mut crlf = String::new();
            reader.read_line(&mut crlf)?;
        }
    } else if let Some(n) = content_length {
        body = vec![0u8; n];
        reader.read_exact(&mut body)?;
    } else {
        reader.read_to_end(&mut body)?;
    }
    Ok((status, headers, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Handler for Echo {
        fn handle(&self, req: Request) -> Response {
            match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/ping") => Response::json(200, r#"{"pong": true}"#),
                ("POST", "/echo") => {
                    Response::full(200, "text/plain", req.body)
                }
                ("GET", "/stream") => {
                    let chunks = (0..5).map(|i| format!("line {i}\n").into_bytes());
                    Response::stream(200, Box::new(chunks))
                }
                ("GET", "/busy") => Response::json(503, r#"{"error": "over capacity"}"#)
                    .with_header("retry-after", "1"),
                _ => Response::json(404, r#"{"error": "not found"}"#),
            }
        }
    }

    fn server() -> Server {
        Server::start("127.0.0.1:0", Arc::new(Echo)).unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let mut s = server();
        let (status, _, body) = http_request(s.addr(), "GET", "/ping", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, br#"{"pong": true}"#);
        s.stop();
    }

    #[test]
    fn post_body_roundtrip() {
        let s = server();
        let (status, _, body) =
            http_request(s.addr(), "POST", "/echo", Some("hello transport")).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"hello transport");
    }

    #[test]
    fn chunked_stream_reassembles() {
        let s = server();
        let (status, headers, body) = http_request(s.addr(), "GET", "/stream", None).unwrap();
        assert_eq!(status, 200);
        assert!(headers
            .iter()
            .any(|(k, v)| k == "transfer-encoding" && v.eq_ignore_ascii_case("chunked")));
        let text = String::from_utf8(body).unwrap();
        assert_eq!(text.lines().count(), 5);
        assert!(text.starts_with("line 0"));
    }

    #[test]
    fn backpressure_status_and_header() {
        let s = server();
        let (status, headers, _) = http_request(s.addr(), "GET", "/busy", None).unwrap();
        assert_eq!(status, 503);
        assert!(headers.iter().any(|(k, v)| k == "retry-after" && v == "1"));
    }

    #[test]
    fn unknown_path_404_and_sequential_requests() {
        let s = server();
        for _ in 0..3 {
            let (status, _, _) = http_request(s.addr(), "GET", "/nope", None).unwrap();
            assert_eq!(status, 404);
        }
    }

    #[test]
    fn stop_unblocks_accept() {
        let mut s = server();
        s.stop();
        s.stop(); // idempotent
        assert!(http_request(s.addr(), "GET", "/ping", None).is_err());
    }
}
