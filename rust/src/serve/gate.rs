//! Admission control shared by the live front end and the simulated
//! engine.
//!
//! One semantics, two enforcement points:
//!
//!   * **Virtual time** — the engine's arrival path
//!     (`engine::Engine::surface_arrivals`) applies [`AdmissionLimits`]
//!     against its scheduler queues (`sched::Queues`): an arrival that
//!     finds the waiting queue over the depth or token bound is
//!     load-shed and counted in `ServingStats::rejected_requests`.
//!     That is how open-loop overload sweeps measure goodput under
//!     admission control, deterministically.
//!   * **Wall clock** — the HTTP front end ([`LiveGate`]) applies the
//!     same limits to its in-flight request set; a shed request gets a
//!     `503` with `Retry-After` (see `serve::Frontend`).
//!
//! Both bounds zero (the default) disables the gate entirely; the
//! engine path is then bit-identical to the pre-front-end arrival code
//! (pinned by `prop_serve_off_bit_identical`).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::config::ServingConfig;

/// Admission bounds: how much backlog the serving system will queue
/// before shedding new work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionLimits {
    /// Maximum queued requests (turns) before shedding; 0 = unbounded.
    pub max_queue: usize,
    /// Maximum summed queued prompt tokens before shedding; 0 =
    /// unbounded.
    pub max_tokens: usize,
}

impl AdmissionLimits {
    /// The limits a serving config encodes (`admit_queue` /
    /// `admit_tokens`).
    pub fn from_config(cfg: &ServingConfig) -> AdmissionLimits {
        AdmissionLimits { max_queue: cfg.admit_queue, max_tokens: cfg.admit_tokens }
    }

    /// Whether any bound is active.
    pub fn enabled(&self) -> bool {
        self.max_queue > 0 || self.max_tokens > 0
    }

    /// Whether a new request may be admitted given the current backlog
    /// (`depth` queued requests holding `tokens` prompt tokens).
    /// Always true when disabled.
    pub fn admits(&self, depth: usize, tokens: usize) -> bool {
        let depth_over = self.max_queue > 0 && depth >= self.max_queue;
        let tokens_over = self.max_tokens > 0 && tokens >= self.max_tokens;
        !(depth_over || tokens_over)
    }
}

/// Wall-clock admission gate for the HTTP front end: lock-free
/// in-flight accounting with RAII release.
///
/// `try_admit` either returns an [`Admission`] guard (the request's
/// slot and token budget are held until the guard drops — i.e. for the
/// whole response, streamed or not) or counts a rejection for the
/// caller to turn into backpressure (`503` + `Retry-After`).
#[derive(Debug)]
pub struct LiveGate {
    limits: AdmissionLimits,
    inflight: AtomicUsize,
    inflight_tokens: AtomicUsize,
    submitted: AtomicU64,
    rejected: AtomicU64,
}

/// Counter snapshot for the stats endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GateCounters {
    /// Requests that reached the gate.
    pub submitted: u64,
    /// Requests shed at the gate.
    pub rejected: u64,
    /// Requests currently holding an [`Admission`].
    pub inflight: usize,
    /// Prompt tokens currently held by in-flight requests.
    pub inflight_tokens: usize,
}

impl LiveGate {
    /// Gate with the given limits.
    pub fn new(limits: AdmissionLimits) -> LiveGate {
        LiveGate {
            limits,
            inflight: AtomicUsize::new(0),
            inflight_tokens: AtomicUsize::new(0),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// The configured limits.
    pub fn limits(&self) -> AdmissionLimits {
        self.limits
    }

    /// Optimistically reserve a slot, then check: the small
    /// over-admission window of check-then-reserve is gone, and a
    /// losing reservation is rolled back before anyone observes its
    /// work.  True = admitted (reservation held).
    fn reserve(&self, prompt_tokens: usize) -> bool {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let depth = self.inflight.fetch_add(1, Ordering::SeqCst);
        let tokens = self.inflight_tokens.fetch_add(prompt_tokens, Ordering::SeqCst);
        if self.limits.admits(depth, tokens) {
            true
        } else {
            self.release(prompt_tokens);
            self.rejected.fetch_add(1, Ordering::Relaxed);
            false
        }
    }

    fn release(&self, prompt_tokens: usize) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        self.inflight_tokens.fetch_sub(prompt_tokens, Ordering::SeqCst);
    }

    /// Try to admit a request carrying `prompt_tokens`; `None` means
    /// shed (the rejection is already counted).
    pub fn try_admit(&self, prompt_tokens: usize) -> Option<Admission<'_>> {
        self.reserve(prompt_tokens).then_some(Admission { gate: self, prompt_tokens })
    }

    /// [`LiveGate::try_admit`] returning an owned (`'static`) guard —
    /// for handlers that must move the admission into a streamed
    /// response whose iterator outlives the handler call.
    pub fn try_admit_owned(self: &Arc<Self>, prompt_tokens: usize) -> Option<AdmissionOwned> {
        self.reserve(prompt_tokens)
            .then(|| AdmissionOwned { gate: Arc::clone(self), prompt_tokens })
    }

    /// Snapshot the counters.
    pub fn counters(&self) -> GateCounters {
        GateCounters {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::SeqCst),
            inflight_tokens: self.inflight_tokens.load(Ordering::SeqCst),
        }
    }
}

/// RAII admission: the slot and token budget return to the gate on
/// drop.
#[derive(Debug)]
pub struct Admission<'a> {
    gate: &'a LiveGate,
    prompt_tokens: usize,
}

impl Drop for Admission<'_> {
    fn drop(&mut self) {
        self.gate.release(self.prompt_tokens);
    }
}

/// Owned counterpart of [`Admission`] (keeps the gate alive via `Arc`);
/// see [`LiveGate::try_admit_owned`].
#[derive(Debug)]
pub struct AdmissionOwned {
    gate: Arc<LiveGate>,
    prompt_tokens: usize,
}

impl Drop for AdmissionOwned {
    fn drop(&mut self) {
        self.gate.release(self.prompt_tokens);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_limits_admit_everything() {
        let l = AdmissionLimits { max_queue: 0, max_tokens: 0 };
        assert!(!l.enabled());
        assert!(l.admits(usize::MAX - 1, usize::MAX - 1));
    }

    #[test]
    fn depth_and_token_bounds() {
        let l = AdmissionLimits { max_queue: 2, max_tokens: 100 };
        assert!(l.enabled());
        assert!(l.admits(1, 50));
        assert!(!l.admits(2, 0), "depth bound");
        assert!(!l.admits(0, 100), "token bound");
    }

    #[test]
    fn live_gate_sheds_and_releases() {
        let gate = LiveGate::new(AdmissionLimits { max_queue: 2, max_tokens: 0 });
        let a = gate.try_admit(10).expect("first fits");
        let _b = gate.try_admit(20).expect("second fits");
        assert!(gate.try_admit(5).is_none(), "third over depth bound");
        let c = gate.counters();
        assert_eq!((c.submitted, c.rejected, c.inflight, c.inflight_tokens), (3, 1, 2, 30));
        drop(a);
        let _d = gate.try_admit(5).expect("slot freed");
        let c = gate.counters();
        assert_eq!((c.submitted, c.rejected, c.inflight, c.inflight_tokens), (4, 1, 2, 25));
    }

    #[test]
    fn conservation_under_contention() {
        let gate = LiveGate::new(AdmissionLimits { max_queue: 4, max_tokens: 0 });
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..500 {
                        if let Some(adm) = gate.try_admit(3) {
                            std::hint::black_box(&adm);
                        }
                    }
                });
            }
        });
        let c = gate.counters();
        assert_eq!(c.submitted, 8 * 500);
        assert_eq!(c.inflight, 0, "every admission released");
        assert_eq!(c.inflight_tokens, 0);
        assert!(c.rejected < c.submitted, "some admissions must succeed");
    }

    #[test]
    fn owned_admission_moves_across_threads() {
        let gate = Arc::new(LiveGate::new(AdmissionLimits { max_queue: 1, max_tokens: 0 }));
        let adm = gate.try_admit_owned(4).expect("first fits");
        assert!(gate.try_admit_owned(1).is_none(), "slot held");
        let g2 = Arc::clone(&gate);
        std::thread::spawn(move || drop(adm)).join().unwrap();
        assert_eq!(g2.counters().inflight, 0);
        assert!(g2.try_admit_owned(1).is_some(), "slot released from other thread");
    }

    #[test]
    fn limits_from_config() {
        let cfg = ServingConfig { admit_queue: 7, admit_tokens: 9, ..Default::default() };
        assert_eq!(
            AdmissionLimits::from_config(&cfg),
            AdmissionLimits { max_queue: 7, max_tokens: 9 }
        );
        assert!(!AdmissionLimits::from_config(&ServingConfig::default()).enabled());
    }
}
