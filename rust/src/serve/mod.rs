//! The serving front end: an Inference-Protocol-style HTTP service in
//! front of the simulated cluster.
//!
//! The repo's core is a virtual-time simulator, so the front end plays
//! two roles:
//!
//!   * **Live protocol surface** ([`Frontend`] behind
//!     [`http::Server`]): request/response + streaming token transport
//!     with real admission control and backpressure (`503` +
//!     `Retry-After` from [`gate::LiveGate`]).  Token *content* is
//!     synthetic — the models themselves are synthetic stand-ins —
//!     but the protocol mechanics (framing, streaming, shedding) are
//!     real and tested over loopback TCP.
//!   * **Job bridge** (`POST /v2/jobs/simulate`): accepts a serving +
//!     workload configuration as JSON, runs it through the actual
//!     `cluster`/`sched`/`engine` stack in virtual time, and returns
//!     the stats — including goodput and SLO attainment — so a client
//!     can drive open-loop sweeps over the wire.
//!
//! Endpoints:
//!
//! | Method | Path                      | Purpose                       |
//! |--------|---------------------------|-------------------------------|
//! | GET    | `/v2/health/ready`        | readiness probe               |
//! | GET    | `/v2/stats`               | gate counters snapshot        |
//! | POST   | `/v2/models/{m}/infer`    | generate (stream or full)     |
//! | POST   | `/v2/jobs/simulate`       | run a sim job, return stats   |
//!
//! Admission semantics are shared with the engine's virtual-time gate
//! (`ServingConfig::{admit_queue, admit_tokens}`); see [`gate`] for
//! the one-semantics-two-clocks story, and [`openloop`] for the
//! open-loop traffic generator that drives overload experiments.

pub mod gate;
pub mod http;
pub mod openloop;
pub mod protocol;

pub use gate::{AdmissionLimits, GateCounters, LiveGate};
pub use http::{Handler, Request, Response, Server};
pub use openloop::{generate_open_loop, OpenLoopConfig, OpenLoopGen};
pub use protocol::InferRequest;

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::config::{ServingConfig, WorkloadConfig};
use crate::engine::executor::CostModel;
use crate::json::{self, Value};
use crate::rng::Rng;
use crate::tokenizer::Tokenizer;
use crate::workload;

use gate::AdmissionOwned;

/// Default request-completion SLO for goodput (seconds).
pub const DEFAULT_SLO_REQUEST_S: f64 = 30.0;
/// Default time-to-first-token SLO (seconds).
pub const DEFAULT_SLO_TTFT_S: f64 = 2.0;
/// Default inter-token-latency SLO (seconds).
pub const DEFAULT_SLO_ITL_S: f64 = 0.2;

/// Upper bound on `n_requests` a simulate job may ask for — the
/// endpoint is synchronous, so runaway jobs would pin the connection
/// thread.
const MAX_JOB_REQUESTS: usize = 4096;

/// The HTTP request handler; see the module docs for the endpoints.
pub struct Frontend {
    gate: Arc<LiveGate>,
    tokenizer: Tokenizer,
    n_models: usize,
}

impl Frontend {
    /// Front end over `n_models` synthetic models with the given
    /// admission limits.
    pub fn new(limits: AdmissionLimits, n_models: usize) -> Frontend {
        Frontend {
            gate: Arc::new(LiveGate::new(limits)),
            tokenizer: Tokenizer::new(2048),
            n_models: n_models.max(1),
        }
    }

    /// Shared handle to the admission gate (tests saturate it through
    /// this; operators could export its counters).
    pub fn gate(&self) -> Arc<LiveGate> {
        Arc::clone(&self.gate)
    }

    fn infer(&self, model: usize, req: Request) -> Response {
        if model >= self.n_models {
            return Response::json(
                404,
                &protocol::error_body(&format!(
                    "model {model} out of range (have {})",
                    self.n_models
                )),
            );
        }
        let body = match req.body_str().map_err(|e| e.to_string()).and_then(|s| {
            Value::parse(s).map_err(|e| e.to_string())
        }) {
            Ok(v) => v,
            Err(e) => return Response::json(400, &protocol::error_body(&e)),
        };
        let infer = match InferRequest::from_json(&body, &self.tokenizer) {
            Ok(r) => r,
            Err(e) => return Response::json(400, &protocol::error_body(&e.to_string())),
        };
        // Backpressure: shed before any generation work happens.  The
        // admission is held until the last byte of the response —
        // streamed responses carry it inside the chunk iterator.
        let Some(admission) = self.gate.try_admit_owned(infer.prompt.len()) else {
            return Response::json(503, &protocol::error_body("over capacity, retry later"))
                .with_header("retry-after", "1");
        };
        if infer.stream {
            let stream = TokenStream::new(model, &infer, admission);
            return Response::stream(200, Box::new(stream));
        }
        let tokens = synth_tokens(model, &infer.prompt, infer.max_tokens);
        let reply = protocol::infer_reply(model, &tokens, infer.session.as_deref());
        drop(admission);
        Response::json(200, &reply)
    }

    fn simulate(&self, req: &Request) -> Response {
        match run_simulate_job(req) {
            Ok(reply) => Response::json(200, &reply),
            Err(e) => Response::json(400, &protocol::error_body(&e.to_string())),
        }
    }

    fn stats(&self) -> Response {
        let c = self.gate.counters();
        let l = self.gate.limits();
        let body = json::obj(vec![
            ("submitted", json::num(c.submitted as f64)),
            ("rejected", json::num(c.rejected as f64)),
            ("inflight", json::num(c.inflight as f64)),
            ("inflight_tokens", json::num(c.inflight_tokens as f64)),
            ("admit_queue", json::num(l.max_queue as f64)),
            ("admit_tokens", json::num(l.max_tokens as f64)),
            ("n_models", json::num(self.n_models as f64)),
        ])
        .to_string_pretty();
        Response::json(200, &body)
    }
}

impl Handler for Frontend {
    fn handle(&self, req: Request) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/v2/health/ready") => Response::json(200, r#"{"ready": true}"#),
            ("GET", "/v2/stats") => self.stats(),
            ("POST", "/v2/jobs/simulate") => self.simulate(&req),
            ("POST", path) => match parse_model_path(path) {
                Some(model) => self.infer(model, req),
                None => Response::json(404, &protocol::error_body("unknown path")),
            },
            _ => Response::json(404, &protocol::error_body("unknown path")),
        }
    }
}

/// `/v2/models/{m}/infer` -> `m`.
fn parse_model_path(path: &str) -> Option<usize> {
    let rest = path.strip_prefix("/v2/models/")?;
    let (model, tail) = rest.split_once('/')?;
    if tail != "infer" {
        return None;
    }
    model.parse().ok()
}

/// Deterministic synthetic generation: same (model, prompt) -> same
/// tokens, drawn from the workload's content-token range so replies
/// look like everything else in the pipeline.
fn synth_tokens(model: usize, prompt: &[u32], n: usize) -> Vec<u32> {
    let mut h: u64 = 0xcbf29ce484222325 ^ model as u64;
    for &t in prompt {
        h ^= t as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut rng = Rng::new(h);
    (0..n).map(|_| 32 + rng.below(1900) as u32).collect()
}

/// Lazily generated token-event stream; holds its admission until the
/// final `done` event has been yielded.
struct TokenStream {
    rng: Rng,
    model: usize,
    session: Option<String>,
    index: usize,
    total: usize,
    done_sent: bool,
    _admission: AdmissionOwned,
}

impl TokenStream {
    fn new(model: usize, req: &InferRequest, admission: AdmissionOwned) -> TokenStream {
        let mut h: u64 = 0xcbf29ce484222325 ^ model as u64;
        for &t in &req.prompt {
            h ^= t as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        TokenStream {
            rng: Rng::new(h),
            model,
            session: req.session.clone(),
            index: 0,
            total: req.max_tokens,
            done_sent: false,
            _admission: admission,
        }
    }
}

impl Iterator for TokenStream {
    type Item = Vec<u8>;

    fn next(&mut self) -> Option<Vec<u8>> {
        if self.index < self.total {
            let token = 32 + self.rng.below(1900) as u32;
            let ev = protocol::token_event(self.index, token);
            self.index += 1;
            return Some(ev.into_bytes());
        }
        if !self.done_sent {
            self.done_sent = true;
            let ev = protocol::done_event(self.model, self.total, self.session.as_deref());
            return Some(ev.into_bytes());
        }
        None
    }
}

/// Parse and run one `POST /v2/jobs/simulate` body; returns the reply
/// JSON.  The body may carry `serving` ([`ServingConfig::from_json`]),
/// either `open_loop` ([`OpenLoopConfig::from_json`]) or `workload`
/// ([`WorkloadConfig::from_json`]), `kv_bytes_per_token`, and `slo`
/// (`request_s` / `ttft_s` / `itl_s`) — everything defaults.
fn run_simulate_job(req: &Request) -> anyhow::Result<String> {
    let body = Value::parse(req.body_str()?)?;
    let scfg = match body.get("serving") {
        Some(v) => ServingConfig::from_json(v)?,
        None => ServingConfig::default(),
    };
    let (wl, wl_json, n_models) = match (body.get("open_loop"), body.get("workload")) {
        (Some(_), Some(_)) => anyhow::bail!("give either open_loop or workload, not both"),
        (Some(ol), None) => {
            let cfg = OpenLoopConfig::from_json(ol)?;
            (generate_open_loop(&cfg), cfg.to_json(), cfg.base.n_models)
        }
        (None, wl) => {
            let cfg = match wl {
                Some(v) => WorkloadConfig::from_json(v)?,
                None => WorkloadConfig::default(),
            };
            (workload::generate(&cfg), cfg.to_json(), cfg.n_models)
        }
    };
    anyhow::ensure!(
        wl.len() <= MAX_JOB_REQUESTS,
        "n_requests {} over the job cap {MAX_JOB_REQUESTS}",
        wl.len()
    );
    let kv_bpt = match body.get("kv_bytes_per_token") {
        None => 2048,
        Some(v) => v.as_u64().ok_or_else(|| anyhow::anyhow!("kv_bytes_per_token: want number"))?,
    };
    let slo = |key: &str, default: f64| -> f64 {
        body.at(&["slo", key]).and_then(Value::as_f64).unwrap_or(default)
    };
    let slo_req = slo("request_s", DEFAULT_SLO_REQUEST_S);
    let slo_ttft = slo("ttft_s", DEFAULT_SLO_TTFT_S);
    let slo_itl = slo("itl_s", DEFAULT_SLO_ITL_S);

    let out = Cluster::new(scfg.clone(), kv_bpt, n_models).run_sim(CostModel::default(), wl);
    let m = &out.merged;
    Ok(json::obj(vec![
        ("serving", scfg.to_json()),
        ("workload", wl_json),
        ("cluster", out.to_json()),
        (
            "slo",
            json::obj(vec![
                ("request_s", json::num(slo_req)),
                ("ttft_s", json::num(slo_ttft)),
                ("itl_s", json::num(slo_itl)),
                ("goodput_rps", json::num(m.goodput_rps(slo_req))),
                ("ttft_attainment", json::num(m.slo_ttft_attainment(slo_ttft))),
                ("itl_attainment", json::num(m.slo_itl_attainment(slo_itl))),
            ]),
        ),
    ])
    .to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::http::http_request;
    use super::*;

    fn start(limits: AdmissionLimits) -> (Server, Arc<LiveGate>) {
        let fe = Frontend::new(limits, 4);
        let gate = fe.gate();
        let server = Server::start("127.0.0.1:0", Arc::new(fe)).unwrap();
        (server, gate)
    }

    fn unlimited() -> AdmissionLimits {
        AdmissionLimits { max_queue: 0, max_tokens: 0 }
    }

    #[test]
    fn health_and_stats() {
        let (s, _) = start(unlimited());
        let (status, _, body) = http_request(s.addr(), "GET", "/v2/health/ready", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(
            Value::parse(std::str::from_utf8(&body).unwrap())
                .unwrap()
                .get("ready")
                .unwrap()
                .as_bool(),
            Some(true)
        );
        let (status, _, body) = http_request(s.addr(), "GET", "/v2/stats", None).unwrap();
        assert_eq!(status, 200);
        let v = Value::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("inflight").unwrap().as_usize(), Some(0));
        assert_eq!(v.get("n_models").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn infer_full_reply_is_deterministic() {
        let (s, _) = start(unlimited());
        let body = r#"{"text": "what is the capital", "max_tokens": 6, "session": "u1"}"#;
        let (status, _, first) =
            http_request(s.addr(), "POST", "/v2/models/2/infer", Some(body)).unwrap();
        assert_eq!(status, 200);
        let v = Value::parse(std::str::from_utf8(&first).unwrap()).unwrap();
        assert_eq!(v.get("generated").unwrap().as_usize(), Some(6));
        assert_eq!(v.get("model").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("session").unwrap().as_str(), Some("u1"));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 6);
        let (_, _, second) =
            http_request(s.addr(), "POST", "/v2/models/2/infer", Some(body)).unwrap();
        assert_eq!(first, second, "same model+prompt must generate identically");
        // A different model diverges.
        let (_, _, other) =
            http_request(s.addr(), "POST", "/v2/models/3/infer", Some(body)).unwrap();
        let vo = Value::parse(std::str::from_utf8(&other).unwrap()).unwrap();
        assert_ne!(
            vo.get("tokens").unwrap().to_string(),
            v.get("tokens").unwrap().to_string()
        );
    }

    #[test]
    fn infer_streams_ndjson_token_events() {
        let (s, _) = start(unlimited());
        let body = r#"{"tokens": [1, 50, 51, 52], "max_tokens": 5, "stream": true}"#;
        let (status, headers, payload) =
            http_request(s.addr(), "POST", "/v2/models/0/infer", Some(body)).unwrap();
        assert_eq!(status, 200);
        assert!(headers
            .iter()
            .any(|(k, v)| k == "transfer-encoding" && v.eq_ignore_ascii_case("chunked")));
        let text = String::from_utf8(payload).unwrap();
        let events: Vec<Value> =
            text.lines().map(|l| Value::parse(l).unwrap()).collect();
        assert_eq!(events.len(), 6, "5 tokens + done");
        for (i, e) in events[..5].iter().enumerate() {
            assert_eq!(e.get("index").unwrap().as_usize(), Some(i));
            assert!(e.get("token").unwrap().as_u64().is_some());
        }
        assert_eq!(events[5].get("done").unwrap().as_bool(), Some(true));
        assert_eq!(events[5].get("generated").unwrap().as_usize(), Some(5));
    }

    #[test]
    fn sheds_with_503_when_saturated() {
        let (s, gate) = start(AdmissionLimits { max_queue: 1, max_tokens: 0 });
        // Hold the only slot, then hit the endpoint.
        let _held = gate.try_admit_owned(1).unwrap();
        let body = r#"{"tokens": [1, 2], "max_tokens": 2}"#;
        let (status, headers, _) =
            http_request(s.addr(), "POST", "/v2/models/0/infer", Some(body)).unwrap();
        assert_eq!(status, 503);
        assert!(headers.iter().any(|(k, v)| k == "retry-after" && v == "1"));
        let c = gate.counters();
        assert_eq!(c.rejected, 1);
        drop(_held);
        let (status, _, _) =
            http_request(s.addr(), "POST", "/v2/models/0/infer", Some(body)).unwrap();
        assert_eq!(status, 200, "recovers once the backlog drains");
    }

    #[test]
    fn rejects_bad_requests_and_paths() {
        let (s, _) = start(unlimited());
        for (path, body, want) in [
            ("/v2/models/9/infer", r#"{"tokens": [1]}"#, 404), // model range
            ("/v2/models/0/infer", "not json", 400),
            ("/v2/models/0/infer", r#"{}"#, 400), // no prompt
            ("/v2/models/x/infer", r#"{"tokens": [1]}"#, 404),
            ("/v2/nope", r#"{}"#, 404),
        ] {
            let (status, _, _) = http_request(s.addr(), "POST", path, Some(body)).unwrap();
            assert_eq!(status, want, "{path} {body}");
        }
        let (status, _, _) = http_request(s.addr(), "DELETE", "/v2/stats", None).unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn simulate_job_runs_cluster_and_reports_slo() {
        let (s, _) = start(unlimited());
        let body = r#"{
            "serving": {"replicas": 2, "admit_queue": 8},
            "open_loop": {"base": {"n_requests": 24, "qps": 4.0, "seed": 3},
                          "pareto_alpha": 1.5, "users": 100},
            "slo": {"request_s": 60.0}
        }"#;
        let (status, _, reply) =
            http_request(s.addr(), "POST", "/v2/jobs/simulate", Some(body)).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&reply));
        let v = Value::parse(std::str::from_utf8(&reply).unwrap()).unwrap();
        let submitted = v.at(&["cluster", "stats", "submitted_requests"]).unwrap();
        assert_eq!(submitted.as_usize(), Some(24), "gate on: every arrival counted");
        let completed =
            v.at(&["cluster", "stats", "completed_requests"]).unwrap().as_u64().unwrap();
        let rejected =
            v.at(&["cluster", "stats", "rejected_requests"]).unwrap().as_u64().unwrap();
        assert_eq!(completed + rejected, 24, "conservation over the wire");
        let good = v.at(&["slo", "goodput_rps"]).unwrap().as_f64().unwrap();
        assert!(good >= 0.0);
        let att = v.at(&["slo", "ttft_attainment"]).unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&att));
        assert_eq!(v.at(&["slo", "request_s"]).unwrap().as_f64(), Some(60.0));
    }

    #[test]
    fn simulate_job_caps_size_and_validates() {
        let (s, _) = start(unlimited());
        let too_big = r#"{"workload": {"n_requests": 100000}}"#;
        let (status, _, _) =
            http_request(s.addr(), "POST", "/v2/jobs/simulate", Some(too_big)).unwrap();
        assert_eq!(status, 400);
        let both = r#"{"workload": {}, "open_loop": {}}"#;
        let (status, _, _) =
            http_request(s.addr(), "POST", "/v2/jobs/simulate", Some(both)).unwrap();
        assert_eq!(status, 400);
    }

    #[test]
    fn parse_model_path_shapes() {
        assert_eq!(parse_model_path("/v2/models/0/infer"), Some(0));
        assert_eq!(parse_model_path("/v2/models/12/infer"), Some(12));
        assert_eq!(parse_model_path("/v2/models/12/other"), None);
        assert_eq!(parse_model_path("/v2/models/abc/infer"), None);
        assert_eq!(parse_model_path("/v2/models/"), None);
    }
}
