//! The serving front end: an Inference-Protocol-style HTTP service in
//! front of the simulated cluster.
//!
//! The repo's core is a virtual-time simulator, so the front end plays
//! two roles:
//!
//!   * **Live protocol surface** ([`Frontend`] behind
//!     [`http::Server`]): request/response + streaming token transport
//!     with real admission control and backpressure (`503` +
//!     `Retry-After` from [`gate::LiveGate`]).  Token *content* is
//!     synthetic — the models themselves are synthetic stand-ins —
//!     but the protocol mechanics (framing, streaming, shedding) are
//!     real and tested over loopback TCP.
//!   * **Job bridge** (`POST /v2/jobs/simulate`): accepts a serving +
//!     workload configuration as JSON, runs it through the actual
//!     `cluster`/`sched`/`engine` stack in virtual time, and returns
//!     the stats — including goodput and SLO attainment — so a client
//!     can drive open-loop sweeps over the wire.
//!
//! Endpoints:
//!
//! | Method | Path                      | Purpose                       |
//! |--------|---------------------------|-------------------------------|
//! | GET    | `/v2/health/ready`        | readiness probe               |
//! | GET    | `/v2/stats`               | gate counters + last-job view |
//! | GET    | `/v2/metrics`             | Prometheus text exposition    |
//! | POST   | `/v2/models/{m}/infer`    | generate (stream or full)     |
//! | POST   | `/v2/jobs/simulate`       | run a sim job, return stats   |
//!
//! `/v2/metrics` serves the live gate counters plus a snapshot of the
//! most recent simulate job in Prometheus text format (version 0.0.4),
//! so a scraper pointed at the front end sees shedding and phase
//! attribution without parsing results JSON.  When the last job ran
//! with `serving.obs = true`, `/v2/stats` additionally carries the
//! per-shard store counters and per-model phase histograms.
//!
//! Admission semantics are shared with the engine's virtual-time gate
//! (`ServingConfig::{admit_queue, admit_tokens}`); see [`gate`] for
//! the one-semantics-two-clocks story, and [`openloop`] for the
//! open-loop traffic generator that drives overload experiments.

pub mod gate;
pub mod http;
pub mod openloop;
pub mod protocol;

pub use gate::{AdmissionLimits, GateCounters, LiveGate};
pub use http::{Handler, Request, Response, Server};
pub use openloop::{generate_open_loop, OpenLoopConfig, OpenLoopGen};
pub use protocol::InferRequest;

use std::sync::{Arc, Mutex};

use crate::cluster::{Cluster, ClusterStats};
use crate::config::{ServingConfig, WorkloadConfig};
use crate::engine::executor::CostModel;
use crate::json::{self, Value};
use crate::rng::Rng;
use crate::store::ShardStats;
use crate::tokenizer::Tokenizer;
use crate::workload;

use gate::AdmissionOwned;

/// Default request-completion SLO for goodput (seconds).
pub const DEFAULT_SLO_REQUEST_S: f64 = 30.0;
/// Default time-to-first-token SLO (seconds).
pub const DEFAULT_SLO_TTFT_S: f64 = 2.0;
/// Default inter-token-latency SLO (seconds).
pub const DEFAULT_SLO_ITL_S: f64 = 0.2;

/// Upper bound on `n_requests` a simulate job may ask for — the
/// endpoint is synchronous, so runaway jobs would pin the connection
/// thread.
const MAX_JOB_REQUESTS: usize = 4096;

/// The HTTP request handler; see the module docs for the endpoints.
pub struct Frontend {
    gate: Arc<LiveGate>,
    tokenizer: Tokenizer,
    n_models: usize,
    /// Stats of the most recent `POST /v2/jobs/simulate` run — the
    /// source for the job-scoped blocks of `/v2/stats` and
    /// `/v2/metrics`.  `None` until the first job completes.
    last_job: Mutex<Option<ClusterStats>>,
}

impl Frontend {
    /// Front end over `n_models` synthetic models with the given
    /// admission limits.
    pub fn new(limits: AdmissionLimits, n_models: usize) -> Frontend {
        Frontend {
            gate: Arc::new(LiveGate::new(limits)),
            tokenizer: Tokenizer::new(2048),
            n_models: n_models.max(1),
            last_job: Mutex::new(None),
        }
    }

    /// Shared handle to the admission gate (tests saturate it through
    /// this; operators could export its counters).
    pub fn gate(&self) -> Arc<LiveGate> {
        Arc::clone(&self.gate)
    }

    fn infer(&self, model: usize, req: Request) -> Response {
        if model >= self.n_models {
            return Response::json(
                404,
                &protocol::error_body(&format!(
                    "model {model} out of range (have {})",
                    self.n_models
                )),
            );
        }
        let body = match req.body_str().map_err(|e| e.to_string()).and_then(|s| {
            Value::parse(s).map_err(|e| e.to_string())
        }) {
            Ok(v) => v,
            Err(e) => return Response::json(400, &protocol::error_body(&e)),
        };
        let infer = match InferRequest::from_json(&body, &self.tokenizer) {
            Ok(r) => r,
            Err(e) => return Response::json(400, &protocol::error_body(&e.to_string())),
        };
        // Backpressure: shed before any generation work happens.  The
        // admission is held until the last byte of the response —
        // streamed responses carry it inside the chunk iterator.
        let Some(admission) = self.gate.try_admit_owned(infer.prompt.len()) else {
            return Response::json(503, &protocol::error_body("over capacity, retry later"))
                .with_header("retry-after", "1");
        };
        if infer.stream {
            let stream = TokenStream::new(model, &infer, admission);
            return Response::stream(200, Box::new(stream));
        }
        let tokens = synth_tokens(model, &infer.prompt, infer.max_tokens);
        let reply = protocol::infer_reply(model, &tokens, infer.session.as_deref());
        drop(admission);
        Response::json(200, &reply)
    }

    fn simulate(&self, req: &Request) -> Response {
        match run_simulate_job(req) {
            Ok((reply, out)) => {
                *self.last_job.lock().unwrap_or_else(|e| e.into_inner()) = Some(out);
                Response::json(200, &reply)
            }
            Err(e) => Response::json(400, &protocol::error_body(&e.to_string())),
        }
    }

    fn stats(&self) -> Response {
        let c = self.gate.counters();
        let l = self.gate.limits();
        let mut entries = vec![
            ("submitted", json::num(c.submitted as f64)),
            ("rejected", json::num(c.rejected as f64)),
            ("inflight", json::num(c.inflight as f64)),
            ("inflight_tokens", json::num(c.inflight_tokens as f64)),
            ("admit_queue", json::num(l.max_queue as f64)),
            ("admit_tokens", json::num(l.max_tokens as f64)),
            ("n_models", json::num(self.n_models as f64)),
        ];
        // Job-scoped diagnostics: only present once a simulate job ran
        // with the matching features on, so the base response shape is
        // untouched for plain protocol deployments.
        if let Some(job) = self.last_job.lock().unwrap_or_else(|e| e.into_inner()).as_ref() {
            if !job.store_shards.is_empty() {
                entries.push((
                    "store_shards",
                    Value::Arr(job.store_shards.iter().map(ShardStats::to_json).collect()),
                ));
            }
            if !job.merged.phases.is_empty() {
                entries.push((
                    "phases",
                    Value::Arr(job.merged.phases.iter().map(|p| p.to_json()).collect()),
                ));
            }
        }
        let body = json::obj(entries).to_string_pretty();
        Response::json(200, &body)
    }

    /// `GET /v2/metrics`: Prometheus text exposition.  Gate counters
    /// are live (and monotone where named `_total`); job metrics are a
    /// snapshot of the last simulate run.
    fn metrics(&self) -> Response {
        let c = self.gate.counters();
        let mut out = String::new();
        let one = |out: &mut String, name: &str, kind: &str, help: &str, v: f64| {
            prom_block(out, name, kind, help, &[(String::new(), v)]);
        };
        one(
            &mut out,
            "icarus_gate_submitted_total",
            "counter",
            "Requests that reached the admission gate.",
            c.submitted as f64,
        );
        one(
            &mut out,
            "icarus_gate_rejected_total",
            "counter",
            "Requests shed at the admission gate.",
            c.rejected as f64,
        );
        one(
            &mut out,
            "icarus_gate_inflight",
            "gauge",
            "Requests currently holding an admission.",
            c.inflight as f64,
        );
        one(
            &mut out,
            "icarus_gate_inflight_tokens",
            "gauge",
            "Prompt tokens held by in-flight requests.",
            c.inflight_tokens as f64,
        );
        if let Some(job) = self.last_job.lock().unwrap_or_else(|e| e.into_inner()).as_ref() {
            let m = &job.merged;
            one(
                &mut out,
                "icarus_job_completed_requests",
                "gauge",
                "Requests completed by the last simulate job.",
                m.completed_requests as f64,
            );
            one(
                &mut out,
                "icarus_job_generated_tokens",
                "gauge",
                "Tokens generated by the last simulate job.",
                m.generated_tokens as f64,
            );
            if !m.phases.is_empty() {
                let mut samples = Vec::new();
                for (model, p) in m.phases.iter().enumerate() {
                    for (phase, h) in [
                        ("queue", &p.queue),
                        ("prefill", &p.prefill),
                        ("stall", &p.stall),
                        ("decode", &p.decode),
                    ] {
                        samples
                            .push((format!("{{model=\"{model}\",phase=\"{phase}\"}}"), h.sum()));
                    }
                }
                prom_block(
                    &mut out,
                    "icarus_phase_seconds_total",
                    "counter",
                    "Virtual seconds per request phase over the last simulate job (obs on).",
                    &samples,
                );
            }
            if !job.store_shards.is_empty() {
                let shard_samples = |f: &dyn Fn(&ShardStats) -> u64| -> Vec<(String, f64)> {
                    job.store_shards
                        .iter()
                        .enumerate()
                        .map(|(i, s)| (format!("{{shard=\"{i}\"}}"), f(s) as f64))
                        .collect()
                };
                prom_block(
                    &mut out,
                    "icarus_store_shard_hits",
                    "gauge",
                    "Blocks restored per store shard over the last simulate job.",
                    &shard_samples(&|s| s.hits),
                );
                prom_block(
                    &mut out,
                    "icarus_store_shard_evictions",
                    "gauge",
                    "Entries evicted per store shard over the last simulate job.",
                    &shard_samples(&|s| s.evictions),
                );
                prom_block(
                    &mut out,
                    "icarus_store_shard_contended",
                    "gauge",
                    "Contended lock acquisitions per store shard over the last simulate job.",
                    &shard_samples(&|s| s.contended),
                );
            }
        }
        Response::full(200, "text/plain; version=0.0.4", out.into_bytes())
    }
}

/// Append one metric family in Prometheus text exposition format:
/// `# HELP` / `# TYPE` header, then one sample line per label set
/// (the label string is either empty or a complete `{k="v",...}`).
fn prom_block(out: &mut String, name: &str, kind: &str, help: &str, samples: &[(String, f64)]) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    for (labels, value) in samples {
        let _ = writeln!(out, "{name}{labels} {value}");
    }
}

impl Handler for Frontend {
    fn handle(&self, req: Request) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/v2/health/ready") => Response::json(200, r#"{"ready": true}"#),
            ("GET", "/v2/stats") => self.stats(),
            ("GET", "/v2/metrics") => self.metrics(),
            ("POST", "/v2/jobs/simulate") => self.simulate(&req),
            ("POST", path) => match parse_model_path(path) {
                Some(model) => self.infer(model, req),
                None => Response::json(404, &protocol::error_body("unknown path")),
            },
            _ => Response::json(404, &protocol::error_body("unknown path")),
        }
    }
}

/// `/v2/models/{m}/infer` -> `m`.
fn parse_model_path(path: &str) -> Option<usize> {
    let rest = path.strip_prefix("/v2/models/")?;
    let (model, tail) = rest.split_once('/')?;
    if tail != "infer" {
        return None;
    }
    model.parse().ok()
}

/// Deterministic synthetic generation: same (model, prompt) -> same
/// tokens, drawn from the workload's content-token range so replies
/// look like everything else in the pipeline.
fn synth_tokens(model: usize, prompt: &[u32], n: usize) -> Vec<u32> {
    let mut h: u64 = 0xcbf29ce484222325 ^ model as u64;
    for &t in prompt {
        h ^= t as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut rng = Rng::new(h);
    (0..n).map(|_| 32 + rng.below(1900) as u32).collect()
}

/// Lazily generated token-event stream; holds its admission until the
/// final `done` event has been yielded.
struct TokenStream {
    rng: Rng,
    model: usize,
    session: Option<String>,
    index: usize,
    total: usize,
    done_sent: bool,
    _admission: AdmissionOwned,
}

impl TokenStream {
    fn new(model: usize, req: &InferRequest, admission: AdmissionOwned) -> TokenStream {
        let mut h: u64 = 0xcbf29ce484222325 ^ model as u64;
        for &t in &req.prompt {
            h ^= t as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        TokenStream {
            rng: Rng::new(h),
            model,
            session: req.session.clone(),
            index: 0,
            total: req.max_tokens,
            done_sent: false,
            _admission: admission,
        }
    }
}

impl Iterator for TokenStream {
    type Item = Vec<u8>;

    fn next(&mut self) -> Option<Vec<u8>> {
        if self.index < self.total {
            let token = 32 + self.rng.below(1900) as u32;
            let ev = protocol::token_event(self.index, token);
            self.index += 1;
            return Some(ev.into_bytes());
        }
        if !self.done_sent {
            self.done_sent = true;
            let ev = protocol::done_event(self.model, self.total, self.session.as_deref());
            return Some(ev.into_bytes());
        }
        None
    }
}

/// Parse and run one `POST /v2/jobs/simulate` body; returns the reply
/// JSON plus the raw cluster stats (deposited as the front end's
/// last-job snapshot).  The body may carry `serving`
/// ([`ServingConfig::from_json`]), either `open_loop`
/// ([`OpenLoopConfig::from_json`]) or `workload`
/// ([`WorkloadConfig::from_json`]), `kv_bytes_per_token`, and `slo`
/// (`request_s` / `ttft_s` / `itl_s`) — everything defaults.
fn run_simulate_job(req: &Request) -> anyhow::Result<(String, ClusterStats)> {
    let body = Value::parse(req.body_str()?)?;
    let scfg = match body.get("serving") {
        Some(v) => ServingConfig::from_json(v)?,
        None => ServingConfig::default(),
    };
    let (wl, wl_json, n_models) = match (body.get("open_loop"), body.get("workload")) {
        (Some(_), Some(_)) => anyhow::bail!("give either open_loop or workload, not both"),
        (Some(ol), None) => {
            let cfg = OpenLoopConfig::from_json(ol)?;
            (generate_open_loop(&cfg), cfg.to_json(), cfg.base.n_models)
        }
        (None, wl) => {
            let cfg = match wl {
                Some(v) => WorkloadConfig::from_json(v)?,
                None => WorkloadConfig::default(),
            };
            (workload::generate(&cfg), cfg.to_json(), cfg.n_models)
        }
    };
    anyhow::ensure!(
        wl.len() <= MAX_JOB_REQUESTS,
        "n_requests {} over the job cap {MAX_JOB_REQUESTS}",
        wl.len()
    );
    let kv_bpt = match body.get("kv_bytes_per_token") {
        None => 2048,
        Some(v) => v.as_u64().ok_or_else(|| anyhow::anyhow!("kv_bytes_per_token: want number"))?,
    };
    let slo = |key: &str, default: f64| -> f64 {
        body.at(&["slo", key]).and_then(Value::as_f64).unwrap_or(default)
    };
    let slo_req = slo("request_s", DEFAULT_SLO_REQUEST_S);
    let slo_ttft = slo("ttft_s", DEFAULT_SLO_TTFT_S);
    let slo_itl = slo("itl_s", DEFAULT_SLO_ITL_S);

    let out = Cluster::new(scfg.clone(), kv_bpt, n_models).run_sim(CostModel::default(), wl);
    let m = &out.merged;
    let reply = json::obj(vec![
        ("serving", scfg.to_json()),
        ("workload", wl_json),
        ("cluster", out.to_json()),
        (
            "slo",
            json::obj(vec![
                ("request_s", json::num(slo_req)),
                ("ttft_s", json::num(slo_ttft)),
                ("itl_s", json::num(slo_itl)),
                ("goodput_rps", json::num(m.goodput_rps(slo_req))),
                ("ttft_attainment", json::num(m.slo_ttft_attainment(slo_ttft))),
                ("itl_attainment", json::num(m.slo_itl_attainment(slo_itl))),
            ]),
        ),
    ])
    .to_string_pretty();
    Ok((reply, out))
}

#[cfg(test)]
mod tests {
    use super::http::http_request;
    use super::*;

    fn start(limits: AdmissionLimits) -> (Server, Arc<LiveGate>) {
        let fe = Frontend::new(limits, 4);
        let gate = fe.gate();
        let server = Server::start("127.0.0.1:0", Arc::new(fe)).unwrap();
        (server, gate)
    }

    fn unlimited() -> AdmissionLimits {
        AdmissionLimits { max_queue: 0, max_tokens: 0 }
    }

    #[test]
    fn health_and_stats() {
        let (s, _) = start(unlimited());
        let (status, _, body) = http_request(s.addr(), "GET", "/v2/health/ready", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(
            Value::parse(std::str::from_utf8(&body).unwrap())
                .unwrap()
                .get("ready")
                .unwrap()
                .as_bool(),
            Some(true)
        );
        let (status, _, body) = http_request(s.addr(), "GET", "/v2/stats", None).unwrap();
        assert_eq!(status, 200);
        let v = Value::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("inflight").unwrap().as_usize(), Some(0));
        assert_eq!(v.get("n_models").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn infer_full_reply_is_deterministic() {
        let (s, _) = start(unlimited());
        let body = r#"{"text": "what is the capital", "max_tokens": 6, "session": "u1"}"#;
        let (status, _, first) =
            http_request(s.addr(), "POST", "/v2/models/2/infer", Some(body)).unwrap();
        assert_eq!(status, 200);
        let v = Value::parse(std::str::from_utf8(&first).unwrap()).unwrap();
        assert_eq!(v.get("generated").unwrap().as_usize(), Some(6));
        assert_eq!(v.get("model").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("session").unwrap().as_str(), Some("u1"));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 6);
        let (_, _, second) =
            http_request(s.addr(), "POST", "/v2/models/2/infer", Some(body)).unwrap();
        assert_eq!(first, second, "same model+prompt must generate identically");
        // A different model diverges.
        let (_, _, other) =
            http_request(s.addr(), "POST", "/v2/models/3/infer", Some(body)).unwrap();
        let vo = Value::parse(std::str::from_utf8(&other).unwrap()).unwrap();
        assert_ne!(
            vo.get("tokens").unwrap().to_string(),
            v.get("tokens").unwrap().to_string()
        );
    }

    #[test]
    fn infer_streams_ndjson_token_events() {
        let (s, _) = start(unlimited());
        let body = r#"{"tokens": [1, 50, 51, 52], "max_tokens": 5, "stream": true}"#;
        let (status, headers, payload) =
            http_request(s.addr(), "POST", "/v2/models/0/infer", Some(body)).unwrap();
        assert_eq!(status, 200);
        assert!(headers
            .iter()
            .any(|(k, v)| k == "transfer-encoding" && v.eq_ignore_ascii_case("chunked")));
        let text = String::from_utf8(payload).unwrap();
        let events: Vec<Value> =
            text.lines().map(|l| Value::parse(l).unwrap()).collect();
        assert_eq!(events.len(), 6, "5 tokens + done");
        for (i, e) in events[..5].iter().enumerate() {
            assert_eq!(e.get("index").unwrap().as_usize(), Some(i));
            assert!(e.get("token").unwrap().as_u64().is_some());
        }
        assert_eq!(events[5].get("done").unwrap().as_bool(), Some(true));
        assert_eq!(events[5].get("generated").unwrap().as_usize(), Some(5));
    }

    #[test]
    fn sheds_with_503_when_saturated() {
        let (s, gate) = start(AdmissionLimits { max_queue: 1, max_tokens: 0 });
        // Hold the only slot, then hit the endpoint.
        let _held = gate.try_admit_owned(1).unwrap();
        let body = r#"{"tokens": [1, 2], "max_tokens": 2}"#;
        let (status, headers, _) =
            http_request(s.addr(), "POST", "/v2/models/0/infer", Some(body)).unwrap();
        assert_eq!(status, 503);
        assert!(headers.iter().any(|(k, v)| k == "retry-after" && v == "1"));
        let c = gate.counters();
        assert_eq!(c.rejected, 1);
        drop(_held);
        let (status, _, _) =
            http_request(s.addr(), "POST", "/v2/models/0/infer", Some(body)).unwrap();
        assert_eq!(status, 200, "recovers once the backlog drains");
    }

    #[test]
    fn rejects_bad_requests_and_paths() {
        let (s, _) = start(unlimited());
        for (path, body, want) in [
            ("/v2/models/9/infer", r#"{"tokens": [1]}"#, 404), // model range
            ("/v2/models/0/infer", "not json", 400),
            ("/v2/models/0/infer", r#"{}"#, 400), // no prompt
            ("/v2/models/x/infer", r#"{"tokens": [1]}"#, 404),
            ("/v2/nope", r#"{}"#, 404),
        ] {
            let (status, _, _) = http_request(s.addr(), "POST", path, Some(body)).unwrap();
            assert_eq!(status, want, "{path} {body}");
        }
        let (status, _, _) = http_request(s.addr(), "DELETE", "/v2/stats", None).unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn simulate_job_runs_cluster_and_reports_slo() {
        let (s, _) = start(unlimited());
        let body = r#"{
            "serving": {"replicas": 2, "admit_queue": 8},
            "open_loop": {"base": {"n_requests": 24, "qps": 4.0, "seed": 3},
                          "pareto_alpha": 1.5, "users": 100},
            "slo": {"request_s": 60.0}
        }"#;
        let (status, _, reply) =
            http_request(s.addr(), "POST", "/v2/jobs/simulate", Some(body)).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&reply));
        let v = Value::parse(std::str::from_utf8(&reply).unwrap()).unwrap();
        let submitted = v.at(&["cluster", "stats", "submitted_requests"]).unwrap();
        assert_eq!(submitted.as_usize(), Some(24), "gate on: every arrival counted");
        let completed =
            v.at(&["cluster", "stats", "completed_requests"]).unwrap().as_u64().unwrap();
        let rejected =
            v.at(&["cluster", "stats", "rejected_requests"]).unwrap().as_u64().unwrap();
        assert_eq!(completed + rejected, 24, "conservation over the wire");
        let good = v.at(&["slo", "goodput_rps"]).unwrap().as_f64().unwrap();
        assert!(good >= 0.0);
        let att = v.at(&["slo", "ttft_attainment"]).unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&att));
        assert_eq!(v.at(&["slo", "request_s"]).unwrap().as_f64(), Some(60.0));
    }

    #[test]
    fn simulate_job_caps_size_and_validates() {
        let (s, _) = start(unlimited());
        let too_big = r#"{"workload": {"n_requests": 100000}}"#;
        let (status, _, _) =
            http_request(s.addr(), "POST", "/v2/jobs/simulate", Some(too_big)).unwrap();
        assert_eq!(status, 400);
        let both = r#"{"workload": {}, "open_loop": {}}"#;
        let (status, _, _) =
            http_request(s.addr(), "POST", "/v2/jobs/simulate", Some(both)).unwrap();
        assert_eq!(status, 400);
    }

    /// The value of metric `name` in a Prometheus text body (first
    /// sample line, any label set).
    fn sample(text: &str, name: &str) -> f64 {
        text.lines()
            .find(|l| {
                !l.starts_with('#')
                    && l.split(|ch: char| ch == '{' || ch == ' ').next() == Some(name)
            })
            .and_then(|l| l.rsplit(' ').next())
            .unwrap_or_else(|| panic!("no sample for {name}"))
            .parse()
            .unwrap()
    }

    #[test]
    fn metrics_serve_valid_prometheus_text_with_monotone_counters() {
        let (s, _) = start(unlimited());
        let (status, headers, body) = http_request(s.addr(), "GET", "/v2/metrics", None).unwrap();
        assert_eq!(status, 200);
        assert!(
            headers
                .iter()
                .any(|(k, v)| k == "content-type" && v.starts_with("text/plain")),
            "{headers:?}"
        );
        let first = String::from_utf8(body).unwrap();
        // Exposition-format shape: every family announces # HELP then
        // # TYPE before its samples, and every sample parses as
        // `name[{labels}] value`.
        let mut helped = std::collections::HashSet::new();
        let mut typed = std::collections::HashSet::new();
        for line in first.lines() {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                helped.insert(rest.split(' ').next().unwrap().to_string());
            } else if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split(' ');
                let name = it.next().unwrap();
                assert!(helped.contains(name), "TYPE before HELP for {name}");
                assert!(matches!(it.next(), Some("counter" | "gauge")), "{line}");
                typed.insert(name.to_string());
            } else if !line.is_empty() {
                let name = line.split(|ch: char| ch == '{' || ch == ' ').next().unwrap();
                assert!(typed.contains(name), "sample without TYPE: {line}");
                let value = line.rsplit(' ').next().unwrap();
                assert!(value.parse::<f64>().is_ok(), "unparseable sample: {line}");
            }
        }
        let before = sample(&first, "icarus_gate_submitted_total");
        let infer = r#"{"tokens": [1, 2], "max_tokens": 2}"#;
        http_request(s.addr(), "POST", "/v2/models/0/infer", Some(infer)).unwrap();
        let (_, _, after) = http_request(s.addr(), "GET", "/v2/metrics", None).unwrap();
        let after = String::from_utf8(after).unwrap();
        assert!(
            sample(&after, "icarus_gate_submitted_total") > before,
            "counters must be monotone across scrapes"
        );
        assert_eq!(sample(&after, "icarus_gate_inflight"), 0.0, "admission released");
    }

    #[test]
    fn obs_job_surfaces_phases_and_shard_stats() {
        let (s, _) = start(unlimited());
        // No job yet: the scrape has gate families only.
        let (_, _, bare) = http_request(s.addr(), "GET", "/v2/metrics", None).unwrap();
        assert!(!String::from_utf8(bare).unwrap().contains("icarus_job_"));
        let body = r#"{
            "serving": {"replicas": 2, "obs": true, "store_host_bytes": 134217728},
            "workload": {"n_requests": 16, "seed": 5}
        }"#;
        let (status, _, reply) =
            http_request(s.addr(), "POST", "/v2/jobs/simulate", Some(body)).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&reply));
        let (_, _, stats) = http_request(s.addr(), "GET", "/v2/stats", None).unwrap();
        let v = Value::parse(std::str::from_utf8(&stats).unwrap()).unwrap();
        assert!(
            !v.get("store_shards").unwrap().as_arr().unwrap().is_empty(),
            "per-shard store block after an obs job"
        );
        assert!(
            !v.get("phases").unwrap().as_arr().unwrap().is_empty(),
            "phase summary after an obs job"
        );
        let (_, _, m) = http_request(s.addr(), "GET", "/v2/metrics", None).unwrap();
        let m = String::from_utf8(m).unwrap();
        assert!(m.contains("icarus_phase_seconds_total{model=\"0\",phase=\"queue\"}"), "{m}");
        assert!(m.contains("icarus_store_shard_hits{shard=\"0\"}"), "{m}");
        assert!(sample(&m, "icarus_job_completed_requests") > 0.0);
    }

    #[test]
    fn parse_model_path_shapes() {
        assert_eq!(parse_model_path("/v2/models/0/infer"), Some(0));
        assert_eq!(parse_model_path("/v2/models/12/infer"), Some(12));
        assert_eq!(parse_model_path("/v2/models/12/other"), None);
        assert_eq!(parse_model_path("/v2/models/abc/infer"), None);
        assert_eq!(parse_model_path("/v2/models/"), None);
    }
}
