//! Open-loop traffic generation: heavy tails, sessions, diurnal bursts.
//!
//! The closed-loop generator (`workload::generate`) draws Poisson
//! arrivals and forgets each request as it emits it.  Real serving
//! traffic is none of that: arrivals are open-loop (the offered rate
//! does not slow down when the system backs up — which is exactly what
//! makes admission control matter), inter-arrivals are heavy-tailed
//! (bursts), load breathes diurnally, and requests come from persistent
//! users whose sessions re-send a growing shared prefix (the store /
//! prefix-cache hit source).
//!
//! [`OpenLoopGen`] models all four as a streaming iterator with **O(1)
//! state per arrival**: no per-user table is kept — a user's session
//! prefix is a pure function of `(seed, user id)`, re-derived from a
//! fresh child RNG at each arrival.  A population of ten million users
//! costs exactly as much memory as a population of ten, which is what
//! lets the generator scale to the "million-user" north star by
//! streaming sessions instead of materializing them.
//!
//! Determinism: the whole stream is a pure function of
//! [`OpenLoopConfig`]; two iterators with equal configs yield
//! bit-identical workflows (pinned by `prop_openloop_deterministic`).

use crate::config::WorkloadConfig;
use crate::json::{self, Value};
use crate::rng::Rng;
use crate::tokens::TokenBuf;
use crate::workload::{self, Workflow, SYSTEM_PREFIX_LEN};

/// Open-loop traffic parameters wrapping a base workload config (which
/// supplies rate, length distributions, turn structure and seed).
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Base workload: `qps` is the mean offered rate, `n_requests` the
    /// stream length, `seed` the determinism root; length/turn
    /// distributions are drawn exactly as in the closed-loop generator.
    pub base: WorkloadConfig,
    /// Simulated user population.  Users are never materialized — any
    /// size up to `u64::MAX` costs O(1) memory.
    pub users: u64,
    /// Zipf skew of user popularity (> 1; heavier skew near 1 is
    /// *larger* s here — rank-0 users dominate as s grows).  Values
    /// <= 1 fall back to a uniform user draw.
    pub zipf_s: f64,
    /// Pareto tail index of inter-arrival times.  Must exceed 1 for the
    /// mean to exist; values <= 1 fall back to Poisson (exponential)
    /// arrivals.  Smaller alpha (closer to 1) = burstier traffic.
    pub pareto_alpha: f64,
    /// Tokens of per-user session prefix inserted between the shared
    /// system prefix and the fresh request body.  A user's prefix is
    /// stable across their arrivals — the recurring context that prefix
    /// caching and the snapshot store can reuse.  0 disables sessions.
    pub user_prefix_tokens: usize,
    /// Diurnal modulation amplitude in [0, 1): instantaneous rate is
    /// `qps * (1 + amplitude * sin(2*pi*t / period))`.  0 disables.
    pub diurnal_amplitude: f64,
    /// Diurnal period in seconds.
    pub diurnal_period_s: f64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        OpenLoopConfig {
            base: WorkloadConfig::default(),
            users: 1 << 20,
            zipf_s: 1.3,
            pareto_alpha: 1.5,
            user_prefix_tokens: 32,
            diurnal_amplitude: 0.0,
            diurnal_period_s: 600.0,
        }
    }
}

impl OpenLoopConfig {
    /// Dump the open-loop parameters (base config included) for result
    /// files and the job endpoint.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("base", self.base.to_json()),
            ("users", json::num(self.users as f64)),
            ("zipf_s", json::num(self.zipf_s)),
            ("pareto_alpha", json::num(self.pareto_alpha)),
            ("user_prefix_tokens", json::num(self.user_prefix_tokens as f64)),
            ("diurnal_amplitude", json::num(self.diurnal_amplitude)),
            ("diurnal_period_s", json::num(self.diurnal_period_s)),
        ])
    }

    /// Build from (possibly partial) JSON: the `base` member feeds
    /// [`WorkloadConfig::from_json`]; every other key defaults.
    pub fn from_json(v: &Value) -> anyhow::Result<OpenLoopConfig> {
        let d = OpenLoopConfig::default();
        let base = match v.get("base") {
            Some(b) => WorkloadConfig::from_json(b)?,
            None => d.base,
        };
        let n = |key: &str, default: f64| -> anyhow::Result<f64> {
            match v.get(key) {
                None => Ok(default),
                Some(x) => x.as_f64().ok_or_else(|| anyhow::anyhow!("{key}: want number")),
            }
        };
        Ok(OpenLoopConfig {
            base,
            users: n("users", d.users as f64)? as u64,
            zipf_s: n("zipf_s", d.zipf_s)?,
            pareto_alpha: n("pareto_alpha", d.pareto_alpha)?,
            user_prefix_tokens: n("user_prefix_tokens", d.user_prefix_tokens as f64)? as usize,
            diurnal_amplitude: n("diurnal_amplitude", d.diurnal_amplitude)?,
            diurnal_period_s: n("diurnal_period_s", d.diurnal_period_s)?,
        })
    }
}

/// Session prefix of `user` under `seed`: a pure function, so it can be
/// re-derived at every arrival instead of stored per user.
fn user_prefix(seed: u64, user: u64, len: usize) -> Vec<u32> {
    // Decorrelate the child stream from both the workload rng and
    // neighbouring users (plain XOR of small user ids would feed
    // near-identical seeds to the generator's splitmix init).
    let mut r = Rng::new(seed ^ user.wrapping_mul(0x9e3779b97f4a7c15).rotate_left(17));
    workload::content_tokens(&mut r, len)
}

/// Streaming open-loop workflow generator; see the module docs.
///
/// Yields exactly `cfg.base.n_requests` workflows.  All mutable state
/// is the clock, the id counter and one RNG — independent of `users`.
#[derive(Debug)]
pub struct OpenLoopGen {
    cfg: OpenLoopConfig,
    rng: Rng,
    sys: Vec<u32>,
    now: f64,
    next_id: u64,
}

impl OpenLoopGen {
    /// Generator over `cfg`'s stream, starting at t = 0.
    pub fn new(cfg: OpenLoopConfig) -> OpenLoopGen {
        let rng = Rng::new(cfg.base.seed);
        let sys = workload::system_prefix(SYSTEM_PREFIX_LEN);
        OpenLoopGen { cfg, rng, sys, now: 0.0, next_id: 0 }
    }

    /// Inter-arrival draw at the current clock: heavy-tailed base draw,
    /// compressed/stretched by the diurnal rate factor at `now`.
    fn next_gap(&mut self) -> f64 {
        let c = &self.cfg;
        let qps = c.base.qps;
        let gap = if c.pareto_alpha > 1.0 {
            // x_m chosen so the Pareto mean is 1/qps.
            let x_m = (c.pareto_alpha - 1.0) / (c.pareto_alpha * qps);
            self.rng.pareto(c.pareto_alpha, x_m)
        } else {
            self.rng.exp(qps)
        };
        if c.diurnal_amplitude > 0.0 && c.diurnal_period_s > 0.0 {
            let phase = 2.0 * std::f64::consts::PI * self.now / c.diurnal_period_s;
            // Rate modulation shortens gaps at the peak of the day and
            // stretches them in the trough; the floor keeps a mis-set
            // amplitude >= 1 from freezing the clock.
            let factor = (1.0 + c.diurnal_amplitude * phase.sin()).max(0.05);
            gap / factor
        } else {
            gap
        }
    }
}

impl Iterator for OpenLoopGen {
    type Item = Workflow;

    fn next(&mut self) -> Option<Workflow> {
        if self.next_id >= self.cfg.base.n_requests as u64 {
            return None;
        }
        self.now += self.next_gap();
        let c = &self.cfg;
        let user = if c.users <= 1 {
            0
        } else if c.zipf_s > 1.0 {
            self.rng.zipf(c.users, c.zipf_s)
        } else {
            self.rng.below(c.users)
        };
        // Prompt layout: shared system prefix, then the user's stable
        // session prefix, then a fresh body — so popular users' prompts
        // share a reusable prefix deeper than the system prompt alone.
        let body_len =
            self.rng.len_sample(c.base.prompt_mean, c.base.prompt_std, 8, 4096) as usize;
        let mut prompt = self.sys.clone();
        if c.user_prefix_tokens > 0 {
            prompt.extend(user_prefix(c.base.seed, user, c.user_prefix_tokens));
        }
        prompt.extend(workload::content_tokens(&mut self.rng, body_len));
        let turns = workload::plan_turns(&mut self.rng, &c.base);
        let id = self.next_id;
        self.next_id += 1;
        Some(Workflow { id, arrival: self.now, prompt: TokenBuf::from(prompt), turns })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = (self.cfg.base.n_requests as u64 - self.next_id) as usize;
        (left, Some(left))
    }
}

/// Collect the full stream (bounded by `base.n_requests`) — the
/// convenience entry the CLI, benches and job endpoint use.
pub fn generate_open_loop(cfg: &OpenLoopConfig) -> Vec<Workflow> {
    OpenLoopGen::new(cfg.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> OpenLoopConfig {
        OpenLoopConfig {
            base: WorkloadConfig { n_requests: 256, qps: 2.0, seed: 11, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_open_loop(&cfg());
        let b = generate_open_loop(&cfg());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.turns.len(), y.turns.len());
        }
        let mut other = cfg();
        other.base.seed = 12;
        let c = generate_open_loop(&other);
        assert_ne!(a[0].prompt, c[0].prompt);
    }

    #[test]
    fn arrivals_monotone_and_mean_rate_close() {
        let mut c = cfg();
        c.base.n_requests = 20_000;
        c.base.qps = 4.0;
        let wf = generate_open_loop(&c);
        let mut prev = 0.0;
        for w in &wf {
            assert!(w.arrival > prev);
            prev = w.arrival;
        }
        let rate = wf.len() as f64 / prev;
        // Heavy-tailed arrivals converge on the mean slowly; a loose
        // band still catches an x_m miscalibration (off by alpha/(a-1)
        // would read ~3x).
        assert!((rate / 4.0 - 1.0).abs() < 0.25, "rate {rate}");
    }

    #[test]
    fn heavier_tail_than_poisson() {
        let mut c = cfg();
        c.base.n_requests = 20_000;
        c.pareto_alpha = 1.2;
        let wf = generate_open_loop(&c);
        let gaps: Vec<f64> = wf.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let max = gaps.iter().cloned().fold(0.0, f64::max);
        // For exponential gaps max/mean ~ ln n ≈ 10; a 1.2-tail blows
        // far past that.
        assert!(max / mean > 30.0, "max/mean {}", max / mean);
    }

    #[test]
    fn session_prefix_recurs_for_same_user() {
        // Single user: every arrival shares system + session prefix.
        let mut c = cfg();
        c.users = 1;
        c.user_prefix_tokens = 24;
        let wf = generate_open_loop(&c);
        let shared = SYSTEM_PREFIX_LEN + 24;
        for w in &wf[1..] {
            assert_eq!(&w.prompt[..shared], &wf[0].prompt[..shared]);
        }
        // Distinct seeds give distinct session prefixes.
        assert_ne!(
            user_prefix(1, 0, 24),
            user_prefix(2, 0, 24),
            "session prefix must depend on seed"
        );
        // Neighbouring users differ despite the tiny id distance.
        assert_ne!(user_prefix(1, 0, 24), user_prefix(1, 1, 24));
    }

    #[test]
    fn zipf_popularity_concentrates_prefixes() {
        let mut c = cfg();
        c.base.n_requests = 4000;
        c.users = 1 << 40; // absurd population: still O(1) memory
        c.zipf_s = 1.5;
        c.user_prefix_tokens = 16;
        let wf = generate_open_loop(&c);
        // Count distinct session prefixes: with strong skew, far fewer
        // than one per request — the reuse the store feeds on.
        let mut seen = std::collections::HashSet::new();
        for w in &wf {
            seen.insert(w.prompt[SYSTEM_PREFIX_LEN..SYSTEM_PREFIX_LEN + 16].to_vec());
        }
        assert!(seen.len() < wf.len() / 2, "{} prefixes / {} reqs", seen.len(), wf.len());
        assert!(seen.len() > 10, "population must not collapse to one user");
    }

    #[test]
    fn diurnal_phases_modulate_local_rate() {
        let mut c = cfg();
        c.base.n_requests = 30_000;
        c.base.qps = 10.0;
        c.pareto_alpha = 0.0; // Poisson base: isolates the diurnal term
        c.diurnal_amplitude = 0.8;
        c.diurnal_period_s = 200.0;
        let wf = generate_open_loop(&c);
        // Bucket arrivals by phase quadrant: the peak quadrant
        // (sin > 0.5 region) must see far more arrivals than the trough.
        let (mut peak, mut trough) = (0usize, 0usize);
        for w in &wf {
            let s = (2.0 * std::f64::consts::PI * w.arrival / 200.0).sin();
            if s > 0.5 {
                peak += 1;
            } else if s < -0.5 {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 2.0 * trough as f64,
            "peak {peak} vs trough {trough}: diurnal modulation missing"
        );
    }

    #[test]
    fn streams_at_scale_without_materializing_users() {
        let mut c = cfg();
        c.base.n_requests = 50_000;
        c.users = u64::MAX; // the ultimate "millions of users"
        c.zipf_s = 1.1;
        let mut gen = OpenLoopGen::new(c);
        // Drive the iterator without collecting: constant memory.
        let mut count = 0usize;
        let mut last = 0.0;
        for w in &mut gen {
            count += 1;
            last = w.arrival;
        }
        assert_eq!(count, 50_000);
        assert!(last > 0.0);
        assert_eq!(gen.size_hint(), (0, Some(0)));
    }

    #[test]
    fn json_roundtrip() {
        let c = OpenLoopConfig {
            users: 777,
            zipf_s: 1.25,
            pareto_alpha: 2.0,
            user_prefix_tokens: 8,
            diurnal_amplitude: 0.4,
            diurnal_period_s: 120.0,
            ..Default::default()
        };
        let back = OpenLoopConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.users, 777);
        assert_eq!(back.zipf_s, 1.25);
        assert_eq!(back.pareto_alpha, 2.0);
        assert_eq!(back.user_prefix_tokens, 8);
        assert_eq!(back.diurnal_amplitude, 0.4);
        assert_eq!(back.diurnal_period_s, 120.0);
        // Partial JSON defaults the rest.
        let partial = Value::parse(r#"{"users": 5, "base": {"qps": 9.0}}"#).unwrap();
        let p = OpenLoopConfig::from_json(&partial).unwrap();
        assert_eq!(p.users, 5);
        assert_eq!(p.base.qps, 9.0);
        assert_eq!(p.pareto_alpha, OpenLoopConfig::default().pareto_alpha);
    }
}
