//! Wire types of the inference protocol (request/response JSON).
//!
//! The shapes follow the open Inference Protocol conventions (model in
//! the path, JSON body, optional streaming): enough structure that a
//! real client shim would be mechanical, small enough to live on the
//! in-repo JSON parser.  Streamed responses are newline-delimited JSON
//! events, one per generated token, closed by a `done` event — each
//! event rides one HTTP chunk (see `serve::http`).

use crate::json::{self, Value};
use crate::tokenizer::Tokenizer;

/// A parsed `/v2/models/{m}/infer` request body.
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// Prompt tokens: either given directly (`"tokens": [..]`) or
    /// encoded from `"text"` with the deterministic tokenizer.
    pub prompt: Vec<u32>,
    /// Tokens to generate (`"max_tokens"`, default 16, capped at 4096).
    pub max_tokens: usize,
    /// Stream one event per token instead of a single JSON reply.
    pub stream: bool,
    /// Optional session tag, echoed back (persistent-user bookkeeping
    /// for clients; the open-loop generator models sessions natively).
    pub session: Option<String>,
}

impl InferRequest {
    /// Parse a request body.  Exactly one of `tokens` / `text` must be
    /// present.
    pub fn from_json(v: &Value, tokenizer: &Tokenizer) -> anyhow::Result<InferRequest> {
        let prompt = match (v.get("tokens"), v.get("text")) {
            (Some(_), Some(_)) => anyhow::bail!("give either tokens or text, not both"),
            (Some(toks), None) => toks
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("tokens: want array"))?
                .iter()
                .map(|t| {
                    t.as_f64()
                        .filter(|&f| f >= 0.0 && f < u32::MAX as f64)
                        .map(|f| f as u32)
                        .ok_or_else(|| anyhow::anyhow!("tokens: want non-negative numbers"))
                })
                .collect::<anyhow::Result<Vec<u32>>>()?,
            (None, Some(text)) => {
                let text = text.as_str().ok_or_else(|| anyhow::anyhow!("text: want string"))?;
                tokenizer.encode(text)
            }
            (None, None) => anyhow::bail!("missing prompt: give tokens or text"),
        };
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let max_tokens = match v.get("max_tokens") {
            None => 16,
            Some(x) => x.as_usize().ok_or_else(|| anyhow::anyhow!("max_tokens: want number"))?,
        };
        anyhow::ensure!(max_tokens >= 1, "max_tokens must be >= 1");
        let stream = match v.get("stream") {
            None => false,
            Some(x) => x.as_bool().ok_or_else(|| anyhow::anyhow!("stream: want bool"))?,
        };
        let session = v.get("session").and_then(|s| s.as_str()).map(str::to_string);
        Ok(InferRequest { prompt, max_tokens: max_tokens.min(4096), stream, session })
    }
}

/// One streamed token event (newline-terminated for ndjson framing).
pub fn token_event(index: usize, token: u32) -> String {
    let mut s = json::obj(vec![
        ("index", json::num(index as f64)),
        ("token", json::num(token as f64)),
    ])
    .to_string();
    s.push('\n');
    s
}

/// The closing stream event.
pub fn done_event(model: usize, generated: usize, session: Option<&str>) -> String {
    let mut entries = vec![
        ("done", Value::Bool(true)),
        ("model", json::num(model as f64)),
        ("generated", json::num(generated as f64)),
    ];
    if let Some(sess) = session {
        entries.push(("session", json::s(sess)));
    }
    let mut s = json::obj(entries).to_string();
    s.push('\n');
    s
}

/// The single-shot (non-streamed) reply body.
pub fn infer_reply(model: usize, tokens: &[u32], session: Option<&str>) -> String {
    let mut entries = vec![
        ("model", json::num(model as f64)),
        ("generated", json::num(tokens.len() as f64)),
        ("tokens", Value::Arr(tokens.iter().map(|&t| json::num(t as f64)).collect())),
    ];
    if let Some(sess) = session {
        entries.push(("session", json::s(sess)));
    }
    json::obj(entries).to_string_pretty()
}

/// A JSON error body (for 4xx/5xx responses).
pub fn error_body(msg: &str) -> String {
    json::obj(vec![("error", json::s(msg))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(2048)
    }

    #[test]
    fn parses_token_prompt() {
        let v = Value::parse(r#"{"tokens": [1, 40, 41], "max_tokens": 8, "stream": true}"#)
            .unwrap();
        let r = InferRequest::from_json(&v, &tok()).unwrap();
        assert_eq!(r.prompt, vec![1, 40, 41]);
        assert_eq!(r.max_tokens, 8);
        assert!(r.stream);
        assert!(r.session.is_none());
    }

    #[test]
    fn parses_text_prompt_via_tokenizer() {
        let v = Value::parse(r#"{"text": "hello world", "session": "u7"}"#).unwrap();
        let r = InferRequest::from_json(&v, &tok()).unwrap();
        assert_eq!(r.prompt, tok().encode("hello world"));
        assert_eq!(r.max_tokens, 16, "default");
        assert!(!r.stream, "default");
        assert_eq!(r.session.as_deref(), Some("u7"));
    }

    #[test]
    fn rejects_bad_prompts() {
        let t = tok();
        for bad in [
            r#"{}"#,
            r#"{"tokens": [1], "text": "x"}"#,
            r#"{"tokens": "nope"}"#,
            r#"{"tokens": [-3]}"#,
            r#"{"tokens": []}"#,
            r#"{"text": "x", "max_tokens": 0}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(InferRequest::from_json(&v, &t).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn caps_max_tokens() {
        let v = Value::parse(r#"{"tokens": [1], "max_tokens": 1000000}"#).unwrap();
        assert_eq!(InferRequest::from_json(&v, &tok()).unwrap().max_tokens, 4096);
    }

    #[test]
    fn events_are_ndjson() {
        let e = token_event(3, 99);
        assert!(e.ends_with('\n'));
        let v = Value::parse(e.trim()).unwrap();
        assert_eq!(v.get("index").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("token").unwrap().as_u64(), Some(99));

        let d = done_event(2, 8, Some("s1"));
        let v = Value::parse(d.trim()).unwrap();
        assert_eq!(v.get("done").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("generated").unwrap().as_usize(), Some(8));
        assert_eq!(v.get("session").unwrap().as_str(), Some("s1"));

        let r = Value::parse(&infer_reply(1, &[5, 6], None)).unwrap();
        assert_eq!(r.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert!(r.get("session").is_none());
    }
}
