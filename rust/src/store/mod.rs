//! The tiered KV snapshot store: content-addressed host + disk tiers
//! shared across engine replicas, with background write-back and
//! prefetch.
//!
//! ICaRus's thesis is that the KV cache for an identical context is
//! *one* reusable artifact across N models.  The radix prefix cache
//! realizes that inside one replica's GPU pool; this module extends it
//! past the GPU: published contexts are written back (in the
//! background) to a bounded **host tier**, demoted under pressure to a
//! bounded **disk tier**, and dropped only when both tiers are full —
//! the full demotion pipeline GPU → host → disk → drop.  A later turn
//! whose prompt prefix is store-resident *restores* the KV bytes over
//! the modeled transfer path (PCIe for host, NVMe + PCIe for disk)
//! instead of re-prefilling them, and because the store is one
//! `Arc`-shared instance behind all R replicas of a cluster, a context
//! prefilled on replica 0 is a warm hit on replica 3 even under plain
//! round-robin routing — no prefix-affinity routing tricks required
//! (DroidSpeak/PrefillShare-style cross-server KV reuse).
//!
//! Content addressing: entries are per-KV-block, keyed by the same
//! rolling block-hash chain the radix prefix cache indexes children
//! with ([`crate::kvcache::block::hash_block`]).  Identical context
//! prefixes — from different models, workflows or replicas — therefore
//! dedupe to one stored copy per block, and a probe finds the longest
//! stored block prefix of *any* prompt, whether the stored context is
//! longer or shorter than it (the radix tree's partial-match
//! semantics, extended across tiers and replicas).
//!
//! Timing model: the store itself holds no clock.  Callers pass their
//! engine's virtual `now` into every operation; writes carry a
//! `visible_at` (publish) or `ready_at` (prefetch stage) computed by
//! the caller from the executor's transfer cost model, so write-back
//! and prefetch are *background* transfers: they consume no engine
//! time, and the entry simply becomes usable once the requesting
//! replica's clock passes the transfer completion.  Cross-replica
//! causality is enforced by [`ClockFence`]: replicas advance their
//! virtual clocks within a bounded window of each other, and the store
//! clamps every visibility time to at least one window in the future,
//! so an entry visible at virtual time `t` was always published
//! (wall-clock) before any replica probes at `t`.  Within the window,
//! LRU tie order between replicas is scheduling-dependent; hit/miss
//! outcomes are not.

mod fence;
mod tiered;

pub use fence::{ClockFence, DEFAULT_WINDOW};
pub use tiered::{StoreHandle, StorePrefetch, TieredStore};

pub use crate::kvcache::block::{chain_keys, BlockKey};

use crate::json::{self, Value};

/// Which storage tier an entry currently occupies (and therefore which
/// transfer path a restore is charged for).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreTier {
    /// Pinned host memory: restores pay one PCIe hop.
    Host,
    /// NVMe-backed spill: restores pay an NVMe read plus the PCIe hop
    /// (unless a prefetch already staged the entry into host memory).
    Disk,
}

impl StoreTier {
    /// CLI / JSON spelling of the tier.
    pub fn as_str(self) -> &'static str {
        match self {
            StoreTier::Host => "host",
            StoreTier::Disk => "disk",
        }
    }
}

/// Underflow detected by tier byte accounting: more bytes released than
/// were ever reserved.  This is always a caller bug (double restore,
/// double discard); tiers refuse to absorb it silently — the pre-store
/// `SwapTier` hid exactly this class of bug behind `debug_assert` +
/// `saturating_sub`, corrupting occupancy in release builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierAccountingError {
    /// Bytes the caller tried to release.
    pub released: u64,
    /// Bytes actually reserved at the time of the call.
    pub used: u64,
}

impl std::fmt::Display for TierAccountingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tier accounting underflow: released {} bytes with only {} reserved \
             (double restore/discard?)",
            self.released, self.used
        )
    }
}

impl std::error::Error for TierAccountingError {}

/// Bounded byte budget with hard-error accounting, shared by the swap
/// tier and the store tiers.
///
/// `reserve` is a soft failure (the tier is simply full — callers fall
/// back to the next tier or drop); `release` underflow is a hard error
/// (see [`TierAccountingError`]).
#[derive(Debug, Clone)]
pub struct TierBudget {
    capacity: u64,
    used: u64,
}

impl TierBudget {
    /// An empty budget of `capacity` bytes.
    pub fn new(capacity: u64) -> Self {
        TierBudget { capacity, used: 0 }
    }

    /// Total bytes the tier may hold.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Bytes currently reserved.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes of remaining capacity.
    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    /// Reserve `bytes`; false when the tier lacks room (caller must
    /// demote or drop instead).
    pub fn reserve(&mut self, bytes: u64) -> bool {
        if self.used + bytes > self.capacity {
            return false;
        }
        self.used += bytes;
        true
    }

    /// Release `bytes` back to the tier.  Underflow is a hard error:
    /// occupancy is left untouched so the caller's bug cannot silently
    /// corrupt later admission decisions.
    pub fn release(&mut self, bytes: u64) -> Result<(), TierAccountingError> {
        if bytes > self.used {
            return Err(TierAccountingError { released: bytes, used: self.used });
        }
        self.used -= bytes;
        Ok(())
    }
}

/// A store probe that found a usable stored prefix: the engine charges
/// the per-tier transfer costs and treats `tokens` of the prompt as
/// cached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreHit {
    /// Block-aligned prompt tokens the stored prefix covers.
    pub tokens: usize,
    /// Restored bytes moving over PCIe only: host-tier blocks, plus
    /// disk blocks a prefetch already staged into host memory (that
    /// is the whole point of prefetching).
    pub host_bytes: u64,
    /// Restored bytes additionally paying the NVMe read (disk-tier
    /// blocks, unstaged).
    pub disk_bytes: u64,
    /// True when any restored block was published by a different
    /// replica (the cross-replica reuse the shared store exists for).
    pub remote: bool,
}

impl StoreHit {
    /// Total bytes this restore transfers.
    pub fn bytes(&self) -> u64 {
        self.host_bytes + self.disk_bytes
    }
}

/// Aggregate store counters (global across replicas — per-replica
/// restore stats live in `ServingStats`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StoreStats {
    /// Entries currently resident across both tiers.
    pub entries: usize,
    /// Bytes resident in the host tier.
    pub host_used: u64,
    /// Bytes resident in the disk tier.
    pub disk_used: u64,
    /// Host tier capacity in bytes.
    pub host_capacity: u64,
    /// Disk tier capacity in bytes.
    pub disk_capacity: u64,
    /// Publishes that admitted a new entry.
    pub publishes: u64,
    /// Publishes that found the identical context already stored (the
    /// content-addressed dedup across models/workflows/replicas).
    pub dedup_publishes: u64,
    /// Publishes rejected because the entry fits in no tier.
    pub publish_rejected: u64,
    /// Bytes admitted into the tiers over the run.
    pub bytes_published: u64,
    /// Bytes dropped out of the pipeline's far end over the run.
    pub bytes_dropped: u64,
    /// Entries demoted host → disk under host pressure.
    pub demotions_to_disk: u64,
    /// Entries dropped (disk pressure, or host pressure with no disk).
    pub dropped_entries: u64,
    /// Restores served from the host tier.
    pub host_hits: u64,
    /// Restores served from the disk tier (unstaged).
    pub disk_hits: u64,
    /// Restores of entries published by a different replica.
    pub remote_hits: u64,
    /// Disk restores that found the entry already prefetch-staged in
    /// host memory (and were therefore charged PCIe, not NVMe).
    pub prefetch_hits: u64,
    /// Prefetch stagings issued.
    pub prefetches: u64,
    /// Pin operations taken out on handoff chains (see
    /// [`SnapshotStore::pin`]).
    pub handoff_pins: u64,
    /// Blocks currently carrying at least one handoff pin (gauge).
    pub pinned_blocks: usize,
    /// Shard-lock acquisitions that found the lock poisoned by a
    /// panicking replica.  Non-zero means the store degraded to a
    /// static miss-everything state mid-run (see [`TieredStore`]); the
    /// CLI fails the run with a clean error instead of letting the
    /// panic cascade across replicas.
    pub lock_poisoned: u64,
}

impl StoreStats {
    /// Dump every counter for results files.
    pub fn to_json(&self) -> Value {
        use json::num;
        json::obj(vec![
            ("entries", num(self.entries as f64)),
            ("host_used", num(self.host_used as f64)),
            ("disk_used", num(self.disk_used as f64)),
            ("host_capacity", num(self.host_capacity as f64)),
            ("disk_capacity", num(self.disk_capacity as f64)),
            ("publishes", num(self.publishes as f64)),
            ("dedup_publishes", num(self.dedup_publishes as f64)),
            ("publish_rejected", num(self.publish_rejected as f64)),
            ("bytes_published", num(self.bytes_published as f64)),
            ("bytes_dropped", num(self.bytes_dropped as f64)),
            ("demotions_to_disk", num(self.demotions_to_disk as f64)),
            ("dropped_entries", num(self.dropped_entries as f64)),
            ("host_hits", num(self.host_hits as f64)),
            ("disk_hits", num(self.disk_hits as f64)),
            ("remote_hits", num(self.remote_hits as f64)),
            ("prefetch_hits", num(self.prefetch_hits as f64)),
            ("prefetches", num(self.prefetches as f64)),
            ("handoff_pins", num(self.handoff_pins as f64)),
            ("pinned_blocks", num(self.pinned_blocks as f64)),
            ("lock_poisoned", num(self.lock_poisoned as f64)),
        ])
    }
}

/// Per-shard counters of a sharded store (`--obs on` surfacing only —
/// deliberately **not** part of [`StoreStats`]: aggregate stats are
/// shard-count-invariant, pinned by `prop_store_shards_bit_identical`,
/// while this breakdown is exactly the shard-layout-dependent view that
/// invariant forbids there).  The contention counters are how a
/// misconfigured `--store-shards` shows up: one hot shard with high
/// `contended` means the hash partitioning is fighting the access
/// pattern.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardStats {
    /// Blocks restored out of this shard.
    pub hits: u64,
    /// Block entries published into this shard.
    pub publishes: u64,
    /// Entries evicted (demoted or dropped) out of this shard.
    pub evictions: u64,
    /// Read-lock acquisitions on this shard.
    pub read_locks: u64,
    /// Write-lock acquisitions on this shard.
    pub write_locks: u64,
    /// Lock acquisitions that found the shard held and had to block —
    /// the striping-efficacy signal.
    pub contended: u64,
}

impl ShardStats {
    /// Dump the shard's counters for results files.
    pub fn to_json(&self) -> Value {
        use json::num;
        json::obj(vec![
            ("hits", num(self.hits as f64)),
            ("publishes", num(self.publishes as f64)),
            ("evictions", num(self.evictions as f64)),
            ("read_locks", num(self.read_locks as f64)),
            ("write_locks", num(self.write_locks as f64)),
            ("contended", num(self.contended as f64)),
        ])
    }
}

/// The store abstraction the engine talks to: content-addressed KV
/// snapshot entries behind tiered byte budgets (see the module docs;
/// [`TieredStore`] is the shipped implementation).
///
/// The core methods are **chain-based**: they take the prompt's rolling
/// block-hash chain ([`BlockKey`]s, ascending depth — see
/// [`chain_keys`] and the memoized `TokenBuf::block_chain`) instead of
/// raw tokens, so a hot path that probes the same growing context every
/// step hashes each block once for its lifetime, and a sharded store
/// can group a whole chain's keys and acquire each shard **once per
/// chain** instead of once per block.  Token-slice wrappers (`peek`,
/// `publish`, ...) are provided for callers without a memoized chain
/// (tests, one-shot tools).
///
/// Every method takes the caller's virtual `now`; see the module docs
/// for the background-transfer timing model.  `Send + Sync` because one
/// instance is shared across cluster replica threads.
pub trait SnapshotStore: Send + Sync {
    /// Tokens per stored block — the block size chains passed to the
    /// `_chain` methods must be keyed at (the wrappers use it to hash).
    fn block_tokens(&self) -> usize;

    /// Chain-based [`SnapshotStore::peek`]: side-effect-free coverage
    /// probe over a precomputed chain.  Takes **no exclusive lock** —
    /// concurrent probes never serialize against each other.
    fn peek_chain(&self, chain: &[BlockKey], now: f64) -> usize;

    /// Chain-based [`SnapshotStore::begin_restore`].
    fn restore_chain(
        &self,
        chain: &[BlockKey],
        min_tokens: usize,
        now: f64,
        replica: usize,
    ) -> Option<StoreHit>;

    /// Chain-based [`SnapshotStore::publish`].
    fn publish_chain(&self, chain: &[BlockKey], now: f64, visible_at: f64, replica: usize);

    /// Chain-based [`SnapshotStore::prefetch_candidate`] (read-only,
    /// like [`SnapshotStore::peek_chain`]).
    fn prefetch_candidate_chain(&self, chain: &[BlockKey], now: f64) -> Option<StorePrefetch>;

    /// Chain-based [`SnapshotStore::stage`].
    fn stage_chain(&self, chain: &[BlockKey], now: f64, price: &dyn Fn(u64) -> f64) -> bool;

    /// Chain-based [`SnapshotStore::pin`] (default no-op for stores
    /// without eviction).
    fn pin_chain(&self, chain: &[BlockKey]) {
        let _ = chain;
    }

    /// Chain-based [`SnapshotStore::unpin`] (default no-op).
    fn unpin_chain(&self, chain: &[BlockKey]) {
        let _ = chain;
    }

    /// Side-effect-free coverage probe: block-aligned prompt tokens a
    /// restore could serve right now (no LRU touch — schedulers may
    /// probe every waiting turn every step, mirroring
    /// `RadixCache::peek`).
    fn peek(&self, prompt: &[u32], now: f64) -> usize {
        self.peek_chain(&chain_keys(prompt, self.block_tokens()), now)
    }

    /// Find the longest visible stored block prefix of `prompt`
    /// covering strictly more than `min_tokens` (the caller's local
    /// radix coverage, block-aligned) and begin restoring it: touches
    /// LRU, counts the hit, and consumes any prefetch staging the
    /// restored blocks carry (entries never change tier here — staging
    /// is the promotion path, and it is transient).  The caller
    /// charges the returned per-tier byte counts' transfer costs —
    /// only bytes beyond `min_tokens` are transferred.
    fn begin_restore(
        &self,
        prompt: &[u32],
        min_tokens: usize,
        now: f64,
        replica: usize,
    ) -> Option<StoreHit> {
        self.restore_chain(&chain_keys(prompt, self.block_tokens()), min_tokens, now, replica)
    }

    /// Publish a completed context into the store (write-back), one
    /// content-addressed entry per block.  The transfer runs in the
    /// background: new blocks become visible to probes at `visible_at`
    /// (clamped to at least one causality window past `now`).  Blocks
    /// shared with already-stored contexts dedupe to one copy.
    /// Admission is prefix-first: a context longer than the tiers can
    /// hold is truncated rather than allowed to evict its own shallower
    /// blocks — the stored prefix stays probe-reachable instead of
    /// degenerating to unreachable tail blocks.
    fn publish(&self, ctx: &[u32], now: f64, visible_at: f64, replica: usize) {
        self.publish_chain(&chain_keys(ctx, self.block_tokens()), now, visible_at, replica);
    }

    /// Disk-resident, unstaged blocks inside `prompt`'s stored prefix,
    /// if any — what a prefetch would stage.  Side-effect-free
    /// (diagnostics and tests; [`SnapshotStore::stage`] is
    /// self-contained and does not need a prior candidate probe).
    fn prefetch_candidate(&self, prompt: &[u32], now: f64) -> Option<StorePrefetch> {
        self.prefetch_candidate_chain(&chain_keys(prompt, self.block_tokens()), now)
    }

    /// Begin staging `prompt`'s disk-resident, unstaged stored blocks
    /// into host memory.  The bytes to move and the completion time —
    /// `now + price(bytes)`, clamped to the causality window — are
    /// determined atomically with the marking, so concurrent replicas
    /// can neither double-stage nor misprice a partial staging.  From
    /// completion on, the next restore of each staged block is charged
    /// PCIe instead of NVMe (the staging scratch is transient —
    /// consumed by that restore, not a third tier); the transfer runs
    /// in the background and consumes no engine time.  Returns false
    /// when there was nothing (new) to stage.
    fn stage(&self, prompt: &[u32], now: f64, price: &dyn Fn(u64) -> f64) -> bool {
        self.stage_chain(&chain_keys(prompt, self.block_tokens()), now, price)
    }

    /// Pin `ctx`'s stored block chain against demotion and drop — the
    /// disaggregated handoff guarantee: a prefix published by a prefill
    /// replica must still be restorable (from the tier it was published
    /// to) when the owning decode replica consumes it, no matter what
    /// pressure other publishes apply in between.  Pins are counted, so
    /// overlapping handoffs sharing prefix blocks nest; blocks absent
    /// from the store (truncated publish) are skipped.
    fn pin(&self, ctx: &[u32]) {
        self.pin_chain(&chain_keys(ctx, self.block_tokens()));
    }

    /// Release one pin on each block of `ctx`'s stored chain (the
    /// decode-side consume).  Saturating: blocks that were dropped
    /// before ever being pinned, or never pinned, are skipped.
    fn unpin(&self, ctx: &[u32]) {
        self.unpin_chain(&chain_keys(ctx, self.block_tokens()));
    }

    /// Snapshot of the aggregate store counters.
    fn stats(&self) -> StoreStats;

    /// Snapshot of per-shard counters, indexed by shard (empty for
    /// unsharded stores — the default keeps existing implementations
    /// untouched).  Surfaced only under `--obs on`; see [`ShardStats`]
    /// for why this lives outside [`SnapshotStore::stats`].
    fn shard_stats(&self) -> Vec<ShardStats> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_budget_reserve_release_roundtrip() {
        let mut b = TierBudget::new(100);
        assert!(b.reserve(60));
        assert_eq!(b.free(), 40);
        assert!(!b.reserve(50), "over capacity");
        assert_eq!(b.used(), 60, "failed reserve leaves occupancy untouched");
        assert!(b.release(60).is_ok());
        assert_eq!(b.used(), 0);
    }

    #[test]
    fn tier_budget_underflow_is_hard_error() {
        let mut b = TierBudget::new(100);
        assert!(b.reserve(30));
        let err = b.release(40).unwrap_err();
        assert_eq!(err, TierAccountingError { released: 40, used: 30 });
        assert_eq!(b.used(), 30, "occupancy untouched after the error");
        assert!(b.release(30).is_ok());
        assert!(b.release(1).is_err(), "double release surfaces");
    }
}
