//! Conservative virtual-clock synchronization across cluster replicas.
//!
//! Each engine replica runs its own discrete-event timeline.  Without a
//! shared store that is fine — replicas never exchange state mid-run
//! and their stats merge afterwards.  A *shared* store introduces
//! causality: replica B probing at virtual time `t` must observe every
//! publish with `visible_at <= t`, no matter how the OS interleaved the
//! replica threads.  The fence makes that hold conservatively (classic
//! time-window synchronization from parallel discrete-event
//! simulation): a replica may not advance more than [`ClockFence::window`]
//! seconds of virtual time past the slowest replica, and the store
//! clamps every visibility time at least one window into the future —
//! so by the time any replica's clock reaches an entry's `visible_at`,
//! the publishing replica has (wall-clock) already executed the
//! publish.
//!
//! Hit/miss outcomes are therefore functions of virtual time alone.
//! What remains scheduling-dependent is sub-window interleaving of LRU
//! touches, which can reorder *eviction* ties inside the store — an
//! approximation the module docs of `store` call out.
//!
//! A replica that finishes (or unwinds) parks its clock at `+inf` via
//! [`ClockFence::finish`], so stragglers never deadlock the fence;
//! `StoreHandle` calls it from `Drop`, which covers panics.

use std::sync::atomic::{AtomicU64, Ordering};

/// Default causality window in virtual seconds: far below every
/// latency the benches report (milliseconds and up), far above the
/// per-step spin granularity that would serialize replicas.
pub const DEFAULT_WINDOW: f64 = 2e-3;

/// Shared virtual-clock fence for one cluster run (see module docs).
#[derive(Debug)]
pub struct ClockFence {
    /// Per-replica virtual clocks, as `f64::to_bits` (monotone for the
    /// non-negative times the engine produces).
    clocks: Vec<AtomicU64>,
    window: f64,
}

impl ClockFence {
    /// Fence over `replicas` clocks, all starting at virtual 0.
    pub fn new(replicas: usize) -> Self {
        ClockFence {
            clocks: (0..replicas.max(1)).map(|_| AtomicU64::new(0f64.to_bits())).collect(),
            window: DEFAULT_WINDOW,
        }
    }

    /// The causality window in virtual seconds: the most any replica
    /// may run ahead of the slowest, and the minimum visibility delay
    /// the store imposes on cross-replica writes.
    pub fn window(&self) -> f64 {
        self.window
    }

    /// Publish `now` as `replica`'s current virtual time and block
    /// until every other replica is within the window behind it.  The
    /// globally slowest replica never blocks, so the fence always makes
    /// progress.
    pub fn sync(&self, replica: usize, now: f64) {
        self.clocks[replica].store(now.to_bits(), Ordering::Release);
        let horizon = now - self.window;
        let mut spins = 0u32;
        loop {
            let min = self
                .clocks
                .iter()
                .map(|c| f64::from_bits(c.load(Ordering::Acquire)))
                .fold(f64::INFINITY, f64::min);
            if min >= horizon {
                return;
            }
            // Brief spin for the common close-race case, then yield the
            // core on a timer: a replica that idle-jumped far ahead may
            // wait a long wall-clock time for the laggards.
            spins += 1;
            if spins < 64 {
                std::thread::yield_now();
            } else {
                std::thread::park_timeout(std::time::Duration::from_micros(50));
            }
        }
    }

    /// Park `replica`'s clock at `+inf`: it no longer constrains
    /// anyone.  Called when a replica drains its shard (or unwinds).
    pub fn finish(&self, replica: usize) {
        self.clocks[replica].store(f64::INFINITY.to_bits(), Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_replica_never_blocks() {
        let f = ClockFence::new(1);
        f.sync(0, 0.0);
        f.sync(0, 1e9);
    }

    #[test]
    fn finished_replica_releases_waiters() {
        let f = Arc::new(ClockFence::new(2));
        // Replica 1 parks at +inf; replica 0 may then run arbitrarily
        // far ahead without spinning forever.
        f.finish(1);
        f.sync(0, 1e6);
    }

    #[test]
    fn fence_bounds_clock_skew() {
        let f = Arc::new(ClockFence::new(2));
        let g = f.clone();
        let t = std::thread::spawn(move || {
            // Replica 1 walks slowly to 1.0; replica 0 wants to jump to
            // 10.0 and must wait until replica 1 finishes.
            for i in 0..=10 {
                g.sync(1, i as f64 * 0.1);
            }
            g.finish(1);
        });
        f.sync(0, 10.0); // returns only once replica 1 caught up/finished
        t.join().unwrap();
    }
}
