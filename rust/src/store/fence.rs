//! Conservative virtual-clock synchronization across cluster replicas.
//!
//! Each engine replica runs its own discrete-event timeline.  Without a
//! shared store that is fine — replicas never exchange state mid-run
//! and their stats merge afterwards.  A *shared* store introduces
//! causality: replica B probing at virtual time `t` must observe every
//! publish with `visible_at <= t`, no matter how the OS interleaved the
//! replica threads.  The fence makes that hold conservatively (classic
//! time-window synchronization from parallel discrete-event
//! simulation): a replica may not advance more than [`ClockFence::window`]
//! seconds of virtual time past the slowest replica, and the store
//! clamps every visibility time at least one window into the future —
//! so by the time any replica's clock reaches an entry's `visible_at`,
//! the publishing replica has (wall-clock) already executed the
//! publish.
//!
//! Hit/miss outcomes are therefore functions of virtual time alone.
//! What remains scheduling-dependent is sub-window interleaving of LRU
//! touches, which can reorder *eviction* ties inside the store — an
//! approximation the module docs of `store` call out.
//!
//! A replica that finishes (or unwinds) parks its clock at `+inf` via
//! [`ClockFence::finish`], so stragglers never deadlock the fence;
//! `StoreHandle` calls it from `Drop`, which covers panics.
//!
//! # De-amortized fast path
//!
//! `sync` is on the store hot path — it runs before *every* store
//! operation of every replica, including the scheduler's per-turn
//! per-step coverage probes — and the common case by far is "nobody is
//! behind".  Proving that used to cost a full O(replicas) scan of the
//! clock array (R² cache-line traffic per step across the cluster).
//! The fence now keeps a monotone **horizon hint**: a lower bound on
//! the minimum live clock, maintained with `fetch_max`.  A sync whose
//! horizon is at or below the hint returns after one atomic load.
//!
//! The hint is only advanced from a scan in which **no clock was
//! parked at `+inf`**.  That restriction is what keeps it a valid
//! lower bound forever: a live replica's clock only moves forward, so
//! a min over live clocks is monotone — but a *parked* clock may later
//! be overwritten by `sync` again (a disaggregated replica finishing
//! its prefill phase parks, then resumes as its decode half catches
//! up), and resuming always re-enters at a clock ≥ the one it parked
//! from (each engine's `now` is monotone), never below any min that
//! was computed while it was still live.  Mins computed *while* it was
//! parked, by contrast, could exceed its resume clock — so those are
//! never folded into the hint.
use std::sync::atomic::{AtomicU64, Ordering};

/// Default causality window in virtual seconds: far below every
/// latency the benches report (milliseconds and up), far above the
/// per-step spin granularity that would serialize replicas.
pub const DEFAULT_WINDOW: f64 = 2e-3;

/// Shared virtual-clock fence for one cluster run (see module docs).
#[derive(Debug)]
pub struct ClockFence {
    /// Per-replica virtual clocks, as `f64::to_bits` (monotone for the
    /// non-negative times the engine produces).
    clocks: Vec<AtomicU64>,
    /// Monotone lower bound on the minimum live clock (`f64::to_bits`;
    /// see the module docs) — the one-load fast path for `sync`.
    hint: AtomicU64,
    window: f64,
}

impl ClockFence {
    /// Fence over `replicas` clocks, all starting at virtual 0.
    pub fn new(replicas: usize) -> Self {
        ClockFence {
            clocks: (0..replicas.max(1)).map(|_| AtomicU64::new(0f64.to_bits())).collect(),
            hint: AtomicU64::new(0f64.to_bits()),
            window: DEFAULT_WINDOW,
        }
    }

    /// The causality window in virtual seconds: the most any replica
    /// may run ahead of the slowest, and the minimum visibility delay
    /// the store imposes on cross-replica writes.
    pub fn window(&self) -> f64 {
        self.window
    }

    /// Publish `now` as `replica`'s current virtual time and block
    /// until every other replica is within the window behind it.  The
    /// globally slowest replica never blocks, so the fence always makes
    /// progress.
    pub fn sync(&self, replica: usize, now: f64) {
        self.clocks[replica].store(now.to_bits(), Ordering::Release);
        if self.clocks.len() == 1 {
            return; // a lone replica fences against nobody
        }
        let horizon = now - self.window;
        // Fast path: the monotone hint already proves every live
        // replica is past the horizon — one load instead of a scan.
        if f64::from_bits(self.hint.load(Ordering::Acquire)) >= horizon {
            return;
        }
        let mut spins = 0u32;
        loop {
            let mut min = f64::INFINITY;
            let mut parked = false;
            for c in &self.clocks {
                let t = f64::from_bits(c.load(Ordering::Acquire));
                if t.is_infinite() {
                    parked = true;
                } else {
                    min = min.min(t);
                }
            }
            // Advance the hint only from all-live scans (see module
            // docs: a parked replica may resume below a min computed
            // while it was parked, but never below an all-live min).
            if !parked && min.is_finite() {
                self.hint.fetch_max(min.to_bits(), Ordering::AcqRel);
            }
            // `min` folds live clocks only, but a parked clock is +inf
            // and can never lower a minimum — so this is exactly the
            // old all-clocks gate (all-parked ⇒ min = +inf ⇒ pass).
            if min >= horizon {
                return;
            }
            // Brief spin for the common close-race case, then yield the
            // core on a timer: a replica that idle-jumped far ahead may
            // wait a long wall-clock time for the laggards.
            spins += 1;
            if spins < 64 {
                std::thread::yield_now();
            } else {
                std::thread::park_timeout(std::time::Duration::from_micros(50));
            }
        }
    }

    /// Park `replica`'s clock at `+inf`: it no longer constrains
    /// anyone.  Called when a replica drains its shard (or unwinds).
    pub fn finish(&self, replica: usize) {
        self.clocks[replica].store(f64::INFINITY.to_bits(), Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_replica_never_blocks() {
        let f = ClockFence::new(1);
        f.sync(0, 0.0);
        f.sync(0, 1e9);
    }

    #[test]
    fn finished_replica_releases_waiters() {
        let f = Arc::new(ClockFence::new(2));
        // Replica 1 parks at +inf; replica 0 may then run arbitrarily
        // far ahead without spinning forever.
        f.finish(1);
        f.sync(0, 1e6);
    }

    #[test]
    fn fence_bounds_clock_skew() {
        let f = Arc::new(ClockFence::new(2));
        let g = f.clone();
        let t = std::thread::spawn(move || {
            // Replica 1 walks slowly to 1.0; replica 0 wants to jump to
            // 10.0 and must wait until replica 1 finishes.
            for i in 0..=10 {
                g.sync(1, i as f64 * 0.1);
            }
            g.finish(1);
        });
        f.sync(0, 10.0); // returns only once replica 1 caught up/finished
        t.join().unwrap();
    }

    #[test]
    fn hint_never_outruns_a_parked_resume() {
        // The disagg park/resume pattern: replica 1 parks, replica 0
        // runs far ahead (scans see a parked clock, so the hint must
        // NOT advance to replica 0's level), then replica 1 resumes at
        // a much lower clock.  A later sync by replica 0 must still
        // wait for it — a stale-high hint would skip that wait.
        let f = Arc::new(ClockFence::new(2));
        f.sync(1, 1e-3); // sub-window: does not block on replica 0 at t=0
        f.finish(1);
        f.sync(0, 100.0); // unblocked by the park; hint must not follow
        assert!(f64::from_bits(f.hint.load(Ordering::Acquire)) <= 1e-3);
        let g = f.clone();
        let t = std::thread::spawn(move || {
            // Resume below replica 0's clock (≥ its own park point, per
            // engine monotonicity) and walk forward to release the main
            // thread's fence.
            for i in 0..=20 {
                g.sync(1, 90.0 + f64::from(i));
            }
            g.finish(1);
        });
        // Must block until replica 1 passes 100 - window, not return on
        // a stale hint.
        f.sync(0, 100.0 + f64::from(1u8));
        t.join().unwrap();
        let hint = f64::from_bits(f.hint.load(Ordering::Acquire));
        assert!(hint.is_finite(), "hint never becomes +inf");
    }
}
