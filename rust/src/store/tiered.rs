//! The shipped [`SnapshotStore`]: bounded host + disk tiers over
//! content-addressed **block** entries, with LRU demotion (host → disk
//! → drop), background write-back visibility and prefetch staging.
//!
//! Granularity: one entry per KV block, keyed by the rolling
//! block-hash chain — the same keying the radix prefix cache uses for
//! child indexing.  Publishing a context inserts (or refreshes) one
//! entry per block boundary, so overlapping contexts share their
//! common-prefix blocks byte-for-byte, and a probe for *any* prompt
//! finds the longest stored block prefix even when the stored context
//! is longer or shorter than the prompt — exactly the partial-match
//! semantics of the in-GPU radix tree, extended across tiers and
//! replicas.
//!
//! LRU discipline: every chain touch ticks entries deepest-block
//! first, so within one chain the root block is always the most
//! recent and same-tier eviction peels chains from the tail.  Because
//! the two tiers evict independently, a chain whose blocks straddle
//! tiers can still lose a shallow block ahead of a deeper one; the
//! orphaned deeper blocks are simply unreachable (probes stop at the
//! hole) until LRU ages them out or a republish of the context
//! reinserts the missing prefix — wasted budget at worst, never a
//! wrong hit.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::{Arc, Mutex};

use crate::kvcache::block::{hash_block, ROOT_HASH};

use super::fence::{ClockFence, DEFAULT_WINDOW};
use super::{SnapshotStore, StoreHit, StoreStats, StoreTier, TierBudget};

/// Block-entry key: the rolling hash chain through this block plus the
/// token depth it ends at (the depth disambiguates the astronomically
/// unlikely chain-hash collision across depths; same-depth collisions
/// cost a spurious sim hit, never memory unsafety — README
/// §Substitutions notes the approximation).
type Key = (u64, usize);

#[derive(Debug)]
struct Entry {
    tier: StoreTier,
    /// Replica that published the block (remote-hit attribution).
    publisher: usize,
    /// Virtual time the background write-back completes; probes before
    /// this miss.
    visible_at: f64,
    /// Virtual time a prefetch finishes staging this (disk) block into
    /// host memory; `+inf` when never staged.
    staged_at: f64,
    /// LRU tick (strictly increasing across all touches).
    tick: u64,
    /// Outstanding handoff pins (see [`SnapshotStore::pin`]): while
    /// non-zero the block is skipped by every eviction scan — neither
    /// demoted nor dropped.  Counted so overlapping handoffs sharing
    /// prefix blocks nest.
    pins: u32,
}

#[derive(Debug)]
struct Inner {
    entries: HashMap<Key, Entry>,
    /// Per-tier LRU indexes: tick -> key (ticks are unique, so each is
    /// a total recency order within its tier).  Split per tier so
    /// demotion cascades find a tier's LRU entry in O(log n) instead
    /// of scanning a global order past the other tier's entries.
    lru: [BTreeMap<u64, Key>; 2],
    host: TierBudget,
    disk: TierBudget,
    next_tick: u64,
    stats: StoreStats,
}

fn tier_idx(tier: StoreTier) -> usize {
    match tier {
        StoreTier::Host => 0,
        StoreTier::Disk => 1,
    }
}

impl Inner {
    fn touch(&mut self, key: Key) {
        let tick = self.next_tick;
        self.next_tick += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            self.lru[tier_idx(e.tier)].remove(&e.tick);
            e.tick = tick;
            self.lru[tier_idx(e.tier)].insert(tick, key);
        }
    }

    /// Least-recently-used *unpinned* key currently in `tier`.
    /// Handoff-pinned blocks are immovable until consumed, so eviction
    /// scans past them in recency order (O(pinned) extra per scan, and
    /// pins are transient); `None` when every resident block is pinned.
    fn lru_victim(&self, tier: StoreTier) -> Option<Key> {
        self.lru[tier_idx(tier)].values().find(|k| self.entries[*k].pins == 0).copied()
    }

    fn drop_entry(&mut self, key: Key, block_bytes: u64) {
        let e = self.entries.remove(&key).expect("dropping a present entry");
        self.lru[tier_idx(e.tier)].remove(&e.tick);
        match e.tier {
            StoreTier::Host => self.host.release(block_bytes),
            StoreTier::Disk => self.disk.release(block_bytes),
        }
        .expect("tier accounting");
        self.stats.dropped_entries += 1;
        self.stats.bytes_dropped += block_bytes;
    }

    /// Demote the host-LRU block one tier down: into disk when disk
    /// has capacity for a block (dropping disk-LRU blocks as needed),
    /// off the pipeline's far end otherwise.  Returns false — making
    /// no change — when the host tier is empty, or when making room
    /// would *drop* a block in `protected` (prefix-first admission: a
    /// publish must never destroy its own already-placed prefix; see
    /// [`SnapshotStore::publish`]).  Demoting a protected block to
    /// disk is fine — the chain stays contiguous across tiers.
    fn demote_host_lru(&mut self, block_bytes: u64, protected: &HashSet<Key>) -> bool {
        let Some(key) = self.lru_victim(StoreTier::Host) else {
            return false;
        };
        if block_bytes <= self.disk.capacity() {
            // Pre-check the disk victims before touching any budget so
            // a protected victim aborts with no partial state.
            while self.disk.free() < block_bytes {
                let Some(victim) = self.lru_victim(StoreTier::Disk) else {
                    return false; // every disk block is pinned
                };
                if protected.contains(&victim) {
                    return false;
                }
                self.drop_entry(victim, block_bytes);
            }
            self.host.release(block_bytes).expect("tier accounting");
            assert!(self.disk.reserve(block_bytes), "free space was checked");
            let e = self.entries.get_mut(&key).expect("demoting a present entry");
            e.tier = StoreTier::Disk;
            // The host copy is gone; any prefetch staging with it.
            e.staged_at = f64::INFINITY;
            let tick = e.tick;
            self.lru[tier_idx(StoreTier::Host)].remove(&tick);
            self.lru[tier_idx(StoreTier::Disk)].insert(tick, key);
            self.stats.demotions_to_disk += 1;
        } else {
            if protected.contains(&key) {
                return false;
            }
            self.drop_entry(key, block_bytes);
        }
        true
    }
}

/// A prefetchable span: disk-resident, unstaged blocks inside a
/// prompt's stored prefix (see [`SnapshotStore::prefetch_candidate`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StorePrefetch {
    /// Block-aligned tokens the stored prefix covers.
    pub tokens: usize,
    /// Bytes of disk-tier blocks the staging transfer would move.
    pub bytes: u64,
}

/// Content-addressed host + disk block store (see the `store` module
/// docs for the architecture and timing model).  One instance is
/// shared, behind an `Arc`, by every engine replica of a cluster.
#[derive(Debug)]
pub struct TieredStore {
    inner: Mutex<Inner>,
    block_tokens: usize,
    /// Bytes one stored block holds (block_tokens * kv_bytes_per_token).
    block_bytes: u64,
    /// Causality window: minimum delay imposed on every visibility /
    /// staging time (matches the cluster's [`ClockFence`] window).
    window: f64,
}

impl TieredStore {
    /// Store with `host_bytes` + `disk_bytes` budgets, pricing blocks
    /// of `block_tokens` tokens at `kv_bytes_per_token`.
    pub fn new(
        host_bytes: u64,
        disk_bytes: u64,
        block_tokens: usize,
        kv_bytes_per_token: u64,
    ) -> Self {
        let stats = StoreStats {
            host_capacity: host_bytes,
            disk_capacity: disk_bytes,
            ..Default::default()
        };
        let block_tokens = block_tokens.max(1);
        TieredStore {
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                lru: [BTreeMap::new(), BTreeMap::new()],
                host: TierBudget::new(host_bytes),
                disk: TierBudget::new(disk_bytes),
                next_tick: 0,
                stats,
            }),
            block_tokens,
            block_bytes: block_tokens as u64 * kv_bytes_per_token,
            window: DEFAULT_WINDOW,
        }
    }

    /// Bytes one stored block costs.
    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    /// The rolling chain keys of every block-aligned prefix of
    /// `prompt`, ascending by depth.
    fn chain_keys(&self, prompt: &[u32]) -> Vec<Key> {
        let bt = self.block_tokens;
        let mut keys = Vec::with_capacity(prompt.len() / bt);
        let mut h = ROOT_HASH;
        let mut off = 0;
        while off + bt <= prompt.len() {
            h = hash_block(h, &prompt[off..off + bt]);
            off += bt;
            keys.push((h, off));
        }
        keys
    }

    /// Longest contiguous visible block prefix of `keys`: the count of
    /// leading keys whose entries are present and past write-back.
    fn covered(inner: &Inner, keys: &[Key], now: f64) -> usize {
        keys.iter()
            .take_while(|&k| inner.entries.get(k).is_some_and(|e| now >= e.visible_at))
            .count()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("store lock poisoned (a replica panicked)")
    }
}

impl SnapshotStore for TieredStore {
    fn peek(&self, prompt: &[u32], now: f64) -> usize {
        let keys = self.chain_keys(prompt);
        let inner = self.lock();
        Self::covered(&inner, &keys, now) * self.block_tokens
    }

    fn begin_restore(
        &self,
        prompt: &[u32],
        min_tokens: usize,
        now: f64,
        replica: usize,
    ) -> Option<StoreHit> {
        let keys = self.chain_keys(prompt);
        let mut inner = self.lock();
        let inner = &mut *inner;
        let blocks = Self::covered(inner, &keys, now);
        let tokens = blocks * self.block_tokens;
        if tokens <= min_tokens {
            return None;
        }
        // Blocks beyond the caller's (block-aligned) local coverage are
        // what the restore actually transfers.
        debug_assert_eq!(min_tokens % self.block_tokens, 0, "radix coverage is aligned");
        let first = min_tokens / self.block_tokens;
        let mut host_bytes = 0;
        let mut disk_bytes = 0;
        let mut remote = false;
        for k in &keys[first..blocks] {
            let e = inner.entries.get_mut(k).expect("covered block is present");
            match e.tier {
                StoreTier::Host => host_bytes += self.block_bytes,
                StoreTier::Disk if e.staged_at <= now => {
                    host_bytes += self.block_bytes;
                    // The staged host copy is consumed by this restore;
                    // the next one pays NVMe again unless re-prefetched
                    // (staging scratch is transient, not a third tier).
                    e.staged_at = f64::INFINITY;
                    inner.stats.prefetch_hits += 1;
                }
                StoreTier::Disk => disk_bytes += self.block_bytes,
            }
            if e.publisher != replica {
                remote = true;
            }
        }
        // Touch the whole matched chain, deepest block first, so the
        // root stays the most recent and LRU eviction peels chain
        // tails instead of punching holes.
        for &k in keys[..blocks].iter().rev() {
            inner.touch(k);
        }
        if disk_bytes > 0 {
            inner.stats.disk_hits += 1;
        } else {
            inner.stats.host_hits += 1;
        }
        if remote {
            inner.stats.remote_hits += 1;
        }
        Some(StoreHit { tokens, host_bytes, disk_bytes, remote })
    }

    fn publish(&self, ctx: &[u32], now: f64, visible_at: f64, replica: usize) {
        let keys = self.chain_keys(ctx);
        if keys.is_empty() {
            return;
        }
        let visible_at = visible_at.max(now + self.window);
        let mut inner = self.lock();
        let inner = &mut *inner;
        let mut inserted = 0u64;
        let mut rejected = false;
        // Blocks of THIS chain already resident (deduped or just
        // placed): making room for a deeper block must never drop one
        // of them — a context longer than the tiers would otherwise
        // evict its own roots block by block, ending with nothing but
        // unreachable tail blocks after thrashing out other entries.
        // Prefix-first admission truncates the chain instead: the
        // placed prefix stays usable.
        let mut placed: HashSet<Key> = HashSet::new();
        for &key in &keys {
            if let Some(e) = inner.entries.get_mut(&key) {
                // Shared-prefix block already stored (possibly by
                // another model/workflow/replica): one copy, refreshed.
                e.visible_at = e.visible_at.min(visible_at);
                placed.insert(key);
                continue;
            }
            let tier = if self.block_bytes <= inner.host.capacity() {
                let mut truncated = false;
                while !inner.host.reserve(self.block_bytes) {
                    if !inner.demote_host_lru(self.block_bytes, &placed) {
                        truncated = true;
                        break;
                    }
                }
                if truncated {
                    break;
                }
                StoreTier::Host
            } else if self.block_bytes <= inner.disk.capacity() {
                let mut truncated = false;
                while !inner.disk.reserve(self.block_bytes) {
                    let victim = inner.lru_victim(StoreTier::Disk);
                    let Some(victim) = victim.filter(|v| !placed.contains(v)) else {
                        truncated = true;
                        break;
                    };
                    inner.drop_entry(victim, self.block_bytes);
                }
                if truncated {
                    break;
                }
                StoreTier::Disk
            } else {
                // A block fits in no tier: nothing deeper can be
                // reachable either.
                inner.stats.publish_rejected += 1;
                rejected = true;
                break;
            };
            let tick = inner.next_tick;
            inner.next_tick += 1;
            inner.entries.insert(
                key,
                Entry {
                    tier,
                    publisher: replica,
                    visible_at,
                    staged_at: f64::INFINITY,
                    tick,
                    pins: 0,
                },
            );
            inner.lru[tier_idx(tier)].insert(tick, key);
            placed.insert(key);
            inserted += 1;
            inner.stats.bytes_published += self.block_bytes;
        }
        // Refresh LRU over the whole chain, deepest first (see
        // `begin_restore`), covering both new and deduped blocks.
        for &k in keys.iter().rev() {
            inner.touch(k);
        }
        if inserted > 0 {
            inner.stats.publishes += 1;
        } else if !rejected {
            inner.stats.dedup_publishes += 1;
        }
    }

    fn prefetch_candidate(&self, prompt: &[u32], now: f64) -> Option<StorePrefetch> {
        let keys = self.chain_keys(prompt);
        let inner = self.lock();
        let blocks = Self::covered(&inner, &keys, now);
        let bytes: u64 = keys[..blocks]
            .iter()
            .filter(|k| {
                let e = &inner.entries[*k];
                e.tier == StoreTier::Disk && e.staged_at.is_infinite()
            })
            .map(|_| self.block_bytes)
            .sum();
        (bytes > 0).then_some(StorePrefetch { tokens: blocks * self.block_tokens, bytes })
    }

    fn stage(&self, prompt: &[u32], now: f64, price: &dyn Fn(u64) -> f64) -> bool {
        {
            // Nothing on disk -> nothing stageable; skip the hash walk.
            let inner = self.lock();
            if inner.disk.used() == 0 {
                return false;
            }
        }
        let keys = self.chain_keys(prompt);
        let mut inner = self.lock();
        let inner = &mut *inner;
        let blocks = Self::covered(inner, &keys, now);
        // Bytes and completion time are computed under the same lock
        // that marks the staging, so a racing replica can neither
        // double-stage nor leave this staging priced for a transfer
        // larger than what it actually moves.
        let bytes: u64 = keys[..blocks]
            .iter()
            .filter(|&k| {
                let e = &inner.entries[k];
                e.tier == StoreTier::Disk && e.staged_at.is_infinite()
            })
            .map(|_| self.block_bytes)
            .sum();
        if bytes == 0 {
            return false;
        }
        let ready_at = (now + price(bytes)).max(now + self.window);
        for k in &keys[..blocks] {
            let e = inner.entries.get_mut(k).expect("covered block is present");
            if e.tier == StoreTier::Disk && e.staged_at.is_infinite() {
                e.staged_at = ready_at;
            }
        }
        inner.stats.prefetches += 1;
        true
    }

    fn pin(&self, ctx: &[u32]) {
        let keys = self.chain_keys(ctx);
        let mut inner = self.lock();
        let inner = &mut *inner;
        let mut any = false;
        for k in &keys {
            if let Some(e) = inner.entries.get_mut(k) {
                if e.pins == 0 {
                    inner.stats.pinned_blocks += 1;
                }
                e.pins += 1;
                any = true;
            }
        }
        if any {
            inner.stats.handoff_pins += 1;
        }
    }

    fn unpin(&self, ctx: &[u32]) {
        let keys = self.chain_keys(ctx);
        let mut inner = self.lock();
        let inner = &mut *inner;
        for k in &keys {
            if let Some(e) = inner.entries.get_mut(k) {
                if e.pins > 0 {
                    e.pins -= 1;
                    if e.pins == 0 {
                        inner.stats.pinned_blocks -= 1;
                    }
                }
            }
        }
    }

    fn stats(&self) -> StoreStats {
        let inner = self.lock();
        let mut s = inner.stats.clone();
        s.entries = inner.entries.len();
        s.host_used = inner.host.used();
        s.disk_used = inner.disk.used();
        s
    }
}

/// One replica's view of the shared store: the store `Arc`, the
/// replica's id (remote-hit attribution) and the cluster's clock fence.
///
/// Every store operation fences first at the virtual time it is about
/// to use — the engine's clock advances *within* a step (prefills,
/// restores), so fencing only at step boundaries would let a replica
/// probe at a clock far past what the other replicas have been held
/// to, re-introducing the thread-interleaving dependence the fence
/// exists to remove.  Dropping the handle parks the replica's fence
/// clock, so a finished (or panicking) replica never deadlocks the
/// others.
pub struct StoreHandle {
    store: Arc<dyn SnapshotStore>,
    fence: Option<Arc<ClockFence>>,
    replica: usize,
}

impl StoreHandle {
    /// Handle for `replica` over a shared `store` (and, in cluster
    /// runs, the shared `fence`).
    pub fn new(
        store: Arc<dyn SnapshotStore>,
        fence: Option<Arc<ClockFence>>,
        replica: usize,
    ) -> Self {
        StoreHandle { store, fence, replica }
    }

    /// This replica's id within the cluster.
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// Fence this replica's virtual clock (no-op without a fence).
    pub fn sync(&self, now: f64) {
        if let Some(f) = &self.fence {
            f.sync(self.replica, now);
        }
    }

    /// Park this replica's fence clock at `+inf` — it no longer
    /// constrains the other replicas (also done on drop, which covers
    /// unwinding replicas).
    pub fn finish(&self) {
        if let Some(f) = &self.fence {
            f.finish(self.replica);
        }
    }

    /// See [`SnapshotStore::peek`] (fences at `now` first).
    pub fn peek(&self, prompt: &[u32], now: f64) -> usize {
        self.sync(now);
        self.store.peek(prompt, now)
    }

    /// See [`SnapshotStore::begin_restore`] (fences at `now` first).
    pub fn begin_restore(&self, prompt: &[u32], min_tokens: usize, now: f64) -> Option<StoreHit> {
        self.sync(now);
        self.store.begin_restore(prompt, min_tokens, now, self.replica)
    }

    /// See [`SnapshotStore::publish`] (fences at `now` first).
    pub fn publish(&self, ctx: &[u32], now: f64, visible_at: f64) {
        self.sync(now);
        self.store.publish(ctx, now, visible_at, self.replica);
    }

    /// See [`SnapshotStore::prefetch_candidate`] (fences at `now`
    /// first).
    pub fn prefetch_candidate(&self, prompt: &[u32], now: f64) -> Option<StorePrefetch> {
        self.sync(now);
        self.store.prefetch_candidate(prompt, now)
    }

    /// See [`SnapshotStore::stage`] (fences at `now` first).
    pub fn stage(&self, prompt: &[u32], now: f64, price: &dyn Fn(u64) -> f64) -> bool {
        self.sync(now);
        self.store.stage(prompt, now, price)
    }

    /// See [`SnapshotStore::pin`] (no fence: pins have no visibility
    /// semantics — they only constrain eviction).
    pub fn pin(&self, ctx: &[u32]) {
        self.store.pin(ctx);
    }

    /// See [`SnapshotStore::unpin`].
    pub fn unpin(&self, ctx: &[u32]) {
        self.store.unpin(ctx);
    }

    /// Snapshot of the shared store's aggregate counters.
    pub fn stats(&self) -> StoreStats {
        self.store.stats()
    }
}

impl Drop for StoreHandle {
    fn drop(&mut self) {
        if let Some(f) = &self.fence {
            f.finish(self.replica);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BT: usize = 16;
    const BPT: u64 = 64; // block_bytes = 1024

    fn toks(n: usize, salt: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 11 + salt).collect()
    }

    fn store(host_blocks: u64, disk_blocks: u64) -> TieredStore {
        TieredStore::new(host_blocks * 1024, disk_blocks * 1024, BT, BPT)
    }

    /// Publish with write-back already completed (visible immediately
    /// after the causality window).
    fn publish_now(s: &TieredStore, ctx: &[u32], now: f64, replica: usize) {
        s.publish(ctx, now, now, replica);
    }

    const LATER: f64 = 1.0; // comfortably past the causality window

    fn ledger_balances(s: &TieredStore) {
        let st = s.stats();
        assert_eq!(
            st.bytes_published,
            st.host_used + st.disk_used + st.bytes_dropped,
            "every published byte is resident or dropped"
        );
    }

    #[test]
    fn publish_probe_restore_roundtrip() {
        let s = store(16, 0);
        let ctx = toks(48, 0); // 3 blocks
        publish_now(&s, &ctx, 0.0, 0);
        // Not yet visible at publish time (background write-back).
        assert_eq!(s.peek(&ctx, 0.0), 0);
        assert_eq!(s.peek(&ctx, LATER), 48);
        // A prompt extending the context hits its stored prefix...
        let mut longer = ctx.clone();
        longer.extend(toks(40, 999));
        assert_eq!(s.peek(&longer, LATER), 48);
        // ...and a *shorter* prompt hits its aligned sub-prefix (the
        // block granularity the radix tree also matches at).
        assert_eq!(s.peek(&ctx[..32], LATER), 32);
        let hit = s.begin_restore(&longer, 0, LATER, 1).expect("hit");
        assert_eq!(hit.tokens, 48);
        assert_eq!(hit.host_bytes, 3 * 1024);
        assert_eq!(hit.disk_bytes, 0);
        assert!(hit.remote, "published by replica 0, restored by 1");
        // Local radix already covering one block: only the rest moves.
        let partial = s.begin_restore(&longer, 16, LATER, 1).expect("hit");
        assert_eq!(partial.tokens, 48);
        assert_eq!(partial.host_bytes, 2 * 1024);
        // No hit when coverage does not beat the floor.
        assert!(s.begin_restore(&longer, 48, LATER, 1).is_none());
        let st = s.stats();
        assert_eq!((st.host_hits, st.remote_hits), (2, 2));
        ledger_balances(&s);
    }

    #[test]
    fn shared_prefix_blocks_dedupe_to_one_copy() {
        let s = store(16, 0);
        let a = toks(32, 3);
        let mut b = a.clone();
        b.extend(toks(32, 77)); // same first 2 blocks, 2 more
        publish_now(&s, &a, 0.0, 0);
        publish_now(&s, &b, 0.5, 1);
        let st = s.stats();
        assert_eq!(st.publishes, 2);
        assert_eq!(st.entries, 4, "shared prefix stored once");
        assert_eq!(st.host_used, 4 * 1024);
        // Identical republish adds nothing.
        publish_now(&s, &a, 0.6, 1);
        assert_eq!(s.stats().dedup_publishes, 1);
        assert_eq!(s.stats().entries, 4);
        ledger_balances(&s);
    }

    #[test]
    fn partial_blocks_are_not_stored() {
        let s = store(16, 0);
        publish_now(&s, &toks(10, 0), 0.0, 0); // below one block
        assert_eq!(s.stats().publishes, 0);
        let ctx = toks(40, 1); // 2.5 blocks -> 2 stored
        publish_now(&s, &ctx, 0.0, 0);
        assert_eq!(s.peek(&ctx, LATER), 32);
    }

    #[test]
    fn demotion_pipeline_host_to_disk_to_drop() {
        // Host holds 4 blocks, disk 4: ten published blocks push the
        // oldest through disk and off the far end.
        let s = store(4, 4);
        for salt in 0..5u32 {
            publish_now(&s, &toks(32, 1000 * (salt + 1)), f64::from(salt), 0);
        }
        let st = s.stats();
        assert_eq!(st.host_used, 4 * 1024, "host full");
        assert_eq!(st.disk_used, 4 * 1024, "disk full");
        assert_eq!(st.demotions_to_disk, 6, "blocks cascade in LRU order");
        assert_eq!(st.dropped_entries, 2, "pipeline's far end drops");
        ledger_balances(&s);
        // The newest context is host-resident, the oldest gone.
        assert_eq!(s.peek(&toks(32, 1000), 10.0), 0, "oldest dropped");
        let hit = s.begin_restore(&toks(32, 5000), 0, 10.0, 0).expect("newest");
        assert_eq!(hit.disk_bytes, 0, "newest still host-resident");
    }

    #[test]
    fn long_chain_publish_truncates_instead_of_self_evicting() {
        // A 6-block context into a 4-block host-only store: admission
        // is prefix-first — the first 4 blocks stay probe-reachable
        // and the tail is truncated, instead of the chain eating its
        // own roots and ending 100% unreachable.
        let s = store(4, 0);
        let long = toks(96, 5);
        publish_now(&s, &long, 0.0, 0);
        assert_eq!(s.peek(&long, LATER), 64, "placed prefix stays usable");
        assert_eq!(s.stats().dropped_entries, 0, "no self-thrash");
        ledger_balances(&s);
        // With a disk tier the chain spreads across tiers instead:
        // shallow blocks demote to disk, everything stays reachable.
        let s2 = store(4, 4);
        publish_now(&s2, &long, 0.0, 0);
        assert_eq!(s2.peek(&long, LATER), 96, "tiers jointly hold the chain");
        let st = s2.stats();
        assert_eq!((st.host_used, st.disk_used), (4 * 1024, 2 * 1024));
        // And longer than both tiers combined: truncate at capacity.
        let s3 = store(2, 2);
        publish_now(&s3, &long, 0.0, 0);
        assert_eq!(s3.peek(&long, LATER), 64, "prefix bounded by total budget");
        assert_eq!(s3.stats().dropped_entries, 0);
        ledger_balances(&s3);
    }

    #[test]
    fn chain_eviction_peels_tails_not_roots() {
        // One long chain; pressure drops its deepest blocks first, so
        // the surviving prefix stays contiguous and probe-able.
        let s = store(4, 0);
        publish_now(&s, &toks(64, 9), 0.0, 0); // exactly fills host
        publish_now(&s, &toks(32, 7777), 0.5, 0); // 2 blocks push out 2
        assert_eq!(s.peek(&toks(64, 9), LATER), 32, "tail peeled, root kept");
        ledger_balances(&s);
    }

    #[test]
    fn disk_restore_charges_disk_until_staged() {
        let s = store(2, 8);
        let cold = toks(32, 1);
        let hot = toks(32, 2);
        publish_now(&s, &cold, 0.0, 0);
        publish_now(&s, &hot, 0.1, 0); // demotes `cold` to disk
        // Host is full, so the disk hit cannot promote; charged Disk.
        let hit = s.begin_restore(&cold, 0, LATER, 0).expect("disk hit");
        assert_eq!(hit.disk_bytes, 2 * 1024);
        assert_eq!(s.stats().disk_hits, 1);
        // Prefetch staging flips the charge to host-side once ready.
        let p = s.prefetch_candidate(&cold, LATER).expect("stageable");
        assert_eq!(p.bytes, 2 * 1024);
        assert!(s.stage(&cold, LATER, &|_| 0.5), "staging starts");
        assert!(s.prefetch_candidate(&cold, LATER).is_none(), "no double stage");
        assert!(!s.stage(&cold, LATER, &|_| 0.5), "no double stage via stage");
        let early = s.begin_restore(&cold, 0, LATER + 0.1, 0).expect("in flight");
        assert!(early.disk_bytes > 0, "staging not finished yet");
        let staged = s.begin_restore(&cold, 0, LATER + 1.0, 0).expect("staged");
        assert_eq!(staged.disk_bytes, 0, "PCIe-only after staging");
        assert_eq!(s.stats().prefetch_hits, 2, "both staged blocks consumed");
        assert_eq!(s.stats().prefetches, 1);
        // Staging scratch is transient: the restore consumed it, so the
        // next restore pays NVMe again — and the chain is stageable
        // again.
        let after = s.begin_restore(&cold, 0, LATER + 2.0, 0).expect("hit");
        assert!(after.disk_bytes > 0, "staged copy was consumed");
        assert!(s.prefetch_candidate(&cold, LATER + 2.0).is_some());
        ledger_balances(&s);
    }

    #[test]
    fn peek_is_side_effect_free_for_lru() {
        let s = store(4, 0);
        let a = toks(32, 1);
        let b = toks(32, 2);
        publish_now(&s, &a, 0.0, 0);
        publish_now(&s, &b, 0.1, 0);
        for _ in 0..8 {
            assert_eq!(s.peek(&a, LATER), 32);
        }
        // Host full; the next publish demotes LRU blocks — still `a`'s
        // (peeks don't refresh), and with no disk they drop.
        publish_now(&s, &toks(32, 3), LATER, 0);
        assert_eq!(s.peek(&a, LATER + 1.0), 0, "peeked-only chain stayed LRU");
        assert_eq!(s.peek(&b, LATER + 1.0), 32);
        ledger_balances(&s);
    }

    #[test]
    fn oversized_blocks_are_rejected_not_thrashed() {
        // Budgets below one block: nothing can ever be admitted.
        let s = TieredStore::new(100, 100, BT, BPT); // block_bytes = 1024
        publish_now(&s, &toks(32, 1), 0.0, 0);
        let st = s.stats();
        assert_eq!(st.publish_rejected, 1, "chain placement stops at the first reject");
        assert_eq!(st.entries, 0);
        ledger_balances(&s);
    }

    #[test]
    fn pinned_handoff_chain_survives_pressure_until_unpinned() {
        let s = store(4, 0); // host-only, 4 blocks
        let handoff = toks(32, 1); // 2 blocks
        publish_now(&s, &handoff, 0.0, 0);
        s.pin(&handoff);
        let st = s.stats();
        assert_eq!((st.pinned_blocks, st.handoff_pins), (2, 1));
        // Causality: the pinned publish is still invisible before its
        // write-back horizon — a consumer must not restore it early.
        assert!(s.begin_restore(&handoff, 0, 0.0, 1).is_none());
        // Pressure that would evict the LRU chain (the handoff is
        // oldest) must scan past the pinned blocks.
        publish_now(&s, &toks(32, 2), 0.5, 0); // fills host
        publish_now(&s, &toks(32, 3), 1.0, 0); // evicts salt-2, not the pin
        assert_eq!(s.peek(&handoff, 2.0), 32, "pinned chain still resident");
        // Consume on the decode side, then release the pin.
        let hit = s.begin_restore(&handoff, 0, 2.0, 1).expect("handoff restore");
        assert_eq!((hit.tokens, hit.remote), (32, true));
        s.unpin(&handoff);
        assert_eq!(s.stats().pinned_blocks, 0);
        // Unpinned, the chain ages out under pressure like any other.
        publish_now(&s, &toks(32, 4), 3.0, 0); // evicts salt-3 (LRU)
        publish_now(&s, &toks(32, 5), 4.0, 0); // evicts the old handoff
        assert_eq!(s.peek(&handoff, 5.0), 0, "unpinned chain evictable again");
        // Pins on absent blocks are skipped; double unpin saturates.
        s.pin(&handoff);
        s.unpin(&handoff);
        s.unpin(&handoff);
        assert_eq!(s.stats().pinned_blocks, 0);
        ledger_balances(&s);
    }

    #[test]
    fn fully_pinned_store_truncates_publishes_instead_of_evicting() {
        let s = store(2, 0);
        let pinned = toks(32, 1); // exactly fills host
        publish_now(&s, &pinned, 0.0, 0);
        s.pin(&pinned);
        publish_now(&s, &toks(32, 2), 1.0, 0); // nowhere to go
        assert_eq!(s.peek(&pinned, 2.0), 32, "pins win over new publishes");
        assert_eq!(s.peek(&toks(32, 2), 2.0), 0, "newcomer truncated away");
        s.unpin(&pinned);
        ledger_balances(&s);
    }

    #[test]
    fn zero_host_budget_goes_straight_to_disk() {
        let s = store(0, 4);
        let ctx = toks(32, 9);
        publish_now(&s, &ctx, 0.0, 0);
        let hit = s.begin_restore(&ctx, 0, LATER, 0).expect("disk-only store");
        assert_eq!(hit.host_bytes, 0);
        assert_eq!(hit.disk_bytes, 2 * 1024);
        assert_eq!(s.stats().disk_used, 2 * 1024);
        ledger_balances(&s);
    }
}
