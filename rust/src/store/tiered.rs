//! The shipped [`SnapshotStore`]: bounded host + disk tiers over
//! content-addressed **block** entries, with LRU demotion (host → disk
//! → drop), background write-back visibility, prefetch staging — and
//! **lock striping**: entries live in N shards keyed by the rolling
//! block hash (`shard = hash & (N-1)`), so store traffic from
//! different replicas only serializes when it actually touches the
//! same shards.
//!
//! Granularity: one entry per KV block, keyed by the rolling
//! block-hash chain — the same keying the radix prefix cache uses for
//! child indexing.  Publishing a context inserts (or refreshes) one
//! entry per block boundary, so overlapping contexts share their
//! common-prefix blocks byte-for-byte, and a probe for *any* prompt
//! finds the longest stored block prefix even when the stored context
//! is longer or shorter than the prompt — exactly the partial-match
//! semantics of the in-GPU radix tree, extended across tiers and
//! replicas.
//!
//! LRU discipline: every chain touch ticks entries deepest-block
//! first, so within one chain the root block is always the most
//! recent and same-tier eviction peels chains from the tail.  Because
//! the two tiers evict independently, a chain whose blocks straddle
//! tiers can still lose a shallow block ahead of a deeper one; the
//! orphaned deeper blocks are simply unreachable (probes stop at the
//! hole) until LRU ages them out or a republish of the context
//! reinserts the missing prefix — wasted budget at worst, never a
//! wrong hit.
//!
//! # Sharding and determinism
//!
//! The shard count is an implementation knob, **never** a semantic
//! one: stats and traces are bit-identical for every shard count
//! (pinned by `prop_store_shards_bit_identical`).  That holds because
//! everything order-bearing is global, not per-shard:
//!
//!   * **LRU ticks** come from one atomic counter, so recency is a
//!     single total order no matter which shard an entry lives in;
//!     eviction scans take the *globally* least-recent unpinned entry
//!     (the minimum over each locked shard's per-tier LRU head —
//!     identical to the unsharded scan, since every entry older than a
//!     shard's first unpinned entry is pinned).
//!   * **Tier budgets** are global atomics with reserve-then-commit
//!     discipline: a reservation is made with a CAS (never
//!     over-admitting past capacity), and commits under the shard lock
//!     that also guards the entry, so a successful reservation always
//!     materializes; failure paths (truncation) occur strictly before
//!     a successful reserve, so no reservation dangles.
//!   * **Lock order** is ascending shard index, always — probes and
//!     chain ops lock only the chain's shards; eviction pressure
//!     upgrades to all shards (releasing the chain locks first), so
//!     two publishes can never deadlock.
//!
//! Read-only probes ([`SnapshotStore::peek_chain`],
//! [`SnapshotStore::prefetch_candidate_chain`]) take shard **read**
//! locks only, so scheduler coverage probes — issued for every waiting
//! turn, every step, on every replica — never serialize against each
//! other, only against writers of the same shards.
//!
//! # Poison recovery
//!
//! A replica that panics while holding a shard lock poisons it.
//! Instead of propagating the panic into every other replica (a
//! cascade that used to take the whole cluster down with one bug), the
//! store flips into a degraded static state: every later operation is
//! a miss/no-op, the `lock_poisoned` stat counts the encounters, and
//! the CLI fails the run with a clean error.  The panicking replica
//! itself still surfaces once through the cluster's thread join.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard, TryLockError};

use crate::kvcache::block::BlockKey;
use crate::tokens::TokenBuf;

use super::fence::{ClockFence, DEFAULT_WINDOW};
use super::{
    chain_keys, ShardStats, SnapshotStore, StoreHit, StoreStats, StoreTier, TierAccountingError,
};

/// Block-entry key (see [`BlockKey`]): the rolling hash chain through
/// this block plus the token depth it ends at (the depth disambiguates
/// the astronomically unlikely chain-hash collision across depths;
/// same-depth collisions cost a spurious sim hit, never memory
/// unsafety — README §Substitutions notes the approximation).
type Key = BlockKey;

#[derive(Debug)]
struct Entry {
    tier: StoreTier,
    /// Replica that published the block (remote-hit attribution).
    publisher: usize,
    /// Virtual time the background write-back completes; probes before
    /// this miss.
    visible_at: f64,
    /// Virtual time a prefetch finishes staging this (disk) block into
    /// host memory; `+inf` when never staged.
    staged_at: f64,
    /// LRU tick (strictly increasing across all touches, globally).
    tick: u64,
    /// Outstanding handoff pins (see [`SnapshotStore::pin`]): while
    /// non-zero the block is skipped by every eviction scan — neither
    /// demoted nor dropped.  Counted so overlapping handoffs sharing
    /// prefix blocks nest.
    pins: u32,
}

/// One lock-striped partition of the store: the entries whose chain
/// hash lands in this shard, plus per-tier LRU indexes over them
/// (tick → key; ticks are globally unique, so each BTreeMap is a total
/// recency order within its shard × tier).
#[derive(Debug, Default)]
struct Shard {
    entries: HashMap<Key, Entry>,
    lru: [BTreeMap<u64, Key>; 2],
}

fn tier_idx(tier: StoreTier) -> usize {
    match tier {
        StoreTier::Host => 0,
        StoreTier::Disk => 1,
    }
}

/// Global tier byte budget behind an atomic: `reserve` is a CAS that
/// refuses to pass `capacity` (soft failure — the caller demotes,
/// drops or truncates), `release` a checked decrement whose underflow
/// is the same hard error [`super::TierBudget`] reports (a caller
/// bug, never absorbed silently).
#[derive(Debug)]
struct AtomicBudget {
    capacity: u64,
    used: AtomicU64,
}

impl AtomicBudget {
    fn new(capacity: u64) -> Self {
        AtomicBudget { capacity, used: AtomicU64::new(0) }
    }

    fn used(&self) -> u64 {
        self.used.load(Ordering::SeqCst)
    }

    /// Reserve `bytes` unless that would exceed capacity.  Lock-free:
    /// concurrent reservations in different shards proceed in
    /// parallel; the CAS guarantees the sum never over-admits.
    fn reserve(&self, bytes: u64) -> bool {
        self.used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |u| {
                let next = u.checked_add(bytes)?;
                (next <= self.capacity).then_some(next)
            })
            .is_ok()
    }

    /// Release `bytes`; underflow is a hard error and leaves occupancy
    /// untouched (see [`TierAccountingError`]).
    fn release(&self, bytes: u64) -> Result<(), TierAccountingError> {
        self.used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |u| u.checked_sub(bytes))
            .map(|_| ())
            .map_err(|used| TierAccountingError { released: bytes, used })
    }
}

/// Monotone event counters + gauges behind atomics, so [`stats`]
/// snapshots — and every counted event — are lock-free.
///
/// [`stats`]: SnapshotStore::stats
#[derive(Debug, Default)]
struct Counters {
    entries: AtomicU64,
    publishes: AtomicU64,
    dedup_publishes: AtomicU64,
    publish_rejected: AtomicU64,
    bytes_published: AtomicU64,
    bytes_dropped: AtomicU64,
    demotions_to_disk: AtomicU64,
    dropped_entries: AtomicU64,
    host_hits: AtomicU64,
    disk_hits: AtomicU64,
    remote_hits: AtomicU64,
    prefetch_hits: AtomicU64,
    prefetches: AtomicU64,
    handoff_pins: AtomicU64,
    pinned_blocks: AtomicU64,
    lock_poisoned: AtomicU64,
}

fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}

/// Per-shard counters ([`ShardStats`] is the snapshot form).  Kept
/// outside [`Counters`] and outside `stats()`: the aggregate view is
/// shard-count-invariant by contract, this breakdown is deliberately
/// not.  All relaxed atomics — a handful of uncontended adds per store
/// operation, never a lock.
#[derive(Debug, Default)]
struct ShardCounters {
    hits: AtomicU64,
    publishes: AtomicU64,
    evictions: AtomicU64,
    read_locks: AtomicU64,
    write_locks: AtomicU64,
    contended: AtomicU64,
}

impl ShardCounters {
    fn snapshot(&self) -> ShardStats {
        ShardStats {
            hits: self.hits.load(Ordering::Relaxed),
            publishes: self.publishes.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            read_locks: self.read_locks.load(Ordering::Relaxed),
            write_locks: self.write_locks.load(Ordering::Relaxed),
            contended: self.contended.load(Ordering::Relaxed),
        }
    }
}

/// Shard guards held for one store operation, indexed by shard id
/// (`None` for shards the operation does not touch).  Built in
/// ascending shard order, always — the store's whole deadlock-freedom
/// argument.
struct Guards<G> {
    g: Vec<Option<G>>,
}

impl<G: std::ops::Deref<Target = Shard>> Guards<G> {
    fn shard(&self, idx: usize) -> &Shard {
        self.g[idx].as_deref().expect("operation locked this shard")
    }

    fn all(&self) -> bool {
        self.g.iter().all(Option::is_some)
    }
}

impl<G: std::ops::DerefMut<Target = Shard>> Guards<G> {
    fn shard_mut(&mut self, idx: usize) -> &mut Shard {
        self.g[idx].as_deref_mut().expect("operation locked this shard")
    }
}

type ReadGuards<'a> = Guards<RwLockReadGuard<'a, Shard>>;
type WriteGuards<'a> = Guards<RwLockWriteGuard<'a, Shard>>;

/// A prefetchable span: disk-resident, unstaged blocks inside a
/// prompt's stored prefix (see [`SnapshotStore::prefetch_candidate`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StorePrefetch {
    /// Block-aligned tokens the stored prefix covers.
    pub tokens: usize,
    /// Bytes of disk-tier blocks the staging transfer would move.
    pub bytes: u64,
}

/// Content-addressed host + disk block store, lock-striped into
/// power-of-two shards (see the module docs for the architecture,
/// timing model and determinism argument).  One instance is shared,
/// behind an `Arc`, by every engine replica of a cluster.
#[derive(Debug)]
pub struct TieredStore {
    /// Lock-striped partitions; `shard_of(key) = key.0 & mask`.
    shards: Box<[RwLock<Shard>]>,
    /// `shards.len() - 1` (shard counts are powers of two).
    mask: u64,
    /// Global host-tier budget (atomic: reservations from different
    /// shards never serialize).
    host: AtomicBudget,
    /// Global disk-tier budget.
    disk: AtomicBudget,
    /// Global LRU tick source — one total recency order across shards.
    next_tick: AtomicU64,
    c: Counters,
    /// Per-shard hit/publish/eviction/lock counters, indexed like
    /// `shards` (observability surfacing; see [`ShardCounters`]).
    per_shard: Box<[ShardCounters]>,
    /// Set once a poisoned shard lock is seen; all later operations
    /// degrade to miss/no-op (see the module docs).
    dead: AtomicBool,
    block_tokens: usize,
    /// Bytes one stored block holds (block_tokens * kv_bytes_per_token).
    block_bytes: u64,
    /// Causality window: minimum delay imposed on every visibility /
    /// staging time (matches the cluster's [`ClockFence`] window).
    window: f64,
}

/// Hard ceiling on the shard count: the shard set must fit a `u64`
/// lock-acquisition bitmask, and 64 stripes is already far past the
/// point of diminishing returns for any plausible replica count.
pub const MAX_SHARDS: usize = 64;

impl TieredStore {
    /// Unsharded store (`shards = 1`) with `host_bytes` + `disk_bytes`
    /// budgets, pricing blocks of `block_tokens` tokens at
    /// `kv_bytes_per_token` — the exact pre-sharding layout (pinned by
    /// `prop_store_shards_bit_identical`).
    pub fn new(
        host_bytes: u64,
        disk_bytes: u64,
        block_tokens: usize,
        kv_bytes_per_token: u64,
    ) -> Self {
        Self::with_shards(host_bytes, disk_bytes, block_tokens, kv_bytes_per_token, 1)
    }

    /// Store striped into `shards` partitions (rounded up to a power
    /// of two, clamped to `1..=`[`MAX_SHARDS`]).  Stats and traces are
    /// bit-identical for every value; the knob only moves lock
    /// contention (`--store-shards` on the CLI,
    /// `benches/store_contention.rs` for the scaling curve).
    pub fn with_shards(
        host_bytes: u64,
        disk_bytes: u64,
        block_tokens: usize,
        kv_bytes_per_token: u64,
        shards: usize,
    ) -> Self {
        let n = shards.clamp(1, MAX_SHARDS).next_power_of_two().min(MAX_SHARDS);
        let block_tokens = block_tokens.max(1);
        TieredStore {
            shards: (0..n).map(|_| RwLock::new(Shard::default())).collect(),
            mask: (n - 1) as u64,
            host: AtomicBudget::new(host_bytes),
            disk: AtomicBudget::new(disk_bytes),
            next_tick: AtomicU64::new(0),
            c: Counters::default(),
            per_shard: (0..n).map(|_| ShardCounters::default()).collect(),
            dead: AtomicBool::new(false),
            block_tokens,
            block_bytes: block_tokens as u64 * kv_bytes_per_token,
            window: DEFAULT_WINDOW,
        }
    }

    /// The default shard count for a cluster of `replicas` consumers:
    /// the next power of two ≥ 2× the replica count (two stripes per
    /// consumer keeps the expected collision rate of independent
    /// chains low), clamped to [`MAX_SHARDS`].
    pub fn auto_shards(replicas: usize) -> usize {
        (replicas.max(1) * 2).next_power_of_two().min(MAX_SHARDS)
    }

    /// Number of lock-striped shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Bytes one stored block costs.
    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    fn shard_of(&self, key: Key) -> usize {
        (key.0 & self.mask) as usize
    }

    /// Bit i set ⇔ shard i holds at least one of `chain`'s keys.
    fn chain_mask(&self, chain: &[Key]) -> u64 {
        chain.iter().fold(0u64, |m, k| m | 1u64 << self.shard_of(*k))
    }

    fn all_mask(&self) -> u64 {
        if self.shards.len() == MAX_SHARDS {
            u64::MAX
        } else {
            (1u64 << self.shards.len()) - 1
        }
    }

    fn poisoned(&self) -> bool {
        self.dead.load(Ordering::Relaxed)
    }

    fn mark_poisoned(&self) {
        self.dead.store(true, Ordering::Relaxed);
        bump(&self.c.lock_poisoned);
    }

    /// Write-lock the shards in `mask`, ascending.  `None` (after
    /// flipping the store dead) when any lock is poisoned.  Each
    /// acquisition tries the lock first so the per-shard `contended`
    /// counter sees exactly the acquisitions that had to block.
    fn write_shards(&self, mask: u64) -> Option<WriteGuards<'_>> {
        let mut g = Vec::with_capacity(self.shards.len());
        for (i, s) in self.shards.iter().enumerate() {
            if mask >> i & 1 == 1 {
                bump(&self.per_shard[i].write_locks);
                let locked = match s.try_write() {
                    Ok(guard) => Ok(guard),
                    Err(TryLockError::WouldBlock) => {
                        bump(&self.per_shard[i].contended);
                        s.write().map_err(|_| ())
                    }
                    Err(TryLockError::Poisoned(_)) => Err(()),
                };
                match locked {
                    Ok(guard) => g.push(Some(guard)),
                    Err(()) => {
                        self.mark_poisoned();
                        return None;
                    }
                }
            } else {
                g.push(None);
            }
        }
        Some(Guards { g })
    }

    /// Read-lock the shards in `mask`, ascending (probes: readers
    /// never serialize against each other, so `contended` here counts
    /// only reader-vs-writer collisions).
    fn read_shards(&self, mask: u64) -> Option<ReadGuards<'_>> {
        let mut g = Vec::with_capacity(self.shards.len());
        for (i, s) in self.shards.iter().enumerate() {
            if mask >> i & 1 == 1 {
                bump(&self.per_shard[i].read_locks);
                let locked = match s.try_read() {
                    Ok(guard) => Ok(guard),
                    Err(TryLockError::WouldBlock) => {
                        bump(&self.per_shard[i].contended);
                        s.read().map_err(|_| ())
                    }
                    Err(TryLockError::Poisoned(_)) => Err(()),
                };
                match locked {
                    Ok(guard) => g.push(Some(guard)),
                    Err(()) => {
                        self.mark_poisoned();
                        return None;
                    }
                }
            } else {
                g.push(None);
            }
        }
        Some(Guards { g })
    }

    /// Longest contiguous visible block prefix of `chain`: the count
    /// of leading keys whose entries are present and past write-back.
    fn covered<G: std::ops::Deref<Target = Shard>>(
        &self,
        lk: &Guards<G>,
        chain: &[Key],
        now: f64,
    ) -> usize {
        chain
            .iter()
            .take_while(|k| {
                lk.shard(self.shard_of(**k)).entries.get(k).is_some_and(|e| now >= e.visible_at)
            })
            .count()
    }

    /// Re-tick `key` to most-recent (no-op when absent — the global
    /// tick is still consumed, exactly like the unsharded layout, so
    /// tick streams stay comparable across shard counts).
    fn touch(&self, lk: &mut WriteGuards<'_>, key: Key) {
        let tick = self.next_tick.fetch_add(1, Ordering::Relaxed);
        let shard = lk.shard_mut(self.shard_of(key));
        if let Some(e) = shard.entries.get_mut(&key) {
            shard.lru[tier_idx(e.tier)].remove(&e.tick);
            e.tick = tick;
            shard.lru[tier_idx(e.tier)].insert(tick, key);
        }
    }

    /// Globally least-recently-used *unpinned* key currently in
    /// `tier`.  Requires **all** shards locked: the global minimum is
    /// the min over each shard's first unpinned entry (every entry
    /// globally older than the winner is pinned — otherwise it would
    /// be its own shard's earlier first-unpinned — so this equals the
    /// unsharded scan).  Handoff-pinned blocks are immovable until
    /// consumed; `None` when every resident block is pinned.
    fn lru_victim(&self, lk: &WriteGuards<'_>, tier: StoreTier) -> Option<Key> {
        debug_assert!(lk.all(), "global LRU scan requires every shard locked");
        let mut best: Option<(u64, Key)> = None;
        for g in &lk.g {
            let shard = g.as_deref().expect("all shards locked");
            if let Some(key) =
                shard.lru[tier_idx(tier)].values().find(|k| shard.entries[*k].pins == 0).copied()
            {
                let tick = shard.entries[&key].tick;
                let better = match best {
                    None => true,
                    Some((t, _)) => tick < t,
                };
                if better {
                    best = Some((tick, key));
                }
            }
        }
        best.map(|(_, k)| k)
    }

    fn drop_entry(&self, lk: &mut WriteGuards<'_>, key: Key) {
        let shard = lk.shard_mut(self.shard_of(key));
        let e = shard.entries.remove(&key).expect("dropping a present entry");
        shard.lru[tier_idx(e.tier)].remove(&e.tick);
        match e.tier {
            StoreTier::Host => self.host.release(self.block_bytes),
            StoreTier::Disk => self.disk.release(self.block_bytes),
        }
        .expect("tier accounting");
        self.c.entries.fetch_sub(1, Ordering::Relaxed);
        bump(&self.c.dropped_entries);
        bump(&self.per_shard[self.shard_of(key)].evictions);
        self.c.bytes_dropped.fetch_add(self.block_bytes, Ordering::Relaxed);
    }

    /// Demote the global host-LRU block one tier down: into disk when
    /// disk has capacity for a block (dropping disk-LRU blocks as
    /// needed), off the pipeline's far end otherwise.  Returns false —
    /// reserving nothing further — when the host tier is empty, or
    /// when making room would *drop* a block in `protected`
    /// (prefix-first admission: a publish must never destroy its own
    /// already-placed prefix; see [`SnapshotStore::publish`]).
    /// Demoting a protected block to disk is fine — the chain stays
    /// contiguous across tiers.  Requires all shards locked (global
    /// LRU); aborts happen strictly before a successful disk reserve,
    /// so no reservation is ever left dangling.
    fn demote_host_lru(&self, lk: &mut WriteGuards<'_>, protected: &HashSet<Key>) -> bool {
        let Some(key) = self.lru_victim(lk, StoreTier::Host) else {
            return false;
        };
        if self.block_bytes <= self.disk.capacity {
            while !self.disk.reserve(self.block_bytes) {
                let Some(victim) = self.lru_victim(lk, StoreTier::Disk) else {
                    return false; // every disk block is pinned
                };
                if protected.contains(&victim) {
                    return false;
                }
                self.drop_entry(lk, victim);
            }
            // Commit: the disk reservation is held, move the entry.
            self.host.release(self.block_bytes).expect("tier accounting");
            let shard = lk.shard_mut(self.shard_of(key));
            let e = shard.entries.get_mut(&key).expect("demoting a present entry");
            e.tier = StoreTier::Disk;
            // The host copy is gone; any prefetch staging with it.
            e.staged_at = f64::INFINITY;
            let tick = e.tick;
            shard.lru[tier_idx(StoreTier::Host)].remove(&tick);
            shard.lru[tier_idx(StoreTier::Disk)].insert(tick, key);
            bump(&self.c.demotions_to_disk);
            bump(&self.per_shard[self.shard_of(key)].evictions);
        } else {
            if protected.contains(&key) {
                return false;
            }
            self.drop_entry(lk, key);
        }
        true
    }
}

impl SnapshotStore for TieredStore {
    fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    fn peek_chain(&self, chain: &[Key], now: f64) -> usize {
        if self.poisoned() {
            return 0;
        }
        let Some(lk) = self.read_shards(self.chain_mask(chain)) else {
            return 0;
        };
        self.covered(&lk, chain, now) * self.block_tokens
    }

    fn restore_chain(
        &self,
        chain: &[Key],
        min_tokens: usize,
        now: f64,
        replica: usize,
    ) -> Option<StoreHit> {
        if self.poisoned() {
            return None;
        }
        let Some(mut lk) = self.write_shards(self.chain_mask(chain)) else {
            return None;
        };
        let blocks = self.covered(&lk, chain, now);
        let tokens = blocks * self.block_tokens;
        if tokens <= min_tokens {
            return None;
        }
        // Blocks beyond the caller's (block-aligned) local coverage are
        // what the restore actually transfers.
        debug_assert_eq!(min_tokens % self.block_tokens, 0, "radix coverage is aligned");
        let first = min_tokens / self.block_tokens;
        let mut host_bytes = 0;
        let mut disk_bytes = 0;
        let mut remote = false;
        for k in &chain[first..blocks] {
            bump(&self.per_shard[self.shard_of(*k)].hits);
            let e = lk
                .shard_mut(self.shard_of(*k))
                .entries
                .get_mut(k)
                .expect("covered block is present");
            match e.tier {
                StoreTier::Host => host_bytes += self.block_bytes,
                StoreTier::Disk if e.staged_at <= now => {
                    host_bytes += self.block_bytes;
                    // The staged host copy is consumed by this restore;
                    // the next one pays NVMe again unless re-prefetched
                    // (staging scratch is transient, not a third tier).
                    e.staged_at = f64::INFINITY;
                    bump(&self.c.prefetch_hits);
                }
                StoreTier::Disk => disk_bytes += self.block_bytes,
            }
            if e.publisher != replica {
                remote = true;
            }
        }
        // Touch the whole matched chain, deepest block first, so the
        // root stays the most recent and LRU eviction peels chain
        // tails instead of punching holes.
        for &k in chain[..blocks].iter().rev() {
            self.touch(&mut lk, k);
        }
        if disk_bytes > 0 {
            bump(&self.c.disk_hits);
        } else {
            bump(&self.c.host_hits);
        }
        if remote {
            bump(&self.c.remote_hits);
        }
        Some(StoreHit { tokens, host_bytes, disk_bytes, remote })
    }

    fn publish_chain(&self, chain: &[Key], now: f64, visible_at: f64, replica: usize) {
        if chain.is_empty() || self.poisoned() {
            return;
        }
        let visible_at = visible_at.max(now + self.window);
        // Fast path: lock only the chain's own shards.  Budget
        // reservations are atomic, so as long as the tiers have room
        // no other shard is ever involved; only eviction pressure
        // (reserve failure) upgrades to the all-shards slow path,
        // because victim selection is global.
        let chain_mask = self.chain_mask(chain);
        let Some(mut lk) = self.write_shards(chain_mask) else {
            return;
        };
        let mut have_all = chain_mask == self.all_mask();
        let mut inserted = 0u64;
        let mut rejected = false;
        // Blocks of THIS chain already resident (deduped or just
        // placed): making room for a deeper block must never drop one
        // of them — a context longer than the tiers would otherwise
        // evict its own roots block by block, ending with nothing but
        // unreachable tail blocks after thrashing out other entries.
        // Prefix-first admission truncates the chain instead: the
        // placed prefix stays usable.
        let mut placed: HashSet<Key> = HashSet::new();
        let mut idx = 0;
        'place: while idx < chain.len() {
            let key = chain[idx];
            let sid = self.shard_of(key);
            if let Some(e) = lk.shard_mut(sid).entries.get_mut(&key) {
                // Shared-prefix block already stored (possibly by
                // another model/workflow/replica): one copy, refreshed.
                e.visible_at = e.visible_at.min(visible_at);
                placed.insert(key);
                idx += 1;
                continue;
            }
            let tier = if self.block_bytes <= self.host.capacity {
                if self.host.reserve(self.block_bytes) {
                    StoreTier::Host
                } else if !have_all {
                    // Upgrade: eviction needs the global LRU, i.e.
                    // every shard.  Release the chain locks, take all
                    // (still ascending — deadlock-free) and re-examine
                    // this block: a racing publisher may have inserted
                    // it, or freed room, in the window between.
                    drop(lk);
                    let Some(all) = self.write_shards(self.all_mask()) else {
                        return;
                    };
                    lk = all;
                    have_all = true;
                    continue;
                } else {
                    let mut truncated = false;
                    while !self.host.reserve(self.block_bytes) {
                        if !self.demote_host_lru(&mut lk, &placed) {
                            truncated = true;
                            break;
                        }
                    }
                    if truncated {
                        break 'place;
                    }
                    StoreTier::Host
                }
            } else if self.block_bytes <= self.disk.capacity {
                if self.disk.reserve(self.block_bytes) {
                    StoreTier::Disk
                } else if !have_all {
                    drop(lk);
                    let Some(all) = self.write_shards(self.all_mask()) else {
                        return;
                    };
                    lk = all;
                    have_all = true;
                    continue;
                } else {
                    let mut truncated = false;
                    while !self.disk.reserve(self.block_bytes) {
                        let victim = self.lru_victim(&lk, StoreTier::Disk);
                        let Some(victim) = victim.filter(|v| !placed.contains(v)) else {
                            truncated = true;
                            break;
                        };
                        self.drop_entry(&mut lk, victim);
                    }
                    if truncated {
                        break 'place;
                    }
                    StoreTier::Disk
                }
            } else {
                // A block fits in no tier: nothing deeper can be
                // reachable either.
                bump(&self.c.publish_rejected);
                rejected = true;
                break 'place;
            };
            // Commit the reservation: insert under this key's shard
            // lock (the same lock the presence check above ran under,
            // so a racing duplicate insert is impossible).
            let tick = self.next_tick.fetch_add(1, Ordering::Relaxed);
            let shard = lk.shard_mut(sid);
            shard.entries.insert(
                key,
                Entry {
                    tier,
                    publisher: replica,
                    visible_at,
                    staged_at: f64::INFINITY,
                    tick,
                    pins: 0,
                },
            );
            shard.lru[tier_idx(tier)].insert(tick, key);
            self.c.entries.fetch_add(1, Ordering::Relaxed);
            bump(&self.per_shard[sid].publishes);
            self.c.bytes_published.fetch_add(self.block_bytes, Ordering::Relaxed);
            placed.insert(key);
            inserted += 1;
            idx += 1;
        }
        // Refresh LRU over the whole chain, deepest first (see
        // `restore_chain`), covering both new and deduped blocks.
        for &k in chain.iter().rev() {
            self.touch(&mut lk, k);
        }
        drop(lk);
        if inserted > 0 {
            bump(&self.c.publishes);
        } else if !rejected {
            bump(&self.c.dedup_publishes);
        }
    }

    fn prefetch_candidate_chain(&self, chain: &[Key], now: f64) -> Option<StorePrefetch> {
        if self.poisoned() {
            return None;
        }
        let lk = self.read_shards(self.chain_mask(chain))?;
        let blocks = self.covered(&lk, chain, now);
        let bytes: u64 = chain[..blocks]
            .iter()
            .filter(|k| {
                let e = &lk.shard(self.shard_of(**k)).entries[*k];
                e.tier == StoreTier::Disk && e.staged_at.is_infinite()
            })
            .map(|_| self.block_bytes)
            .sum();
        (bytes > 0).then_some(StorePrefetch { tokens: blocks * self.block_tokens, bytes })
    }

    fn stage_chain(&self, chain: &[Key], now: f64, price: &dyn Fn(u64) -> f64) -> bool {
        if self.poisoned() || self.disk.used() == 0 {
            // Nothing on disk -> nothing stageable.
            return false;
        }
        let Some(mut lk) = self.write_shards(self.chain_mask(chain)) else {
            return false;
        };
        let blocks = self.covered(&lk, chain, now);
        // Bytes and completion time are computed under the same locks
        // that mark the staging, so a racing replica can neither
        // double-stage nor leave this staging priced for a transfer
        // larger than what it actually moves.
        let bytes: u64 = chain[..blocks]
            .iter()
            .filter(|k| {
                let e = &lk.shard(self.shard_of(**k)).entries[*k];
                e.tier == StoreTier::Disk && e.staged_at.is_infinite()
            })
            .map(|_| self.block_bytes)
            .sum();
        if bytes == 0 {
            return false;
        }
        let ready_at = (now + price(bytes)).max(now + self.window);
        for k in &chain[..blocks] {
            let e = lk
                .shard_mut(self.shard_of(*k))
                .entries
                .get_mut(k)
                .expect("covered block is present");
            if e.tier == StoreTier::Disk && e.staged_at.is_infinite() {
                e.staged_at = ready_at;
            }
        }
        bump(&self.c.prefetches);
        true
    }

    fn pin_chain(&self, chain: &[Key]) {
        if self.poisoned() {
            return;
        }
        let Some(mut lk) = self.write_shards(self.chain_mask(chain)) else {
            return;
        };
        let mut any = false;
        for k in chain {
            if let Some(e) = lk.shard_mut(self.shard_of(*k)).entries.get_mut(k) {
                if e.pins == 0 {
                    bump(&self.c.pinned_blocks);
                }
                e.pins += 1;
                any = true;
            }
        }
        if any {
            bump(&self.c.handoff_pins);
        }
    }

    fn unpin_chain(&self, chain: &[Key]) {
        if self.poisoned() {
            return;
        }
        let Some(mut lk) = self.write_shards(self.chain_mask(chain)) else {
            return;
        };
        for k in chain {
            if let Some(e) = lk.shard_mut(self.shard_of(*k)).entries.get_mut(k) {
                if e.pins > 0 {
                    e.pins -= 1;
                    if e.pins == 0 {
                        self.c.pinned_blocks.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }

    /// Token-slice staging keeps the unsharded fast-out: an empty disk
    /// tier skips the hash walk entirely.
    fn stage(&self, prompt: &[u32], now: f64, price: &dyn Fn(u64) -> f64) -> bool {
        if self.disk.used() == 0 {
            return false;
        }
        self.stage_chain(&chain_keys(prompt, self.block_tokens), now, price)
    }

    fn stats(&self) -> StoreStats {
        // Lock-free: gauges and counters are atomics, so a stats
        // snapshot never serializes against store traffic.
        StoreStats {
            entries: self.c.entries.load(Ordering::Relaxed) as usize,
            host_used: self.host.used(),
            disk_used: self.disk.used(),
            host_capacity: self.host.capacity,
            disk_capacity: self.disk.capacity,
            publishes: self.c.publishes.load(Ordering::Relaxed),
            dedup_publishes: self.c.dedup_publishes.load(Ordering::Relaxed),
            publish_rejected: self.c.publish_rejected.load(Ordering::Relaxed),
            bytes_published: self.c.bytes_published.load(Ordering::Relaxed),
            bytes_dropped: self.c.bytes_dropped.load(Ordering::Relaxed),
            demotions_to_disk: self.c.demotions_to_disk.load(Ordering::Relaxed),
            dropped_entries: self.c.dropped_entries.load(Ordering::Relaxed),
            host_hits: self.c.host_hits.load(Ordering::Relaxed),
            disk_hits: self.c.disk_hits.load(Ordering::Relaxed),
            remote_hits: self.c.remote_hits.load(Ordering::Relaxed),
            prefetch_hits: self.c.prefetch_hits.load(Ordering::Relaxed),
            prefetches: self.c.prefetches.load(Ordering::Relaxed),
            handoff_pins: self.c.handoff_pins.load(Ordering::Relaxed),
            pinned_blocks: self.c.pinned_blocks.load(Ordering::Relaxed) as usize,
            lock_poisoned: self.c.lock_poisoned.load(Ordering::Relaxed),
        }
    }

    fn shard_stats(&self) -> Vec<ShardStats> {
        self.per_shard.iter().map(ShardCounters::snapshot).collect()
    }
}

/// One replica's view of the shared store: the store `Arc`, the
/// replica's id (remote-hit attribution) and the cluster's clock fence.
///
/// Every store operation fences first at the virtual time it is about
/// to use — the engine's clock advances *within* a step (prefills,
/// restores), so fencing only at step boundaries would let a replica
/// probe at a clock far past what the other replicas have been held
/// to, re-introducing the thread-interleaving dependence the fence
/// exists to remove.  Dropping the handle parks the replica's fence
/// clock, so a finished (or panicking) replica never deadlocks the
/// others.
///
/// The handle speaks [`TokenBuf`]s, not token slices: every operation
/// goes through the buffer's memoized rolling-hash chain
/// (`TokenBuf::block_chain`), so a growing context re-hashes only its
/// new tokens across the engine's repeated probes, publishes and
/// restores.
pub struct StoreHandle {
    store: Arc<dyn SnapshotStore>,
    fence: Option<Arc<ClockFence>>,
    replica: usize,
}

impl StoreHandle {
    /// Handle for `replica` over a shared `store` (and, in cluster
    /// runs, the shared `fence`).
    pub fn new(
        store: Arc<dyn SnapshotStore>,
        fence: Option<Arc<ClockFence>>,
        replica: usize,
    ) -> Self {
        StoreHandle { store, fence, replica }
    }

    /// This replica's id within the cluster.
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// Fence this replica's virtual clock (no-op without a fence).
    pub fn sync(&self, now: f64) {
        if let Some(f) = &self.fence {
            f.sync(self.replica, now);
        }
    }

    /// Park this replica's fence clock at `+inf` — it no longer
    /// constrains the other replicas (also done on drop, which covers
    /// unwinding replicas).
    pub fn finish(&self) {
        if let Some(f) = &self.fence {
            f.finish(self.replica);
        }
    }

    /// The memoized chain of `prompt` at this store's block size.
    fn chain(&self, prompt: &TokenBuf) -> Arc<Vec<BlockKey>> {
        prompt.block_chain(self.store.block_tokens())
    }

    /// See [`SnapshotStore::peek_chain`] (fences at `now` first).
    pub fn peek(&self, prompt: &TokenBuf, now: f64) -> usize {
        let chain = self.chain(prompt);
        self.sync(now);
        self.store.peek_chain(&chain, now)
    }

    /// See [`SnapshotStore::restore_chain`] (fences at `now` first).
    pub fn begin_restore(
        &self,
        prompt: &TokenBuf,
        min_tokens: usize,
        now: f64,
    ) -> Option<StoreHit> {
        let chain = self.chain(prompt);
        self.sync(now);
        self.store.restore_chain(&chain, min_tokens, now, self.replica)
    }

    /// See [`SnapshotStore::publish_chain`] (fences at `now` first).
    pub fn publish(&self, ctx: &TokenBuf, now: f64, visible_at: f64) {
        let chain = self.chain(ctx);
        self.sync(now);
        self.store.publish_chain(&chain, now, visible_at, self.replica);
    }

    /// See [`SnapshotStore::prefetch_candidate_chain`] (fences at
    /// `now` first).
    pub fn prefetch_candidate(&self, prompt: &TokenBuf, now: f64) -> Option<StorePrefetch> {
        let chain = self.chain(prompt);
        self.sync(now);
        self.store.prefetch_candidate_chain(&chain, now)
    }

    /// See [`SnapshotStore::stage_chain`] (fences at `now` first).
    pub fn stage(&self, prompt: &TokenBuf, now: f64, price: &dyn Fn(u64) -> f64) -> bool {
        let chain = self.chain(prompt);
        self.sync(now);
        self.store.stage_chain(&chain, now, price)
    }

    /// See [`SnapshotStore::pin_chain`] (no fence: pins have no
    /// visibility semantics — they only constrain eviction).
    pub fn pin(&self, ctx: &TokenBuf) {
        self.store.pin_chain(&self.chain(ctx));
    }

    /// See [`SnapshotStore::unpin_chain`].
    pub fn unpin(&self, ctx: &TokenBuf) {
        self.store.unpin_chain(&self.chain(ctx));
    }

    /// Snapshot of the shared store's aggregate counters.
    pub fn stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Snapshot of the shared store's per-shard counters (empty for
    /// unsharded implementations; see [`ShardStats`]).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.store.shard_stats()
    }
}

impl Drop for StoreHandle {
    fn drop(&mut self) {
        if let Some(f) = &self.fence {
            f.finish(self.replica);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BT: usize = 16;
    const BPT: u64 = 64; // block_bytes = 1024

    fn toks(n: usize, salt: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 11 + salt).collect()
    }

    fn store(host_blocks: u64, disk_blocks: u64) -> TieredStore {
        TieredStore::new(host_blocks * 1024, disk_blocks * 1024, BT, BPT)
    }

    /// Publish with write-back already completed (visible immediately
    /// after the causality window).
    fn publish_now(s: &TieredStore, ctx: &[u32], now: f64, replica: usize) {
        s.publish(ctx, now, now, replica);
    }

    const LATER: f64 = 1.0; // comfortably past the causality window

    fn ledger_balances(s: &TieredStore) {
        let st = s.stats();
        assert_eq!(
            st.bytes_published,
            st.host_used + st.disk_used + st.bytes_dropped,
            "every published byte is resident or dropped"
        );
    }

    #[test]
    fn shard_counters_track_publishes_hits_and_evictions() {
        let s = TieredStore::with_shards(16 * 1024, 0, BT, BPT, 4);
        assert_eq!(s.shard_stats().len(), 4);
        let ctx = toks(48, 0); // 3 blocks
        publish_now(&s, &ctx, 0.0, 0);
        let st = s.shard_stats();
        assert_eq!(st.iter().map(|x| x.publishes).sum::<u64>(), 3, "one per block");
        assert!(st.iter().map(|x| x.write_locks).sum::<u64>() > 0);
        assert_eq!(st.iter().map(|x| x.contended).sum::<u64>(), 0, "single thread");
        s.begin_restore(&ctx, 0, LATER, 1);
        let st = s.shard_stats();
        assert_eq!(st.iter().map(|x| x.hits).sum::<u64>(), 3, "one per restored block");
        // Peeks take read locks only.
        s.peek(&ctx, LATER);
        assert!(s.shard_stats().iter().map(|x| x.read_locks).sum::<u64>() > 0);
        // Overflowing a host-only store drops entries: evictions land
        // on the shard that owned the victim.
        for salt in 1..40u32 {
            publish_now(&s, &toks(32, salt * 1000), salt as f64, 0);
        }
        assert!(
            s.shard_stats().iter().map(|x| x.evictions).sum::<u64>() > 0,
            "pressure must evict"
        );
        // The aggregate view stays shard-blind: no per-shard fields.
        assert_eq!(s.stats().publishes + s.stats().dedup_publishes, 40);
    }

    #[test]
    fn publish_probe_restore_roundtrip() {
        let s = store(16, 0);
        let ctx = toks(48, 0); // 3 blocks
        publish_now(&s, &ctx, 0.0, 0);
        // Not yet visible at publish time (background write-back).
        assert_eq!(s.peek(&ctx, 0.0), 0);
        assert_eq!(s.peek(&ctx, LATER), 48);
        // A prompt extending the context hits its stored prefix...
        let mut longer = ctx.clone();
        longer.extend(toks(40, 999));
        assert_eq!(s.peek(&longer, LATER), 48);
        // ...and a *shorter* prompt hits its aligned sub-prefix (the
        // block granularity the radix tree also matches at).
        assert_eq!(s.peek(&ctx[..32], LATER), 32);
        let hit = s.begin_restore(&longer, 0, LATER, 1).expect("hit");
        assert_eq!(hit.tokens, 48);
        assert_eq!(hit.host_bytes, 3 * 1024);
        assert_eq!(hit.disk_bytes, 0);
        assert!(hit.remote, "published by replica 0, restored by 1");
        // Local radix already covering one block: only the rest moves.
        let partial = s.begin_restore(&longer, 16, LATER, 1).expect("hit");
        assert_eq!(partial.tokens, 48);
        assert_eq!(partial.host_bytes, 2 * 1024);
        // No hit when coverage does not beat the floor.
        assert!(s.begin_restore(&longer, 48, LATER, 1).is_none());
        let st = s.stats();
        assert_eq!((st.host_hits, st.remote_hits), (2, 2));
        ledger_balances(&s);
    }

    #[test]
    fn shared_prefix_blocks_dedupe_to_one_copy() {
        let s = store(16, 0);
        let a = toks(32, 3);
        let mut b = a.clone();
        b.extend(toks(32, 77)); // same first 2 blocks, 2 more
        publish_now(&s, &a, 0.0, 0);
        publish_now(&s, &b, 0.5, 1);
        let st = s.stats();
        assert_eq!(st.publishes, 2);
        assert_eq!(st.entries, 4, "shared prefix stored once");
        assert_eq!(st.host_used, 4 * 1024);
        // Identical republish adds nothing.
        publish_now(&s, &a, 0.6, 1);
        assert_eq!(s.stats().dedup_publishes, 1);
        assert_eq!(s.stats().entries, 4);
        ledger_balances(&s);
    }

    #[test]
    fn partial_blocks_are_not_stored() {
        let s = store(16, 0);
        publish_now(&s, &toks(10, 0), 0.0, 0); // below one block
        assert_eq!(s.stats().publishes, 0);
        let ctx = toks(40, 1); // 2.5 blocks -> 2 stored
        publish_now(&s, &ctx, 0.0, 0);
        assert_eq!(s.peek(&ctx, LATER), 32);
    }

    #[test]
    fn demotion_pipeline_host_to_disk_to_drop() {
        // Host holds 4 blocks, disk 4: ten published blocks push the
        // oldest through disk and off the far end.
        let s = store(4, 4);
        for salt in 0..5u32 {
            publish_now(&s, &toks(32, 1000 * (salt + 1)), f64::from(salt), 0);
        }
        let st = s.stats();
        assert_eq!(st.host_used, 4 * 1024, "host full");
        assert_eq!(st.disk_used, 4 * 1024, "disk full");
        assert_eq!(st.demotions_to_disk, 6, "blocks cascade in LRU order");
        assert_eq!(st.dropped_entries, 2, "pipeline's far end drops");
        ledger_balances(&s);
        // The newest context is host-resident, the oldest gone.
        assert_eq!(s.peek(&toks(32, 1000), 10.0), 0, "oldest dropped");
        let hit = s.begin_restore(&toks(32, 5000), 0, 10.0, 0).expect("newest");
        assert_eq!(hit.disk_bytes, 0, "newest still host-resident");
    }

    #[test]
    fn long_chain_publish_truncates_instead_of_self_evicting() {
        // A 6-block context into a 4-block host-only store: admission
        // is prefix-first — the first 4 blocks stay probe-reachable
        // and the tail is truncated, instead of the chain eating its
        // own roots and ending 100% unreachable.
        let s = store(4, 0);
        let long = toks(96, 5);
        publish_now(&s, &long, 0.0, 0);
        assert_eq!(s.peek(&long, LATER), 64, "placed prefix stays usable");
        assert_eq!(s.stats().dropped_entries, 0, "no self-thrash");
        ledger_balances(&s);
        // With a disk tier the chain spreads across tiers instead:
        // shallow blocks demote to disk, everything stays reachable.
        let s2 = store(4, 4);
        publish_now(&s2, &long, 0.0, 0);
        assert_eq!(s2.peek(&long, LATER), 96, "tiers jointly hold the chain");
        let st = s2.stats();
        assert_eq!((st.host_used, st.disk_used), (4 * 1024, 2 * 1024));
        // And longer than both tiers combined: truncate at capacity.
        let s3 = store(2, 2);
        publish_now(&s3, &long, 0.0, 0);
        assert_eq!(s3.peek(&long, LATER), 64, "prefix bounded by total budget");
        assert_eq!(s3.stats().dropped_entries, 0);
        ledger_balances(&s3);
    }

    #[test]
    fn chain_eviction_peels_tails_not_roots() {
        // One long chain; pressure drops its deepest blocks first, so
        // the surviving prefix stays contiguous and probe-able.
        let s = store(4, 0);
        publish_now(&s, &toks(64, 9), 0.0, 0); // exactly fills host
        publish_now(&s, &toks(32, 7777), 0.5, 0); // 2 blocks push out 2
        assert_eq!(s.peek(&toks(64, 9), LATER), 32, "tail peeled, root kept");
        ledger_balances(&s);
    }

    #[test]
    fn disk_restore_charges_disk_until_staged() {
        let s = store(2, 8);
        let cold = toks(32, 1);
        let hot = toks(32, 2);
        publish_now(&s, &cold, 0.0, 0);
        publish_now(&s, &hot, 0.1, 0); // demotes `cold` to disk
        // Host is full, so the disk hit cannot promote; charged Disk.
        let hit = s.begin_restore(&cold, 0, LATER, 0).expect("disk hit");
        assert_eq!(hit.disk_bytes, 2 * 1024);
        assert_eq!(s.stats().disk_hits, 1);
        // Prefetch staging flips the charge to host-side once ready.
        let p = s.prefetch_candidate(&cold, LATER).expect("stageable");
        assert_eq!(p.bytes, 2 * 1024);
        assert!(s.stage(&cold, LATER, &|_| 0.5), "staging starts");
        assert!(s.prefetch_candidate(&cold, LATER).is_none(), "no double stage");
        assert!(!s.stage(&cold, LATER, &|_| 0.5), "no double stage via stage");
        let early = s.begin_restore(&cold, 0, LATER + 0.1, 0).expect("in flight");
        assert!(early.disk_bytes > 0, "staging not finished yet");
        let staged = s.begin_restore(&cold, 0, LATER + 1.0, 0).expect("staged");
        assert_eq!(staged.disk_bytes, 0, "PCIe-only after staging");
        assert_eq!(s.stats().prefetch_hits, 2, "both staged blocks consumed");
        assert_eq!(s.stats().prefetches, 1);
        // Staging scratch is transient: the restore consumed it, so the
        // next restore pays NVMe again — and the chain is stageable
        // again.
        let after = s.begin_restore(&cold, 0, LATER + 2.0, 0).expect("hit");
        assert!(after.disk_bytes > 0, "staged copy was consumed");
        assert!(s.prefetch_candidate(&cold, LATER + 2.0).is_some());
        ledger_balances(&s);
    }

    #[test]
    fn peek_is_side_effect_free_for_lru() {
        let s = store(4, 0);
        let a = toks(32, 1);
        let b = toks(32, 2);
        publish_now(&s, &a, 0.0, 0);
        publish_now(&s, &b, 0.1, 0);
        for _ in 0..8 {
            assert_eq!(s.peek(&a, LATER), 32);
        }
        // Host full; the next publish demotes LRU blocks — still `a`'s
        // (peeks don't refresh), and with no disk they drop.
        publish_now(&s, &toks(32, 3), LATER, 0);
        assert_eq!(s.peek(&a, LATER + 1.0), 0, "peeked-only chain stayed LRU");
        assert_eq!(s.peek(&b, LATER + 1.0), 32);
        ledger_balances(&s);
    }

    #[test]
    fn oversized_blocks_are_rejected_not_thrashed() {
        // Budgets below one block: nothing can ever be admitted.
        let s = TieredStore::new(100, 100, BT, BPT); // block_bytes = 1024
        publish_now(&s, &toks(32, 1), 0.0, 0);
        let st = s.stats();
        assert_eq!(st.publish_rejected, 1, "chain placement stops at the first reject");
        assert_eq!(st.entries, 0);
        ledger_balances(&s);
    }

    #[test]
    fn pinned_handoff_chain_survives_pressure_until_unpinned() {
        let s = store(4, 0); // host-only, 4 blocks
        let handoff = toks(32, 1); // 2 blocks
        publish_now(&s, &handoff, 0.0, 0);
        s.pin(&handoff);
        let st = s.stats();
        assert_eq!((st.pinned_blocks, st.handoff_pins), (2, 1));
        // Causality: the pinned publish is still invisible before its
        // write-back horizon — a consumer must not restore it early.
        assert!(s.begin_restore(&handoff, 0, 0.0, 1).is_none());
        // Pressure that would evict the LRU chain (the handoff is
        // oldest) must scan past the pinned blocks.
        publish_now(&s, &toks(32, 2), 0.5, 0); // fills host
        publish_now(&s, &toks(32, 3), 1.0, 0); // evicts salt-2, not the pin
        assert_eq!(s.peek(&handoff, 2.0), 32, "pinned chain still resident");
        // Consume on the decode side, then release the pin.
        let hit = s.begin_restore(&handoff, 0, 2.0, 1).expect("handoff restore");
        assert_eq!((hit.tokens, hit.remote), (32, true));
        s.unpin(&handoff);
        assert_eq!(s.stats().pinned_blocks, 0);
        // Unpinned, the chain ages out under pressure like any other.
        publish_now(&s, &toks(32, 4), 3.0, 0); // evicts salt-3 (LRU)
        publish_now(&s, &toks(32, 5), 4.0, 0); // evicts the old handoff
        assert_eq!(s.peek(&handoff, 5.0), 0, "unpinned chain evictable again");
        // Pins on absent blocks are skipped; double unpin saturates.
        s.pin(&handoff);
        s.unpin(&handoff);
        s.unpin(&handoff);
        assert_eq!(s.stats().pinned_blocks, 0);
        ledger_balances(&s);
    }

    #[test]
    fn fully_pinned_store_truncates_publishes_instead_of_evicting() {
        let s = store(2, 0);
        let pinned = toks(32, 1); // exactly fills host
        publish_now(&s, &pinned, 0.0, 0);
        s.pin(&pinned);
        publish_now(&s, &toks(32, 2), 1.0, 0); // nowhere to go
        assert_eq!(s.peek(&pinned, 2.0), 32, "pins win over new publishes");
        assert_eq!(s.peek(&toks(32, 2), 2.0), 0, "newcomer truncated away");
        s.unpin(&pinned);
        ledger_balances(&s);
    }

    #[test]
    fn zero_host_budget_goes_straight_to_disk() {
        let s = store(0, 4);
        let ctx = toks(32, 9);
        publish_now(&s, &ctx, 0.0, 0);
        let hit = s.begin_restore(&ctx, 0, LATER, 0).expect("disk-only store");
        assert_eq!(hit.host_bytes, 0);
        assert_eq!(hit.disk_bytes, 2 * 1024);
        assert_eq!(s.stats().disk_used, 2 * 1024);
        ledger_balances(&s);
    }

    #[test]
    fn sharded_store_behaves_like_unsharded() {
        // The full-surface smoke at shards = 8: same answers as every
        // other unit test expects at shards = 1.  (The exhaustive
        // bit-identity sweep lives in prop_store_shards_bit_identical.)
        let s = TieredStore::with_shards(4 * 1024, 4 * 1024, BT, BPT, 8);
        assert_eq!(s.shards(), 8);
        for salt in 0..5u32 {
            publish_now(&s, &toks(32, 1000 * (salt + 1)), f64::from(salt), 0);
        }
        let st = s.stats();
        assert_eq!(st.host_used, 4 * 1024, "host full");
        assert_eq!(st.disk_used, 4 * 1024, "disk full");
        assert_eq!(st.demotions_to_disk, 6, "cross-shard demotions follow global LRU");
        assert_eq!(st.dropped_entries, 2);
        assert_eq!(s.peek(&toks(32, 1000), 10.0), 0, "oldest dropped");
        let hit = s.begin_restore(&toks(32, 5000), 0, 10.0, 1).expect("newest");
        assert_eq!(hit.disk_bytes, 0, "newest still host-resident");
        assert!(hit.remote);
        ledger_balances(&s);
    }

    #[test]
    fn shard_counts_round_up_and_clamp() {
        for (asked, got) in [(0usize, 1usize), (1, 1), (2, 2), (3, 4), (5, 8), (64, 64), (500, 64)]
        {
            let s = TieredStore::with_shards(1024, 0, BT, BPT, asked);
            assert_eq!(s.shards(), got, "asked {asked}");
        }
        assert_eq!(TieredStore::auto_shards(1), 2);
        assert_eq!(TieredStore::auto_shards(3), 8);
        assert_eq!(TieredStore::auto_shards(4), 8);
        assert_eq!(TieredStore::auto_shards(100), 64, "clamped");
    }

    #[test]
    fn chain_ops_match_token_ops() {
        // The chain-based entry points and the token-slice wrappers
        // are the same operation (the wrappers just hash first).
        let s = store(16, 4);
        let ctx = TokenBuf::from_vec(toks(48, 3));
        let chain = ctx.block_chain(BT);
        s.publish_chain(&chain, 0.0, 0.0, 0);
        assert_eq!(s.peek_chain(&chain, LATER), 48);
        assert_eq!(s.peek(&ctx, LATER), 48, "wrapper agrees");
        let hit = s.restore_chain(&chain, 16, LATER, 1).expect("hit");
        assert_eq!(hit.tokens, 48);
        assert_eq!(hit.host_bytes, 2 * 1024);
        s.pin_chain(&chain);
        assert_eq!(s.stats().pinned_blocks, 3);
        s.unpin_chain(&chain);
        assert_eq!(s.stats().pinned_blocks, 0);
        ledger_balances(&s);
    }

    #[test]
    fn poisoned_lock_degrades_to_static_misses_not_a_cascade() {
        // One shard so every operation's lock mask includes the
        // poisoned lock (with more shards, which ops notice first
        // depends on where their chains hash).
        let s = Arc::new(TieredStore::with_shards(16 * 1024, 0, BT, BPT, 1));
        let ctx = toks(32, 1);
        publish_now(&s, &ctx, 0.0, 0);
        assert_eq!(s.peek(&ctx, LATER), 32);
        // A replica panics while holding a shard write lock.
        let s2 = Arc::clone(&s);
        let t = std::thread::spawn(move || {
            let _guard = s2.shards[0].write().unwrap();
            panic!("replica dies mid-publish");
        });
        assert!(t.join().is_err(), "the panicking thread itself still fails");
        // Every later op degrades instead of propagating the panic:
        // probes miss, publishes/pins no-op, restores decline.
        assert_eq!(s.peek(&ctx, LATER), 0);
        publish_now(&s, &toks(32, 2), 2.0, 0);
        assert!(s.begin_restore(&ctx, 0, LATER, 1).is_none());
        assert!(!s.stage(&ctx, LATER, &|_| 0.5));
        assert!(s.prefetch_candidate(&ctx, LATER).is_none());
        s.pin(&ctx);
        s.unpin(&ctx);
        let st = s.stats();
        assert!(st.lock_poisoned >= 1, "poison encounters are counted");
        assert_eq!(st.publishes, 1, "no publish after the poison");
        // Stats stay readable (lock-free) for the clean run-fail path.
        assert_eq!(st.host_used, 2 * 1024);
    }

    #[test]
    fn concurrent_hammer_conserves_budgets() {
        // 8 threads publish/restore/peek overlapping chains through a
        // small sharded store; the atomic budgets must never over-admit
        // and the ledger must balance once quiet.
        let s = Arc::new(TieredStore::with_shards(8 * 1024, 4 * 1024, BT, BPT, 8));
        let threads: Vec<_> = (0..8)
            .map(|r| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..200u32 {
                        let ctx = toks(32 + (i as usize % 3) * 16, (r as u32) * 7 + i % 11);
                        let now = f64::from(i) * 0.01;
                        s.publish(&ctx, now, now, r);
                        let _ = s.begin_restore(&ctx, 0, now + 1.0, (r + 1) % 8);
                        let _ = s.peek(&ctx, now + 1.0);
                        let st = s.stats();
                        assert!(st.host_used <= st.host_capacity, "host over-admitted");
                        assert!(st.disk_used <= st.disk_capacity, "disk over-admitted");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().expect("hammer thread");
        }
        let st = s.stats();
        assert_eq!(st.lock_poisoned, 0);
        assert_eq!(
            st.bytes_published,
            st.host_used + st.disk_used + st.bytes_dropped,
            "ledger balances after concurrent churn"
        );
        assert_eq!(st.entries as u64 * 1024, st.host_used + st.disk_used, "entry gauge matches");
    }
}
