//! Table 1 reproduction: measured memory and prefill/decode complexity
//! vs number of models N, baseline vs ICaRus.
//!
//! Paper claims:
//!   memory   — baseline O(M + N·L_t)  vs ICaRus O(M + L_t)
//!   prefill  — baseline O(N(M·L_t + L_t²)) vs ICaRus O(M·L_t + L_t²)
//!   decode   — both O(M + L_t) memory traffic per token (ICaRus runs
//!              2x compute but parallelized; factor measured separately
//!              in the ablation bench).
//!
//! We drive the *same* workflow trace through both modes with an ample
//! pool (no eviction noise) and report peak KV bytes and total
//! uncached-prefill tokens as functions of N — the measured analogue of
//! the table.  Run: cargo bench --bench table1_complexity

use icarus::bench_util::{Point, KV_BPT_SMALL};
use icarus::config::ServingMode;
use icarus::json::{self, Value};

fn main() {
    println!("== Table 1: measured scaling vs N (ample pool, qps 0.4) ==\n");
    println!(
        "{:<10} {:>6} {:>14} {:>16} {:>16}",
        "mode", "N", "peakKV(MB)", "prefill-tokens", "decode-tokens"
    );
    let mut results = Vec::new();
    let mut mem = std::collections::BTreeMap::new();
    let mut pre = std::collections::BTreeMap::new();
    for &n in &[1usize, 2, 4, 8] {
        for mode in [ServingMode::Baseline, ServingMode::Icarus] {
            let p = Point {
                mode,
                n_models: n,
                qps: 0.4,
                kv_pool_bytes: 1 << 30, // ample: measure pure footprint
                kv_bytes_per_token: KV_BPT_SMALL,
                n_requests: 64,
                ..Default::default()
            };
            let s = p.run();
            println!(
                "{:<10} {:>6} {:>14.1} {:>16} {:>16}",
                mode.as_str(),
                n,
                s.peak_kv_bytes as f64 / (1 << 20) as f64,
                s.prefill_tokens,
                s.generated_tokens
            );
            mem.insert((mode.as_str(), n), s.peak_kv_bytes as f64);
            pre.insert((mode.as_str(), n), s.prefill_tokens as f64);
            results.push(json::obj(vec![
                ("mode", json::s(mode.as_str())),
                ("n_models", json::num(n as f64)),
                ("peak_kv_bytes", json::num(s.peak_kv_bytes as f64)),
                ("prefill_tokens", json::num(s.prefill_tokens as f64)),
                ("cached_prefill_tokens", json::num(s.cached_prefill_tokens as f64)),
                ("generated_tokens", json::num(s.generated_tokens as f64)),
            ]));
        }
    }

    // Scaling-law check: baseline grows ~linearly in N, icarus ~flat.
    println!("\n--- growth factors N=1 -> N=8 ---");
    for metric in ["memory", "prefill"] {
        let table = if metric == "memory" { &mem } else { &pre };
        let gb = table[&("baseline", 8)] / table[&("baseline", 1)];
        let gi = table[&("icarus", 8)] / table[&("icarus", 1)];
        println!("{metric}: baseline x{gb:.2}, icarus x{gi:.2} (paper: ~N vs ~1)");
    }

    std::fs::create_dir_all("bench_results").ok();
    std::fs::write(
        "bench_results/table1_complexity.json",
        json::obj(vec![("bench", json::s("table1")), ("rows", Value::Arr(results))])
            .to_string_pretty(),
    )
    .unwrap();
    println!("\nwrote bench_results/table1_complexity.json");
}
