//! Tiered snapshot-store sweep: tier budgets × prefetch × replicas,
//! against the Fig-8 swap-eviction baseline (EXPERIMENTS.md §Tiered
//! store).
//!
//! What this demonstrates:
//!   * a bounded host tier catches evicted contexts, so the memory-
//!     pressure regime of Fig 8 restores KV over PCIe instead of
//!     re-prefilling (or swap-thrashing) it;
//!   * a disk tier extends the reuse window at NVMe cost, and
//!     `--store-prefetch` claws the NVMe latency back off the critical
//!     path by staging queued turns' prefixes early;
//!   * shared across 4 replicas, the store turns plain round-robin
//!     routing into a warm-cache cluster: contexts prefilled on one
//!     replica hit on the others (the `store`/`remote` columns).
//!
//! Results land in bench_results/store_tiers.json and, machine-
//! readably for the perf trajectory, BENCH_store_tiers.json at the
//! repo root (CI runs this at smoke scale and uploads the artifact).
//!
//! Run: cargo bench --bench store_tiers  [-- --smoke]

use icarus::bench_util::{sweep, write_results, Point, Row, KV_BPT_SMALL};
use icarus::config::{EvictionPolicy, ServingMode};
use icarus::json::{self, Value};

/// Store budget variants swept against the swap baseline, labeled.
const HOST_64MB: u64 = 64 << 20;
const HOST_8MB: u64 = 8 << 20;
const DISK_256MB: u64 = 256 << 20;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (qps_list, n_requests, replica_list): (&[f64], usize, &[usize]) = if smoke {
        (&[0.8], 24, &[1, 4])
    } else {
        (&[0.4, 0.8, 1.5], 96, &[1, 4])
    };

    // (host, disk, prefetch, overlap) store variants; (0, 0, false,
    // false) is the store-less swap baseline every other row is judged
    // against.  The final variant reruns the full tiered+prefetch
    // config with the cooperative overlap runtime flying its restores.
    let variants: &[(u64, u64, bool, bool)] = &[
        (0, 0, false, false),
        (HOST_64MB, 0, false, false),
        (HOST_8MB, DISK_256MB, false, false),
        (HOST_8MB, DISK_256MB, true, false),
        (HOST_8MB, DISK_256MB, true, true),
    ];

    let mut points = Vec::new();
    for &replicas in replica_list {
        for &(host, disk, prefetch, overlap) in variants {
            for &qps in qps_list {
                points.push(Point {
                    mode: ServingMode::Icarus,
                    n_models: 4,
                    qps,
                    n_requests,
                    // Fig-8's memory-pressure regime: a 12 MB pool per
                    // replica forces constant eviction between turns.
                    kv_pool_bytes: 12 << 20,
                    kv_bytes_per_token: KV_BPT_SMALL,
                    // The baseline keeps Fig 8's swap eviction; store
                    // rows run plain Recompute — the store IS their
                    // second chance, and a restore beats both paths.
                    eviction: if host + disk == 0 {
                        EvictionPolicy::Swap
                    } else {
                        EvictionPolicy::Recompute
                    },
                    replicas,
                    store_host_bytes: host,
                    store_disk_bytes: disk,
                    store_prefetch: prefetch,
                    overlap,
                    seed: 13,
                    ..Default::default()
                });
            }
        }
    }
    println!(
        "== Tiered store sweep: budgets x prefetch x replicas vs fig8 swap baseline, \
         ICaRus N=4, pool 12 MB/replica{} ==\n",
        if smoke { " [smoke]" } else { "" }
    );
    let rows = sweep(&points);

    // The acceptance comparison: each store variant vs the swap
    // baseline at the same replica count and QPS.
    let find = |replicas: usize,
                host: u64,
                disk: u64,
                prefetch: bool,
                overlap: bool,
                qps: f64|
     -> Option<&Row> {
        points
            .iter()
            .zip(&rows)
            .find(|(p, _)| {
                p.replicas == replicas
                    && p.store_host_bytes == host
                    && p.store_disk_bytes == disk
                    && p.store_prefetch == prefetch
                    && p.overlap == overlap
                    && p.qps == qps
            })
            .map(|(_, r)| r)
    };
    println!("\n--- store vs fig8 swap baseline (same replicas, qps) ---");
    let mut comparisons = Vec::new();
    for &replicas in replica_list {
        for &qps in qps_list {
            let Some(base) = find(replicas, 0, 0, false, false, qps) else { continue };
            for &(host, disk, prefetch, overlap) in variants.iter().filter(|v| v.0 + v.1 > 0) {
                let Some(row) = find(replicas, host, disk, prefetch, overlap, qps) else {
                    continue;
                };
                let speedup = if row.p95_s > 0.0 { base.p95_s / row.p95_s } else { 0.0 };
                println!(
                    "R={replicas} qps={qps:.2} host={}M disk={}M pf={} ov={}: p95 {:.3}s -> \
                     {:.3}s ({speedup:.2}x), {} store hits ({} remote)",
                    host >> 20,
                    disk >> 20,
                    prefetch,
                    overlap,
                    base.p95_s,
                    row.p95_s,
                    row.store_hits,
                    row.store_remote_hits,
                );
                comparisons.push(json::obj(vec![
                    ("replicas", json::num(replicas as f64)),
                    ("qps", json::num(qps)),
                    ("store_host_bytes", json::num(host as f64)),
                    ("store_disk_bytes", json::num(disk as f64)),
                    ("store_prefetch", Value::Bool(prefetch)),
                    ("overlap", Value::Bool(overlap)),
                    ("p95_baseline_s", json::num(base.p95_s)),
                    ("p95_store_s", json::num(row.p95_s)),
                    ("p95_speedup", json::num(speedup)),
                    ("store_hits", json::num(row.store_hits as f64)),
                    ("store_remote_hits", json::num(row.store_remote_hits as f64)),
                ]));
            }
        }
    }
    write_results(
        "store_tiers",
        &rows,
        vec![
            ("figure", json::s("8-extended")),
            ("baseline", json::s("fig8 swap eviction, store off")),
            ("smoke", Value::Bool(smoke)),
            ("store_vs_swap_baseline", Value::Arr(comparisons)),
        ],
    );
}
