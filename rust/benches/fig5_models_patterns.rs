//! Fig 5 reproduction: P95 latency and max throughput across model
//! sizes (LLaMA-3.1-8B -> serve-small, Qwen3-14B -> serve-base) and
//! agent patterns (ReAct, Reflexion), N = 4 models.
//!
//! Paper result (shape): ICaRus's advantage persists for the larger
//! model (up to 7.4x lower latency, 3.6x higher throughput on Qwen-14B)
//! and for Reflexion's heavier multi-turn contexts.
//!
//! Run: cargo bench --bench fig5_models_patterns

use icarus::bench_util::{summarize_pairs, sweep, write_results, Point, KV_BPT_BASE, KV_BPT_SMALL};
use icarus::config::{AgentPattern, ServingMode};
use icarus::engine::executor::CostModel;
use icarus::json;

fn main() {
    let mut all_rows = Vec::new();
    for (model, kv_bpt, qps_list) in [
        ("serve-small(8B)", KV_BPT_SMALL, [0.2, 0.4, 0.8, 1.5, 3.0]),
        ("serve-base(14B)", KV_BPT_BASE, [0.1, 0.2, 0.4, 0.8, 1.5]),
    ] {
        for pattern in [AgentPattern::ReAct, AgentPattern::Reflexion] {
            println!("\n== Fig 5: {model}, {} ==\n", pattern.as_str());
            let mut points = Vec::new();
            for mode in [ServingMode::Baseline, ServingMode::Icarus] {
                for &qps in &qps_list {
                    // Larger model: proportionally larger per-token costs
                    // (the paper's lower QPS range reflects the same).
                    let scale = if kv_bpt == KV_BPT_BASE { 2.5 } else { 1.0 };
                    let mut cost = CostModel::default();
                    cost.prefill_per_token *= scale;
                    cost.decode_base *= scale;
                    cost.decode_per_ctx_token *= scale;
                    points.push(Point {
                        mode,
                        n_models: 4,
                        qps,
                        pattern,
                        kv_pool_bytes: 24 << 20,
                        kv_bytes_per_token: kv_bpt,
                        cost,
                        ..Default::default()
                    });
                }
            }
            let mut rows = sweep(&points);
            summarize_pairs(&rows);
            for r in &mut rows {
                r.label = format!("{model}/{}/{}", pattern.as_str(), r.label);
            }
            all_rows.extend(rows);
        }
    }
    write_results("fig5_models_patterns", &all_rows, vec![("figure", json::s("5"))]);
}
