//! Fig 9 / Appendix F reproduction: random + skewed agent invocation —
//! one hot agent takes 50% of turns, the rest share the remainder in
//! random order (vs Fig 4's round-robin).
//!
//! Paper result (shape): ICaRus's advantage (per-model prefix caching on
//! top of cross-model sharing) is preserved under skew; baseline
//! throughput saturates once KV growth triggers evictions, ICaRus keeps
//! scaling (up to 3.5x throughput at N=8; 15x P95 at N=2, 0.4 qps).
//!
//! Run: cargo bench --bench fig9_skewed

use icarus::bench_util::{summarize_pairs, sweep, write_results, Point, KV_BPT_SMALL};
use icarus::config::{Routing, ServingMode};
use icarus::json;

fn main() {
    let qps_list = [0.2, 0.4, 0.8, 1.5, 3.0];
    let mut points = Vec::new();
    for &n in &[2usize, 4, 8] {
        for mode in [ServingMode::Baseline, ServingMode::Icarus] {
            for &qps in &qps_list {
                points.push(Point {
                    mode,
                    n_models: n,
                    qps,
                    routing: Routing::Skewed { hot_p_percent: 50 },
                    kv_pool_bytes: 24 << 20,
                    kv_bytes_per_token: KV_BPT_SMALL,
                    ..Default::default()
                });
            }
        }
    }
    println!("== Fig 9: ReAct, random+skewed invocation (hot agent p=50%) ==\n");
    let rows = sweep(&points);
    summarize_pairs(&rows);
    write_results(
        "fig9_skewed",
        &rows,
        vec![("figure", json::s("9")), ("routing", json::s("skewed"))],
    );
}
