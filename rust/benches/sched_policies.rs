//! Scheduler-subsystem sweep: admission policy × prefill-chunk size ×
//! QPS under a long-prompt agentic workload (EXPERIMENTS.md
//! §Scheduling).
//!
//! What this demonstrates:
//!   * chunked prefill removes head-of-line blocking — at fixed FCFS
//!     order, splitting long prompts into fused-step chunks cuts P95
//!     turn latency and collapses inter-token-latency spikes;
//!   * admission order matters independently — `cache_aware` (probe
//!     the radix index, admit the hottest context first) and `sjf`
//!     (shortest remaining prefill first) reorder around long cold
//!     prompts, compounding with chunking.
//!
//! Results land in bench_results/sched_policies.json and, machine-
//! readably for the perf trajectory, BENCH_sched_policies.json at the
//! repo root (CI runs this at smoke scale and uploads the artifact).
//!
//! Run: cargo bench --bench sched_policies  [-- --smoke]

use icarus::bench_util::{sweep, write_results, Point, Row, KV_BPT_SMALL};
use icarus::config::{SchedPolicy, ServingMode};
use icarus::json::{self, Value};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (qps_list, n_requests, chunks): (&[f64], usize, &[usize]) = if smoke {
        (&[0.8], 24, &[0, 256])
    } else {
        (&[0.4, 0.8, 1.5], 96, &[0, 256, 1024])
    };
    let policies = [SchedPolicy::Fcfs, SchedPolicy::CacheAware, SchedPolicy::Sjf];

    let mut points = Vec::new();
    for &policy in &policies {
        for &chunk in chunks {
            for &qps in qps_list {
                points.push(Point {
                    mode: ServingMode::Icarus,
                    n_models: 4,
                    qps,
                    n_requests,
                    // Long-prompt regime: mean 1.6k tokens, heavy tail to
                    // 4k — atomic prefills of these stall whole seconds.
                    prompt_mean: 1600.0,
                    prompt_std: 800.0,
                    kv_pool_bytes: 256 << 20,
                    kv_bytes_per_token: KV_BPT_SMALL,
                    sched_policy: policy,
                    prefill_chunk: chunk,
                    seed: 11,
                    ..Default::default()
                });
            }
        }
    }
    println!(
        "== Scheduler sweep: policy x chunk x QPS, long prompts (mean 1.6k tok), \
         ICaRus N=4, pool 256 MB{} ==\n",
        if smoke { " [smoke]" } else { "" }
    );
    let rows = sweep(&points);

    // The acceptance comparison: chunked vs unchunked FCFS at each QPS.
    let find = |policy: SchedPolicy, chunk: usize, qps: f64| -> Option<&Row> {
        points
            .iter()
            .zip(&rows)
            .find(|(p, _)| p.sched_policy == policy && p.prefill_chunk == chunk && p.qps == qps)
            .map(|(_, r)| r)
    };
    println!("\n--- chunked prefill vs atomic (FCFS) ---");
    let mut comparisons = Vec::new();
    for &qps in qps_list {
        let Some(atomic) = find(SchedPolicy::Fcfs, 0, qps) else { continue };
        for &chunk in chunks.iter().filter(|&&c| c > 0) {
            let Some(chunked) = find(SchedPolicy::Fcfs, chunk, qps) else { continue };
            let speedup = if chunked.p95_s > 0.0 { atomic.p95_s / chunked.p95_s } else { 0.0 };
            println!(
                "qps={qps:.2} chunk={chunk}: p95 {:.3}s -> {:.3}s ({speedup:.2}x lower)",
                atomic.p95_s, chunked.p95_s
            );
            comparisons.push(json::obj(vec![
                ("qps", json::num(qps)),
                ("chunk", json::num(chunk as f64)),
                ("p95_atomic_s", json::num(atomic.p95_s)),
                ("p95_chunked_s", json::num(chunked.p95_s)),
                ("p95_speedup", json::num(speedup)),
            ]));
        }
    }
    println!("\n--- best policy per QPS (chunk fixed to the smallest enabled) ---");
    let chunk = chunks.iter().copied().find(|&c| c > 0).unwrap_or(0);
    for &qps in qps_list {
        let mut best: Option<(&Row, SchedPolicy)> = None;
        for &policy in &policies {
            if let Some(r) = find(policy, chunk, qps) {
                if best.is_none_or(|(b, _)| r.p95_s < b.p95_s) {
                    best = Some((r, policy));
                }
            }
        }
        if let Some((r, policy)) = best {
            println!("qps={qps:.2}: {} (p95 {:.3}s)", policy.as_str(), r.p95_s);
        }
    }
    write_results(
        "sched_policies",
        &rows,
        vec![
            ("workload", json::s("react long-prompt (mean 1600, std 800)")),
            ("smoke", Value::Bool(smoke)),
            ("fcfs_chunked_vs_atomic", Value::Arr(comparisons)),
        ],
    );
}
